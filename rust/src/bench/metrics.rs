//! Metrics utilities for the benchmark framework (Fig. 7): summaries,
//! percentiles, and fixed-width table rendering for figure output.

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, stddev: 0.0 };
        }
        let mut sorted = xs.to_vec();
        // total_cmp, not partial_cmp: a NaN sample must never panic the
        // summary (it orders after every real number and surfaces in max)
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        Summary {
            n: xs.len(),
            mean,
            min: sorted[0],
            max: sorted[sorted.len() - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            stddev: var.sqrt(),
        }
    }
}

/// Percentile over a pre-sorted sample (nearest-rank).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// A figure's tabular report: title + header + rows, with aligned rendering.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cols: Vec<String>) {
        debug_assert_eq!(cols.len(), self.header.len(), "column count mismatch");
        self.rows.push(cols);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cols: &[String], widths: &[usize]| -> String {
            cols.iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        // saturating_sub: a zero-column table must render its title, not
        // underflow usize and panic on a ~2^64-char separator allocation
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncols.saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Column index by header name (for shape assertions in tests).
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Numeric value at (row, header-name), if parseable.
    pub fn num(&self, row: usize, name: &str) -> Option<f64> {
        let c = self.col(name)?;
        self.rows.get(row)?.get(c)?.replace(',', "").parse().ok()
    }

    /// Find the first row whose first column equals `key`.
    pub fn find_row(&self, key: &str) -> Option<usize> {
        self.rows.iter().position(|r| r[0] == key)
    }
}

/// Format ops/s with thousands separators (paper-style "27,999 TPS").
pub fn fmt_tps(x: f64) -> String {
    let v = x.round() as i64;
    let s = v.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // regression: partial_cmp().unwrap() panicked the moment a NaN
        // latency entered the sample; total_cmp must not. The NaN sorts
        // last, so the finite order statistics stay meaningful.
        let s = Summary::of(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.max.is_nan(), "the NaN surfaces in max, not in a panic");
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
        assert!((percentile_sorted(&sorted, 0.5) - 50.0).abs() <= 1.0);
        assert!((percentile_sorted(&sorted, 0.99) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn table_render_and_lookup() {
        let mut t = Table::new("Fig X", &["algo", "tput", "lat"]);
        t.row(vec!["raft".into(), "10136".into(), "495.0".into()]);
        t.row(vec!["cab f10%".into(), "27999".into(), "178.5".into()]);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("cab f10%"));
        assert_eq!(t.num(0, "tput"), Some(10136.0));
        assert_eq!(t.find_row("cab f10%"), Some(1));
    }

    #[test]
    fn zero_column_table_renders() {
        // regression: `2 * (ncols - 1)` underflowed usize for an empty
        // header and panicked render() on a ~2^64-char separator
        let empty: &[&str] = &[];
        let t = Table::new("degenerate", empty);
        let s = t.render();
        assert!(s.contains("degenerate"));
    }

    #[test]
    fn one_column_table_renders() {
        let mut t = Table::new("single", &["only"]);
        t.row(vec!["value".into()]);
        let s = t.render();
        assert!(s.contains("only"));
        assert!(s.contains("value"));
        // separator spans exactly the one column (no inter-column padding)
        assert!(s.lines().any(|l| l == "-----"), "got:\n{s}");
    }

    #[test]
    fn tps_formatting() {
        assert_eq!(fmt_tps(27999.4), "27,999");
        assert_eq!(fmt_tps(999.0), "999");
        assert_eq!(fmt_tps(1_234_567.0), "1,234,567");
    }
}
