//! Schema tests for the `BENCH_<suite>.json` perf-trajectory artifacts:
//! (1) an emitted report parses back to exactly the same report, and
//! (2) the `sim_throughput` grid emits one record per (n, depth, G) cell
//! with the derived rate metrics present — the figures_shape.rs-style
//! guarantee that the artifact covers the whole grid.

use std::time::Duration;

use cabinet::bench::throughput::{self, Cell};
use cabinet::bench::{BenchReport, Bencher};

/// A 1-sample, no-warmup bencher so the grid test stays cheap.
fn cheap_bencher() -> Bencher {
    Bencher { samples: 1, warmup: 0, min_time: Duration::ZERO }
}

#[test]
fn report_json_round_trips_through_emission() {
    let b = cheap_bencher();
    let mut report = BenchReport::new("schema_probe", "probe cfg v1", true);
    let stats = b.iter("probe/a", || std::hint::black_box(41 + 1));
    report.push("probe/a", &stats).metrics.push(("ops_per_sec".to_string(), 123.456));
    let stats2 = b.iter("probe/b", || std::hint::black_box("x".repeat(8)));
    report.push("probe/b", &stats2);

    let json = report.to_json();
    let parsed = BenchReport::parse(&json).expect("own emission must parse");
    assert_eq!(parsed, report, "write -> parse must be lossless");
    // and re-emission is byte-stable (shortest-round-trip float formatting)
    assert_eq!(parsed.to_json(), json);
}

#[test]
fn sim_throughput_grid_emits_one_record_per_cell() {
    // 2 virtual rounds per cell keeps this test-suite-cheap while still
    // executing every (n, depth, G) configuration end to end
    let report = throughput::build_report(&cheap_bencher(), 2, true);
    let cells = throughput::cells();
    assert_eq!(report.records.len(), cells.len(), "one record per grid cell");
    for cell in &cells {
        let rec = report
            .record(&cell.label())
            .unwrap_or_else(|| panic!("missing record for {}", cell.label()));
        assert!(rec.samples >= 1);
        assert!(rec.mean_ns > 0.0);
        for m in ["rounds_per_sec", "messages_per_sec", "ops_per_sec"] {
            let v = rec
                .metric(m)
                .unwrap_or_else(|| panic!("{} missing metric {m}", cell.label()));
            assert!(v > 0.0, "{}: {m} = {v} must be positive", cell.label());
        }
    }
    // the whole report survives emission
    let parsed = BenchReport::parse(&report.to_json()).expect("grid report parses");
    assert_eq!(parsed.records.len(), cells.len());
}

#[test]
fn cell_labels_match_emitted_names() {
    let c = Cell { n: 50, t: 5, depth: 8, groups: 4 };
    assert_eq!(c.label(), "sim/n50_d8_g4");
}
