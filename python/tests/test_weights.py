"""Weight-scheme solver (L2 graph): Eq. 4 invariants + Fig. 3/4 goldens."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import MAX_NODES

I32 = jnp.int32


def _scheme(n, t):
    r, w, ct = model.weight_scheme(I32(n), I32(t))
    return float(r), np.array(w), float(ct)


def _check_invariants(n, t, w, ct):
    """I1: Σ top t+1 weights > CT;  I2: Σ top t weights < CT."""
    ws = np.sort(w[:n])[::-1]
    assert ws[: t + 1].sum() > ct, f"I1 violated n={n} t={t}"
    assert ws[:t].sum() < ct, f"I2 violated n={n} t={t}"
    # CT really is half the total weight
    np.testing.assert_allclose(ct, w[:n].sum() / 2.0, rtol=1e-9)
    # padding stays zero
    assert (w[n:] == 0).all()


def test_fig4_paper_ratios_are_feasible():
    """The paper's Fig. 4 r values satisfy Eq. 4 for n=10 (our validator)."""
    for t, r_paper in [(1, 1.40), (2, 1.38), (3, 1.19), (4, 1.08)]:
        lo, hi = model.ratio_bounds(I32(10), I32(t))
        assert float(lo) < r_paper < float(hi), (t, r_paper, float(lo), float(hi))


def test_fig4_our_ratios_match_paper_upper_edge_rows():
    """Our r matches the paper's published r to ±0.01 for t=2,3,4 (the
    paper's t=1 row picked near the lower feasible edge; see DESIGN.md)."""
    for t, r_paper in [(2, 1.38), (3, 1.19), (4, 1.08)]:
        r, _, _ = _scheme(10, t)
        assert abs(r - r_paper) < 0.011, (t, r, r_paper)


def test_fig4_weight_table_t1_shape():
    """Fig. 4 t=1 row: w_i = r^(n-i), descending, w_n = 1."""
    r, w, ct = _scheme(10, 1)
    np.testing.assert_allclose(w[9], 1.0, rtol=1e-9)
    assert (np.diff(w[:10]) < 0).all()
    np.testing.assert_allclose(w[:10], r ** np.arange(9, -1, -1.0), rtol=1e-9)
    _check_invariants(10, 1, w, ct)


def test_invariants_all_small_n():
    for n in range(3, 26):
        for t in range(1, (n - 1) // 2 + 1):
            r, w, ct = _scheme(n, t)
            assert 1.0 < r < 2.0, (n, t, r)
            _check_invariants(n, t, w, ct)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(3, MAX_NODES))
def test_invariants_hypothesis(n):
    t_max = (n - 1) // 2
    for t in {1, max(1, t_max // 2), t_max}:
        _, w, ct = _scheme(n, t)
        _check_invariants(n, t, w, ct)


def test_paper_eval_thresholds():
    """The evaluation's t = 10..40% of n for n = 10,20,50,100 (§5.1)."""
    for n in (10, 20, 50, 100):
        for pct in (10, 20, 30, 40):
            t = max(1, n * pct // 100)
            if t > (n - 1) // 2:
                continue
            _, w, ct = _scheme(n, t)
            _check_invariants(n, t, w, ct)


def test_fast_agreement_lemma31():
    """Lemma 3.1: non-cabinet members' total weight < CT."""
    for n, t in [(7, 2), (10, 3), (50, 5), (100, 10)]:
        _, w, ct = _scheme(n, t)
        assert w[t + 1 : n].sum() < ct


def test_fault_tolerance_lemma32():
    """Lemma 3.2: any n−t nodes' total weight > CT (check worst combo)."""
    for n, t in [(7, 2), (10, 3), (50, 5), (100, 10)]:
        _, w, ct = _scheme(n, t)
        worst = np.sort(w[:n])[: n - t]  # the n−t lightest nodes
        assert worst.sum() > ct
