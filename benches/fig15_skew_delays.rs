//! `cargo bench` target regenerating Fig 15 — skew delays, all YCSB workloads (quick scale; run
//! `cargo run --release --example figures -- fig15 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig15_skew_delays", || {
        last = Some(figures::fig15(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
