//! `cargo bench` target regenerating Fig 25 — dynamic membership (quick
//! scale; run `cargo run --release --example figures -- fig25 --paper` for
//! the full version). A staggered schedule replaces every founding voter of
//! a 5-voter cabinet (10 slots, cab t=1) while the client keeps proposing:
//! join at minimum weight, warmup promotion, weight drain, joint-consensus
//! removal. The acceptance shape: the rolling replace completes with no
//! commit-to-commit gap longer than one election timeout, and the
//! config-epoch / joint-quorum safety checker stays clean.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig25_membership", || {
        last = Some(figures::fig25_membership(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
