//! `cargo bench` target regenerating Fig 23 — linearizable read paths
//! (quick scale; run `cargo run --release --example figures -- fig23
//! --paper` for the full version). Each row drives read-heavy YCSB through
//! one of the three read paths — `log` (replicate every read), `readindex`
//! (weighted-quorum leadership confirmation), `lease` (confirmation-free
//! within a weighted-quorum-granted lease) — across a leader-isolation
//! nemesis window, with the read-linearizability checker validating every
//! run. The acceptance shape: `lease ≥ readindex > log` combined throughput
//! on YCSB-C at every scale.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig23_read_paths", || {
        last = Some(figures::fig23_read_paths(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
