"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONCE here; the Rust binary is self-contained afterwards
(`make artifacts` is a no-op when the artifacts are newer than this tree).
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model
from .kernels import (
    MAX_NODES,
    STATE_SLOTS,
    TPCC_BATCH,
    TPCC_WAREHOUSES,
    YCSB_BATCH,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, lowered in model.lower_all().items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # Artifact manifest: the shape contract the Rust runtime validates
    # against its compiled-in constants at load time.
    manifest = {
        "state_slots": STATE_SLOTS,
        "ycsb_batch": YCSB_BATCH,
        "tpcc_batch": TPCC_BATCH,
        "tpcc_warehouses": TPCC_WAREHOUSES,
        "max_nodes": MAX_NODES,
        "artifacts": ["ycsb_apply", "tpcc_cost", "weight_scheme"],
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
