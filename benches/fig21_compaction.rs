//! `cargo bench` target regenerating Fig 21 — the snapshot/compaction
//! interval sweep (quick scale; run `cargo run --release --example figures
//! -- fig21 --paper` for the full version). Each row runs the pipelined
//! driver under the D2 slow-follower skew profile with a mid-run follower
//! kill + restart: compaction must bound the in-memory log without moving
//! committed throughput, and the restarted follower catches up from an
//! InstallSnapshot instead of full log replay.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig21_compaction", || {
        last = Some(figures::fig21_compaction(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
