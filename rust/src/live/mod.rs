//! Live runtime: OS-thread nodes + channel transport + wall-clock timers +
//! the PJRT apply service. (The environment's vendored crate set has no
//! async runtime, so this is std-threads rather than tokio — the
//! architecture is identical: an event loop per node, a dedicated
//! apply-service thread owning the PJRT engine.)

pub mod apply;
pub mod cluster;

pub use apply::{ApplyService, Backend};
pub use cluster::{digest_map, LiveCluster, LiveEvent, LiveTimers, NodeReport};
