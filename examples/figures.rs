//! Regenerate every paper table/figure (DESIGN.md §5 index).
//!
//! Run: `cargo run --release --example figures -- [all|fig3|fig4|fig8|...]
//!      [--paper]`
//!
//! `--paper` uses the paper's 100-round scale; the default quick scale uses
//! 12 rounds (same shapes, faster).

use cabinet::bench::{figures, Scale};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let paper = args.iter().any(|a| a == "--paper");
    let scale = if paper { Scale::Paper } else { Scale::Quick };
    let which = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "all".into());

    let tables = match which.as_str() {
        "all" => figures::all_figures(scale),
        "fig3" => vec![figures::fig3()],
        "fig4" => vec![figures::fig4()],
        "fig8" => vec![figures::fig8(scale)],
        "fig9" => vec![figures::fig9(scale)],
        "fig10" => vec![figures::fig10(scale)],
        "fig11" => vec![figures::fig11(scale)],
        "fig12" => vec![figures::fig12(scale)],
        "fig13" => vec![figures::fig13()],
        "fig14" => vec![figures::fig14(scale)],
        "fig15" => vec![figures::fig15(scale)],
        "fig16" => vec![figures::fig16(scale)],
        "fig17" => vec![figures::fig17(scale), figures::fig17_series(scale)],
        "fig18" => vec![figures::fig18(scale)],
        "fig19" => vec![figures::fig19(scale)],
        "fig20" => vec![figures::fig20_pipeline_depth(scale)],
        "fig21" => vec![figures::fig21_compaction(scale)],
        "fig22" => vec![figures::fig22_partitions(scale)],
        "fig23" => vec![figures::fig23_read_paths(scale)],
        "fig24" => vec![figures::fig24_sharding(scale)],
        other => {
            eprintln!("unknown figure {other}; use fig3..fig24 or all");
            std::process::exit(1);
        }
    };
    for t in tables {
        println!("{}", t.render());
    }
}
