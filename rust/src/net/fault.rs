//! Fault injection (§5.4): targeted crash strategies and CPU-contention
//! ("dummy task") injection.
//!
//! * **Strong kills** crash the x nodes holding the top-x weights.
//! * **Weak kills** crash the x nodes holding the bottom-x weights.
//! * **Random kills** crash x nodes regardless of weight.
//!
//! The simulator consults [`KillSpec::victims`] at the configured round with
//! the leader's *current* weight assignment — matching the paper, where
//! e.g. "in f=20% under strong kills we crashed the nodes with the top 2
//! weights at Round 20".

use crate::net::rng::Rng;

/// Crash strategy (§5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillStrategy {
    Strong,
    Weak,
    Random,
}

impl KillStrategy {
    pub const ALL: [KillStrategy; 3] =
        [KillStrategy::Strong, KillStrategy::Weak, KillStrategy::Random];

    pub fn name(self) -> &'static str {
        match self {
            KillStrategy::Strong => "strong",
            KillStrategy::Weak => "weak",
            KillStrategy::Random => "random",
        }
    }
}

/// A scheduled crash event.
#[derive(Clone, Debug)]
pub struct KillSpec {
    /// Replication round at which the crash fires (paper: round 20).
    pub round: u64,
    /// Number of nodes to crash.
    pub count: usize,
    pub strategy: KillStrategy,
}

impl KillSpec {
    pub fn new(round: u64, count: usize, strategy: KillStrategy) -> Self {
        KillSpec { round, count, strategy }
    }

    /// Choose victims given the current weights. The leader (`leader`) is
    /// never killed — the paper's crash experiments keep the leader alive
    /// and measure replication throughput through the fault.
    pub fn victims(
        &self,
        weights: &[f64],
        leader: usize,
        alive: &[bool],
        rng: &mut Rng,
    ) -> Vec<usize> {
        let mut candidates: Vec<usize> = (0..weights.len())
            .filter(|&i| i != leader && alive[i])
            .collect();
        match self.strategy {
            // total_cmp, not partial_cmp: a NaN weight must not panic victim
            // selection (it counts as the largest weight, so strong kills
            // target it first and weak kills last)
            KillStrategy::Strong => {
                candidates.sort_by(|&a, &b| weights[b].total_cmp(&weights[a]));
            }
            KillStrategy::Weak => {
                candidates.sort_by(|&a, &b| weights[a].total_cmp(&weights[b]));
            }
            KillStrategy::Random => rng.shuffle(&mut candidates),
        }
        candidates.truncate(self.count);
        candidates
    }
}

/// CPU-contention injection (§5.3 "Resource contention"): from
/// `start_round`, a hash-computing dummy task pinned to every vCPU inflates
/// each node's service time by `slowdown`.
#[derive(Clone, Debug)]
pub struct ContentionSpec {
    pub start_round: u64,
    /// Service-time multiplier while the dummy task runs (≥ 1).
    pub slowdown: f64,
}

impl ContentionSpec {
    pub fn new(start_round: u64, slowdown: f64) -> Self {
        assert!(slowdown >= 1.0);
        ContentionSpec { start_round, slowdown }
    }

    /// Effective service multiplier at the given round.
    pub fn factor(&self, round: u64) -> f64 {
        if round >= self.start_round {
            self.slowdown
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights() -> Vec<f64> {
        // node 0 = leader (highest), descending by id
        vec![12.0, 10.0, 8.0, 6.0, 4.0, 3.0, 2.0]
    }

    #[test]
    fn strong_kills_take_top_weights() {
        let mut rng = Rng::new(1);
        let alive = vec![true; 7];
        let spec = KillSpec::new(20, 2, KillStrategy::Strong);
        let v = spec.victims(&weights(), 0, &alive, &mut rng);
        assert_eq!(v, vec![1, 2]); // top non-leader weights
    }

    #[test]
    fn weak_kills_take_bottom_weights() {
        let mut rng = Rng::new(2);
        let alive = vec![true; 7];
        let spec = KillSpec::new(20, 2, KillStrategy::Weak);
        let v = spec.victims(&weights(), 0, &alive, &mut rng);
        assert_eq!(v, vec![6, 5]);
    }

    #[test]
    fn random_kills_respect_count_and_leader() {
        let mut rng = Rng::new(3);
        let alive = vec![true; 7];
        let spec = KillSpec::new(20, 3, KillStrategy::Random);
        let v = spec.victims(&weights(), 0, &alive, &mut rng);
        assert_eq!(v.len(), 3);
        assert!(!v.contains(&0));
        let mut sorted = v.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), 3);
    }

    #[test]
    fn nan_weight_does_not_panic_victim_selection() {
        // regression: strong/weak kills sorted with partial_cmp().unwrap(),
        // so one NaN weight (reachable via a degenerate scheme) panicked the
        // kill schedule instead of selecting victims
        let mut rng = Rng::new(5);
        let alive = vec![true; 7];
        let mut w = weights();
        w[2] = f64::NAN;
        let strong = KillSpec::new(20, 2, KillStrategy::Strong).victims(&w, 0, &alive, &mut rng);
        assert_eq!(strong, vec![2, 1], "NaN counts as the top weight");
        let weak = KillSpec::new(20, 2, KillStrategy::Weak).victims(&w, 0, &alive, &mut rng);
        assert_eq!(weak, vec![6, 5], "NaN sorts last in ascending total order");
    }

    #[test]
    fn dead_nodes_not_rekilled() {
        let mut rng = Rng::new(4);
        let mut alive = vec![true; 7];
        alive[1] = false;
        let spec = KillSpec::new(20, 2, KillStrategy::Strong);
        let v = spec.victims(&weights(), 0, &alive, &mut rng);
        assert_eq!(v, vec![2, 3]);
    }

    #[test]
    fn contention_applies_from_round() {
        let c = ContentionSpec::new(20, 2.5);
        assert_eq!(c.factor(19), 1.0);
        assert_eq!(c.factor(20), 2.5);
        assert_eq!(c.factor(99), 2.5);
    }
}
