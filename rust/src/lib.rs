//! Cabinet: dynamically weighted consensus made fast.
//!
//! Full-system reproduction of "Cabinet: Dynamically Weighted Consensus Made
//! Fast" (Zhang et al., 2025). Layer-3 Rust coordinator implementing Raft,
//! Cabinet weighted consensus, and an HQC baseline over both a deterministic
//! discrete-event simulator and a live threaded runtime; Layer-2/1 JAX +
//! Pallas state-machine kernels AOT-compiled to HLO and executed via PJRT.
//!
//! Replication is pipelined: the leader keeps up to `SimConfig::pipeline`
//! rounds of AppendEntries in flight, with per-index weighted-ack
//! bookkeeping and out-of-order-ack-tolerant commit advancement under both
//! the Raft majority rule and the Cabinet weighted rule (weight re-deals
//! and §4.1.4 reconfigurations may land mid-window — every round is judged
//! by its propose-time snapshot). Depth 1 is the paper's lock-step
//! benchmark pipeline, reproduced bit-for-bit; see README "Pipelined
//! replication" and `bench::figures::fig20_pipeline_depth`.

pub mod config;
pub mod consensus;
pub(crate) mod util;
pub mod net;
pub mod sim;
pub mod live;
pub mod storage;
pub mod workload;
pub mod bench;
pub mod runtime;
