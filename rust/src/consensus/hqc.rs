//! Hierarchical Quorum Consensus (HQC) baseline — the comparison system in
//! Fig. 17 (Kumar '91; also the Zookeeper "hierarchical quorums" option).
//!
//! The cluster is partitioned into groups (Fig. 17 uses 3-3-5 for n = 11).
//! A round commits in two levels: each group leader replicates to its group
//! and reports once a majority of its group acks; the root commits once a
//! majority of *groups* has decided. The two message-passing levels are
//! exactly the latency amplifier the paper calls out under delay spikes
//! (§5.3: 4.3× Cabinet's latency in round 18 of Fig. 17a).
//!
//! Replication-only (static root), like the paper's HQC baseline runs.

use crate::consensus::message::NodeId;

/// HQC wire messages.
#[derive(Clone, Debug)]
pub enum HqcMsg {
    /// root → group leader: replicate round `round`.
    Propose { round: u64 },
    /// group leader → group member.
    GroupAppend { round: u64 },
    /// member → group leader.
    GroupAck { round: u64, from: NodeId },
    /// group leader → root: this group has a majority.
    GroupDecide { round: u64, group: usize },
}

/// Outputs from an HQC node step.
#[derive(Clone, Debug)]
pub enum HqcOutput {
    Send(NodeId, HqcMsg),
    /// Root only: the round reached a majority of groups.
    Committed { round: u64 },
}

/// Static group topology.
#[derive(Clone, Debug)]
pub struct HqcTopology {
    /// Node ids per group; `groups[g][0]` is group g's leader.
    pub groups: Vec<Vec<NodeId>>,
    /// The coordinating root node (a group leader).
    pub root: NodeId,
}

impl HqcTopology {
    /// Split `n` nodes into the given group sizes (e.g. `[3, 3, 5]`).
    pub fn split(n: usize, sizes: &[usize]) -> Self {
        assert_eq!(sizes.iter().sum::<usize>(), n, "sizes must cover n");
        let mut groups: Vec<Vec<NodeId>> = Vec::new();
        let mut next = 0;
        for &s in sizes {
            assert!(s >= 1);
            groups.push((next..next + s).collect());
            next += s;
        }
        let root = groups[0][0];
        HqcTopology { groups, root }
    }

    pub fn n(&self) -> usize {
        self.groups.iter().map(Vec::len).sum()
    }

    pub fn group_of(&self, node: NodeId) -> usize {
        self.groups
            .iter()
            .position(|g| g.contains(&node))
            .expect("node in topology")
    }

    pub fn leader_of(&self, group: usize) -> NodeId {
        self.groups[group][0]
    }

    pub fn is_group_leader(&self, node: NodeId) -> bool {
        self.groups.iter().any(|g| g[0] == node)
    }

    /// Majority of groups needed at the root.
    pub fn group_quorum(&self) -> usize {
        self.groups.len() / 2 + 1
    }

    /// Majority within group g (leader included).
    pub fn member_quorum(&self, group: usize) -> usize {
        self.groups[group].len() / 2 + 1
    }
}

/// One HQC node (root, group leader, and member behaviors as applicable).
#[derive(Clone, Debug)]
pub struct HqcNode {
    id: NodeId,
    topo: HqcTopology,
    /// group-leader state: acks per round (round → count incl. self).
    acks: Vec<(u64, usize)>,
    /// root state: groups decided per round.
    decided: Vec<(u64, usize)>,
    committed_rounds: u64,
}

impl HqcNode {
    pub fn new(id: NodeId, topo: HqcTopology) -> Self {
        HqcNode { id, topo, acks: Vec::new(), decided: Vec::new(), committed_rounds: 0 }
    }

    pub fn id(&self) -> NodeId {
        self.id
    }
    pub fn topology(&self) -> &HqcTopology {
        &self.topo
    }
    pub fn committed_rounds(&self) -> u64 {
        self.committed_rounds
    }

    /// Root API: start a replication round.
    pub fn propose(&mut self, round: u64) -> Vec<HqcOutput> {
        assert_eq!(self.id, self.topo.root, "only the root proposes");
        let mut out = Vec::new();
        for g in 0..self.topo.groups.len() {
            let leader = self.topo.leader_of(g);
            if leader == self.id {
                // we are our own group's leader: fan out locally
                out.extend(self.start_group_round(round));
            } else {
                out.push(HqcOutput::Send(leader, HqcMsg::Propose { round }));
            }
        }
        out
    }

    fn start_group_round(&mut self, round: u64) -> Vec<HqcOutput> {
        let g = self.topo.group_of(self.id);
        let mut out = Vec::new();
        self.acks.push((round, 1)); // self-ack
        for &m in &self.topo.groups[g] {
            if m != self.id {
                out.push(HqcOutput::Send(m, HqcMsg::GroupAppend { round }));
            }
        }
        // singleton group decides immediately
        out.extend(self.check_group_quorum(round));
        out
    }

    fn check_group_quorum(&mut self, round: u64) -> Vec<HqcOutput> {
        let g = self.topo.group_of(self.id);
        let need = self.topo.member_quorum(g);
        let have = self
            .acks
            .iter()
            .find(|(r, _)| *r == round)
            .map(|(_, c)| *c)
            .unwrap_or(0);
        if have == need {
            // exactly at quorum: report once
            if self.id == self.topo.root {
                return self.on_group_decide(round);
            }
            return vec![HqcOutput::Send(
                self.topo.root,
                HqcMsg::GroupDecide { round, group: g },
            )];
        }
        Vec::new()
    }

    fn on_group_decide(&mut self, round: u64) -> Vec<HqcOutput> {
        let need = self.topo.group_quorum();
        let slot = self.decided.iter_mut().find(|(r, _)| *r == round);
        let have = match slot {
            Some((_, c)) => {
                *c += 1;
                *c
            }
            None => {
                self.decided.push((round, 1));
                1
            }
        };
        if have == need {
            self.committed_rounds += 1;
            return vec![HqcOutput::Committed { round }];
        }
        Vec::new()
    }

    /// Deliver a message.
    pub fn receive(&mut self, from: NodeId, msg: HqcMsg) -> Vec<HqcOutput> {
        match msg {
            HqcMsg::Propose { round } => self.start_group_round(round),
            HqcMsg::GroupAppend { round } => {
                vec![HqcOutput::Send(from, HqcMsg::GroupAck { round, from: self.id })]
            }
            HqcMsg::GroupAck { round, .. } => {
                match self.acks.iter_mut().find(|(r, _)| *r == round) {
                    Some((_, c)) => *c += 1,
                    None => self.acks.push((round, 1)),
                }
                self.check_group_quorum(round)
            }
            HqcMsg::GroupDecide { round, .. } => {
                assert_eq!(self.id, self.topo.root);
                self.on_group_decide(round)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pump(nodes: &mut [HqcNode], outs: Vec<(NodeId, HqcOutput)>) -> Vec<u64> {
        let mut committed = Vec::new();
        let mut queue: Vec<(NodeId, NodeId, HqcMsg)> = Vec::new();
        let absorb = |src: NodeId,
                      o: HqcOutput,
                      q: &mut Vec<(NodeId, NodeId, HqcMsg)>,
                      c: &mut Vec<u64>| match o {
            HqcOutput::Send(dst, m) => q.push((src, dst, m)),
            HqcOutput::Committed { round } => c.push(round),
        };
        for (src, o) in outs {
            absorb(src, o, &mut queue, &mut committed);
        }
        while let Some((src, dst, m)) = queue.pop() {
            for o in nodes[dst].receive(src, m) {
                absorb(dst, o, &mut queue, &mut committed);
            }
        }
        committed
    }

    fn cluster(sizes: &[usize]) -> Vec<HqcNode> {
        let n = sizes.iter().sum();
        let topo = HqcTopology::split(n, sizes);
        (0..n).map(|i| HqcNode::new(i, topo.clone())).collect()
    }

    #[test]
    fn topology_3_3_5() {
        let topo = HqcTopology::split(11, &[3, 3, 5]);
        assert_eq!(topo.n(), 11);
        assert_eq!(topo.group_of(0), 0);
        assert_eq!(topo.group_of(4), 1);
        assert_eq!(topo.group_of(10), 2);
        assert_eq!(topo.leader_of(2), 6);
        assert_eq!(topo.group_quorum(), 2);
        assert_eq!(topo.member_quorum(2), 3);
        assert!(topo.is_group_leader(0));
        assert!(topo.is_group_leader(3));
        assert!(!topo.is_group_leader(1));
    }

    #[test]
    fn commits_a_round_3_3_5() {
        let mut nodes = cluster(&[3, 3, 5]);
        let outs: Vec<_> =
            nodes[0].propose(1).into_iter().map(|o| (0usize, o)).collect();
        let committed = pump(&mut nodes, outs);
        assert_eq!(committed, vec![1]);
        assert_eq!(nodes[0].committed_rounds(), 1);
    }

    #[test]
    fn commits_many_rounds() {
        let mut nodes = cluster(&[3, 3, 5]);
        for round in 1..=10 {
            let outs: Vec<_> =
                nodes[0].propose(round).into_iter().map(|o| (0usize, o)).collect();
            assert_eq!(pump(&mut nodes, outs), vec![round]);
        }
        assert_eq!(nodes[0].committed_rounds(), 10);
    }

    #[test]
    fn singleton_groups_work() {
        let mut nodes = cluster(&[1, 1, 1]);
        let outs: Vec<_> =
            nodes[0].propose(7).into_iter().map(|o| (0usize, o)).collect();
        assert_eq!(pump(&mut nodes, outs), vec![7]);
    }

    #[test]
    #[should_panic(expected = "sizes must cover n")]
    fn split_checks_sizes() {
        HqcTopology::split(10, &[3, 3, 5]);
    }
}
