//! The deterministic safety checker: validates the evidence a run leaves
//! behind ([`sim::SafetyLog`] — per-node commit sequences plus per-term
//! leadership observations) against the three properties every
//! adversarial-network scenario must preserve:
//!
//! 1. **Prefix consistency** — no two nodes ever commit different terms at
//!    the same log index (Theorem 4.2 / Raft's State Machine Safety), and
//!    each node's committed indices form a strictly increasing sequence
//!    (no replays; forward jumps are legitimate — an installed snapshot
//!    covers its prefix without re-emitting commits).
//! 2. **Single leader per term** — at most one node ever establishes
//!    leadership in any given term (Election Safety).
//! 3. **Monotone applied state** — a node's commit index never regresses
//!    (a duplicated or reordered InstallSnapshot / AppendEntries must not
//!    rewind what was applied).
//! 4. **Read linearizability** — every read served through a non-log read
//!    path (ReadIndex or leader lease) observes a read index that is at
//!    least every write completed *strictly before* the read was invoked
//!    (no stale reads — the property an expired lease on a deposed leader
//!    would break) and at most the highest index committed by the time the
//!    read was served (no reading uncommitted futures).
//!
//! The checker is pure data → verdict: the simulator collects the log when
//! `SimConfig::track_safety` is set, the chaos harness in
//! `rust/tests/consensus_safety.rs` assembles one by hand, and fig22 runs
//! it over every row it prints.

use crate::sim::SafetyLog;

/// The checker's verdict: every violated property, spelled out.
#[derive(Clone, Debug)]
pub struct SafetyReport {
    /// Human-readable violations; empty = the run was safe.
    pub violations: Vec<String>,
    /// Total commit records examined.
    pub commits_checked: usize,
    /// Distinct (index → term) decisions reconciled across nodes.
    pub decisions: usize,
    /// Leadership establishments examined.
    pub leaders_checked: usize,
    /// Linearizable reads validated against the commit timeline.
    pub reads_checked: usize,
}

impl SafetyReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Validate a run's safety evidence. See the module docs for the property
/// list. Returns every violation found (never panics — callers assert).
pub fn check(log: &SafetyLog) -> SafetyReport {
    let mut violations = Vec::new();
    let mut commits_checked = 0usize;

    // 1a + 3: per-node commit sequences are strictly increasing by index —
    // commit order is apply order, so this is both "no gaps below a later
    // commit on the same node" and "applied state never regresses".
    for (node, commits) in log.commits.iter().enumerate() {
        commits_checked += commits.len();
        for w in commits.windows(2) {
            if w[1].0 <= w[0].0 {
                violations.push(format!(
                    "node {node}: commit index regressed {} -> {} (terms {} -> {})",
                    w[0].0, w[1].0, w[0].1, w[1].1
                ));
            }
        }
    }

    // 1b: cross-node prefix consistency — one decided term per index.
    // (index, term, first decider) sorted by index; a second term at the
    // same index is a split-brain decision.
    let mut decided: Vec<(u64, u64, usize)> = Vec::new();
    for (node, commits) in log.commits.iter().enumerate() {
        for &(index, term) in commits {
            decided.push((index, term, node));
        }
    }
    decided.sort_unstable();
    let mut decisions = 0usize;
    let mut i = 0;
    while i < decided.len() {
        let (index, term, node) = decided[i];
        decisions += 1;
        let mut j = i + 1;
        while j < decided.len() && decided[j].0 == index {
            if decided[j].1 != term {
                violations.push(format!(
                    "index {index}: node {node} committed term {term} but node {} \
                     committed term {}",
                    decided[j].2, decided[j].1
                ));
                // report each divergent pair once, not once per replica
                break;
            }
            j += 1;
        }
        while j < decided.len() && decided[j].0 == index {
            j += 1;
        }
        i = j;
    }

    // 4: read linearizability. Build the running-max commit timeline (commit
    // times can interleave across leader changes), then check every read
    // against its invocation-time floor and response-time ceiling.
    let mut timeline: Vec<(f64, u64)> = log.commit_times.clone();
    timeline.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut time_axis: Vec<f64> = Vec::with_capacity(timeline.len());
    let mut max_idx: Vec<u64> = Vec::with_capacity(timeline.len());
    let mut running = 0u64;
    for (t, i) in &timeline {
        running = running.max(*i);
        time_axis.push(*t);
        max_idx.push(running);
    }
    // highest index committed at a time satisfying `pred` (strictly-before
    // for the invocation floor, at-or-before for the response ceiling —
    // writes concurrent with the read may legitimately land on either side)
    let committed = |t: f64, strict: bool| -> u64 {
        let k = if strict {
            time_axis.partition_point(|&x| x < t)
        } else {
            time_axis.partition_point(|&x| x <= t)
        };
        if k == 0 {
            0
        } else {
            max_idx[k - 1]
        }
    };
    let mut reads_checked = 0usize;
    for r in &log.reads {
        reads_checked += 1;
        let floor = committed(r.invoked_ms, true);
        if r.read_index < floor {
            violations.push(format!(
                "read {} at node {}: STALE — read_index {} < {} committed before \
                 invocation at {:.1} ms (lease = {})",
                r.id, r.node, r.read_index, floor, r.invoked_ms, r.lease
            ));
        }
        let ceiling = committed(r.served_ms, false);
        if r.read_index > ceiling {
            violations.push(format!(
                "read {} at node {}: read_index {} beyond {} committed by its \
                 response at {:.1} ms",
                r.id, r.node, r.read_index, ceiling, r.served_ms
            ));
        }
    }

    // 2: single leader per term.
    let mut by_term: Vec<(u64, usize)> = Vec::new();
    for &(term, node) in &log.leaders {
        match by_term.iter().find(|(t, _)| *t == term) {
            Some(&(_, prev)) if prev != node => {
                violations.push(format!(
                    "term {term}: both node {prev} and node {node} became leader"
                ));
            }
            Some(_) => {} // re-observing the same leader is fine
            None => by_term.push((term, node)),
        }
    }

    SafetyReport {
        violations,
        commits_checked,
        decisions,
        leaders_checked: log.leaders.len(),
        reads_checked,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::sim::ReadRecord;

    fn log2(a: Vec<(u64, u64)>, b: Vec<(u64, u64)>) -> SafetyLog {
        let mut log = SafetyLog::new(2);
        log.commits = vec![a, b];
        log
    }

    fn read(invoked: f64, served: f64, read_index: u64, lease: bool) -> ReadRecord {
        ReadRecord { node: 1, id: 0, invoked_ms: invoked, served_ms: served, read_index, lease }
    }

    #[test]
    fn clean_log_passes() {
        let mut log = log2(
            vec![(1, 1), (2, 1), (3, 2)],
            vec![(1, 1), (2, 1)],
        );
        log.leaders = vec![(1, 0), (2, 1), (2, 1)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.commits_checked, 5);
        assert_eq!(r.decisions, 3);
        assert_eq!(r.leaders_checked, 3);
    }

    #[test]
    fn divergent_terms_at_same_index_flagged() {
        let log = log2(vec![(1, 1), (2, 1)], vec![(1, 1), (2, 2)]);
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("index 2"), "{:?}", r.violations);
    }

    #[test]
    fn commit_regression_flagged() {
        let log = log2(vec![(1, 1), (3, 1), (2, 1)], vec![]);
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("regressed"), "{:?}", r.violations);
        // duplicate re-commit of the same index is also a regression
        let log = log2(vec![(1, 1), (1, 1)], vec![]);
        assert!(!check(&log).is_clean());
    }

    #[test]
    fn two_leaders_in_one_term_flagged() {
        let mut log = SafetyLog::new(2);
        log.leaders = vec![(3, 0), (4, 1), (3, 1)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("term 3"), "{:?}", r.violations);
    }

    #[test]
    fn linearizable_reads_pass() {
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 1), (20.0, 2), (30.0, 3)];
        log.reads = vec![
            // invoked after index 2 committed, observes 2: fine
            read(25.0, 26.0, 2, false),
            // observes 3 the moment it lands: fine (ceiling is inclusive)
            read(25.0, 30.0, 3, true),
            // a write commits at the exact invocation instant — concurrent,
            // so observing the pre-state is linearizable (floor is strict)
            read(20.0, 21.0, 1, false),
            // invoked before anything committed, observes nothing: fine
            read(5.0, 6.0, 0, false),
        ];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.reads_checked, 4);
    }

    #[test]
    fn stale_read_flagged() {
        // the stale-lease scenario: index 2 committed (by a new leader) at
        // t=20, a read invoked at t=25 still observes index 1
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 1), (20.0, 2)];
        log.reads = vec![read(25.0, 26.0, 1, true)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("STALE"), "{:?}", r.violations);
    }

    #[test]
    fn read_ahead_of_commit_flagged() {
        // a read cannot observe an index nothing had committed by its
        // response time
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 1)];
        log.reads = vec![read(11.0, 12.0, 5, false)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("beyond"), "{:?}", r.violations);
    }

    #[test]
    fn out_of_order_commit_times_use_running_max() {
        // commit observations can interleave across leader changes; the
        // floor must be the running max, not the last record
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 3), (15.0, 2), (20.0, 4)];
        log.reads = vec![read(16.0, 17.0, 3, false)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
    }

    #[test]
    fn empty_log_is_clean() {
        let r = check(&SafetyLog::new(3));
        assert!(r.is_clean());
        assert_eq!(r.commits_checked, 0);
    }
}
