//! Live-runtime integration: real threads, real timers, PJRT apply when
//! artifacts are present, leader failover by killing the leader's thread.

use std::sync::Arc;
use std::time::Duration;

use cabinet::consensus::{Mode, Payload};
use cabinet::live::{ApplyService, LiveCluster, LiveEvent, LiveTimers};
use cabinet::runtime::default_artifact_dir;
use cabinet::workload::{Workload, YcsbGen};

fn timers() -> LiveTimers {
    LiveTimers::default()
}

#[test]
fn raft_live_round_trip() {
    let cluster = LiveCluster::start(3, Mode::Raft, timers(), None, 1);
    cluster.force_election(0);
    let leader = cluster.wait_for_leader(Duration::from_secs(5)).unwrap();
    for i in 0..5u8 {
        cluster.propose(leader, Payload::Bytes(Arc::new(vec![i])));
    }
    assert!(cluster.wait_for_round(6, Duration::from_secs(5)).is_some());
    let reports = cluster.shutdown();
    assert!(reports.iter().any(|r| r.commit_index >= 6));
}

#[test]
fn cabinet_live_with_apply_service_converges() {
    let svc = ApplyService::spawn(default_artifact_dir());
    let cluster =
        LiveCluster::start(7, Mode::cabinet(7, 2), timers(), Some(svc.submitter()), 2);
    cluster.force_election(0);
    let leader = cluster.wait_for_leader(Duration::from_secs(5)).unwrap();
    let mut gen = YcsbGen::new(Workload::A, 10_000, 3);
    for _ in 0..5 {
        cluster.propose(leader, Payload::Ycsb(Arc::new(gen.batch(500))));
    }
    assert!(cluster.wait_for_round(6, Duration::from_secs(20)).is_some());
    std::thread::sleep(Duration::from_millis(400));
    let reports = cluster.shutdown();
    let digests: Vec<_> = reports.iter().filter_map(|r| r.final_digest).collect();
    assert!(digests.len() >= 5, "most replicas applied: {}", digests.len());
    assert!(digests.windows(2).all(|w| w[0] == w[1]), "divergence: {digests:?}");
}

#[test]
fn live_leader_failover() {
    let cluster = LiveCluster::start(5, Mode::cabinet(5, 1), timers(), None, 3);
    cluster.force_election(0);
    let leader = cluster.wait_for_leader(Duration::from_secs(5)).unwrap();
    cluster.propose(leader, Payload::Bytes(Arc::new(vec![1])));
    assert!(cluster.wait_for_round(2, Duration::from_secs(5)).is_some());

    // crash the leader; a follower must take over within election timeout
    cluster.stop_node(leader);
    let new_leader = cluster
        .wait_for_leader(Duration::from_secs(10))
        .expect("no failover election");
    assert_ne!(new_leader, leader);

    // and the new leader can commit
    cluster.propose(new_leader, Payload::Bytes(Arc::new(vec![2])));
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut committed = false;
    while std::time::Instant::now() < deadline {
        match cluster.events.recv_timeout(Duration::from_millis(250)) {
            Ok(LiveEvent::RoundCommitted { node, .. }) if node == new_leader => {
                committed = true;
                break;
            }
            Ok(_) => {}
            Err(_) => {}
        }
    }
    assert!(committed, "new leader failed to commit");
    cluster.shutdown();
}

#[test]
fn reconfig_live() {
    let cluster = LiveCluster::start(7, Mode::cabinet(7, 3), timers(), None, 4);
    cluster.force_election(0);
    let leader = cluster.wait_for_leader(Duration::from_secs(5)).unwrap();
    cluster.propose(leader, Payload::Reconfig { new_t: 1 });
    assert!(cluster.wait_for_round(2, Duration::from_secs(5)).is_some());
    cluster.propose(leader, Payload::Bytes(Arc::new(vec![9])));
    assert!(cluster.wait_for_round(3, Duration::from_secs(5)).is_some());
    cluster.shutdown();
}
