//! The `sim_throughput` macro-bench grid: host-side rounds/sec and
//! messages/sec for the simulator across n × pipeline-depth × group-count
//! cells. The grid lives in the library (not in `benches/sim_throughput.rs`
//! itself) so the schema test in `rust/tests/bench_report.rs` can assert
//! one emitted record per cell without duplicating the cell list.
//!
//! What the numbers mean: the simulator advances virtual time, so the
//! committed-throughput figures in EXPERIMENTS.md are *virtual*; this suite
//! measures the *host* cost of pushing a round (and a message) through the
//! engine — the quantity the hot-path optimizations (VecDeque windows,
//! scratch-vector routing, incremental digests) move. The digest guardrail
//! lives elsewhere: replay tests pin bit-identical commit/metrics digests,
//! so a perf PR that changes these rates but not the digests is safe.

use crate::bench::report::BenchReport;
use crate::bench::Bencher;
use crate::sim::{run, Protocol, SimConfig, SimResult};

/// One grid cell. `t = n/10` keeps the failure threshold at the paper's
/// 10% operating point across scales.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cell {
    pub n: usize,
    pub t: usize,
    pub depth: usize,
    pub groups: usize,
}

impl Cell {
    /// Record name in the emitted report: `sim/n{n}_d{depth}_g{groups}`.
    pub fn label(&self) -> String {
        format!("sim/n{}_d{}_g{}", self.n, self.depth, self.groups)
    }

    /// The cell's run configuration (heterogeneous zones, fixed seed — the
    /// run is deterministic, so every sample re-executes the same
    /// trajectory and only host time varies).
    pub fn config(&self, rounds: u64) -> SimConfig {
        let mut c = SimConfig::new(Protocol::Cabinet { t: self.t }, self.n, true);
        c.rounds = rounds;
        c.pipeline = self.depth;
        c.groups = self.groups;
        c.seed = 42;
        c
    }
}

/// The full grid: n ∈ {11, 50, 100} × depth ∈ {1, 8} × G ∈ {1, 4}.
pub fn cells() -> Vec<Cell> {
    let mut out = Vec::with_capacity(12);
    for &n in &[11usize, 50, 100] {
        for &depth in &[1usize, 8] {
            for &groups in &[1usize, 4] {
                out.push(Cell { n, t: n / 10, depth, groups });
            }
        }
    }
    out
}

/// Measure every cell with `bencher`, recording per-cell host-time stats
/// plus derived `rounds_per_sec` / `messages_per_sec` / `ops_per_sec`
/// rates (committed counts over mean host time per run).
pub fn build_report(bencher: &Bencher, rounds: u64, quick: bool) -> BenchReport {
    let config = format!(
        "grid n=[11,50,100] depth=[1,8] groups=[1,4] rounds={rounds} seed=42 het=true"
    );
    let mut report = BenchReport::new("sim_throughput", &config, quick);
    for cell in cells() {
        let c = cell.config(rounds);
        let mut last: Option<SimResult> = None;
        let stats = bencher.iter(&cell.label(), || {
            let r = run(&c);
            let digest = r.commit_sequence_digest();
            last = Some(r);
            digest
        });
        let r = last.expect("at least one sample ran");
        let committed_rounds = r.rounds.len() as f64;
        let committed_ops: usize = r.rounds.iter().map(|s| s.ops).sum();
        let secs = stats.mean.as_secs_f64();
        let rec = report.push(&cell.label(), &stats);
        rec.metrics.push(("rounds_per_sec".to_string(), committed_rounds / secs));
        rec.metrics
            .push(("messages_per_sec".to_string(), r.messages_delivered as f64 / secs));
        rec.metrics.push(("ops_per_sec".to_string(), committed_ops as f64 / secs));
        rec.metrics.push(("messages_delivered".to_string(), r.messages_delivered as f64));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_the_full_cross_product() {
        let cs = cells();
        assert_eq!(cs.len(), 12);
        for &n in &[11usize, 50, 100] {
            for &depth in &[1usize, 8] {
                for &groups in &[1usize, 4] {
                    assert!(
                        cs.iter().any(|c| c.n == n && c.depth == depth && c.groups == groups),
                        "missing cell n={n} depth={depth} groups={groups}"
                    );
                }
            }
        }
        // thresholds track the 10% operating point
        assert!(cs.iter().all(|c| c.t == c.n / 10 && c.t >= 1));
    }

    #[test]
    fn cell_labels_are_unique() {
        let mut labels: Vec<String> = cells().iter().map(Cell::label).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 12);
    }
}
