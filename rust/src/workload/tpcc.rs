//! TPC-C transaction workload (TPC Benchmark C), as used in §5 with
//! PostgreSQL followers.
//!
//! The standard mix: NewOrder 45%, Payment 43%, OrderStatus 4%, Delivery 4%,
//! StockLevel 4% — NewOrder and Payment are the throughput-reported txns.
//! Batches are flat u32 arrays in the layout the AOT `tpcc_cost` artifact
//! consumes (types / warehouse-ids / args).

use crate::net::rng::Rng;

/// Txn codes — shared spec with the Pallas kernel (`kernels.TXN_*`).
pub const TXN_NEW_ORDER: u32 = 0;
pub const TXN_PAYMENT: u32 = 1;
pub const TXN_ORDER_STATUS: u32 = 2;
pub const TXN_DELIVERY: u32 = 3;
pub const TXN_STOCK_LEVEL: u32 = 4;
pub const TXN_NOP: u32 = 5;

/// Standard TPC-C transaction mix (§5.1 "predefined ratio").
pub const MIX: [(u32, f64); 5] = [
    (TXN_NEW_ORDER, 0.45),
    (TXN_PAYMENT, 0.43),
    (TXN_ORDER_STATUS, 0.04),
    (TXN_DELIVERY, 0.04),
    (TXN_STOCK_LEVEL, 0.04),
];

pub const TXN_NAMES: [&str; 5] =
    ["NewOrder", "Payment", "OrderStatus", "Delivery", "StockLevel"];

/// One generated txn batch in kernel layout.
#[derive(Clone, Debug, PartialEq)]
pub struct TpccBatch {
    pub types: Vec<u32>,
    /// Home warehouse of each txn.
    pub wids: Vec<u32>,
    /// Per-txn argument (order-line count for NewOrder, district for
    /// Payment, …) — feeds the cost model's argument factor.
    pub args: Vec<u32>,
}

impl TpccBatch {
    pub fn len(&self) -> usize {
        self.types.len()
    }
    pub fn is_empty(&self) -> bool {
        self.types.is_empty()
    }

    pub fn live_txns(&self) -> usize {
        self.types.iter().filter(|&&t| t < TXN_NOP).count()
    }

    /// Count per txn type (the Fig. 10/11 breakdown).
    pub fn type_counts(&self) -> [usize; 5] {
        let mut counts = [0usize; 5];
        for &t in &self.types {
            if t < TXN_NOP {
                counts[t as usize] += 1;
            }
        }
        counts
    }

    /// Pad (with NOPs) or truncate to the fixed artifact batch shape.
    pub fn padded_to(&self, n: usize) -> TpccBatch {
        let mut b = self.clone();
        b.types.resize(n, TXN_NOP);
        b.wids.resize(n, 0);
        b.args.resize(n, 0);
        b
    }
}

/// TPC-C batch generator over `warehouses` warehouses.
#[derive(Clone, Debug)]
pub struct TpccGen {
    rng: Rng,
    warehouses: u32,
}

impl TpccGen {
    /// §5.1 config: 10 warehouses per follower instance.
    pub fn new(warehouses: u32, seed: u64) -> Self {
        assert!(warehouses > 0);
        TpccGen { rng: Rng::new(seed), warehouses }
    }

    fn next_type(&mut self) -> u32 {
        let x = self.rng.f64();
        let mut acc = 0.0;
        for (code, share) in MIX {
            acc += share;
            if x < acc {
                return code;
            }
        }
        TXN_NEW_ORDER
    }

    /// Generate a batch of exactly `size` live txns.
    pub fn batch(&mut self, size: usize) -> TpccBatch {
        // the full warehouse range — draw-for-draw identical to the
        // pre-sharding generator (below(w - 0) at offset 0)
        self.batch_sharded(size, 0, self.warehouses)
    }

    /// Generate a batch of exactly `size` live txns homed in the warehouse
    /// range `[lo, hi)` — one group's shard under the range partition
    /// ([`crate::workload::shard::warehouse_range`]). `batch()` is the
    /// degenerate full-range case, so an unsharded run consumes the RNG
    /// identically to the historical generator.
    pub fn batch_sharded(&mut self, size: usize, lo: u32, hi: u32) -> TpccBatch {
        assert!(lo < hi && hi <= self.warehouses, "bad warehouse range {lo}..{hi}");
        let mut types = Vec::with_capacity(size);
        let mut wids = Vec::with_capacity(size);
        let mut args = Vec::with_capacity(size);
        for _ in 0..size {
            let t = self.next_type();
            let arg = match t {
                // NewOrder: 5–15 order lines (TPC-C spec).
                TXN_NEW_ORDER => self.rng.range_u64(5, 15) as u32,
                // Payment: district 1–10.
                TXN_PAYMENT => self.rng.range_u64(1, 10) as u32,
                // Delivery: 10 districts processed.
                TXN_DELIVERY => 10,
                // OrderStatus / StockLevel: single lookup.
                _ => 1,
            };
            types.push(t);
            wids.push(lo + self.rng.below((hi - lo) as u64) as u32);
            args.push(arg);
        }
        TpccBatch { types, wids, args }
    }

    pub fn warehouses(&self) -> u32 {
        self.warehouses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_sums_to_one() {
        let s: f64 = MIX.iter().map(|(_, p)| p).sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn batch_mix_matches_spec() {
        let mut g = TpccGen::new(10, 1);
        let b = g.batch(50_000);
        let counts = b.type_counts();
        let share = |c: usize| c as f64 / b.len() as f64;
        assert!((share(counts[0]) - 0.45).abs() < 0.01, "{counts:?}");
        assert!((share(counts[1]) - 0.43).abs() < 0.01, "{counts:?}");
        assert!((share(counts[2]) - 0.04).abs() < 0.005);
        assert!((share(counts[3]) - 0.04).abs() < 0.005);
        assert!((share(counts[4]) - 0.04).abs() < 0.005);
    }

    #[test]
    fn warehouse_ids_in_range() {
        let mut g = TpccGen::new(10, 2);
        let b = g.batch(10_000);
        assert!(b.wids.iter().all(|&w| w < 10));
    }

    #[test]
    fn new_order_lines_in_spec_range() {
        let mut g = TpccGen::new(10, 3);
        let b = g.batch(10_000);
        for (t, a) in b.types.iter().zip(&b.args) {
            if *t == TXN_NEW_ORDER {
                assert!((5..=15).contains(a));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        assert_eq!(TpccGen::new(10, 4).batch(100), TpccGen::new(10, 4).batch(100));
    }

    #[test]
    fn sharded_batch_stays_in_warehouse_range() {
        use crate::workload::shard::warehouse_range;
        let groups = 4;
        let warehouses = 10u32;
        for group in 0..groups {
            let (lo, hi) = warehouse_range(group, groups, warehouses);
            let mut g = TpccGen::new(warehouses, 7 + group as u64);
            let b = g.batch_sharded(5_000, lo, hi);
            assert_eq!(b.len(), 5_000);
            assert!(b.wids.iter().all(|&w| (lo..hi).contains(&w)), "wid escaped {lo}..{hi}");
        }
    }

    #[test]
    fn full_range_shard_is_plain_batch() {
        // batch() delegates to the full range — pin the equivalence the
        // sharded sim's G=1 bit-for-bit guarantee leans on
        let a = TpccGen::new(10, 8).batch(500);
        let b = TpccGen::new(10, 8).batch_sharded(500, 0, 10);
        assert_eq!(a, b);
    }

    #[test]
    fn padding_and_counts() {
        let mut g = TpccGen::new(10, 5);
        let b = g.batch(100).padded_to(256);
        assert_eq!(b.len(), 256);
        assert_eq!(b.live_txns(), 100);
        assert_eq!(b.type_counts().iter().sum::<usize>(), 100);
    }
}
