"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground-truth semantics of the replicated state machine. All
arithmetic is uint32 modular (wrap on overflow), which makes every reduction
associative + commutative — so the tiled Pallas kernels, the XLA CPU
executable and the native Rust mirror (rust/src/storage/digest.rs) must all
produce *bit-identical* results. The integration tests lean on that: replica
convergence is asserted as digest equality.
"""

import jax.numpy as jnp

from . import (
    MIX1,
    MIX2,
    MIX3,
    MIX4,
    OP_INSERT,
    OP_NOP,
    OP_RMW,
    OP_SCAN,
    OP_UPDATE,
    OP_READ,
    TPCC_ARG_COEF,
    TPCC_BASE_COST,
    TPCC_LOCK_COEF,
    TXN_DELIVERY,
    TXN_NEW_ORDER,
    TXN_NOP,
    TXN_PAYMENT,
)

U32 = jnp.uint32


def mix(k):
    """Primary key-mixing function: m(k) = ((k*MIX1) ^ (k>>15)) * MIX3."""
    k = k.astype(U32)
    m = (k * U32(MIX1)) ^ (k >> U32(15))
    return m * U32(MIX3)


def op_contrib(ops, keys, vals):
    """Per-op state contribution c = ((m ^ v*MIX2) * (2*op+1)) + MIX4."""
    m = mix(keys)
    v = vals.astype(U32) * U32(MIX2)
    c = ((m ^ v) * (U32(2) * ops.astype(U32) + U32(1))) + U32(MIX4)
    return c


def slot_of(keys, n_slots):
    """State slot for a key; n_slots must be a power of two."""
    return mix(keys) & U32(n_slots - 1)


def write_mask(ops):
    return (ops == OP_UPDATE) | (ops == OP_INSERT) | (ops == OP_RMW)


def read_mask(ops):
    return (ops == OP_READ) | (ops == OP_SCAN) | (ops == OP_RMW)


def ycsb_apply_ref(state, ops, keys, vals):
    """Oracle for the YCSB batch apply.

    state: uint32[S]; ops/keys/vals: uint32[B].
    Returns (new_state uint32[S], digest uint32[2]) where digest[0] is the
    state digest and digest[1] the read digest. Ops with code >= OP_NOP are
    padding and contribute nothing.

    Reads observe the *pre-batch* state; writes are commutative wrap-adds —
    both choices make the batch order-independent, hence deterministic
    across any tiling.
    """
    state = state.astype(U32)
    ops = ops.astype(U32)
    n_slots = state.shape[0]

    c = op_contrib(ops, keys, vals)
    slots = slot_of(keys, n_slots)
    live = ops < U32(OP_NOP)
    wm = write_mask(ops) & live
    rm = read_mask(ops) & live

    wc = jnp.where(wm, c, U32(0))
    new_state = state.at[slots].add(wc, mode="promise_in_bounds")

    rvals = jnp.where(rm, state[slots] ^ c, U32(0))
    rdig = jnp.sum(rvals, dtype=U32)

    idx = jnp.arange(n_slots, dtype=U32)
    z = (idx * U32(MIX1)) ^ U32(0x5A5A5A5A)
    sdig = jnp.sum(new_state * z, dtype=U32)
    return new_state, jnp.stack([sdig, rdig])


def tpcc_lock_counts_ref(types, wids, n_warehouses):
    """Oracle for pass 1: per-warehouse write-lock demand.

    counts[w] = #{i : type_i is a lock-taking txn and wid_i == w}.
    Lock-taking txns: NewOrder, Payment, Delivery.
    """
    types = types.astype(U32)
    lock = (
        (types == TXN_NEW_ORDER) | (types == TXN_PAYMENT) | (types == TXN_DELIVERY)
    )
    onehot = (wids.astype(U32)[:, None] == jnp.arange(n_warehouses, dtype=U32)[None, :])
    return jnp.sum(jnp.where(lock[:, None], onehot, False).astype(jnp.float32), axis=0)


def tpcc_cost_ref(types, wids, args, counts):
    """Oracle for pass 2: per-txn cost + stream digest.

    cost_i = BASE[type_i] * (1 + ARG_COEF * args_i/16)
             + LOCK_COEF * max(counts[wid_i] - 1, 0)   [lock txns only]
    digest = wrap-sum of op_contrib(type, wid-as-key, args).
    """
    types_u = types.astype(U32)
    live = types_u < U32(TXN_NOP)
    base = jnp.array(TPCC_BASE_COST + (0.0,), dtype=jnp.float32)
    t_idx = jnp.minimum(types_u, U32(len(TPCC_BASE_COST))).astype(jnp.int32)
    b = base[t_idx]
    argf = args.astype(jnp.float32) / 16.0
    lock = (
        (types_u == TXN_NEW_ORDER)
        | (types_u == TXN_PAYMENT)
        | (types_u == TXN_DELIVERY)
    )
    contention = jnp.maximum(counts[wids.astype(jnp.int32)] - 1.0, 0.0)
    cost = b * (1.0 + TPCC_ARG_COEF * argf) + jnp.where(
        lock, TPCC_LOCK_COEF * contention, 0.0
    )
    cost = jnp.where(live, cost, 0.0)

    c = op_contrib(types_u, wids, args)
    dig = jnp.sum(jnp.where(live, c, U32(0)), dtype=U32)
    return cost, dig
