//! Offline stand-in for the `anyhow` crate — the vendored dependency set has
//! no network access, so this implements the small API surface the cabinet
//! crate uses: [`Error`], [`Result`], the [`Context`] extension trait, and
//! the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream anyhow where it matters:
//! * `Display` prints the outermost context message;
//! * `{:#}` (alternate) prints the whole chain, outermost first,
//!   separated by `": "`;
//! * `Debug` prints the outermost message plus a `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// A context-carrying error: a chain of messages, outermost context first,
/// root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement std::error::Error: that keeps the
// blanket conversions below coherent (same trick as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");

        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: file missing");
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root problem")
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root problem");
        assert_eq!(e.root_cause(), "root problem");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert_eq!(format!("{}", check(-2).unwrap_err()), "x must be positive, got -2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").is_err());
    }
}
