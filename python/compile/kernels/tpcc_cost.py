"""L1 Pallas kernels: TPC-C batch cost model + stream digest.

Two passes, both tiled along the txn-batch axis:

  pass 1 (`_counts_kernel`)  — per-warehouse write-lock demand. The
      block-local one-hot reduction `(wids == iota(W))` is the
      [BLOCK, W]-shaped VPU/MXU-friendly formulation of a segment count;
      partials wrap-accumulate across grid steps.
  pass 2 (`_cost_kernel`)    — per-txn cost (base work * argument factor +
      lock-contention term from pass 1) and the uint32 stream digest.

Both match `ref.tpcc_lock_counts_ref` / `ref.tpcc_cost_ref` exactly (costs
are f32 but computed in the same op order; digests are uint32 modular).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import (
    TPCC_ARG_COEF,
    TPCC_BASE_COST,
    TPCC_LOCK_COEF,
    TXN_DELIVERY,
    TXN_NEW_ORDER,
    TXN_NOP,
    TXN_PAYMENT,
)
from .ref import op_contrib

U32 = jnp.uint32
F32 = jnp.float32


def _lock_mask(types):
    return (
        (types == TXN_NEW_ORDER) | (types == TXN_PAYMENT) | (types == TXN_DELIVERY)
    )


def _counts_kernel(types_ref, wids_ref, counts_ref):
    step = pl.program_id(0)
    types = types_ref[...]
    wids = wids_ref[...]
    n_wh = counts_ref.shape[0]

    lock = _lock_mask(types)
    onehot = wids[:, None] == jnp.arange(n_wh, dtype=U32)[None, :]
    partial = jnp.sum(
        jnp.where(lock[:, None], onehot, False).astype(F32), axis=0
    )

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = partial

    @pl.when(step != 0)
    def _acc():
        counts_ref[...] = counts_ref[...] + partial


def _cost_kernel(types_ref, wids_ref, args_ref, counts_ref, cost_ref, dig_ref):
    types = types_ref[...]
    wids = wids_ref[...]
    args = args_ref[...]
    counts = counts_ref[...]

    live = types < U32(TXN_NOP)
    # Base-cost table as a where-chain (a captured constant array would be
    # rejected by pallas_call; a 5-way select is also the VPU-friendly form).
    b = jnp.zeros(types.shape, F32)
    for code, base_cost in enumerate(TPCC_BASE_COST):
        b = jnp.where(types == U32(code), F32(base_cost), b)
    argf = args.astype(F32) / 16.0
    lock = _lock_mask(types)
    contention = jnp.maximum(counts[wids.astype(jnp.int32)] - 1.0, 0.0)
    cost = b * (1.0 + TPCC_ARG_COEF * argf) + jnp.where(
        lock, TPCC_LOCK_COEF * contention, 0.0
    )
    cost_ref[...] = jnp.where(live, cost, 0.0)

    c = op_contrib(types, wids, args)
    dig_ref[...] = jnp.sum(jnp.where(live, c, U32(0)), dtype=U32).reshape(
        dig_ref.shape
    )


@functools.partial(jax.jit, static_argnames=("block", "n_warehouses"))
def tpcc_cost_pallas(types, wids, args, *, block=256, n_warehouses=64):
    """Tiled Pallas implementation of the TPC-C cost model.

    types/wids/args: uint32[B] with B % block == 0, wids < n_warehouses.
    Returns (counts f32[W], costs f32[B], digest uint32[]).
    """
    batch = types.shape[0]
    assert batch % block == 0, (batch, block)
    grid = batch // block

    counts = pl.pallas_call(
        _counts_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_warehouses,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_warehouses,), F32),
        interpret=True,
    )(types, wids)

    costs, digs = pl.pallas_call(
        _cost_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((n_warehouses,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), F32),
            jax.ShapeDtypeStruct((grid,), U32),
        ],
        interpret=True,
    )(types, wids, args, counts)

    return counts, costs, jnp.sum(digs, dtype=U32)
