//! Simulator integration tests: determinism, elections under faults,
//! reconfiguration, HQC, and config-file round trips.

use cabinet::config::sim_config_from_toml;
use cabinet::net::delay::DelayModel;
use cabinet::net::fault::{KillSpec, KillStrategy};
use cabinet::sim::{run, DigestMode, Protocol, ReconfigSpec, SimConfig, WorkloadSpec};
use cabinet::workload::Workload;

fn base(proto: Protocol, n: usize) -> SimConfig {
    let mut c = SimConfig::new(proto, n, true);
    c.rounds = 10;
    c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
    c
}

#[test]
fn identical_seeds_identical_runs() {
    for proto in [Protocol::Raft, Protocol::Cabinet { t: 2 }, Protocol::Hqc { sizes: vec![3, 3, 5] }] {
        let c = base(proto, 11);
        let a = run(&c);
        let b = run(&c);
        assert_eq!(
            a.rounds.iter().map(|r| r.latency_ms.to_bits()).collect::<Vec<_>>(),
            b.rounds.iter().map(|r| r.latency_ms.to_bits()).collect::<Vec<_>>(),
            "{} not deterministic",
            a.label
        );
    }
}

#[test]
fn different_seeds_differ() {
    let mut c1 = base(Protocol::Cabinet { t: 2 }, 11);
    let mut c2 = c1.clone();
    c1.seed = 1;
    c2.seed = 2;
    let a = run(&c1);
    let b = run(&c2);
    assert_ne!(
        a.rounds.iter().map(|r| r.latency_ms.to_bits()).collect::<Vec<_>>(),
        b.rounds.iter().map(|r| r.latency_ms.to_bits()).collect::<Vec<_>>()
    );
}

#[test]
fn leader_failover_mid_run() {
    for proto in [Protocol::Raft, Protocol::Cabinet { t: 2 }] {
        let mut c = base(proto, 7);
        c.kill_leader_at_round = Some(5);
        let r = run(&c);
        assert_eq!(r.rounds.len(), 10, "{}: rounds incomplete", r.label);
        assert!(r.elections >= 2, "{}: no re-election", r.label);
        // post-failover rounds exist and have sane latencies
        assert!(r.rounds.iter().all(|s| s.latency_ms > 0.0));
    }
}

#[test]
fn cabinet_survives_t_strong_kills_raft_equivalent_load() {
    // worst case (Theorem 3.2): killing exactly t top-weight nodes
    let mut c = base(Protocol::Cabinet { t: 3 }, 11);
    c.rounds = 12;
    c.kills = vec![KillSpec::new(5, 3, KillStrategy::Strong)];
    let r = run(&c);
    assert_eq!(r.rounds.len(), 12);
}

#[test]
fn reconfig_full_ladder() {
    // Fig. 12's ladder 24→20→15→10→5 at n=50 compresses to 5→4→3→2→1 at n=11
    let mut c = base(Protocol::Cabinet { t: 5 }, 11);
    c.rounds = 25;
    c.reconfigs = (1..=4)
        .map(|i| ReconfigSpec { round: i * 5 + 1, new_t: (5 - i) as usize })
        .collect();
    c.digest_mode = DigestMode::Sample;
    let r = run(&c);
    assert_eq!(r.rounds.len(), 25);
    assert_eq!(r.digests_match, Some(true));
    // mean latency of the last segment beats the first segment
    let first: f64 = r.rounds[1..5].iter().map(|s| s.latency_ms).sum::<f64>() / 4.0;
    let last: f64 = r.rounds[21..25].iter().map(|s| s.latency_ms).sum::<f64>() / 4.0;
    assert!(last < first, "t ladder should speed rounds: {first} → {last}");
}

#[test]
fn all_delay_models_complete() {
    for delay in [
        DelayModel::None,
        DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 },
        DelayModel::Skew,
        DelayModel::Rotating { period_rounds: 3 },
        DelayModel::Bursting,
    ] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 11);
        c.delay = delay.clone();
        let r = run(&c);
        assert_eq!(r.rounds.len(), 10, "{}", delay.name());
    }
}

#[test]
fn hqc_latency_exceeds_flat_protocols_with_delays() {
    let mut hqc = base(Protocol::Hqc { sizes: vec![3, 3, 5] }, 11);
    hqc.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
    let mut raft = base(Protocol::Raft, 11);
    raft.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
    let h = run(&hqc);
    let r = run(&raft);
    // two levels of message passing ⇒ roughly double the delay exposure
    assert!(
        h.mean_latency_ms > 1.4 * r.mean_latency_ms,
        "hqc {} vs raft {}",
        h.mean_latency_ms,
        r.mean_latency_ms
    );
}

#[test]
fn tpcc_and_ycsb_digest_convergence() {
    for (kind, spec) in [
        ("ycsb", WorkloadSpec::Ycsb { workload: Workload::F, batch: 400, records: 5000 }),
        ("tpcc", WorkloadSpec::Tpcc { batch: 300, warehouses: 10 }),
    ] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 7);
        c.workload = spec;
        c.digest_mode = DigestMode::All;
        let r = run(&c);
        assert_eq!(r.digests_match, Some(true), "{kind} replicas diverged");
    }
}

#[test]
fn config_file_end_to_end() {
    let cfg = sim_config_from_toml(
        r#"
protocol = "cabinet"
t = 2
n = 11
rounds = 8
digests = true

[workload]
kind = "ycsb"
workload = "B"
batch = 400

[delay]
model = "d4"
"#,
    )
    .unwrap();
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 8);
    assert_eq!(r.digests_match, Some(true));
}

#[test]
fn throughput_accounting_consistent() {
    let c = base(Protocol::Cabinet { t: 2 }, 7);
    let r = run(&c);
    let total_ops: usize = r.rounds.iter().map(|s| s.ops).sum();
    let total_s: f64 = r.rounds.iter().map(|s| s.latency_ms).sum::<f64>() / 1000.0;
    let expect = total_ops as f64 / total_s;
    assert!((r.tput_ops_s - expect).abs() / expect < 1e-9);
}
