//! `cargo bench` target regenerating Fig 20 — the pipelined-replication
//! depth sweep (quick scale; run `cargo run --release --example figures --
//! fig20 --paper` for the full 100-round version). Depth 1 is the lock-step
//! driver the rest of the figure suite uses; depths 2/4/8 exercise the
//! pipelined engine under the Fig. 14 delay model.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig20_pipeline_depth", || {
        last = Some(figures::fig20_pipeline_depth(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
