//! The Cabinet benchmark framework (Fig. 7): metrics, the in-crate bench
//! harness (criterion substitute), and one experiment harness per paper
//! figure.

pub mod figures;
pub mod harness;
pub mod metrics;
pub mod safety;

pub use figures::{all_figures, lineup, Scale};
pub use harness::{Bencher, BenchStats};
pub use metrics::{fmt_tps, Summary, Table};
pub use safety::{check as safety_check, SafetyReport};
