//! `cargo bench` target regenerating Fig 18 — CPU contention (quick scale; run
//! `cargo run --release --example figures -- fig18 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig18_contention", || {
        last = Some(figures::fig18(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
