//! Workload generators: YCSB core workloads A–F and TPC-C (§5.1).

pub mod tpcc;
pub mod ycsb;

pub use tpcc::{TpccBatch, TpccGen};
pub use ycsb::{Workload, YcsbBatch, YcsbGen};
