//! `cargo bench` target regenerating Fig 12 — dynamic failure thresholds (quick scale; run
//! `cargo run --release --example figures -- fig12 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig12_dynamic_threshold", || {
        last = Some(figures::fig12(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
