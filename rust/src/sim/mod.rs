//! Deterministic discrete-event simulation of the full benchmark pipeline
//! (virtual time, seeded): the environment in which every paper figure is
//! regenerated. See DESIGN.md §6 for the calibration model and
//! `docs/ARCHITECTURE.md` for how this driver relates to the sans-io core.
//!
//! A run is a pure function of `(SimConfig, seed)`: same inputs ⇒
//! bit-identical commit sequence and metrics (the replay-determinism tests
//! pin this). The scheduler in [`cluster`] steps `SimConfig::groups`
//! independent consensus groups — each a `sim::group::GroupEngine` owning
//! one workload shard — over one shared event queue, delay model and nemesis
//! fabric; with `groups = 1` it reproduces the historical single-group
//! driver bit-for-bit. Each engine drives one of two round windows: the
//! lock-step window (`pipeline = 1`, frozen so the historical figures
//! reproduce bit-for-bit) and the pipelined window (`pipeline > 1`,
//! overlapping replication rounds). Both support snapshot compaction
//! (`SimConfig::snapshot_every`), fault schedules (kills, contention, a
//! follower kill + restart via [`RestartSpec`]), delay models D1–D4,
//! heterogeneous zones, the adversarial nemesis layer (`SimConfig::nemesis`
//! — partitions, loss, duplication, reordering; per-group or all-group
//! scope via `SimConfig::nemesis_groups`), PreVote elections
//! (`SimConfig::pre_vote`), durable storage (`SimConfig::storage` →
//! [`StorageSpec`]: per-node simulated WAL with group-commit fsync,
//! torn-write faults and crash recovery on restart), and safety-evidence
//! recording (`SimConfig::track_safety` → [`SafetyLog`], validated by
//! `bench::safety::check` — per group on sharded runs).

pub mod cluster;
pub mod event;
pub(crate) mod group;

pub use cluster::{
    run, CommitEvidence, DigestMode, GroupStat, Protocol, ReadPath, ReadRecord, ReconfigSpec,
    RestartSpec, RoundStat, SafetyLog, SimConfig, SimResult, StorageSpec, WorkloadSpec,
};
pub use event::{EventQueue, SimTime};
