//! YCSB + document store (the paper's "YCSB+MongoDB" scenario, §5.2):
//! simulate a 50-node heterogeneous cluster running Workload A with b = 5k,
//! comparing Raft against Cabinet at every evaluated failure threshold, and
//! show the per-round adaptation when strong nodes slow down mid-run.
//!
//! Run: `cargo run --release --example ycsb_cluster [--paper]`

use cabinet::bench::{fmt_tps, lineup, Scale, Table};
use cabinet::net::delay::DelayModel;
use cabinet::sim::{run, DigestMode, Protocol, SimConfig, WorkloadSpec};
use cabinet::workload::Workload;

fn main() {
    let paper = std::env::args().any(|a| a == "--paper");
    let scale = if paper { Scale::Paper } else { Scale::Quick };
    let n = 50;

    println!("YCSB-A, n={n}, b=5k, {} rounds per experiment\n", scale.rounds());

    let mut table = Table::new(
        "Raft vs Cabinet — YCSB-A (het + hom)",
        &["setting", "algo", "tput_ops_s", "mean_lat_ms", "p99_ms", "digests"],
    );
    for het in [true, false] {
        for (label, proto) in lineup(n) {
            let mut c = SimConfig::new(proto, n, het);
            c.rounds = scale.rounds();
            c.workload = WorkloadSpec::ycsb(Workload::A, 5000);
            c.digest_mode = DigestMode::Sample;
            let r = run(&c);
            table.row(vec![
                if het { "het" } else { "hom" }.into(),
                label,
                fmt_tps(r.tput_ops_s),
                format!("{:.1}", r.mean_latency_ms),
                format!("{:.1}", r.p99_latency_ms),
                format!("{:?}", r.digests_match.unwrap_or(false)),
            ]);
        }
    }
    println!("{}", table.render());

    // adaptation demo: rotating skew — watch Cabinet recover per round
    println!("adaptation under rotating skew delays (D3):\n");
    let mut series = Table::new(
        "per-round latency, cab f10% vs raft (first 12 rounds)",
        &["round", "raft_lat_ms", "cab_lat_ms"],
    );
    let mut raft_cfg = SimConfig::new(Protocol::Raft, n, true);
    raft_cfg.rounds = 12;
    raft_cfg.delay = DelayModel::Rotating { period_rounds: 4 };
    let raft = run(&raft_cfg);
    let mut cab_cfg = SimConfig::new(Protocol::Cabinet { t: 5 }, n, true);
    cab_cfg.rounds = 12;
    cab_cfg.delay = DelayModel::Rotating { period_rounds: 4 };
    let cab = run(&cab_cfg);
    for (a, b) in raft.rounds.iter().zip(&cab.rounds) {
        series.row(vec![
            a.round.to_string(),
            format!("{:.0}", a.latency_ms),
            format!("{:.0}", b.latency_ms),
        ]);
    }
    println!("{}", series.render());
    println!(
        "overall: raft {} ops/s vs cab f10% {} ops/s",
        fmt_tps(raft.tput_ops_s),
        fmt_tps(cab.tput_ops_s)
    );
}
