//! Native mirror of the L1 kernel spec (`python/compile/kernels/__init__.py`
//! + `ref.py`). All arithmetic is u32 modular, so this mirror, the Pallas
//! kernel, and the AOT HLO executable are *bit-identical*; integration tests
//! (`rust/tests/artifact_equiv.rs`) assert it against the PJRT path, and
//! replica-convergence checks lean on digest equality.

/// State slots (power of two) — mirrors `kernels.STATE_SLOTS`.
pub const STATE_SLOTS: usize = 8192;
/// Fixed YCSB artifact batch shape — mirrors `kernels.YCSB_BATCH`.
pub const YCSB_BATCH: usize = 5120;
/// Fixed TPC-C artifact batch shape — mirrors `kernels.TPCC_BATCH`.
pub const TPCC_BATCH: usize = 2048;
/// TPC-C warehouses in the artifact — mirrors `kernels.TPCC_WAREHOUSES`.
pub const TPCC_WAREHOUSES: usize = 64;
/// Weight-scheme artifact max cluster size — mirrors `kernels.MAX_NODES`.
pub const MAX_NODES: usize = 128;

pub const MIX1: u32 = 0x9E37_79B1;
pub const MIX2: u32 = 0x85EB_CA77;
pub const MIX3: u32 = 0xC2B2_AE3D;
pub const MIX4: u32 = 0x27D4_EB2F;

/// TPC-C cost model constants — mirror `kernels.TPCC_*`.
pub const TPCC_BASE_COST: [f32; 5] = [45.0, 18.0, 9.0, 30.0, 22.0];
pub const TPCC_ARG_COEF: f32 = 0.35;
pub const TPCC_LOCK_COEF: f32 = 2.5;

use crate::workload::ycsb::{OP_INSERT, OP_NOP, OP_RMW, OP_SCAN, OP_UPDATE, OP_READ};
use crate::workload::tpcc::{TXN_DELIVERY, TXN_NEW_ORDER, TXN_NOP, TXN_PAYMENT};

/// Primary key-mixing function: m(k) = ((k·MIX1) ^ (k>>15)) · MIX3.
#[inline]
pub fn mix(k: u32) -> u32 {
    (k.wrapping_mul(MIX1) ^ (k >> 15)).wrapping_mul(MIX3)
}

/// Per-op contribution c = ((m ^ v·MIX2) · (2·op+1)) + MIX4.
#[inline]
pub fn op_contrib(op: u32, key: u32, val: u32) -> u32 {
    (mix(key) ^ val.wrapping_mul(MIX2))
        .wrapping_mul(op.wrapping_mul(2).wrapping_add(1))
        .wrapping_add(MIX4)
}

#[inline]
pub fn slot_of(key: u32, n_slots: usize) -> usize {
    (mix(key) & (n_slots as u32 - 1)) as usize
}

#[inline]
pub fn is_write(op: u32) -> bool {
    op == OP_UPDATE || op == OP_INSERT || op == OP_RMW
}

#[inline]
pub fn is_read(op: u32) -> bool {
    op == OP_READ || op == OP_SCAN || op == OP_RMW
}

/// Z-fold coefficient for the state digest.
#[inline]
fn z_coef(i: usize) -> u32 {
    (i as u32).wrapping_mul(MIX1) ^ 0x5A5A_5A5A
}

/// The replicated slot-state (what the digest is computed over). Every
/// replica's `DigestState` must stay bit-identical — that *is* the SMR
/// safety check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DigestState {
    state: Vec<u32>,
    /// Running Σ state\[i\]·z(i): the fold is *linear* over wrapping u32
    /// arithmetic, so each write updates it incrementally
    /// (`digest += c·z(slot)`) bit-identically to refolding the whole
    /// state — turning the per-batch O(slots) fold in the commit path into
    /// O(batch). Always consistent with `state` (both private, every
    /// mutation path maintains it), so the derived equality stays sound.
    digest: u32,
}

impl Default for DigestState {
    fn default() -> Self {
        Self::new(STATE_SLOTS)
    }
}

/// Full Σ state\[i\]·z(i) fold — used once at construction and by tests
/// pinning the incremental digest against it.
fn fold_state(state: &[u32]) -> u32 {
    state
        .iter()
        .enumerate()
        .fold(0u32, |acc, (i, &s)| acc.wrapping_add(s.wrapping_mul(z_coef(i))))
}

impl DigestState {
    pub fn new(n_slots: usize) -> Self {
        assert!(n_slots.is_power_of_two());
        DigestState { state: vec![0; n_slots], digest: 0 }
    }

    pub fn from_state(state: Vec<u32>) -> Self {
        assert!(state.len().is_power_of_two());
        let digest = fold_state(&state);
        DigestState { state, digest }
    }

    pub fn slots(&self) -> &[u32] {
        &self.state
    }

    pub fn n_slots(&self) -> usize {
        self.state.len()
    }

    /// Apply one YCSB batch; returns `[state_digest, read_digest]` —
    /// bit-identical to `ref.ycsb_apply_ref` / the `ycsb_apply` artifact.
    pub fn apply_ycsb(&mut self, ops: &[u32], keys: &[u32], vals: &[u32]) -> [u32; 2] {
        assert_eq!(ops.len(), keys.len());
        assert_eq!(ops.len(), vals.len());
        let n = self.state.len();
        // Two passes so reads observe the pre-batch state without
        // materializing a per-batch delta vector (the old implementation
        // allocated O(slots) and refolded the whole state per batch).
        // Pass 1: reads, in op order — same wrapping-add order as before,
        // so the read digest is bit-identical.
        let mut rdig: u32 = 0;
        for ((&op, &key), &val) in ops.iter().zip(keys).zip(vals) {
            if op >= OP_NOP || !is_read(op) {
                continue;
            }
            let c = op_contrib(op, key, val);
            rdig = rdig.wrapping_add(self.state[slot_of(key, n)] ^ c);
        }
        // Pass 2: writes mutate the state and the running digest. Linearity
        // of the z-fold over wrapping arithmetic makes the incremental
        // update bit-identical to refolding: Σ(sᵢ+δᵢ)·z(i) = Σsᵢ·z(i) + Σδᵢ·z(i).
        for ((&op, &key), &val) in ops.iter().zip(keys).zip(vals) {
            if op >= OP_NOP || !is_write(op) {
                continue;
            }
            let c = op_contrib(op, key, val);
            let s = slot_of(key, n);
            self.state[s] = self.state[s].wrapping_add(c);
            self.digest = self.digest.wrapping_add(c.wrapping_mul(z_coef(s)));
        }
        [self.digest, rdig]
    }

    /// Digest of the current state: Σ state\[i\] · z(i) (wrapping) —
    /// maintained incrementally, so this is O(1).
    pub fn state_digest(&self) -> u32 {
        self.digest
    }
}

/// Is this TPC-C txn type lock-taking (NewOrder / Payment / Delivery)?
#[inline]
pub fn tpcc_takes_lock(txn: u32) -> bool {
    txn == TXN_NEW_ORDER || txn == TXN_PAYMENT || txn == TXN_DELIVERY
}

/// Native mirror of the TPC-C cost kernels: per-warehouse lock demand,
/// per-txn costs, stream digest — matches `ref.tpcc_lock_counts_ref` +
/// `ref.tpcc_cost_ref` (costs to f32 round-off, digest bit-exact).
pub fn tpcc_costs(
    types: &[u32],
    wids: &[u32],
    args: &[u32],
    n_warehouses: usize,
) -> (Vec<f32>, Vec<f32>, u32) {
    let mut counts = vec![0f32; n_warehouses];
    for (&t, &w) in types.iter().zip(wids) {
        if t < TXN_NOP && tpcc_takes_lock(t) {
            counts[w as usize] += 1.0;
        }
    }
    let mut costs = Vec::with_capacity(types.len());
    let mut dig: u32 = 0;
    for ((&t, &w), &a) in types.iter().zip(wids).zip(args) {
        if t >= TXN_NOP {
            costs.push(0.0);
            continue;
        }
        let base = TPCC_BASE_COST[t as usize];
        let argf = a as f32 / 16.0;
        let mut cost = base * (1.0 + TPCC_ARG_COEF * argf);
        if tpcc_takes_lock(t) {
            cost += TPCC_LOCK_COEF * (counts[w as usize] - 1.0).max(0.0);
        }
        costs.push(cost);
        dig = dig.wrapping_add(op_contrib(t, w, a));
    }
    (counts, costs, dig)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::rng::Rng;

    #[test]
    fn mix_constants_spot_check() {
        // pin a few values so any drift from the shared spec is loud
        assert_eq!(mix(0), 0);
        assert_eq!(mix(1), MIX1.wrapping_mul(MIX3) ^ 0); // k>>15 == 0 for k=1
        assert_eq!(op_contrib(0, 0, 0), MIX4);
    }

    #[test]
    fn empty_batch_digest_is_stable() {
        let mut st = DigestState::new(256);
        let d1 = st.apply_ycsb(&[], &[], &[]);
        let d2 = st.apply_ycsb(&[], &[], &[]);
        assert_eq!(d1, d2);
        assert_eq!(d1[1], 0);
    }

    #[test]
    fn writes_mutate_reads_do_not() {
        let mut st = DigestState::new(256);
        let before = st.clone();
        st.apply_ycsb(&[OP_READ, OP_SCAN], &[1, 2], &[3, 4]);
        assert_eq!(st, before);
        st.apply_ycsb(&[OP_UPDATE], &[1], &[3]);
        assert_ne!(st, before);
    }

    #[test]
    fn apply_is_order_invariant() {
        let mut rng = Rng::new(1);
        let n = 512;
        let ops: Vec<u32> = (0..n).map(|_| rng.below(6) as u32).collect();
        let keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut a = DigestState::new(1024);
        let da = a.apply_ycsb(&ops, &keys, &vals);
        // reversed order
        let rops: Vec<u32> = ops.iter().rev().copied().collect();
        let rkeys: Vec<u32> = keys.iter().rev().copied().collect();
        let rvals: Vec<u32> = vals.iter().rev().copied().collect();
        let mut b = DigestState::new(1024);
        let db = b.apply_ycsb(&rops, &rkeys, &rvals);
        assert_eq!(a, b);
        assert_eq!(da, db);
    }

    #[test]
    fn split_batches_equal_one_batch() {
        let mut rng = Rng::new(2);
        let n = 600;
        let ops: Vec<u32> = (0..n).map(|_| rng.below(5) as u32).collect();
        let keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let vals: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut whole = DigestState::new(256);
        whole.apply_ycsb(&ops, &keys, &vals);
        // NOTE: split batches see *different* pre-states for reads, so only
        // the final state (not read digests) must agree — and it does,
        // because writes are wrap-adds.
        let mut parts = DigestState::new(256);
        parts.apply_ycsb(&ops[..200], &keys[..200], &vals[..200]);
        parts.apply_ycsb(&ops[200..], &keys[200..], &vals[200..]);
        assert_eq!(whole.slots(), parts.slots());
    }

    #[test]
    fn incremental_digest_matches_full_fold() {
        // the cached digest must stay bit-identical to refolding the whole
        // state after any batch mix (RMW ops exercise read+write together)
        let mut rng = Rng::new(9);
        let mut st = DigestState::new(512);
        for batch in 0..4 {
            let n = 300 + batch * 50;
            let ops: Vec<u32> = (0..n).map(|_| rng.below(6) as u32).collect();
            let keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let vals: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            st.apply_ycsb(&ops, &keys, &vals);
            assert_eq!(st.state_digest(), fold_state(st.slots()), "batch {batch}");
        }
        // and from_state seeds the cache with the same fold
        let rebuilt = DigestState::from_state(st.slots().to_vec());
        assert_eq!(rebuilt.state_digest(), st.state_digest());
        assert_eq!(rebuilt, st);
    }

    #[test]
    fn digest_detects_divergence() {
        let mut a = DigestState::new(256);
        let mut b = DigestState::new(256);
        a.apply_ycsb(&[OP_UPDATE], &[7], &[100]);
        b.apply_ycsb(&[OP_UPDATE], &[7], &[101]);
        assert_ne!(a.state_digest(), b.state_digest());
    }

    #[test]
    fn tpcc_cost_mirror_basics() {
        // single NewOrder, no contention: base · (1 + 0.35·a/16)
        let (counts, costs, dig) = tpcc_costs(&[TXN_NEW_ORDER], &[0], &[0], 4);
        assert_eq!(counts[0], 1.0);
        assert_eq!(costs[0], 45.0);
        assert_ne!(dig, 0);
        // NOP txn costs nothing
        let (_, costs, dig) = tpcc_costs(&[TXN_NOP], &[0], &[0], 4);
        assert_eq!(costs[0], 0.0);
        assert_eq!(dig, 0);
    }

    #[test]
    fn tpcc_contention_term() {
        let (_, costs, _) =
            tpcc_costs(&[TXN_NEW_ORDER, TXN_NEW_ORDER], &[3, 3], &[0, 0], 8);
        assert_eq!(costs[0], 45.0 + TPCC_LOCK_COEF);
        // read-only txns don't pay the lock term
        let (_, costs, _) = tpcc_costs(
            &[crate::workload::tpcc::TXN_STOCK_LEVEL, TXN_NEW_ORDER],
            &[3, 3],
            &[0, 0],
            8,
        );
        assert_eq!(costs[0], 22.0);
    }
}
