//! `cargo bench` target regenerating Fig 24 — sharded multi-group scaling
//! (quick scale; run `cargo run --release --example figures -- fig24
//! --paper` for the full version). Each row runs G ∈ {1, 2, 4, 8}
//! independent weighted-consensus groups over one shared virtual-time
//! fabric at n = 11 under D1-100 ms, every group replicating only its own
//! hash-partitioned YCSB shard under its own leader. The acceptance shape:
//! aggregate wall-clock throughput increases from G=1 to G=4 (groups
//! overlap their replication rounds), and the G=1 row is bit-for-bit the
//! historical single-group driver.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig24_sharding", || {
        last = Some(figures::fig24_sharding(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
