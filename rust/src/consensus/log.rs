//! The replicated log — stock Raft semantics (1-based indices, term-tagged
//! entries, conflict truncation), plus Cabinet's per-entry stored weight
//! (§4.1 "Write and read": each node stores the weight it held for the
//! instance that committed the entry, so clients can form weighted read
//! quorums), plus snapshot compaction: the committed prefix can be
//! discarded, surviving only as `(last_compacted_index, last_compacted_term,
//! digest)` metadata.
//!
//! Compaction invariants:
//!   * only committed entries are ever compacted (the caller — `node.rs` —
//!     never compacts past its commit index), so the discarded prefix is
//!     immutable and `matches()` can trust any prefix point below the cut;
//!   * `prefix_digest` is chained: the FNV fold over the compacted prefix is
//!     retained as a running state and resumed over retained entries, so the
//!     fingerprint of any reachable prefix is bit-identical whether or not
//!     (and wherever) the log was compacted — replay determinism and the
//!     safety harness's log-matching checks survive compaction.

use std::sync::Arc;

use crate::consensus::message::{ClusterConfig, Entry, LogIndex, Payload, Term};
use crate::util::Fnv64;

/// A node's replicated log.
#[derive(Clone, Debug)]
pub struct Log {
    /// Retained entries: `entries[i]` holds index `compacted_index + i + 1`.
    entries: Vec<Entry>,
    /// `stored_weights[i]` = this node's weight during the round that
    /// replicated `entries[i]` (1.0 in Raft mode).
    stored_weights: Vec<f64>,
    /// Index of the last compacted (discarded) entry; 0 = nothing compacted.
    compacted_index: LogIndex,
    /// Term of the entry at `compacted_index` (0 when nothing compacted).
    compacted_term: Term,
    /// Running FNV state over entries `1..=compacted_index` — the digest
    /// chain that keeps `prefix_digest` identical across compaction.
    compacted_digest: u64,
}

impl Default for Log {
    fn default() -> Self {
        Log {
            entries: Vec::new(),
            stored_weights: Vec::new(),
            compacted_index: 0,
            compacted_term: 0,
            compacted_digest: Fnv64::new().finish(),
        }
    }
}

impl Log {
    pub fn new() -> Self {
        Self::default()
    }

    /// Retained slot (0-based) for `index`, if it is retained.
    fn pos(&self, index: LogIndex) -> Option<usize> {
        if index <= self.compacted_index {
            None
        } else {
            let p = (index - self.compacted_index - 1) as usize;
            (p < self.entries.len()).then_some(p)
        }
    }

    /// Index of the last entry (0 when empty), compacted prefix included.
    pub fn last_index(&self) -> LogIndex {
        self.compacted_index + self.entries.len() as LogIndex
    }

    /// Term of the last entry (0 when empty).
    pub fn last_term(&self) -> Term {
        self.entries.last().map_or(self.compacted_term, |e| e.term)
    }

    /// Index of the last compacted entry (0 = nothing compacted).
    pub fn last_compacted_index(&self) -> LogIndex {
        self.compacted_index
    }

    /// Term of the last compacted entry (0 = nothing compacted).
    pub fn last_compacted_term(&self) -> Term {
        self.compacted_term
    }

    /// Chained `prefix_digest` state through `last_compacted_index` — what a
    /// snapshot records so the chain survives the discarded prefix.
    pub fn compacted_digest(&self) -> u64 {
        self.compacted_digest
    }

    /// Term of the entry at `index`. `Some(0)` for index 0; `Some` of the
    /// compaction-point term at exactly `last_compacted_index`; `None` for
    /// indices strictly inside the discarded prefix or past the tail.
    pub fn term_at(&self, index: LogIndex) -> Option<Term> {
        if index == self.compacted_index {
            Some(self.compacted_term)
        } else if index < self.compacted_index {
            None
        } else {
            self.pos(index).map(|p| self.entries[p].term)
        }
    }

    /// The entry at `index` (None when out of range or compacted away).
    pub fn get(&self, index: LogIndex) -> Option<&Entry> {
        self.pos(index).map(|p| &self.entries[p])
    }

    /// This node's stored weight for the entry at `index`.
    pub fn stored_weight(&self, index: LogIndex) -> Option<f64> {
        self.pos(index).map(|p| self.stored_weights[p])
    }

    /// Append a fresh entry at the tail (leader path). Returns its index.
    pub fn append(&mut self, mut entry: Entry, weight: f64) -> LogIndex {
        entry.index = self.last_index() + 1;
        let idx = entry.index;
        self.entries.push(entry);
        self.stored_weights.push(weight);
        idx
    }

    /// Raft log-matching: does `(prev_index, prev_term)` match our log?
    /// Points strictly below the compaction cut always match: only committed
    /// entries are compacted, and committed prefixes are immutable, so any
    /// legitimate sender agrees with whatever we discarded.
    pub fn matches(&self, prev_index: LogIndex, prev_term: Term) -> bool {
        if prev_index < self.compacted_index {
            return true;
        }
        self.term_at(prev_index) == Some(prev_term)
    }

    /// Follower path: append `entries` after `prev_index`, truncating any
    /// conflicting suffix first (Raft §5.3). `weight` is this node's weight
    /// for the shipping round. Entries at or below the compaction point are
    /// skipped — they are committed state already covered by the snapshot (a
    /// retransmission can race a just-installed snapshot). Returns the new
    /// last index.
    pub fn splice(&mut self, prev_index: LogIndex, entries: &[Entry], weight: f64) -> LogIndex {
        // A prev_index past our tail would push entries with gapped
        // indices. The RPC path can't reach here (`matches()` gates it),
        // but WAL replay calls `splice` on raw recovered records where a
        // torn tail can orphan a later record's prefix — refuse the record
        // instead of corrupting the log. (A debug_assert! compiles out in
        // release, which is exactly the build recovery runs under.)
        if prev_index > self.last_index() {
            return self.last_index();
        }
        let skip = (self.compacted_index.saturating_sub(prev_index) as usize).min(entries.len());
        let mut insert_at =
            (prev_index.max(self.compacted_index) - self.compacted_index) as usize;
        for e in &entries[skip..] {
            if let Some(existing) = self.entries.get(insert_at) {
                if existing.term == e.term {
                    // already have it — skip (idempotent retransmission)
                    insert_at += 1;
                    continue;
                }
                // conflict: truncate from here
                self.entries.truncate(insert_at);
                self.stored_weights.truncate(insert_at);
            }
            let mut e = e.clone();
            e.index = self.compacted_index + insert_at as LogIndex + 1;
            self.entries.push(e);
            self.stored_weights.push(weight);
            insert_at += 1;
        }
        self.last_index()
    }

    /// Entries in `(from, to]` for shipping to a follower. The caller must
    /// not request below the compaction point (`node.rs` ships a snapshot
    /// instead); out-of-range bounds are clamped defensively.
    pub fn slice(&self, from_exclusive: LogIndex, to_inclusive: LogIndex) -> Vec<Entry> {
        let hi = (to_inclusive.saturating_sub(self.compacted_index) as usize)
            .min(self.entries.len());
        let lo = ((from_exclusive.max(self.compacted_index) - self.compacted_index) as usize)
            .min(hi);
        self.entries[lo..hi].to_vec()
    }

    /// Raft §5.4.1 up-to-date check: is (their_term, their_index) at least
    /// as up-to-date as our last entry?
    pub fn candidate_up_to_date(&self, their_index: LogIndex, their_term: Term) -> bool {
        let (lt, li) = (self.last_term(), self.last_index());
        their_term > lt || (their_term == lt && their_index >= li)
    }

    /// Iterate the retained entries (the compacted prefix is gone).
    pub fn iter(&self) -> impl Iterator<Item = &Entry> {
        self.entries.iter()
    }

    /// The most recent membership config in the retained suffix (Raft §6:
    /// configs are effective on append, so the latest entry wins), together
    /// with its index. `None` when no ConfigChange entry is retained — the
    /// caller falls back to the snapshot blob's config or the boot config.
    pub fn latest_config(&self) -> Option<(LogIndex, Arc<ClusterConfig>)> {
        self.entries.iter().rev().find_map(|e| match &e.payload {
            Payload::ConfigChange(c) => Some((e.index, Arc::clone(c))),
            _ => None,
        })
    }

    /// FNV-1a fingerprint over the `(index, term, wclock)` triples of the
    /// first `upto` entries, resumed from the compacted prefix's chained
    /// state. Used by the safety harness to assert the log matching property
    /// cheaply: if two nodes hold the same `(index, term)` entry, their
    /// prefix digests up to that index must coincide — regardless of where
    /// (or whether) either log was compacted. Only meaningful for
    /// `upto >= last_compacted_index` (callers gate on `term_at`).
    pub fn prefix_digest(&self, upto: LogIndex) -> u64 {
        let mut h = Fnv64::from_state(self.compacted_digest);
        let take = upto.saturating_sub(self.compacted_index) as usize;
        for e in self.entries.iter().take(take) {
            h.write_u64(e.index);
            h.write_u64(e.term);
            h.write_u64(e.wclock);
        }
        h.finish()
    }

    /// Discard the prefix through `index` (clamped to the tail), folding it
    /// into the digest chain. The caller guarantees `index` is committed.
    /// Returns the number of entries dropped.
    pub fn compact_to(&mut self, index: LogIndex) -> usize {
        let index = index.min(self.last_index());
        if index <= self.compacted_index {
            return 0;
        }
        let dropped = (index - self.compacted_index) as usize;
        let mut h = Fnv64::from_state(self.compacted_digest);
        for e in &self.entries[..dropped] {
            h.write_u64(e.index);
            h.write_u64(e.term);
            h.write_u64(e.wclock);
        }
        self.compacted_digest = h.finish();
        self.compacted_term = self.entries[dropped - 1].term;
        self.compacted_index = index;
        self.entries.drain(..dropped);
        self.stored_weights.drain(..dropped);
        dropped
    }

    /// Adopt a leader snapshot at `(last_index, last_term)` with chained
    /// digest `digest` (Raft InstallSnapshot rule): if we already hold the
    /// snapshot's last entry with the same term, only the covered prefix is
    /// discarded and the matching suffix is retained; otherwise the whole
    /// log is replaced by the snapshot metadata.
    pub fn install_snapshot(&mut self, last_index: LogIndex, last_term: Term, digest: u64) {
        if last_index <= self.compacted_index {
            return; // stale — we already compacted past it
        }
        if self.term_at(last_index) == Some(last_term) {
            self.compact_to(last_index);
            // identical by the log matching property; adopt the leader's
            // value so divergence would surface in digest asserts
            self.compacted_digest = digest;
        } else {
            self.entries.clear();
            self.stored_weights.clear();
            self.compacted_index = last_index;
            self.compacted_term = last_term;
            self.compacted_digest = digest;
        }
    }

    /// Number of *retained* (in-memory) entries — after compaction this is
    /// `last_index - last_compacted_index`, the quantity snapshotting bounds.
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::message::Payload;

    fn e(term: Term) -> Entry {
        Entry { term, index: 0, payload: Payload::Noop, wclock: 0 }
    }

    #[test]
    fn empty_log_basics() {
        let log = Log::new();
        assert_eq!(log.last_index(), 0);
        assert_eq!(log.last_term(), 0);
        assert_eq!(log.term_at(0), Some(0));
        assert_eq!(log.term_at(1), None);
        assert!(log.matches(0, 0));
        assert!(!log.matches(1, 1));
    }

    #[test]
    fn append_assigns_indices() {
        let mut log = Log::new();
        assert_eq!(log.append(e(1), 1.0), 1);
        assert_eq!(log.append(e(1), 2.0), 2);
        assert_eq!(log.append(e(2), 3.0), 3);
        assert_eq!(log.last_index(), 3);
        assert_eq!(log.last_term(), 2);
        assert_eq!(log.stored_weight(2), Some(2.0));
    }

    #[test]
    fn splice_appends_at_tail() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        let last = log.splice(1, &[e(2), e(2)], 5.0);
        assert_eq!(last, 3);
        assert_eq!(log.term_at(2), Some(2));
        assert_eq!(log.stored_weight(3), Some(5.0));
    }

    #[test]
    fn splice_truncates_conflicts() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        log.append(e(2), 1.0);
        log.append(e(2), 1.0);
        // new leader in term 3 overwrites from index 2
        let last = log.splice(1, &[e(3)], 2.0);
        assert_eq!(last, 2);
        assert_eq!(log.term_at(2), Some(3));
        assert_eq!(log.term_at(3), None);
    }

    #[test]
    fn splice_rejects_gapped_prev_index() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        // prev_index=5 with last_index=1 would create indices 6.. over a
        // hole — the guard must refuse it (release builds included)
        let last = log.splice(5, &[e(2), e(2)], 1.0);
        assert_eq!(last, 1, "gapped splice is a no-op");
        assert_eq!(log.last_index(), 1);
        assert_eq!(log.term_at(2), None);
    }

    #[test]
    fn splice_is_idempotent_for_retransmits() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        log.splice(1, &[e(2), e(2)], 1.0);
        let before: Vec<Term> = log.iter().map(|x| x.term).collect();
        log.splice(1, &[e(2), e(2)], 1.0); // duplicate delivery
        let after: Vec<Term> = log.iter().map(|x| x.term).collect();
        assert_eq!(before, after);
        assert_eq!(log.last_index(), 3);
    }

    #[test]
    fn slice_ranges() {
        let mut log = Log::new();
        for _ in 0..5 {
            log.append(e(1), 1.0);
        }
        assert_eq!(log.slice(0, 5).len(), 5);
        assert_eq!(log.slice(2, 4).len(), 2);
        assert_eq!(log.slice(2, 4)[0].index, 3);
        assert_eq!(log.slice(5, 5).len(), 0);
        assert_eq!(log.slice(2, 99).len(), 3);
    }

    #[test]
    fn prefix_digest_tracks_content() {
        let mut a = Log::new();
        let mut b = Log::new();
        for t in [1, 1, 2] {
            a.append(e(t), 1.0);
            b.append(e(t), 1.0);
        }
        assert_eq!(a.prefix_digest(3), b.prefix_digest(3));
        assert_eq!(a.prefix_digest(2), b.prefix_digest(2));
        // diverge at index 3
        b.splice(2, &[e(5)], 1.0);
        assert_eq!(a.prefix_digest(2), b.prefix_digest(2));
        assert_ne!(a.prefix_digest(3), b.prefix_digest(3));
        // digest over more entries than exist == digest of the whole log
        assert_eq!(a.prefix_digest(99), a.prefix_digest(3));
    }

    #[test]
    fn latest_config_scans_backwards_and_respects_truncation() {
        use crate::consensus::message::ClusterConfig;
        let cfg = |epoch| {
            let mut c = ClusterConfig::bootstrap(3);
            c.epoch = epoch;
            Payload::ConfigChange(Arc::new(c))
        };
        let mut log = Log::new();
        assert!(log.latest_config().is_none());
        log.append(e(1), 1.0);
        log.append(Entry { term: 1, index: 0, payload: cfg(1), wclock: 0 }, 1.0);
        log.append(e(1), 1.0);
        log.append(Entry { term: 1, index: 0, payload: cfg(2), wclock: 0 }, 1.0);
        let (idx, c) = log.latest_config().unwrap();
        assert_eq!((idx, c.epoch), (4, 2));
        // a conflicting splice that truncates the tail rolls the config back
        log.splice(3, &[e(2)], 1.0);
        let (idx, c) = log.latest_config().unwrap();
        assert_eq!((idx, c.epoch), (2, 1));
        // compacting past every config entry leaves nothing retained
        log.compact_to(4);
        assert!(log.latest_config().is_none());
    }

    #[test]
    fn up_to_date_check() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        log.append(e(3), 1.0);
        // higher last term wins
        assert!(log.candidate_up_to_date(1, 4));
        // same term, longer log wins
        assert!(log.candidate_up_to_date(2, 3));
        assert!(log.candidate_up_to_date(3, 3));
        // shorter same-term log loses
        assert!(!log.candidate_up_to_date(1, 3));
        // lower term loses regardless of length
        assert!(!log.candidate_up_to_date(99, 2));
    }

    // ---- compaction ------------------------------------------------------

    #[test]
    fn compaction_offsets_every_accessor() {
        let mut log = Log::new();
        for t in [1, 1, 2, 2, 3] {
            log.append(e(t), t as f64);
        }
        assert_eq!(log.compact_to(3), 3);
        assert_eq!(log.last_compacted_index(), 3);
        assert_eq!(log.last_compacted_term(), 2);
        assert_eq!(log.len(), 2, "only retained entries count");
        assert_eq!(log.last_index(), 5);
        assert_eq!(log.last_term(), 3);
        assert_eq!(log.term_at(2), None, "inside the discarded prefix");
        assert_eq!(log.term_at(3), Some(2), "the cut point keeps its term");
        assert_eq!(log.term_at(4), Some(2));
        assert!(log.get(3).is_none());
        assert_eq!(log.get(4).unwrap().index, 4);
        assert_eq!(log.stored_weight(4), Some(2.0));
        assert_eq!(log.stored_weight(2), None);
        // idempotent / backwards compaction is a no-op
        assert_eq!(log.compact_to(2), 0);
        assert_eq!(log.compact_to(3), 0);
        // appending continues from the true tail
        assert_eq!(log.append(e(3), 1.0), 6);
    }

    #[test]
    fn prefix_digest_chains_across_compaction() {
        let mut whole = Log::new();
        let mut cut = Log::new();
        for t in [1u64, 1, 2, 2, 3, 3] {
            whole.append(e(t), 1.0);
            cut.append(e(t), 1.0);
        }
        cut.compact_to(2);
        assert_eq!(cut.prefix_digest(2), whole.prefix_digest(2));
        assert_eq!(cut.prefix_digest(4), whole.prefix_digest(4));
        assert_eq!(cut.prefix_digest(6), whole.prefix_digest(6));
        // compacting further never changes any still-reachable digest
        cut.compact_to(5);
        assert_eq!(cut.prefix_digest(5), whole.prefix_digest(5));
        assert_eq!(cut.prefix_digest(6), whole.prefix_digest(6));
    }

    #[test]
    fn matches_and_splice_below_the_cut() {
        let mut log = Log::new();
        for t in [1, 1, 2, 2] {
            log.append(e(t), 1.0);
        }
        log.compact_to(3);
        // any point strictly below the cut is trusted (committed prefix)
        assert!(log.matches(1, 1));
        assert!(log.matches(2, 99));
        assert!(log.matches(3, 2), "cut point matches its recorded term");
        assert!(!log.matches(3, 7));
        // a retransmission spanning the cut only splices the live suffix
        let last = log.splice(2, &[e(2), e(2), e(3)], 1.0);
        assert_eq!(last, 5);
        assert_eq!(log.term_at(4), Some(2), "retained entry untouched");
        assert_eq!(log.term_at(5), Some(3));
        assert_eq!(log.len(), 2);
    }

    #[test]
    fn install_snapshot_replaces_or_retains() {
        // divergent log: replaced wholesale
        let mut log = Log::new();
        for t in [1, 1, 1] {
            log.append(e(t), 1.0);
        }
        log.install_snapshot(5, 3, 0xBEEF);
        assert_eq!(log.last_index(), 5);
        assert_eq!(log.last_term(), 3);
        assert_eq!(log.len(), 0);
        assert_eq!(log.compacted_digest(), 0xBEEF);
        assert_eq!(log.prefix_digest(5), 0xBEEF);
        // stale snapshot: no-op
        log.install_snapshot(4, 2, 0xDEAD);
        assert_eq!(log.last_compacted_index(), 5);
        assert_eq!(log.compacted_digest(), 0xBEEF);

        // matching log: the suffix beyond the snapshot survives
        let mut log = Log::new();
        for t in [1, 1, 2, 2] {
            log.append(e(t), 1.0);
        }
        let digest_at_3 = log.prefix_digest(3);
        log.install_snapshot(3, 2, digest_at_3);
        assert_eq!(log.last_compacted_index(), 3);
        assert_eq!(log.last_index(), 4, "matching suffix retained");
        assert_eq!(log.term_at(4), Some(2));
    }
}
