//! Deterministic discrete-event simulation of the full benchmark pipeline
//! (virtual time, seeded): the environment in which every paper figure is
//! regenerated. See DESIGN.md §6 for the calibration model.

pub mod cluster;
pub mod event;

pub use cluster::{
    run, DigestMode, Protocol, ReconfigSpec, RoundStat, SimConfig, SimResult, WorkloadSpec,
};
pub use event::{EventQueue, SimTime};
