"""Layer-1 Pallas kernels for the Cabinet reproduction.

Every kernel has a pure-jnp oracle in `ref.py`; pytest asserts bit-exact
equality (all state-machine arithmetic is uint32 modular, so there is no
tolerance to argue about). The constants here are the *shared spec* with the
Rust coordinator (`rust/src/storage/digest.rs` mirrors them exactly).
"""

# --- shared spec constants (mirrored in rust/src/storage/digest.rs) ---------

# State-machine state: S uint32 slots (power of two).
STATE_SLOTS = 8192
# YCSB batch: padded op-batch size and Pallas block size.
YCSB_BATCH = 5120
YCSB_BLOCK = 512
# TPC-C batch: padded txn-batch size, block size, warehouse count.
TPCC_BATCH = 2048
TPCC_BLOCK = 256
TPCC_WAREHOUSES = 64
# Weight-scheme artifact: max cluster size.
MAX_NODES = 128

# Mixing constants (xxhash/murmur-style odd constants).
MIX1 = 0x9E3779B1
MIX2 = 0x85EBCA77
MIX3 = 0xC2B2AE3D
MIX4 = 0x27D4EB2F

# YCSB op codes (shared with rust workload::ycsb).
OP_READ = 0
OP_UPDATE = 1
OP_SCAN = 2
OP_INSERT = 3
OP_RMW = 4
OP_NOP = 5

# TPC-C transaction codes (shared with rust workload::tpcc).
TXN_NEW_ORDER = 0
TXN_PAYMENT = 1
TXN_ORDER_STATUS = 2
TXN_DELIVERY = 3
TXN_STOCK_LEVEL = 4
TXN_NOP = 5

# TPC-C cost model: base work units per txn type and lock-contention
# coefficient (write txns serialized per warehouse). Mirrored in rust.
TPCC_BASE_COST = (45.0, 18.0, 9.0, 30.0, 22.0)
TPCC_ARG_COEF = 0.35
TPCC_LOCK_COEF = 2.5

from . import ref  # noqa: E402,F401
from .ycsb_apply import ycsb_apply_pallas  # noqa: E402,F401
from .tpcc_cost import tpcc_cost_pallas  # noqa: E402,F401
