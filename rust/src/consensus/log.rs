//! The replicated log — stock Raft semantics (1-based indices, term-tagged
//! entries, conflict truncation), plus Cabinet's per-entry stored weight
//! (§4.1 "Write and read": each node stores the weight it held for the
//! instance that committed the entry, so clients can form weighted read
//! quorums).

use crate::consensus::message::{Entry, LogIndex, Term};

/// A node's replicated log.
#[derive(Clone, Debug, Default)]
pub struct Log {
    entries: Vec<Entry>,
    /// `stored_weight[i]` = this node's weight during the round that
    /// replicated `entries[i]` (1.0 in Raft mode).
    stored_weights: Vec<f64>,
}

impl Log {
    pub fn new() -> Self {
        Self::default()
    }

    /// Index of the last entry (0 when empty).
    pub fn last_index(&self) -> LogIndex {
        self.entries.len() as LogIndex
    }

    /// Term of the last entry (0 when empty).
    pub fn last_term(&self) -> Term {
        self.entries.last().map_or(0, |e| e.term)
    }

    /// Term of the entry at `index` (0 for index 0; None if out of range).
    pub fn term_at(&self, index: LogIndex) -> Option<Term> {
        if index == 0 {
            Some(0)
        } else {
            self.entries.get(index as usize - 1).map(|e| e.term)
        }
    }

    pub fn get(&self, index: LogIndex) -> Option<&Entry> {
        if index == 0 {
            None
        } else {
            self.entries.get(index as usize - 1)
        }
    }

    /// This node's stored weight for the entry at `index`.
    pub fn stored_weight(&self, index: LogIndex) -> Option<f64> {
        if index == 0 {
            None
        } else {
            self.stored_weights.get(index as usize - 1).copied()
        }
    }

    /// Append a fresh entry at the tail (leader path). Returns its index.
    pub fn append(&mut self, mut entry: Entry, weight: f64) -> LogIndex {
        entry.index = self.last_index() + 1;
        let idx = entry.index;
        self.entries.push(entry);
        self.stored_weights.push(weight);
        idx
    }

    /// Raft log-matching: does `(prev_index, prev_term)` match our log?
    pub fn matches(&self, prev_index: LogIndex, prev_term: Term) -> bool {
        self.term_at(prev_index) == Some(prev_term)
    }

    /// Follower path: append `entries` after `prev_index`, truncating any
    /// conflicting suffix first (Raft §5.3). `weight` is this node's weight
    /// for the shipping round. Returns the new last index.
    pub fn splice(&mut self, prev_index: LogIndex, entries: &[Entry], weight: f64) -> LogIndex {
        debug_assert!(prev_index <= self.last_index());
        let mut insert_at = prev_index as usize; // 0-based slot for first new entry
        for e in entries {
            if let Some(existing) = self.entries.get(insert_at) {
                if existing.term == e.term {
                    // already have it — skip (idempotent retransmission)
                    insert_at += 1;
                    continue;
                }
                // conflict: truncate from here
                self.entries.truncate(insert_at);
                self.stored_weights.truncate(insert_at);
            }
            let mut e = e.clone();
            e.index = insert_at as LogIndex + 1;
            self.entries.push(e);
            self.stored_weights.push(weight);
            insert_at += 1;
        }
        self.last_index()
    }

    /// Entries in `(from, to]` for shipping to a follower.
    pub fn slice(&self, from_exclusive: LogIndex, to_inclusive: LogIndex) -> Vec<Entry> {
        let lo = from_exclusive as usize;
        let hi = (to_inclusive as usize).min(self.entries.len());
        self.entries[lo..hi].to_vec()
    }

    /// Raft §5.4.1 up-to-date check: is (their_term, their_index) at least
    /// as up-to-date as our last entry?
    pub fn candidate_up_to_date(&self, their_index: LogIndex, their_term: Term) -> bool {
        let (lt, li) = (self.last_term(), self.last_index());
        their_term > lt || (their_term == lt && their_index >= li)
    }

    pub fn iter(&self) -> impl Iterator<Item = &Entry> {
        self.entries.iter()
    }

    /// FNV-1a fingerprint over the `(index, term, wclock)` triples of the
    /// first `upto` entries. Used by the safety harness to assert the log
    /// matching property cheaply: if two nodes hold the same `(index, term)`
    /// entry, their prefix digests up to that index must coincide.
    pub fn prefix_digest(&self, upto: LogIndex) -> u64 {
        let mut h = crate::util::Fnv64::new();
        for e in self.entries.iter().take(upto as usize) {
            h.write_u64(e.index);
            h.write_u64(e.term);
            h.write_u64(e.wclock);
        }
        h.finish()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::message::Payload;

    fn e(term: Term) -> Entry {
        Entry { term, index: 0, payload: Payload::Noop, wclock: 0 }
    }

    #[test]
    fn empty_log_basics() {
        let log = Log::new();
        assert_eq!(log.last_index(), 0);
        assert_eq!(log.last_term(), 0);
        assert_eq!(log.term_at(0), Some(0));
        assert_eq!(log.term_at(1), None);
        assert!(log.matches(0, 0));
        assert!(!log.matches(1, 1));
    }

    #[test]
    fn append_assigns_indices() {
        let mut log = Log::new();
        assert_eq!(log.append(e(1), 1.0), 1);
        assert_eq!(log.append(e(1), 2.0), 2);
        assert_eq!(log.append(e(2), 3.0), 3);
        assert_eq!(log.last_index(), 3);
        assert_eq!(log.last_term(), 2);
        assert_eq!(log.stored_weight(2), Some(2.0));
    }

    #[test]
    fn splice_appends_at_tail() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        let last = log.splice(1, &[e(2), e(2)], 5.0);
        assert_eq!(last, 3);
        assert_eq!(log.term_at(2), Some(2));
        assert_eq!(log.stored_weight(3), Some(5.0));
    }

    #[test]
    fn splice_truncates_conflicts() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        log.append(e(2), 1.0);
        log.append(e(2), 1.0);
        // new leader in term 3 overwrites from index 2
        let last = log.splice(1, &[e(3)], 2.0);
        assert_eq!(last, 2);
        assert_eq!(log.term_at(2), Some(3));
        assert_eq!(log.term_at(3), None);
    }

    #[test]
    fn splice_is_idempotent_for_retransmits() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        log.splice(1, &[e(2), e(2)], 1.0);
        let before: Vec<Term> = log.iter().map(|x| x.term).collect();
        log.splice(1, &[e(2), e(2)], 1.0); // duplicate delivery
        let after: Vec<Term> = log.iter().map(|x| x.term).collect();
        assert_eq!(before, after);
        assert_eq!(log.last_index(), 3);
    }

    #[test]
    fn slice_ranges() {
        let mut log = Log::new();
        for _ in 0..5 {
            log.append(e(1), 1.0);
        }
        assert_eq!(log.slice(0, 5).len(), 5);
        assert_eq!(log.slice(2, 4).len(), 2);
        assert_eq!(log.slice(2, 4)[0].index, 3);
        assert_eq!(log.slice(5, 5).len(), 0);
        assert_eq!(log.slice(2, 99).len(), 3);
    }

    #[test]
    fn prefix_digest_tracks_content() {
        let mut a = Log::new();
        let mut b = Log::new();
        for t in [1, 1, 2] {
            a.append(e(t), 1.0);
            b.append(e(t), 1.0);
        }
        assert_eq!(a.prefix_digest(3), b.prefix_digest(3));
        assert_eq!(a.prefix_digest(2), b.prefix_digest(2));
        // diverge at index 3
        b.splice(2, &[e(5)], 1.0);
        assert_eq!(a.prefix_digest(2), b.prefix_digest(2));
        assert_ne!(a.prefix_digest(3), b.prefix_digest(3));
        // digest over more entries than exist == digest of the whole log
        assert_eq!(a.prefix_digest(99), a.prefix_digest(3));
    }

    #[test]
    fn up_to_date_check() {
        let mut log = Log::new();
        log.append(e(1), 1.0);
        log.append(e(3), 1.0);
        // higher last term wins
        assert!(log.candidate_up_to_date(1, 4));
        // same term, longer log wins
        assert!(log.candidate_up_to_date(2, 3));
        assert!(log.candidate_up_to_date(3, 3));
        // shorter same-term log loses
        assert!(!log.candidate_up_to_date(1, 3));
        // lower term loses regardless of length
        assert!(!log.candidate_up_to_date(99, 2));
    }
}
