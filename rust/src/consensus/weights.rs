//! Weight schemes for weighted consensus (§3, §4.1.1 of the paper).
//!
//! A weight scheme `WS = w₁ > w₂ > … > w_n` with consensus threshold
//! `CT = Σw/2` must satisfy the paper's two invariants (Eq. 2):
//!
//!   I1: Σ_{i=1..t+1} wᵢ > CT   (cabinet members alone can decide)
//!   I2: Σ_{i=1..t}   wᵢ < CT   (any t failures leave a live quorum)
//!
//! Cabinet realizes WS as the geometric sequence `w_k = r^(n-k)` with ratio
//! `r` solving Eq. 4: `r^(n-t-1) < (r^n+1)/2 < r^(n-t)`. This module is the
//! native mirror of the Layer-2 solver in `python/compile/model.py`
//! (`weight_scheme`); `runtime::tests` cross-checks the two at ~1e-9.

use std::fmt;

/// Bisection trip count — mirrors `model.BISECT_ITERS`.
pub const BISECT_ITERS: usize = 80;
/// Span fraction stepped down from the upper feasible boundary — mirrors
/// `model.RATIO_MARGIN`. Reproduces Fig. 4's r for t = 2, 3, 4 at n = 10.
pub const RATIO_MARGIN: f64 = 0.05;

/// Errors from weight-scheme construction/validation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WeightError {
    ClusterTooSmall(usize),
    ThresholdOutOfRange { n: usize, t: usize, max: usize },
    InvariantViolated(&'static str),
}

impl fmt::Display for WeightError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WeightError::ClusterTooSmall(n) => {
                write!(f, "cluster size {n} too small (need n >= 3)")
            }
            WeightError::ThresholdOutOfRange { n, t, max } => {
                write!(f, "failure threshold t={t} out of range [1, (n-1)/2]={max} for n={n}")
            }
            WeightError::InvariantViolated(inv) => {
                write!(f, "weight scheme violates invariant {inv}")
            }
        }
    }
}

impl std::error::Error for WeightError {}

/// A validated weight scheme: descending weights + consensus threshold.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightScheme {
    /// Descending weights; `weights[0]` is the leader's weight w₁.
    weights: Vec<f64>,
    /// Consensus threshold CT = Σw / 2.
    ct: f64,
    /// Failure threshold t the scheme was built for.
    t: usize,
    /// Geometric ratio used (1 for the all-ones Raft scheme).
    ratio: f64,
}

impl WeightScheme {
    /// Build the Cabinet geometric scheme for `(n, t)` (§4.1.1).
    pub fn geometric(n: usize, t: usize) -> Result<Self, WeightError> {
        Self::check_params(n, t)?;
        let (lo, hi) = ratio_bounds(n, t);
        let r = hi - RATIO_MARGIN * (hi - lo);
        Self::with_ratio(n, t, r)
    }

    /// Build a geometric scheme with an explicit ratio (validated).
    pub fn with_ratio(n: usize, t: usize, r: f64) -> Result<Self, WeightError> {
        Self::check_params(n, t)?;
        let weights: Vec<f64> = (0..n).map(|k| powr(r, (n - 1 - k) as f64)).collect();
        let ct = (powr(r, n as f64) - 1.0) / (2.0 * (r - 1.0));
        let ws = WeightScheme { weights, ct, t, ratio: r };
        ws.validate()?;
        Ok(ws)
    }

    /// The all-ones scheme conventional Raft uses (every node weighs 1,
    /// CT = n/2 so "weight > CT" ≡ "count ≥ ⌊n/2⌋+1").
    pub fn raft(n: usize) -> Result<Self, WeightError> {
        if n < 3 {
            return Err(WeightError::ClusterTooSmall(n));
        }
        let t = (n - 1) / 2;
        Ok(WeightScheme { weights: vec![1.0; n], ct: n as f64 / 2.0, t, ratio: 1.0 })
    }

    /// Construct from explicit weights (e.g. the Fig. 3 examples) and
    /// validate I1/I2 against CT = Σw/2.
    pub fn from_weights(mut weights: Vec<f64>, t: usize) -> Result<Self, WeightError> {
        let n = weights.len();
        Self::check_params(n, t)?;
        // total_cmp, not partial_cmp: a NaN weight must not panic here — it
        // sorts first (ranks highest) and flows into a NaN CT, which stalls
        // commits instead of crashing the sort (validate() passes NaN
        // vacuously, so this is reachable through the public API)
        weights.sort_by(|a, b| b.total_cmp(a));
        let ct = weights.iter().sum::<f64>() / 2.0;
        let ws = WeightScheme { weights, ct, t, ratio: f64::NAN };
        ws.validate()?;
        Ok(ws)
    }

    fn check_params(n: usize, t: usize) -> Result<(), WeightError> {
        if n < 3 {
            return Err(WeightError::ClusterTooSmall(n));
        }
        let max = (n - 1) / 2;
        if t < 1 || t > max {
            return Err(WeightError::ThresholdOutOfRange { n, t, max });
        }
        Ok(())
    }

    /// Check invariants I1 and I2 (Eq. 2).
    pub fn validate(&self) -> Result<(), WeightError> {
        let top_t: f64 = self.weights[..self.t].iter().sum();
        let top_t1: f64 = self.weights[..self.t + 1].iter().sum();
        if top_t1 <= self.ct {
            return Err(WeightError::InvariantViolated("I1"));
        }
        if top_t >= self.ct {
            return Err(WeightError::InvariantViolated("I2"));
        }
        Ok(())
    }

    pub fn n(&self) -> usize {
        self.weights.len()
    }
    pub fn t(&self) -> usize {
        self.t
    }
    pub fn ct(&self) -> f64 {
        self.ct
    }
    pub fn ratio(&self) -> f64 {
        self.ratio
    }
    /// Descending weight values (rank k → weight `w_{k+1}`).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
    /// Weight of rank `k` (0-based: rank 0 = highest = leader's).
    pub fn weight_of_rank(&self, k: usize) -> f64 {
        self.weights[k]
    }
    /// Cabinet size = t + 1 (the minimum weight quorum).
    pub fn cabinet_size(&self) -> usize {
        self.t + 1
    }

    /// Lemma 3.1: total weight of non-cabinet members (< CT by I1).
    pub fn non_cabinet_weight(&self) -> f64 {
        self.weights[self.t + 1..].iter().sum()
    }

    /// Lemma 3.2 worst case: total weight of the n−t lightest nodes.
    pub fn lightest_survivor_weight(&self) -> f64 {
        self.weights[self.t..].iter().sum()
    }

    /// The scheme's minimum weight (rank n−1) — the entry weight for a
    /// `Joining` member and the drain floor for a `Draining` one.
    pub fn min_weight(&self) -> f64 {
        *self.weights.last().expect("schemes are non-empty")
    }
}

impl fmt::Display for WeightScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "WS(n={}, t={}, r={:.4}, ct={:.3}, w=[",
            self.n(),
            self.t,
            self.ratio,
            self.ct
        )?;
        for (i, w) in self.weights.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{w:.3}")?;
        }
        write!(f, "])")
    }
}

/// `r^k` via exp(k·ln r) — the same formulation the L2 jax graph lowers to,
/// so the native and artifact solvers agree to ~1 ulp-chain.
#[inline]
pub fn powr(r: f64, k: f64) -> f64 {
    (k * r.ln()).exp()
}

/// CT numerator form from Eq. 4: (r^n + 1) / 2.
#[inline]
fn half_sum(r: f64, n: f64) -> f64 {
    (powr(r, n) + 1.0) / 2.0
}

/// Bisection mirroring `model._bisect`: root of `f` on [lo, hi] assuming
/// f(lo) ≤ 0 ≤ f(hi); returns `lo` when the whole interval is feasible.
fn bisect(f: impl Fn(f64) -> f64, lo: f64, hi: f64) -> f64 {
    if f(lo) > 0.0 {
        return lo;
    }
    let (mut a, mut b) = (lo, hi);
    for _ in 0..BISECT_ITERS {
        let m = 0.5 * (a + b);
        if f(m) <= 0.0 {
            a = m;
        } else {
            b = m;
        }
    }
    0.5 * (a + b)
}

/// Feasible ratio interval `(r_lower, r_upper)` for Eq. 4.
pub fn ratio_bounds(n: usize, t: usize) -> (f64, f64) {
    let nf = n as f64;
    let tf = t as f64;
    let lo = 1.0 + 1e-9;
    let hi = 2.0;
    let l_fn = |r: f64| half_sum(r, nf) - powr(r, nf - tf - 1.0);
    let u_fn = |r: f64| half_sum(r, nf) - powr(r, nf - tf);
    (bisect(l_fn, lo, hi), bisect(u_fn, lo, hi))
}

/// The paper's evaluation thresholds: t = pct% of n, clamped to [1, ⌊(n−1)/2⌋].
pub fn threshold_pct(n: usize, pct: usize) -> usize {
    ((n * pct) / 100).clamp(1, (n - 1).max(2) / 2)
}

// ---------------------------------------------------------------------------
// Intra-epoch weight floors — dynamic membership's drain/entry schedule
// ---------------------------------------------------------------------------

/// Weight cap for a `Draining` member, `remaining` re-deals before its
/// removal config is proposed out of a `total`-round drain window. Ramps
/// linearly from `w_start` (the weight it held when the drain began) down to
/// `w_floor` (the scheme minimum). A NaN or already-at-floor start collapses
/// to the floor immediately — drains never *raise* a weight.
pub fn drain_cap(w_floor: f64, w_start: f64, remaining: usize, total: usize) -> f64 {
    if total == 0 || !(w_start > w_floor) {
        return w_floor;
    }
    w_floor + (w_start - w_floor) * remaining as f64 / total as f64
}

/// Apply per-member weight caps (`floors` = `(slot, cap)` for each Joining /
/// Draining member) to a freshly dealt assignment, redistributing the shaved
/// excess by *waterfill* over the lightest uncapped members.
///
/// This is the consensus-free intra-epoch reassignment: no config entry is
/// replicated, the leader just deals the next round under the capped
/// weights. The weight-reassignment papers (PAPERS.md: "Efficient
/// Consensus-Free Weight Reassignment for Atomic Storage", "How Hard is
/// Asynchronous Weight Reassignment?") license exactly this — weights may
/// change freely between rounds provided (a) the total (and hence CT = Σ/2,
/// so any two quorums still intersect) is conserved, and (b) every
/// t-subset stays below CT so t failures cannot stall the system. Waterfill
/// raises only the lightest members toward a common level, so it perturbs
/// the heaviest-t sum as little as any redistribution can; both conditions
/// are checked as debug assertions below (skipped when a NaN weight is in
/// play — NaN assignments must degrade, not panic).
///
/// `assign` is the per-slot weight array (non-member slots hold exactly
/// 0.0 and are never donors or receivers — scheme weights are ≥ 1 so
/// `w > 0.0` distinguishes members). `t` is the failure threshold the
/// liveness bound is asserted against.
pub fn apply_weight_floors(assign: &mut [f64], floors: &[(usize, f64)], t: usize) {
    let total_before: f64 = assign.iter().sum();

    // Shave every capped member down to its cap.
    let mut excess = 0.0;
    for &(slot, cap) in floors {
        let w = assign[slot];
        if w.is_finite() && cap.is_finite() && w > cap {
            excess += w - cap;
            assign[slot] = cap;
        }
    }
    if excess <= 0.0 {
        return;
    }

    // Waterfill the excess over the finite, positive, uncapped slots.
    let mut idx: Vec<usize> = (0..assign.len())
        .filter(|&i| {
            assign[i].is_finite()
                && assign[i] > 0.0
                && !floors.iter().any(|&(s, _)| s == i)
        })
        .collect();
    if idx.is_empty() {
        // No receiver (degenerate: everyone floored) — hand the shave back
        // equally so the total stays conserved rather than silently
        // shrinking CT.
        let share = excess / floors.len() as f64;
        for &(slot, _) in floors {
            if assign[slot].is_finite() {
                assign[slot] += share;
            }
        }
        return;
    }
    idx.sort_by(|&a, &b| assign[a].total_cmp(&assign[b]));
    let mut level = assign[idx[0]];
    let mut pool = 1usize;
    let mut rem = excess;
    while pool < idx.len() {
        let next = assign[idx[pool]];
        let need = (next - level) * pool as f64;
        if need >= rem {
            break;
        }
        rem -= need;
        level = next;
        pool += 1;
    }
    level += rem / pool as f64;
    for &i in &idx[..pool] {
        assign[i] = level;
    }

    // The papers' bound, as debug assertions (NaN runs skip — comparisons
    // with NaN are false and would trip the asserts spuriously).
    if assign.iter().all(|w| w.is_finite()) {
        let total_after: f64 = assign.iter().sum();
        debug_assert!(
            (total_after - total_before).abs() <= 1e-9 * total_before.abs().max(1.0),
            "re-deal must conserve total weight: {total_before} -> {total_after}"
        );
        if t > 0 {
            let mut sorted: Vec<f64> = assign.iter().copied().collect();
            sorted.sort_by(|a, b| b.total_cmp(a));
            let top_t: f64 = sorted[..t.min(sorted.len())].iter().sum();
            let ct = total_after / 2.0;
            debug_assert!(
                top_t < ct,
                "heaviest-t must stay below CT after flooring (L3.2): {top_t} vs {ct}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_weight_does_not_panic_construction() {
        // regression: the descending sort used partial_cmp().unwrap(), so a
        // NaN weight panicked before validate() could even look at it. NaN
        // passes I1/I2 vacuously (every comparison is false), so the scheme
        // constructs — degenerate but non-crashing (its CT is NaN, which
        // stalls commits; the node-level sorts are total_cmp-safe too).
        let ws = WeightScheme::from_weights(vec![8.0, f64::NAN, 4.0, 2.0, 1.0], 1)
            .expect("vacuously valid");
        assert!(ws.weights()[0].is_nan(), "NaN ranks highest under total_cmp");
        assert!(ws.ct().is_nan());
    }

    #[test]
    fn fig4_ratios_match_paper() {
        // Fig. 4 (n=10): t=2→1.38, t=3→1.19, t=4→1.08 (±0.011); the paper's
        // t=1 row picked near the lower feasible edge instead (DESIGN.md §5).
        for (t, r_paper) in [(2, 1.38), (3, 1.19), (4, 1.08)] {
            let ws = WeightScheme::geometric(10, t).unwrap();
            assert!(
                (ws.ratio() - r_paper).abs() < 0.011,
                "t={t}: r={} vs paper {r_paper}",
                ws.ratio()
            );
        }
    }

    #[test]
    fn fig4_paper_ratios_feasible() {
        for (t, r_paper) in [(1, 1.40), (2, 1.38), (3, 1.19), (4, 1.08)] {
            let (lo, hi) = ratio_bounds(10, t);
            assert!(lo < r_paper && r_paper < hi, "t={t} bounds=({lo},{hi})");
            WeightScheme::with_ratio(10, t, r_paper).unwrap();
        }
    }

    #[test]
    fn fig4_weight_values_t1() {
        // Fig. 4 row t=1: 20.7, 14.8, 10.5, … 1.4, 1 for r=1.40.
        let ws = WeightScheme::with_ratio(10, 1, 1.40).unwrap();
        let expect = [20.7, 14.8, 10.5, 7.5, 5.4, 3.8, 2.7, 2.0, 1.4, 1.0];
        for (w, e) in ws.weights().iter().zip(expect) {
            assert!((w - e).abs() < 0.1, "w={w} e={e}");
        }
    }

    #[test]
    fn fig3_ws1_violates_safety() {
        // WS₁ = 1..7 with CT=8: two disjoint groups can exceed CT.
        // Our validator rejects it because I1 fails for CT = Σw/2 = 14:
        // sum of top 3 (18) > 14 ✓ but I2: top 2 = 13 < 14 ✓ — with the
        // papers' *chosen* CT=8 the scheme double-decides; from_weights
        // normalizes CT to Σw/2, under which the t=2 scheme is actually
        // valid. The safety violation of the paper's CT=8 choice is what we
        // check here.
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let ct_paper = 8.0;
        // two disjoint sets both exceeding the paper's CT ⇒ safety violation
        let a: f64 = 6.0 + 7.0;
        let b: f64 = 2.0 + 3.0 + 4.0;
        assert!(a > ct_paper && b > ct_paper);
        assert!(a + b <= w.iter().sum::<f64>());
    }

    #[test]
    fn fig3_ws2_violates_liveness() {
        // WS₂ = 10^i with CT = Σ/2: losing just n₇ (t=2 should tolerate 2)
        // stalls the system — I2 fails. from_weights must reject it.
        let w = vec![1.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6];
        let err = WeightScheme::from_weights(w, 2).unwrap_err();
        assert_eq!(err, WeightError::InvariantViolated("I2"));
    }

    #[test]
    fn fig3_ws3_is_valid() {
        // WS₃ = 2,3,4,6,8,10,12 with CT = 22.5 upholds both invariants.
        let ws =
            WeightScheme::from_weights(vec![2.0, 3.0, 4.0, 6.0, 8.0, 10.0, 12.0], 2)
                .unwrap();
        assert!((ws.ct() - 22.5).abs() < 1e-12);
        ws.validate().unwrap();
        // fast agreement: cabinet = {12, 10, 8} > 22.5
        assert!(12.0 + 10.0 + 8.0 > ws.ct());
        // non-cabinet members cannot decide: 6+4+3+2 < 22.5
        assert!(ws.non_cabinet_weight() < ws.ct());
        // tolerates 2 failures: Σ minus top-2 > CT
        assert!(ws.lightest_survivor_weight() > ws.ct());
    }

    #[test]
    fn invariants_hold_across_n_t() {
        for n in 3..=128 {
            for t in 1..=(n - 1) / 2 {
                let ws = WeightScheme::geometric(n, t)
                    .unwrap_or_else(|e| panic!("n={n} t={t}: {e}"));
                ws.validate().unwrap();
                assert!(ws.ratio() > 1.0 && ws.ratio() < 2.0);
                // strictly descending
                for w in ws.weights().windows(2) {
                    assert!(w[0] > w[1], "n={n} t={t}");
                }
            }
        }
    }

    #[test]
    fn raft_scheme_is_majority() {
        let ws = WeightScheme::raft(7).unwrap();
        assert_eq!(ws.ct(), 3.5);
        // 4 repliers (count > n/2) pass, 3 do not
        assert!(4.0 > ws.ct());
        assert!(3.0 < ws.ct());
    }

    #[test]
    fn rejects_bad_params() {
        assert!(matches!(
            WeightScheme::geometric(2, 1),
            Err(WeightError::ClusterTooSmall(2))
        ));
        assert!(matches!(
            WeightScheme::geometric(10, 0),
            Err(WeightError::ThresholdOutOfRange { .. })
        ));
        assert!(matches!(
            WeightScheme::geometric(10, 5),
            Err(WeightError::ThresholdOutOfRange { .. })
        ));
    }

    #[test]
    fn threshold_pct_matches_eval_notation() {
        // "cab f10% under n=50 means t=5" (§5 notation).
        assert_eq!(threshold_pct(50, 10), 5);
        assert_eq!(threshold_pct(50, 20), 10);
        assert_eq!(threshold_pct(50, 40), 20);
        assert_eq!(threshold_pct(100, 40), 40);
        // clamps: t ≥ 1 and t ≤ (n−1)/2
        assert_eq!(threshold_pct(3, 10), 1);
        assert_eq!(threshold_pct(11, 40), 4);
    }

    #[test]
    fn lemma_3_1_and_3_2_sampled() {
        for (n, t) in [(7, 2), (10, 3), (20, 4), (50, 5), (100, 10), (100, 40)] {
            let ws = WeightScheme::geometric(n, t).unwrap();
            assert!(ws.non_cabinet_weight() < ws.ct(), "L3.1 n={n} t={t}");
            assert!(ws.lightest_survivor_weight() > ws.ct(), "L3.2 n={n} t={t}");
        }
    }

    // ---- drain/entry schedule (dynamic membership) -----------------------

    /// Deal the scheme over `n` slots by rank permutation: slot `perm[k]`
    /// gets rank k's weight. `perm` is a deterministic rotation so every
    /// slot cycles through every rank across test iterations.
    fn deal(ws: &WeightScheme, rot: usize) -> Vec<f64> {
        let n = ws.n();
        let mut assign = vec![0.0; n];
        for k in 0..n {
            assign[(k + rot) % n] = ws.weight_of_rank(k);
        }
        assign
    }

    #[test]
    fn floors_conserve_total_weight() {
        for (n, t) in [(5usize, 1usize), (7, 2), (9, 3), (11, 4)] {
            let ws = WeightScheme::geometric(n, t).unwrap();
            let total: f64 = ws.weights().iter().sum();
            for rot in 0..n {
                for floored in 0..n {
                    let mut assign = deal(&ws, rot);
                    apply_weight_floors(
                        &mut assign,
                        &[(floored, ws.min_weight())],
                        t,
                    );
                    let after: f64 = assign.iter().sum();
                    assert!(
                        (after - total).abs() < 1e-9 * total,
                        "n={n} t={t} rot={rot} floored={floored}: {total} -> {after}"
                    );
                }
            }
        }
    }

    #[test]
    fn floors_pin_joining_and_draining_members_at_the_cap() {
        let ws = WeightScheme::geometric(7, 2).unwrap();
        let floor = ws.min_weight();
        for rot in 0..7 {
            let mut assign = deal(&ws, rot);
            // slot 3 joining (cap = floor), slot 5 draining mid-ramp
            let mid = drain_cap(floor, assign[5], 2, 4);
            let caps = [(3, floor), (5, mid)];
            let before3 = assign[3];
            let before5 = assign[5];
            apply_weight_floors(&mut assign, &caps, 2);
            assert!(
                assign[3] <= before3.min(floor) + 1e-12,
                "joining member capped at the scheme minimum"
            );
            assert!(assign[5] <= before5.min(mid.max(floor)) + 1e-12);
            // caps never raise a weight
            assert!(assign[3] <= before3 + 1e-12 && assign[5] <= before5 + 1e-12);
        }
    }

    #[test]
    fn heaviest_t_stays_below_ct_across_every_redeal_and_mid_drain() {
        // L3.2 / the reassignment papers' liveness bound: for every rank
        // rotation and every step of the drain ramp — including draining the
        // *heaviest* member from full weight — the heaviest t members sum to
        // less than CT, so any t failures leave a live quorum.
        for (n, t) in [(5usize, 2usize), (7, 2), (9, 4), (11, 3)] {
            let ws = WeightScheme::geometric(n, t).unwrap();
            let total: f64 = ws.weights().iter().sum();
            let ct = total / 2.0;
            let drain_rounds = 4;
            for rot in 0..n {
                for victim in 0..n {
                    let w_start = deal(&ws, rot)[victim];
                    for remaining in (0..=drain_rounds).rev() {
                        let mut assign = deal(&ws, rot);
                        let cap =
                            drain_cap(ws.min_weight(), w_start, remaining, drain_rounds);
                        apply_weight_floors(&mut assign, &[(victim, cap)], t);
                        let mut sorted = assign.clone();
                        sorted.sort_by(|a, b| b.total_cmp(a));
                        let top_t: f64 = sorted[..t].iter().sum();
                        assert!(
                            top_t < ct,
                            "n={n} t={t} rot={rot} victim={victim} rem={remaining}: \
                             top_t={top_t} ct={ct}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn drain_cap_ramps_monotonically_to_the_floor() {
        let (floor, start) = (1.0, 2.5);
        let mut prev = f64::INFINITY;
        for remaining in (0..=6).rev() {
            let c = drain_cap(floor, start, remaining, 6);
            assert!(c <= prev + 1e-12, "ramp is non-increasing");
            assert!(c >= floor - 1e-12 && c <= start + 1e-12);
            prev = c;
        }
        assert_eq!(drain_cap(floor, start, 0, 6), floor);
        assert_eq!(drain_cap(floor, start, 6, 6), start);
        // degenerate inputs collapse to the floor instead of misbehaving
        assert_eq!(drain_cap(floor, f64::NAN, 3, 6), floor);
        assert_eq!(drain_cap(floor, 0.5, 3, 6), floor);
        assert_eq!(drain_cap(floor, start, 3, 0), floor);
    }

    #[test]
    fn nan_weight_member_survives_join_and_leave_floors() {
        // A NaN weight must degrade (skipped by the waterfill, asserts
        // muted), never panic — mirrors the node-level NaN regression tests.
        let mut assign = vec![2.0, f64::NAN, 1.4, 1.2, 1.0];
        apply_weight_floors(&mut assign, &[(4, 1.0), (0, 1.5)], 2);
        assert!(assign[1].is_nan(), "NaN member untouched");
        assert!(assign[0] <= 1.5 + 1e-12, "finite members still capped");
        // NaN *cap* (drain of a NaN-weight member) is likewise a no-op
        let mut assign = vec![2.0, f64::NAN, 1.4, 1.2, 1.0];
        apply_weight_floors(&mut assign, &[(1, f64::NAN)], 2);
        assert!(assign[1].is_nan());
        assert_eq!(assign[0], 2.0);
        // all-floored degenerate case conserves the total
        let mut assign = vec![2.0, 1.0];
        apply_weight_floors(&mut assign, &[(0, 1.0), (1, 0.5)], 0);
        let total: f64 = assign.iter().sum();
        assert!((total - 3.0).abs() < 1e-12);
    }
}
