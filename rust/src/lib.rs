//! Cabinet: dynamically weighted consensus made fast.
//!
//! Full-system reproduction of "Cabinet: Dynamically Weighted Consensus Made
//! Fast" (Zhang et al., 2025). Layer-3 Rust coordinator implementing Raft,
//! Cabinet weighted consensus, and an HQC baseline over both a deterministic
//! discrete-event simulator and a live tokio runtime; Layer-2/1 JAX + Pallas
//! state-machine kernels AOT-compiled to HLO and executed via PJRT.

pub mod config;
pub mod consensus;
pub mod net;
pub mod sim;
pub mod live;
pub mod storage;
pub mod workload;
pub mod bench;
pub mod runtime;
