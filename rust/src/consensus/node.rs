//! The sans-io consensus state machine: stock Raft, with Cabinet's weighted
//! consensus layered on via `Mode::Cabinet` (Algorithm 1).
//!
//! The node never touches a clock or a socket: inputs are delivered RPCs,
//! fired timers and client proposals; outputs are RPCs to send, timer
//! (re)arms and committed entries. Both the deterministic simulator
//! (`sim::`) and the live std-thread runtime (`live::`) drive this same
//! type, and the property tests in `rust/tests/` drive it with adversarial
//! schedules directly.
//!
//! Cabinet differences from Raft (and nothing else — §4.1.2 "Cabinet does
//! not intervene in the original consensus tasks"):
//!   * AppendEntries carries `(wclock, weight)`;
//!   * the leader accumulates *weights* of repliers (itself included)
//!     against `CT = Σw/2` instead of counting a majority;
//!   * replies are FIFO-ranked per round and the weight multiset is
//!     re-dealt for the next round (fastest → highest);
//!   * elections need `n − t` votes instead of a majority (§4.1.3);
//!   * the failure threshold can be reconfigured at runtime (§4.1.4).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::consensus::coding;
use crate::consensus::log::Log;
use crate::consensus::message::{
    AppState, ClusterConfig, Entry, LogIndex, MemberSpec, MemberState, Message, NodeId,
    Payload, SnapshotBlob, Term, WClock,
};
use crate::consensus::weights::{apply_weight_floors, drain_cap, WeightScheme};

/// Raft role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

/// Quorum mode: conventional Raft or Cabinet weighted consensus.
#[derive(Clone, Debug)]
pub enum Mode {
    Raft,
    Cabinet { scheme: WeightScheme },
}

impl Mode {
    pub fn cabinet(n: usize, t: usize) -> Self {
        Mode::Cabinet { scheme: WeightScheme::geometric(n, t).expect("valid (n, t)") }
    }

    pub fn is_cabinet(&self) -> bool {
        matches!(self, Mode::Cabinet { .. })
    }

    /// Votes required to win an election: majority for Raft, n − t for
    /// Cabinet (§4.1.3).
    pub fn election_quorum(&self, n: usize) -> usize {
        match self {
            Mode::Raft => n / 2 + 1,
            Mode::Cabinet { scheme } => n - scheme.t(),
        }
    }
}

/// Which path serves linearizable reads (Raft §6.4, weighted per Cabinet).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReadPath {
    /// Replicate every read through the log like a write — the historical
    /// behavior, and the only mode with no extra protocol machinery.
    #[default]
    Log,
    /// ReadIndex: the leader records its commit index for the read and
    /// confirms it still leads by collecting probe acks whose *weight*
    /// exceeds CT (Cabinet's quorum rule — fast heavy nodes confirm reads
    /// as quickly as they commit writes). Safe under full asynchrony.
    ReadIndex,
    /// Leader leases: while a weighted-quorum-granted lease (bounded by the
    /// minimum election timeout minus a clock-drift margin) is held, reads
    /// are served locally with no confirmation round at all. An expired
    /// lease falls back to ReadIndex. Relies on the §6.4.1 timing
    /// assumption, enforced here by lease-mode vote stickiness.
    Lease,
}

impl ReadPath {
    pub fn name(self) -> &'static str {
        match self {
            ReadPath::Log => "log",
            ReadPath::ReadIndex => "readindex",
            ReadPath::Lease => "lease",
        }
    }

    pub fn from_name(s: &str) -> Option<ReadPath> {
        match s {
            "log" => Some(ReadPath::Log),
            "readindex" => Some(ReadPath::ReadIndex),
            "lease" => Some(ReadPath::Lease),
            _ => None,
        }
    }
}

/// Inputs to the state machine.
#[derive(Clone, Debug)]
pub enum Input {
    /// The randomized election timer fired.
    ElectionTimeout,
    /// The leader heartbeat tick fired.
    HeartbeatTimeout,
    /// An RPC arrived.
    Receive(NodeId, Message),
    /// A client proposal arrived (leader only; otherwise ignored + reported).
    Propose(Payload),
    /// A client read arrived (non-log read paths only). Leaders serve it via
    /// the configured fast path; followers forward it to their leader and
    /// serve locally once granted.
    Read { id: u64 },
    /// An administrative membership command (leader only; ignored elsewhere —
    /// drivers re-target the current leader). Commands serialize: one
    /// membership operation runs to completion before the next starts.
    Admin(AdminCmd),
}

/// Administrative membership commands. `Replace` is driver-level sugar for
/// `Join(new)` followed by `Leave(old)` — the node itself only ever sees the
/// two primitives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdminCmd {
    /// Add a node: joint consensus admits it in the `Joining` state at
    /// minimum weight; it earns full weight through the responsiveness clock
    /// after a warmup of acked rounds promotes it to `Active`.
    Join(NodeId),
    /// Remove a node: its weight drains to the minimum over `drain_rounds`
    /// re-deals, then joint consensus removes it.
    Leave(NodeId),
}

/// Outputs produced by a step.
#[derive(Clone, Debug)]
pub enum Output {
    /// Send an RPC to a peer.
    Send(NodeId, Message),
    /// (Re)arm the randomized election timer.
    ResetElectionTimer,
    /// Start (or keep) the periodic heartbeat timer — leader only.
    StartHeartbeat,
    /// Stop the heartbeat timer (stepped down).
    StopHeartbeat,
    /// An entry is newly committed (delivered in index order).
    Commit(Entry),
    /// Leader metrics hook: a replication round reached quorum. `epoch`,
    /// `ct`, and `joint` carry the round's propose-time config evidence for
    /// the cross-epoch safety checker: the accumulated weight exceeded `ct`
    /// in the current config, and — when the round was proposed under a
    /// joint config — `joint = (acc_old, ct_old)` shows the old half's rule
    /// held too.
    RoundCommitted {
        wclock: WClock,
        index: LogIndex,
        repliers: usize,
        quorum_weight: f64,
        epoch: u64,
        ct: f64,
        joint: Option<(f64, f64)>,
        /// `(distinct acked shards, k)` when the round's entry shipped
        /// coded — reconstruction evidence for the safety checker (the
        /// commit rule requires `distinct >= k`). `None` for full-copy
        /// rounds, i.e. every coded-off run.
        coded: Option<(u32, u32)>,
    },
    /// A `ConfigChange` entry committed on this node (any role). Drivers use
    /// it to retire removed nodes and to record the config-epoch trajectory
    /// for the safety checker.
    ConfigCommitted { epoch: u64, index: LogIndex, joint: bool, voters: Vec<NodeId> },
    /// Role transitions (metrics / logging). The term is carried so drivers
    /// can record per-term leadership (the safety checker's
    /// single-leader-per-term property) without reaching into the node.
    BecameLeader { term: Term },
    SteppedDown,
    /// A proposal was rejected (not leader / reconfig in flight).
    ProposalRejected(Payload),
    /// Driver-capture handshake (`SnapshotCapture::Driver`): the snapshot
    /// threshold was crossed — capture replica state through `through` and
    /// answer with [`Node::complete_snapshot`].
    SnapshotRequest { through: LogIndex },
    /// A leader snapshot was installed over the local log; the driver must
    /// restore the carried replica state before applying later commits.
    SnapshotInstalled(SnapshotBlob),
    /// A linearizable read is safe to serve from local applied state at
    /// `index` — ReadIndex confirmed, lease held (`lease = true`), or
    /// granted by the leader and now applied locally.
    ReadReady { id: u64, index: LogIndex, lease: bool },
    /// A read could not be served here (no leader known, leadership lost
    /// mid-confirmation, or no committed term barrier yet) — retry.
    ReadFailed { id: u64 },
    /// Durable mode only ([`Node::set_durable`]): `HardState{term,
    /// voted_for}` changed. The driver must make it durable **before**
    /// releasing any `Send` later in this step's output batch — a vote or
    /// term adoption must never outrun its own durability (Raft §5.1), or
    /// a restart re-grants the same term to a second candidate.
    PersistHardState { term: Term, voted_for: Option<NodeId> },
    /// Durable mode only: `entries` were appended after `prev_index` (a
    /// follower splice or a leader self-append); `weight` is this node's
    /// stored weight for the shipping round. Persist before releasing the
    /// acknowledging `Send`s that follow in the batch.
    PersistEntries { prev_index: LogIndex, weight: f64, entries: Vec<Entry> },
}

/// How a node obtains the replica-state payload when it takes a snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotCapture {
    /// Compact immediately with `AppState::None` — for drivers that track
    /// replica state outside the node (the simulator, unit tests).
    Inline,
    /// Emit [`Output::SnapshotRequest`] and wait for the driver to call
    /// [`Node::complete_snapshot`] with captured state (the live runtime's
    /// applier thread — capture must not stall the consensus thread).
    Driver,
}

/// Leader-side bookkeeping for one in-flight replication round (pipelined
/// replication): the weight every node held when the round's entry was
/// proposed, who has acknowledged it, and the accumulated weight against the
/// round's own threshold. Snapshotting weights and CT at propose time keeps
/// each round's quorum rule stable even when weights are re-dealt — or the
/// scheme reconfigured — while the round is still in flight.
#[derive(Clone, Debug)]
struct InflightRound {
    index: LogIndex,
    wclock: WClock,
    /// Propose-time weight assignment (all-ones in Raft mode).
    weights: Vec<f64>,
    /// Commit threshold captured at propose time.
    ct: f64,
    /// Per-node ack flags, leader pre-acked.
    acked: Vec<bool>,
    /// Accumulated weight of ackers (leader included).
    acc_weight: f64,
    /// Config epoch the round was proposed under (checker evidence).
    epoch: u64,
    /// Joint-config old-half accumulator: while C_old,new is in force a
    /// round commits only when the weighted rule holds in *both* halves.
    /// Snapshotted at propose time like `weights`/`ct`.
    joint: Option<JointAcc>,
    /// Shard-ack accumulator when this round's entry ships coded (None for
    /// full-copy entries — every historical round).
    coded: Option<CodedAcc>,
}

/// Shard-ack accumulator for one coded round. The leader keeps the full
/// payload and never occupies a shard slot; bit `s` of `acked_shards` is
/// set once any follower assigned shard `s` acks the round. The round's
/// commit rule gains the conjunct `distinct() >= k` — the acked shard set
/// must reconstruct the entry (any k of the k+1 XOR shards do).
#[derive(Clone, Copy, Debug)]
struct CodedAcc {
    k: u32,
    /// Total shards (k + 1).
    m: u32,
    /// Bitmask over shard ids 0..m.
    acked_shards: u64,
}

impl CodedAcc {
    fn distinct(&self) -> u32 {
        self.acked_shards.count_ones()
    }
    fn reconstructs(&self) -> bool {
        self.distinct() >= self.k
    }
}

/// Old-half quorum accumulator for one round proposed under a joint config.
#[derive(Clone, Debug)]
struct JointAcc {
    /// Old-config weight of every slot (0.0 for nodes outside C_old).
    weights: Vec<f64>,
    ct: f64,
    acc: f64,
}

/// Leader-local state machine for the single membership operation in flight
/// (operations serialize through `admin_queue`). The config *entries* are
/// replicated; this overlay — drain ramps, warmup counters — is deliberately
/// leader-local: per the consensus-free weight-reassignment results
/// (PAPERS.md), intra-epoch weight caps need no consensus round, and a new
/// leader reconstructs the phase from the committed config's member states.
#[derive(Clone, Copy, Debug, PartialEq)]
enum AdminPhase {
    /// Leave: waiting for the Draining-mark config entry to commit.
    MarkDraining(NodeId),
    /// Leave: the drain ramp is running — `remaining` re-deals left before
    /// the node reaches the weight floor and removal is proposed.
    Draining { node: NodeId, remaining: usize, w_start: f64 },
    /// The C_old,new entry is in flight / committed; next step proposes the
    /// C_new entry that leaves the joint phase.
    Joint,
    /// Waiting for the C_new (leave-joint) entry to commit.
    Leaving,
    /// Join: the member is in, still `Joining` at minimum weight; counting
    /// acked rounds until promotion.
    Warmup { node: NodeId, acks: u64 },
    /// Join: waiting for the `Active`-promotion config entry to commit.
    Promoting(NodeId),
}

/// Leader-side bookkeeping for one ReadIndex leadership-confirmation round:
/// the commit index the round's reads observe, the probe weights/CT
/// snapshotted like a replication round, and the reads riding on it. An
/// empty `reads` vec is a lease-renewal round.
#[derive(Clone, Debug)]
struct ReadConfirm {
    seq: u64,
    /// Driver time the probe round was first sent — lease extensions are
    /// measured from here, so retransmits can only be conservative.
    sent_at_ms: f64,
    read_index: LogIndex,
    /// (request id, origin node); origin == self for local reads.
    reads: Vec<(u64, NodeId)>,
    weights: Vec<f64>,
    acked: Vec<bool>,
    acc_weight: f64,
    ct: f64,
    /// Old-half accumulator when the probe round opened under a joint
    /// config — leadership confirmation needs both halves, like commits.
    joint: Option<JointAcc>,
}

/// The consensus node.
#[derive(Clone, Debug)]
pub struct Node {
    id: NodeId,
    n: usize,
    mode: Mode,
    role: Role,
    term: Term,
    voted_for: Option<NodeId>,
    log: Log,
    commit_index: LogIndex,

    // ---- follower weight state (Algorithm 1, Lines 29–31) ----
    my_weight: f64,
    my_wclock: WClock,

    // ---- candidate state ----
    votes: Vec<bool>,

    // ---- PreVote state (Raft §9.6, Cabinet n − t quorum) -----------------
    /// PreVote enabled: an election timeout first runs a non-disruptive
    /// pre-campaign at term + 1; only a full election quorum of pre-grants
    /// starts a real (term-incrementing) candidacy. A partitioned minority
    /// can therefore never inflate its terms, so healing it cannot depose a
    /// working cabinet.
    pre_vote: bool,
    /// A pre-campaign for `term + 1` is in flight.
    prevote_active: bool,
    /// Pre-grants collected (self pre-granted).
    prevotes: Vec<bool>,
    /// Leader contact since our own last election timeout. The sans-io
    /// stand-in for §9.6's "heard from a leader within the minimum election
    /// timeout": an `ElectionTimeout` input *is* the statement that a full
    /// timeout passed without contact. While true, PreVote probes are
    /// denied — a healthy cabinet cannot be pre-voted out from under a
    /// working leader even by an up-to-date disruptor.
    heard_from_leader: bool,
    /// Real (term-incrementing) candidacies this node has started.
    elections_started: u64,

    // ---- leader state ----
    next_index: Vec<LogIndex>,
    match_index: Vec<LogIndex>,
    /// Cabinet weight clock (increments per replication round).
    wclock: WClock,
    /// Current weight of every node under `wclock` (leader's view).
    weight_assign: Vec<f64>,
    /// FIFO reply queue (wQ) for the current round: node ids in arrival order.
    reply_order: Vec<NodeId>,
    replied: Vec<bool>,
    /// In-flight replication rounds in ascending index order (pipelining):
    /// every entry this leader proposed in its current term that has not
    /// committed yet. Per-round weight/CT snapshots make commit advancement
    /// tolerant of out-of-order quorum formation across the window.
    inflight: VecDeque<InflightRound>,
    /// Reconfiguration in flight (§4.1.4): the C′ entry's log index. The
    /// leader already operates under the new scheme (the paper requires the
    /// C′ round to reach consensus under the *new* WS); this marker only
    /// blocks further proposals until the transition commits.
    pending_reconfig: Option<LogIndex>,
    /// Ablation switch (Property P2): when true, weights stay at their
    /// initial assignment instead of being re-dealt by responsiveness.
    static_weights: bool,
    /// Coded replication (leader side): `(k, cutover_bytes)` — entries
    /// whose payload wire size reaches the cutover ship as k-of-(k+1)
    /// shards instead of full copies. `None` (default) keeps every
    /// historical code path bit-for-bit.
    coding: Option<(u32, u64)>,

    // ---- dynamic membership (joint consensus + weight lifecycle) ---------
    /// Current cluster config — effective from the moment its entry is
    /// appended (leader: proposed). `n` stays the *slot* count; the config
    /// says which slots are members and in what lifecycle state.
    config: Arc<ClusterConfig>,
    /// The config this node booted with — the fallback when every config
    /// entry has been truncated out of the log again.
    boot_config: Arc<ClusterConfig>,
    /// Fast path: true while `config` is the full-slot bootstrap config.
    /// Every membership branch is gated on this, so membership-off runs
    /// execute the exact historical code path (bit-identical replays).
    cfg_boot: bool,
    /// Leader: log index of the config entry whose commit we await. Blocks
    /// further config proposals (never client proposals) until it commits.
    pending_config: Option<LogIndex>,
    /// Leader: admin commands queued behind the operation in flight.
    admin_queue: VecDeque<AdminCmd>,
    /// Leader: phase of the membership operation in flight.
    active_op: Option<AdminPhase>,
    /// Leader: old-half weight assignment + CT while the config is joint
    /// (None outside the joint phase). Rebuilt on config adoption; rounds
    /// snapshot it like `weight_assign`.
    joint_assign: Option<(Vec<f64>, f64)>,
    /// Re-deals a leaving node's weight ramps over before removal.
    drain_rounds: usize,
    /// Rounds a Joining member must ack before promotion to Active.
    join_warmup: u64,
    /// Config entries committed on this node (metrics).
    config_commits: u64,

    // ---- snapshot / compaction state -------------------------------------
    /// Take a snapshot (and compact the log prefix) every this many
    /// committed entries. None = never compact (unbounded log).
    snapshot_every: Option<u64>,
    /// How snapshot state is captured (inline vs by the driving runtime).
    snapshot_capture: SnapshotCapture,
    /// Driver-mode handshake: a `SnapshotRequest` is outstanding through
    /// this index (suppresses duplicate requests while capture is pending).
    snapshot_pending: Option<LogIndex>,
    /// Latest completed snapshot — retained to serve `InstallSnapshot` to
    /// followers whose next entry fell behind the compaction point.
    snapshot: Option<SnapshotBlob>,
    snapshots_taken: u64,
    snapshots_installed: u64,

    // ---- linearizable read path ------------------------------------------
    /// Which fast path serves reads. `Log` (default) leaves every historical
    /// code path untouched — `Input::Read` is then rejected outright.
    read_path: ReadPath,
    /// Driver-supplied monotone clock (ms). The node never reads a real
    /// clock; drivers call [`Node::observe_time`] before stepping. Dead
    /// state on the log path.
    now_ms: f64,
    /// Lease length one confirmed probe round grants (driver sets this to
    /// `election_timeout_min − lease_drift`).
    lease_duration_ms: f64,
    /// Leader lease expiry on the driver clock; 0 = no lease held.
    lease_until_ms: f64,
    /// Next ReadIndex probe round id.
    read_seq: u64,
    /// Outstanding leadership-confirmation rounds (leader only).
    pending_confirm: Vec<ReadConfirm>,
    /// Follower: granted reads waiting for local apply (commit < read_index).
    waiting_grants: Vec<(u64, LogIndex)>,
    /// Follower: last known leader — the forwarding target for reads.
    leader_hint: Option<NodeId>,
    /// Index of this term's no-op barrier. ReadIndex is only valid once it
    /// commits (before that the leader's commit index may trail entries the
    /// previous term already committed — Raft §6.4 step 1).
    barrier_index: LogIndex,
    /// Reads this node served via the lease fast path (no probe round).
    lease_reads: u64,
    /// ReadIndex confirmation rounds this node closed as leader.
    readindex_rounds: u64,

    // ---- durability (WAL-backed drivers) ---------------------------------
    /// When true the node emits [`Output::PersistHardState`] /
    /// [`Output::PersistEntries`] and the driver must complete them before
    /// releasing any `Send` that follows in the same output batch
    /// (persist-before-reply). Off by default — the historical in-memory
    /// behavior, bit-identical outputs.
    durable: bool,
}

impl Node {
    pub fn new(id: NodeId, n: usize, mode: Mode) -> Self {
        assert!(id < n && n >= 3);
        let weight_assign = initial_assignment(id, n, &mode);
        let boot = Arc::new(ClusterConfig::bootstrap(n));
        Node {
            id,
            n,
            mode,
            role: Role::Follower,
            term: 0,
            voted_for: None,
            log: Log::new(),
            commit_index: 0,
            my_weight: 1.0,
            my_wclock: 0,
            votes: vec![false; n],
            pre_vote: false,
            prevote_active: false,
            prevotes: vec![false; n],
            heard_from_leader: false,
            elections_started: 0,
            next_index: vec![1; n],
            match_index: vec![0; n],
            wclock: 0,
            weight_assign,
            reply_order: Vec::with_capacity(n),
            replied: vec![false; n],
            inflight: VecDeque::new(),
            pending_reconfig: None,
            static_weights: false,
            coding: None,
            config: Arc::clone(&boot),
            boot_config: boot,
            cfg_boot: true,
            pending_config: None,
            admin_queue: VecDeque::new(),
            active_op: None,
            joint_assign: None,
            drain_rounds: 4,
            join_warmup: 4,
            config_commits: 0,
            snapshot_every: None,
            snapshot_capture: SnapshotCapture::Inline,
            snapshot_pending: None,
            snapshot: None,
            snapshots_taken: 0,
            snapshots_installed: 0,
            read_path: ReadPath::Log,
            now_ms: 0.0,
            lease_duration_ms: 0.0,
            lease_until_ms: 0.0,
            read_seq: 0,
            pending_confirm: Vec::new(),
            waiting_grants: Vec::new(),
            leader_hint: None,
            barrier_index: 0,
            lease_reads: 0,
            readindex_rounds: 0,
            durable: false,
        }
    }

    /// Disable dynamic weight reassignment (the P2 ablation: weighted
    /// quorums with a frozen initial weight assignment).
    pub fn set_static_weights(&mut self, on: bool) {
        self.static_weights = on;
    }

    /// Enable snapshotting: compact the log prefix every `every` committed
    /// entries (None disables compaction — the seed behavior).
    pub fn set_snapshot_every(&mut self, every: Option<u64>) {
        debug_assert!(every.map_or(true, |e| e >= 1));
        self.snapshot_every = every;
    }

    /// Select how snapshot replica state is captured (default: `Inline`).
    pub fn set_snapshot_capture(&mut self, capture: SnapshotCapture) {
        self.snapshot_capture = capture;
    }

    /// Enable PreVote (Raft §9.6, adapted to Cabinet's n − t election
    /// quorum). Off by default — the historical election behavior.
    pub fn set_pre_vote(&mut self, on: bool) {
        self.pre_vote = on;
    }

    /// Select the linearizable read path (default: [`ReadPath::Log`], which
    /// leaves every historical code path untouched).
    pub fn set_read_path(&mut self, path: ReadPath) {
        self.read_path = path;
    }

    /// Enable payload-adaptive coded replication: an entry whose payload
    /// wire size reaches `cutover_bytes` is shipped to each follower as
    /// its assigned shard (k data shards + 1 XOR parity, any k
    /// reconstruct) inside `Message::AppendEntriesShard`, and the round
    /// commits only when acked weight clears CT **and** the acked shard
    /// set covers at least k distinct shards. Entries below the cutover —
    /// and every entry when this is `None` (the default) — keep the
    /// full-copy path bit-for-bit.
    pub fn set_coding(&mut self, coding: Option<(u32, u64)>) {
        debug_assert!(coding.map_or(true, |(k, _)| k >= 2 && (k as usize) + 1 <= self.n - 1));
        self.coding = coding;
    }

    /// Enable durable (WAL-backed) mode: the node emits
    /// [`Output::PersistHardState`] / [`Output::PersistEntries`] and the
    /// driver must complete each before releasing any `Send` that follows
    /// it in the same output batch (persist-before-reply). Off by default —
    /// the historical in-memory behavior with bit-identical outputs.
    pub fn set_durable(&mut self, on: bool) {
        self.durable = on;
    }

    fn emit_hard_state(&mut self, out: &mut Vec<Output>) {
        if self.durable {
            out.push(Output::PersistHardState { term: self.term, voted_for: self.voted_for });
        }
    }

    // ---- restart recovery (WAL replay) -----------------------------------
    //
    // The restore_* methods rebuild a freshly constructed node from its
    // recovered WAL, in order: hard state, then the snapshot (if any), then
    // every splice record oldest-first. They write nothing back to the WAL
    // and emit no outputs — recovery is silent; the node re-enters the
    // cluster as a follower and catches up through the normal protocol.

    /// Adopt the durable `HardState{term, voted_for}`. Must run on a fresh
    /// node, before any step — this is what closes the restart-amnesia
    /// double-vote window.
    pub fn restore_hard_state(&mut self, term: Term, voted_for: Option<NodeId>) {
        debug_assert!(self.term == 0 && self.log.last_index() == 0, "restore on a fresh node");
        self.term = term;
        self.voted_for = voted_for;
    }

    /// Adopt a durable snapshot — the same state transition an incoming
    /// `InstallSnapshot` applies, minus the RPC framing. Entries it covers
    /// are *not* re-emitted as commits; the blob's `AppState` stands in.
    pub fn restore_snapshot(&mut self, blob: SnapshotBlob) {
        if blob.last_index <= self.log.last_compacted_index() {
            return;
        }
        self.log.install_snapshot(blob.last_index, blob.last_term, blob.prefix_digest);
        self.commit_index = self.commit_index.max(blob.last_index);
        if blob.wclock >= self.my_wclock {
            self.my_wclock = blob.wclock;
        }
        if self.log.is_empty() {
            if let Some(t) = blob.cabinet_t {
                if let Ok(scheme) = WeightScheme::geometric(self.n, t) {
                    self.mode = Mode::Cabinet { scheme };
                }
            }
            if let Some(c) = &blob.config {
                self.adopt_config(Arc::clone(c));
            } else if !self.cfg_boot {
                self.adopt_config(Arc::clone(&self.boot_config));
            }
        }
        self.snapshot = Some(blob);
    }

    /// Replay one durable splice record. `Log::splice` is idempotent and
    /// conflict-truncating, so replaying the record sequence oldest-first
    /// reconstructs exactly the log the pre-crash sequence built; a record
    /// orphaned by a torn tail (gapped `prev_index`) is refused by the
    /// splice guard and skipped here.
    pub fn restore_entries(&mut self, prev_index: LogIndex, weight: f64, entries: &[Entry]) {
        if entries.is_empty() || prev_index > self.log.last_index() {
            return;
        }
        let saw_config =
            entries.iter().any(|e| matches!(e.payload, Payload::ConfigChange(_)));
        self.log.splice(prev_index, entries, weight);
        // mirror the follower append path: Reconfig adopts on append...
        for e in entries {
            if let Payload::Reconfig { new_t } = e.payload {
                let m = if self.cfg_boot { self.n } else { self.config.voter_count() };
                if let Ok(scheme) = WeightScheme::geometric(m, new_t) {
                    self.mode = Mode::Cabinet { scheme };
                }
            }
        }
        // ...and so do membership configs (config-on-append, Raft §4.1)
        if saw_config || !self.cfg_boot {
            self.refresh_config_from_log();
        }
        // the record's round weight/clock is the freshest NewWeight this
        // node had durably learned when it crashed
        if let Some(last) = entries.last() {
            if last.wclock >= self.my_wclock {
                self.my_wclock = last.wclock;
                self.my_weight = weight;
            }
        }
    }

    /// Lease length one confirmed probe round grants. Drivers must keep this
    /// below their minimum election timeout minus the clock-drift margin —
    /// the §6.4.1 timing bound lease safety rests on.
    pub fn set_lease_duration_ms(&mut self, ms: f64) {
        debug_assert!(ms >= 0.0);
        self.lease_duration_ms = ms;
    }

    /// Advance the node's view of the driver clock (monotone; stale values
    /// are ignored). Call before [`Node::step`] when a non-log read path is
    /// configured; on the log path this is dead state.
    pub fn observe_time(&mut self, now_ms: f64) {
        if now_ms > self.now_ms {
            self.now_ms = now_ms;
        }
    }

    /// Restart hygiene for lease deployments (§6.4.1): a node restarting
    /// with fresh volatile state must not grant votes until a full election
    /// timeout passes — before the crash it may have acked a probe whose
    /// lease is still live, and a vote now could elect a disruptor inside
    /// that window. Sets the same stickiness flag leader contact sets; the
    /// node's first own election timeout clears it.
    pub fn hold_votes_until_timeout(&mut self) {
        self.heard_from_leader = true;
    }

    // ---- accessors -------------------------------------------------------

    pub fn id(&self) -> NodeId {
        self.id
    }
    pub fn n(&self) -> usize {
        self.n
    }
    pub fn role(&self) -> Role {
        self.role
    }
    pub fn term(&self) -> Term {
        self.term
    }
    pub fn commit_index(&self) -> LogIndex {
        self.commit_index
    }
    pub fn voted_for(&self) -> Option<NodeId> {
        self.voted_for
    }
    pub fn log(&self) -> &Log {
        &self.log
    }
    pub fn mode(&self) -> &Mode {
        &self.mode
    }
    pub fn wclock(&self) -> WClock {
        self.wclock
    }
    /// This node's current weight (leader: rank-0 weight; follower: last
    /// weight received via AppendEntries).
    pub fn my_weight(&self) -> f64 {
        if self.role == Role::Leader {
            self.weight_assign[self.id]
        } else {
            self.my_weight
        }
    }
    /// Leader's current per-node weight assignment (for tests/metrics).
    pub fn weight_assignment(&self) -> &[f64] {
        &self.weight_assign
    }
    /// Members of the current cabinet (the t+1 highest-weight nodes),
    /// leader's view. In Raft mode returns the empty vec.
    pub fn cabinet_members(&self) -> Vec<NodeId> {
        match &self.mode {
            Mode::Raft => vec![],
            Mode::Cabinet { scheme } => {
                let mut ids: Vec<NodeId> = (0..self.n).collect();
                // total_cmp, not partial_cmp: a NaN weight must never panic
                // membership queries (it ranks highest and stays visible)
                ids.sort_by(|&a, &b| {
                    self.weight_assign[b].total_cmp(&self.weight_assign[a])
                });
                ids.truncate(scheme.cabinet_size());
                ids
            }
        }
    }

    /// Consensus threshold for the current mode. In Raft mode the majority
    /// is over the *voter* count once membership is dynamic (the Cabinet
    /// scheme is already rebuilt per config, so its CT follows for free).
    pub fn ct(&self) -> f64 {
        match &self.mode {
            Mode::Raft if !self.cfg_boot => self.config.voter_count() as f64 / 2.0,
            Mode::Raft => self.n as f64 / 2.0,
            Mode::Cabinet { scheme } => scheme.ct(),
        }
    }

    /// Number of replication rounds this leader currently has in flight
    /// (proposed but not yet committed). 0 on followers.
    pub fn inflight_len(&self) -> usize {
        self.inflight.len()
    }

    /// Is a §4.1.4 reconfiguration transition still uncommitted? While true
    /// the leader rejects new proposals.
    pub fn reconfig_pending(&self) -> bool {
        self.pending_reconfig.is_some()
    }

    // ---- dynamic membership hooks ---------------------------------------

    /// The cluster config currently in force on this node.
    pub fn config(&self) -> &ClusterConfig {
        &self.config
    }

    /// Config entries committed on this node.
    pub fn config_commits(&self) -> u64 {
        self.config_commits
    }

    /// Is a membership operation in flight on this leader (any phase,
    /// including queued commands)?
    pub fn membership_active(&self) -> bool {
        self.active_op.is_some()
            || self.pending_config.is_some()
            || !self.admin_queue.is_empty()
    }

    /// Install the config this cluster boots with. Must be called before any
    /// log activity; a config smaller than the slot count `n` leaves the
    /// remaining slots as non-members that can be admitted later via
    /// [`AdminCmd::Join`]. Passing the full-slot bootstrap config is a no-op
    /// that keeps the historical (membership-off) code path.
    pub fn set_initial_config(&mut self, config: Arc<ClusterConfig>) {
        debug_assert!(self.log.is_empty() && self.term == 0);
        self.boot_config = Arc::clone(&config);
        self.adopt_config(config);
        self.weight_assign = config_assignment(self.id, &self.config, &self.mode, self.n);
    }

    /// Re-deals a leaving node's weight ramps over before removal (≥ 1).
    pub fn set_drain_rounds(&mut self, rounds: usize) {
        self.drain_rounds = rounds.max(1);
    }

    /// Rounds a Joining member must ack before promotion to Active.
    pub fn set_join_warmup(&mut self, rounds: u64) {
        self.join_warmup = rounds;
    }

    /// Snapshots this node has taken (threshold crossings that compacted).
    pub fn snapshots_taken(&self) -> u64 {
        self.snapshots_taken
    }

    /// Leader snapshots this node has installed over its own log.
    pub fn snapshots_installed(&self) -> u64 {
        self.snapshots_installed
    }

    /// Real (term-incrementing) candidacies this node has started. PreVote
    /// pre-campaigns that never reached a pre-quorum are not counted —
    /// that is exactly the disruption PreVote suppresses.
    pub fn elections_started(&self) -> u64 {
        self.elections_started
    }

    /// Is a PreVote pre-campaign currently in flight? (Test/metrics hook.)
    pub fn prevote_active(&self) -> bool {
        self.prevote_active
    }

    /// The configured linearizable read path.
    pub fn read_path(&self) -> ReadPath {
        self.read_path
    }

    /// Does this node currently hold a valid leader lease?
    pub fn lease_valid(&self) -> bool {
        self.role == Role::Leader && self.now_ms < self.lease_until_ms
    }

    /// Reads this node served via the lease fast path.
    pub fn lease_reads(&self) -> u64 {
        self.lease_reads
    }

    /// ReadIndex confirmation rounds this node closed as leader (including
    /// lease-renewal rounds carrying no reads).
    pub fn readindex_rounds(&self) -> u64 {
        self.readindex_rounds
    }

    /// Outstanding leadership-confirmation rounds (test hook).
    pub fn pending_confirm_rounds(&self) -> usize {
        self.pending_confirm.len()
    }

    /// The latest snapshot this node holds (taken or installed), if any.
    pub fn snapshot(&self) -> Option<&SnapshotBlob> {
        self.snapshot.as_ref()
    }

    // ---- the step function ----------------------------------------------

    pub fn step(&mut self, input: Input) -> Vec<Output> {
        let mut out = Vec::new();
        self.step_into(input, &mut out);
        out
    }

    /// [`Node::step`] into a caller-provided buffer (appended, not
    /// cleared). Hot-path drivers reuse one scratch vector across steps,
    /// making the sans-io boundary allocation-free; `step` stays as the
    /// convenient allocating wrapper.
    pub fn step_into(&mut self, input: Input, out: &mut Vec<Output>) {
        match input {
            Input::ElectionTimeout => self.on_election_timeout(out),
            Input::HeartbeatTimeout => self.on_heartbeat_timeout(out),
            Input::Receive(from, msg) => self.on_receive(from, msg, out),
            Input::Propose(payload) => self.on_propose(payload, out),
            Input::Read { id } => self.on_read(id, out),
            Input::Admin(cmd) => self.on_admin(cmd, out),
        }
    }

    // ---- timers ----------------------------------------------------------

    fn on_election_timeout(&mut self, out: &mut Vec<Output>) {
        if self.role == Role::Leader {
            return; // stale timer
        }
        // A removed (or never-admitted) slot must not campaign: it could
        // never win, and its term churn would disrupt the real members.
        if !self.cfg_boot && !self.config.involves(self.id) {
            return;
        }
        // a full election timeout passed without leader contact
        self.heard_from_leader = false;
        if self.pre_vote {
            // Pre-campaign (Raft §9.6): probe at term + 1 without touching
            // term or voted_for. A timed-out pre-campaign simply restarts —
            // no state was disturbed, so there is nothing to roll back.
            self.prevote_active = true;
            self.prevotes.fill(false); // reuse, don't reallocate
            self.prevotes[self.id] = true;
            for peer in self.peers() {
                out.push(Output::Send(
                    peer,
                    Message::PreVote {
                        term: self.term + 1,
                        candidate: self.id,
                        last_log_index: self.log.last_index(),
                        last_log_term: self.log.last_term(),
                    },
                ));
            }
            out.push(Output::ResetElectionTimer);
            return;
        }
        self.start_candidacy(out);
    }

    /// Become a real candidate (Raft §5.2): increment the term and request
    /// votes. With PreVote enabled this only runs after a full election
    /// quorum of pre-grants.
    fn start_candidacy(&mut self, out: &mut Vec<Output>) {
        self.prevote_active = false;
        self.role = Role::Candidate;
        self.term += 1;
        self.elections_started += 1;
        self.voted_for = Some(self.id);
        // the self-vote must be durable before any RequestVote leaves, or
        // a restarted candidate could vote for someone else in this term
        self.emit_hard_state(out);
        self.votes.fill(false); // reuse, don't reallocate
        self.votes[self.id] = true;
        for peer in self.peers() {
            out.push(Output::Send(
                peer,
                Message::RequestVote {
                    term: self.term,
                    candidate: self.id,
                    last_log_index: self.log.last_index(),
                    last_log_term: self.log.last_term(),
                },
            ));
        }
        out.push(Output::ResetElectionTimer);
        // single-vote win is impossible for n ≥ 3, no need to check here
    }

    fn on_heartbeat_timeout(&mut self, out: &mut Vec<Output>) {
        if self.role != Role::Leader {
            return;
        }
        self.broadcast_append(out);
        self.read_maintenance(out);
        if !self.cfg_boot {
            // Idle drain progress: with no proposals there are no re-deals
            // to tick the ramp, so heartbeats stand in for rounds.
            if self.inflight.is_empty() {
                if let Some(AdminPhase::Draining { remaining, .. }) = &mut self.active_op {
                    *remaining = remaining.saturating_sub(1);
                }
                if let Some(AdminPhase::Warmup { acks, .. }) = &mut self.active_op {
                    // an idle cluster still promotes an (assumed-responsive)
                    // joiner — there are no rounds for it to ack
                    *acks += 1;
                }
            }
            self.maybe_advance_membership(out);
        }
        out.push(Output::StartHeartbeat);
    }

    // ---- proposals ---------------------------------------------------------

    fn on_propose(&mut self, payload: Payload, out: &mut Vec<Output>) {
        if self.role != Role::Leader || self.pending_reconfig.is_some() {
            out.push(Output::ProposalRejected(payload));
            return;
        }
        // Membership changes only enter the log through [`Input::Admin`] —
        // a client must not smuggle a config past the joint-consensus flow.
        if matches!(payload, Payload::ConfigChange(_)) {
            out.push(Output::ProposalRejected(payload));
            return;
        }
        // §4.1.4: the C′ round itself reaches consensus *under the new WS* —
        // switch the leader's scheme before dealing this round's weights.
        let mut reconfig = false;
        if let Payload::Reconfig { new_t } = payload {
            let m =
                if self.cfg_boot { self.n } else { self.config.voter_count() };
            match WeightScheme::geometric(m, new_t) {
                Ok(scheme) => {
                    self.mode = Mode::Cabinet { scheme };
                    reconfig = true;
                }
                Err(_) => {
                    out.push(Output::ProposalRejected(payload));
                    return;
                }
            }
        }
        // Start a new replication round: bump the weight clock and re-deal
        // weights by the previous round's responsiveness (Algorithm 1).
        self.start_round();
        let wclock = self.wclock;
        let entry =
            Entry { term: self.term, index: 0, payload: payload.clone(), wclock };
        let my_w = self.weight_assign[self.id];
        let idx = self.log.append(entry, my_w);
        // the leader's own ack rides every AppendEntries it sends — its
        // self-append must be durable before the broadcast below releases
        if self.durable {
            let e = self.log.get(idx).cloned().expect("entry just appended");
            out.push(Output::PersistEntries {
                prev_index: idx - 1,
                weight: my_w,
                entries: vec![e],
            });
        }
        self.match_index[self.id] = idx;
        self.register_inflight(idx);
        if reconfig {
            // no replication during the transition (§4.1.4)
            self.pending_reconfig = Some(idx);
        }
        self.broadcast_append(out);
    }

    /// Leader-side adaptive batching: propose several data payloads as ONE
    /// replication round — a single weight-clock bump and re-deal, one
    /// durability record, and one AppendEntries (or AppendEntriesShard)
    /// per follower carrying all the entries. Each entry still gets its
    /// own in-flight ack record, so commit advancement and the coded
    /// reconstruction rule work per entry exactly as for singleton rounds.
    ///
    /// Drivers coalesce queued client ops through this under load, bounded
    /// by their `max_batch_bytes` knob; a one-element batch takes exactly
    /// the historical `Input::Propose` path. Control payloads
    /// (Reconfig / ConfigChange) never batch — they are rejected here like
    /// a config smuggled through `Input::Propose`.
    pub fn propose_all(&mut self, payloads: Vec<Payload>, out: &mut Vec<Output>) {
        if payloads.is_empty() {
            return;
        }
        if payloads.len() == 1 {
            let p = payloads.into_iter().next().expect("len checked");
            self.on_propose(p, out);
            return;
        }
        if self.role != Role::Leader || self.pending_reconfig.is_some() {
            for p in payloads {
                out.push(Output::ProposalRejected(p));
            }
            return;
        }
        let mut data = Vec::with_capacity(payloads.len());
        for p in payloads {
            if matches!(p, Payload::ConfigChange(_) | Payload::Reconfig { .. }) {
                out.push(Output::ProposalRejected(p));
            } else {
                data.push(p);
            }
        }
        if data.is_empty() {
            return;
        }
        self.start_round();
        let wclock = self.wclock;
        let my_w = self.weight_assign[self.id];
        let first = self.log.last_index() + 1;
        for payload in data {
            let entry = Entry { term: self.term, index: 0, payload, wclock };
            let idx = self.log.append(entry, my_w);
            self.match_index[self.id] = idx;
            self.register_inflight(idx);
        }
        // one durability record covers the whole batch (group commit) —
        // and precedes the broadcast, like the singleton path
        if self.durable {
            let entries = self.log.slice(first - 1, self.log.last_index());
            out.push(Output::PersistEntries { prev_index: first - 1, weight: my_w, entries });
        }
        self.broadcast_append(out);
    }

    /// Does this payload ship coded under the current coding config?
    fn payload_coded(&self, payload: &Payload) -> bool {
        match self.coding {
            None => false,
            Some((_, cutover)) => {
                coding::payload_codes(payload)
                    && coding::payload_wire_bytes(payload) >= cutover
            }
        }
    }

    /// Open per-index ack bookkeeping for a freshly proposed entry,
    /// snapshotting this round's weight assignment and commit threshold —
    /// and, under a joint config, the old half's assignment and CT too.
    /// A coded entry additionally opens its shard-ack accumulator.
    fn register_inflight(&mut self, index: LogIndex) {
        let weights = self.weight_assign.clone();
        let mut acked = vec![false; self.n];
        acked[self.id] = true;
        let acc_weight = weights[self.id];
        let joint = self.joint_assign.as_ref().map(|(w, ct)| JointAcc {
            acc: w[self.id],
            weights: w.clone(),
            ct: *ct,
        });
        let coded = self
            .log
            .get(index)
            .filter(|e| self.payload_coded(&e.payload))
            .map(|_| {
                let (k, _) = self.coding.expect("payload_coded implies coding on");
                CodedAcc { k, m: coding::shard_count(k), acked_shards: 0 }
            });
        self.inflight.push_back(InflightRound {
            index,
            wclock: self.wclock,
            ct: self.ct(),
            weights,
            acked,
            acc_weight,
            epoch: self.config.epoch,
            joint,
            coded,
        });
    }

    /// Begin a new weight-clock round: re-deal the weight multiset FIFO by
    /// the previous round's reply order (leader keeps the top weight).
    fn start_round(&mut self) {
        self.wclock += 1;
        if self.static_weights {
            self.reply_order.clear();
            self.replied.fill(false);
            return;
        }
        if !self.cfg_boot {
            self.start_round_configured();
            return;
        }
        if let Mode::Cabinet { scheme } = &self.mode {
            let mut rank = 0usize;
            let mut assign = vec![0.0; self.n];
            // leader always takes w₁ (Algorithm 1: "assigns itself the
            // highest weight w_λ")
            assign[self.id] = scheme.weight_of_rank(rank);
            rank += 1;
            // repliers of the previous round, in wQ FIFO order
            for &nid in &self.reply_order {
                if nid != self.id && assign[nid] == 0.0 {
                    assign[nid] = scheme.weight_of_rank(rank);
                    rank += 1;
                }
            }
            // remaining nodes (Line 20), stably by previous-round rank
            let mut rest: Vec<NodeId> =
                (0..self.n).filter(|&i| i != self.id && assign[i] == 0.0).collect();
            // total_cmp, not partial_cmp: one NaN weight (a degenerate
            // scheme passes I1/I2 vacuously) must not panic the re-deal
            rest.sort_by(|&a, &b| {
                self.weight_assign[b].total_cmp(&self.weight_assign[a])
            });
            for nid in rest {
                assign[nid] = scheme.weight_of_rank(rank);
                rank += 1;
            }
            self.weight_assign = assign;
        }
        self.reply_order.clear();
        self.replied.fill(false); // reuse, don't reallocate (§Perf iter. 3)
    }

    /// The membership-aware re-deal: the FIFO deal runs over the config's
    /// *voters* only (non-member slots hold weight 0.0), then the lifecycle
    /// weight floors cap Joining members at the scheme minimum and ramp a
    /// Draining member down `drain_cap`'s schedule, redistributing the
    /// shaved excess so the total — and invariant I2 — are preserved
    /// (`apply_weight_floors`). This is the consensus-free intra-epoch
    /// reassignment: no config entry is proposed for a weight change.
    fn start_round_configured(&mut self) {
        if let Mode::Cabinet { scheme } = &self.mode {
            let floor = scheme.min_weight();
            let t_eff = scheme.t();
            let mut rank = 0usize;
            let mut assign = vec![0.0; self.n];
            if self.config.is_voter(self.id) {
                assign[self.id] = scheme.weight_of_rank(rank);
                rank += 1;
            }
            for &nid in &self.reply_order {
                if nid != self.id && assign[nid] == 0.0 && self.config.is_voter(nid) {
                    assign[nid] = scheme.weight_of_rank(rank);
                    rank += 1;
                }
            }
            let mut rest: Vec<NodeId> = self
                .config
                .voters()
                .filter(|&i| i != self.id && assign[i] == 0.0)
                .collect();
            rest.sort_by(|&a, &b| {
                self.weight_assign[b].total_cmp(&self.weight_assign[a])
            });
            for nid in rest {
                assign[nid] = scheme.weight_of_rank(rank);
                rank += 1;
            }
            let floors = self.lifecycle_floors(floor);
            apply_weight_floors(&mut assign, &floors, t_eff);
            self.weight_assign = assign;
        }
        // Warmup bookkeeping rides the round boundary: the joiner acked the
        // round that just closed iff it sits in the outgoing reply queue.
        if let Some(AdminPhase::Warmup { node, acks }) = &mut self.active_op {
            if self.replied[*node] {
                *acks += 1;
            }
        }
        // One re-deal = one drain-ramp tick.
        if let Some(AdminPhase::Draining { remaining, .. }) = &mut self.active_op {
            *remaining = remaining.saturating_sub(1);
        }
        self.reply_order.clear();
        self.replied.fill(false);
    }

    /// Weight caps for members in a lifecycle state: Joining members sit at
    /// the scheme floor until promoted; a Draining member follows the drain
    /// ramp (or the floor outright when this leader inherited the drain
    /// mid-flight without a ramp of its own).
    fn lifecycle_floors(&self, floor: f64) -> Vec<(usize, f64)> {
        let mut floors = Vec::new();
        for m in &self.config.members {
            match m.state {
                MemberState::Active => {}
                MemberState::Joining => floors.push((m.id, floor)),
                MemberState::Draining => {
                    let cap = match self.active_op {
                        Some(AdminPhase::Draining { node, remaining, w_start })
                            if node == m.id =>
                        {
                            drain_cap(floor, w_start, remaining, self.drain_rounds)
                        }
                        _ => floor,
                    };
                    floors.push((m.id, cap));
                }
            }
        }
        floors
    }

    fn broadcast_append(&mut self, out: &mut Vec<Output>) {
        // index loop, not peers().collect(): send_append needs &mut self,
        // and collecting allocated a peer list on every heartbeat/propose
        for peer in 0..self.n {
            if peer != self.id && (self.cfg_boot || self.config.involves(peer)) {
                self.send_append(peer, out);
            }
        }
    }

    fn send_append(&mut self, peer: NodeId, out: &mut Vec<Output>) {
        let prev = self.next_index[peer] - 1;
        // The follower's next entry was compacted away: ship the snapshot
        // instead (the term at `prev` is gone, so AppendEntries cannot even
        // state its consistency check). In-flight rounds are unaffected —
        // snapshots cover only the committed prefix, which sits strictly
        // below every open round's index.
        if prev < self.log.last_compacted_index() {
            if let Some(blob) = self.snapshot.clone() {
                out.push(Output::Send(
                    peer,
                    Message::InstallSnapshot { term: self.term, leader: self.id, snapshot: blob },
                ));
                return;
            }
            // unreachable via the public API (compaction always records a
            // blob); degrade to resending from the cut
            debug_assert!(false, "compacted log without a retained snapshot");
            self.next_index[peer] = self.log.last_compacted_index() + 1;
        }
        let prev = self.next_index[peer] - 1;
        let prev_term = self.log.term_at(prev).unwrap_or(0);
        let entries = self.log.slice(prev, self.log.last_index());
        // Coded replication: when any entry in the slice clears the size
        // cutover, substitute each such payload with this peer's assigned
        // shard and ship the shard-bearing variant. `prefix_digest` hashes
        // only (index, term, wclock), so the follower's shard entry matches
        // the leader's full entry for all log-consistency purposes.
        if self.coding.is_some() && entries.iter().any(|e| self.payload_coded(&e.payload)) {
            let (k, _) = self.coding.expect("checked above");
            let m = coding::shard_count(k);
            let sid = coding::shard_for_peer(peer, m) as usize;
            let entries = entries
                .into_iter()
                .map(|e| {
                    if self.payload_coded(&e.payload) {
                        let shards = coding::encode_payload(&e.payload, k)
                            .expect("payload_coded implies a canonical serialization");
                        Entry { payload: shards[sid].clone(), ..e }
                    } else {
                        e
                    }
                })
                .collect();
            out.push(Output::Send(
                peer,
                Message::AppendEntriesShard {
                    term: self.term,
                    leader: self.id,
                    prev_log_index: prev,
                    prev_log_term: prev_term,
                    entries,
                    leader_commit: self.commit_index,
                    wclock: self.wclock,
                    weight: self.weight_assign[peer],
                },
            ));
            return;
        }
        out.push(Output::Send(
            peer,
            Message::AppendEntries {
                term: self.term,
                leader: self.id,
                prev_log_index: prev,
                prev_log_term: prev_term,
                entries,
                leader_commit: self.commit_index,
                wclock: self.wclock,
                weight: self.weight_assign[peer],
            },
        ));
    }

    // ---- RPC handling ------------------------------------------------------

    fn on_receive(&mut self, from: NodeId, msg: Message, out: &mut Vec<Output>) {
        // Raft term rule: higher term ⇒ step down to follower. PreVote
        // probes are exempt — they carry a *prospective* term (§9.6), and
        // adopting it would reintroduce exactly the disruption PreVote
        // exists to prevent. (PreVote *replies* carry the replier's actual
        // term and do follow the rule.)
        if !matches!(msg, Message::PreVote { .. }) && msg.term() > self.term {
            self.become_follower(msg.term(), out);
        }
        match msg {
            Message::AppendEntries {
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                wclock,
                weight,
            } => self.on_append_entries(
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                wclock,
                weight,
                out,
            ),
            // Shard-bearing variant: identical follower semantics — the
            // shard entries splice into the same (index, term) slots and
            // the ack is an ordinary AppendEntriesReply (the leader derives
            // the acked shard id from the replier's identity).
            Message::AppendEntriesShard {
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                wclock,
                weight,
            } => self.on_append_entries(
                term,
                leader,
                prev_log_index,
                prev_log_term,
                entries,
                leader_commit,
                wclock,
                weight,
                out,
            ),
            Message::AppendEntriesReply { term, from, success, match_index, wclock } => {
                self.on_append_reply(term, from, success, match_index, wclock, out)
            }
            Message::RequestVote { term, candidate, last_log_index, last_log_term } => {
                self.on_request_vote(term, candidate, last_log_index, last_log_term, out)
            }
            Message::RequestVoteReply { term, from, granted } => {
                self.on_vote_reply(term, from, granted, out)
            }
            Message::PreVote { term, candidate, last_log_index, last_log_term } => {
                self.on_pre_vote(term, candidate, last_log_index, last_log_term, out)
            }
            Message::PreVoteReply { term, from, granted, for_term } => {
                self.on_pre_vote_reply(term, from, granted, for_term, out)
            }
            Message::InstallSnapshot { term, leader, snapshot } => {
                self.on_install_snapshot(term, leader, snapshot, out)
            }
            Message::InstallSnapshotReply { term, from, match_index } => {
                self.on_install_snapshot_reply(term, from, match_index, out)
            }
            Message::ReadIndex { term, leader, seq } => {
                self.on_read_index(term, leader, seq, out)
            }
            Message::ReadIndexResp { term, from, seq } => {
                self.on_read_index_resp(term, from, seq, out)
            }
            Message::ReadForward { term, from, id } => {
                self.on_read_forward(term, from, id, out)
            }
            Message::ReadGrant { term, leader, id, read_index } => {
                self.on_read_grant(term, leader, id, read_index, out)
            }
        }
        let _ = from;
    }

    #[allow(clippy::too_many_arguments)]
    fn on_append_entries(
        &mut self,
        term: Term,
        leader: NodeId,
        prev_log_index: LogIndex,
        prev_log_term: Term,
        entries: Vec<Entry>,
        leader_commit: LogIndex,
        wclock: WClock,
        weight: f64,
        out: &mut Vec<Output>,
    ) {
        if term < self.term {
            out.push(Output::Send(
                leader,
                Message::AppendEntriesReply {
                    term: self.term,
                    from: self.id,
                    success: false,
                    match_index: 0,
                    wclock,
                },
            ));
            return;
        }
        // current leader's authority: stay/become follower, reset timer
        if self.role != Role::Follower {
            self.become_follower(term, out);
        }
        // a working leader exists — abandon any pre-campaign, deny probes
        self.prevote_active = false;
        self.heard_from_leader = true;
        self.leader_hint = Some(leader);
        out.push(Output::ResetElectionTimer);

        // NewWeight (Algorithm 1, Lines 29–31): store the weight clock and
        // weight value issued by the leader.
        if wclock >= self.my_wclock {
            self.my_wclock = wclock;
            self.my_weight = weight;
        }

        if !self.log.matches(prev_log_index, prev_log_term) {
            out.push(Output::Send(
                leader,
                Message::AppendEntriesReply {
                    term: self.term,
                    from: self.id,
                    success: false,
                    match_index: 0,
                    wclock,
                },
            ));
            return;
        }

        let saw_config =
            entries.iter().any(|e| matches!(e.payload, Payload::ConfigChange(_)));
        let last = self.log.splice(prev_log_index, &entries, weight);

        // Followers adopt reconfigurations when they learn them (§4.1.4):
        // scan the appended suffix for a Reconfig payload.
        for e in &entries {
            if let Payload::Reconfig { new_t } = e.payload {
                let m =
                    if self.cfg_boot { self.n } else { self.config.voter_count() };
                if let Ok(scheme) = WeightScheme::geometric(m, new_t) {
                    self.mode = Mode::Cabinet { scheme };
                }
            }
        }

        // Membership is config-on-append (Raft §4.1): re-derive the
        // effective config from the log whenever this append carried a
        // config entry — or could have truncated one away. Gated so
        // membership-off runs never pay the backward scan.
        if saw_config || !self.cfg_boot {
            self.refresh_config_from_log();
        }

        // Persist-before-reply: the splice must be durable before the
        // success ack below releases — the leader counts this node toward
        // the commit quorum on that ack.
        if self.durable && !entries.is_empty() {
            out.push(Output::PersistEntries { prev_index: prev_log_index, weight, entries });
        }

        let new_commit = leader_commit.min(last);
        self.advance_commit_to(new_commit, out);

        out.push(Output::Send(
            leader,
            Message::AppendEntriesReply {
                term: self.term,
                from: self.id,
                success: true,
                match_index: last,
                wclock,
            },
        ));
    }

    fn on_append_reply(
        &mut self,
        term: Term,
        from: NodeId,
        success: bool,
        match_index: LogIndex,
        wclock: WClock,
        out: &mut Vec<Output>,
    ) {
        if self.role != Role::Leader || term < self.term {
            return;
        }
        if !success {
            // log inconsistency: back off and retry (Raft §5.3)
            self.next_index[from] = self.next_index[from].saturating_sub(1).max(1);
            self.send_append(from, out);
            return;
        }
        self.match_index[from] = self.match_index[from].max(match_index);
        self.next_index[from] = self.match_index[from] + 1;

        // Algorithm 1, Lines 22–25: enqueue into wQ (first reply, first
        // enqueue) — one slot per node per round.
        if wclock == self.wclock && !self.replied[from] {
            self.replied[from] = true;
            self.reply_order.push(from);
        }

        // Per-index ack accounting: a follower matching index m has the
        // whole prefix (log matching), so it acks every in-flight round at
        // or below m — each under that round's own weight snapshot.
        let matched = self.match_index[from];
        for rec in self.inflight.iter_mut() {
            if rec.index <= matched && !rec.acked[from] {
                rec.acked[from] = true;
                rec.acc_weight += rec.weights[from];
                if let Some(j) = &mut rec.joint {
                    // 0.0 outside C_old, so the unconditional add is exact
                    j.acc += j.weights[from];
                }
                // Coded round: this follower's ack vouches for exactly the
                // shard the deterministic assignment gave it.
                if let Some(c) = &mut rec.coded {
                    c.acked_shards |= 1u64 << coding::shard_for_peer(from, c.m);
                }
            }
        }

        self.try_advance_leader_commit(out);
    }

    /// Weighted (or majority) commit rule over the in-flight window. An
    /// index N commits when the accumulated propose-time weight of its
    /// ackers — leader included — exceeds the round's own CT snapshot; the
    /// records all belong to the current term, preserving the Raft §5.4.2
    /// guard. Scanning from the window tail down makes advancement tolerant
    /// of out-of-order quorum formation: if a later round clears its
    /// threshold first, every earlier round commits with it (its ackers
    /// hold the whole prefix).
    fn try_advance_leader_commit(&mut self, out: &mut Vec<Output>) {
        let mut target = self.commit_index;
        let mut quorum_weight = 0.0;
        let mut wclock = self.wclock;
        let mut repliers = 0;
        let mut epoch = 0;
        let mut ct = 0.0;
        let mut joint_ev = None;
        let mut coded_ev = None;
        // Coded rounds gate advancement: committing index N drags every
        // earlier in-flight round with it, and N's weight quorum proves
        // those rounds durable only *as shards* — so no round at or above
        // the first coded round that cannot yet reconstruct (fewer than k
        // distinct shards acked) may become the target. Acked sets only
        // grow towards the window head (a follower matching N holds the
        // whole prefix), so one forward scan finds the barrier.
        let coded_barrier = self
            .inflight
            .iter()
            .find(|r| r.coded.map_or(false, |c| !c.reconstructs()))
            .map(|r| r.index);
        for rec in self.inflight.iter().rev() {
            if rec.index <= self.commit_index {
                continue;
            }
            if coded_barrier.map_or(false, |b| rec.index >= b) {
                continue;
            }
            // Joint phase: the weighted rule must hold in *both* configs
            // before the round commits (Raft §4.3 adapted to weights).
            let joint_ok = rec.joint.as_ref().map_or(true, |j| j.acc > j.ct);
            if rec.acc_weight > rec.ct && joint_ok {
                target = rec.index;
                quorum_weight = rec.acc_weight;
                wclock = rec.wclock;
                // followers whose acks closed this round's quorum (the
                // leader's own pre-ack excluded)
                repliers = rec.acked.iter().filter(|&&a| a).count() - 1;
                epoch = rec.epoch;
                ct = rec.ct;
                joint_ev = rec.joint.as_ref().map(|j| (j.acc, j.ct));
                coded_ev = rec.coded.map(|c| (c.distinct(), c.k));
                break;
            }
        }
        if target > self.commit_index {
            self.advance_commit_to(target, out);
            self.inflight.retain(|rec| rec.index > target);
            if let Some(idx) = self.pending_reconfig {
                if self.commit_index >= idx {
                    // transition committed: accept proposals again
                    self.pending_reconfig = None;
                }
            }
            if let Some(idx) = self.pending_config {
                if self.commit_index >= idx {
                    self.pending_config = None;
                }
            }
            out.push(Output::RoundCommitted {
                wclock,
                index: target,
                repliers,
                quorum_weight,
                epoch,
                ct,
                joint: joint_ev,
                coded: coded_ev,
            });
            if !self.cfg_boot {
                self.maybe_advance_membership(out);
            }
        }
    }

    fn advance_commit_to(&mut self, new_commit: LogIndex, out: &mut Vec<Output>) {
        while self.commit_index < new_commit {
            self.commit_index += 1;
            if let Some(e) = self.log.get(self.commit_index) {
                // Followers complete an in-flight reconfiguration here.
                if self.role != Role::Leader {
                    if let Payload::Reconfig { new_t } = e.payload {
                        let m = if self.cfg_boot {
                            self.n
                        } else {
                            self.config.voter_count()
                        };
                        if let Ok(scheme) = WeightScheme::geometric(m, new_t) {
                            self.mode = Mode::Cabinet { scheme };
                        }
                    }
                }
                let config_event = match &e.payload {
                    Payload::ConfigChange(c) => {
                        self.config_commits += 1;
                        Some(Output::ConfigCommitted {
                            epoch: c.epoch,
                            index: self.commit_index,
                            joint: c.is_joint(),
                            voters: c.voters().collect(),
                        })
                    }
                    _ => None,
                };
                out.push(Output::Commit(e.clone()));
                if let Some(ev) = config_event {
                    out.push(ev);
                }
            }
        }
        // granted reads waiting on this apply point are now servable
        self.flush_waiting_grants(out);
        // Commit outputs precede the snapshot request, so a driver that
        // forwards commits to its applier in output order captures exactly
        // the state through `commit_index`.
        self.maybe_take_snapshot(out);
    }

    /// Cross the snapshot threshold: once `snapshot_every` entries have
    /// committed past the last compaction point, capture replica state
    /// (inline or via the driver handshake) and compact the log.
    fn maybe_take_snapshot(&mut self, out: &mut Vec<Output>) {
        let Some(every) = self.snapshot_every else { return };
        if self.snapshot_pending.is_some() {
            return; // a driver capture is already in flight
        }
        if self.commit_index < self.log.last_compacted_index() + every {
            return;
        }
        match self.snapshot_capture {
            SnapshotCapture::Inline => self.complete_snapshot(self.commit_index, AppState::None),
            SnapshotCapture::Driver => {
                self.snapshot_pending = Some(self.commit_index);
                out.push(Output::SnapshotRequest { through: self.commit_index });
            }
        }
    }

    /// Finish a snapshot: compact the log through `through` (clamped to the
    /// commit index — never beyond what `app` can cover) and retain the blob
    /// for follower catch-up. Drivers call this in response to
    /// [`Output::SnapshotRequest`]; inline capture calls it directly.
    pub fn complete_snapshot(&mut self, through: LogIndex, app: AppState) {
        self.snapshot_pending = None;
        let through = through.min(self.commit_index);
        if through <= self.log.last_compacted_index() {
            return; // stale (an installed leader snapshot already passed it)
        }
        let last_term = self.log.term_at(through).expect("snapshot point must be in the log");
        self.log.compact_to(through);
        let cabinet_t = match &self.mode {
            Mode::Raft => None,
            Mode::Cabinet { scheme } => Some(scheme.t()),
        };
        self.snapshot = Some(SnapshotBlob {
            last_index: through,
            last_term,
            prefix_digest: self.log.compacted_digest(),
            wclock: self.wclock.max(self.my_wclock),
            cabinet_t,
            // like cabinet_t: boot-config blobs stay None so historical
            // snapshots are byte-for-byte unchanged
            config: (!self.cfg_boot).then(|| Arc::clone(&self.config)),
            app,
        });
        self.snapshots_taken += 1;
    }

    /// Follower side of the catch-up flow: adopt a leader snapshot. The
    /// blob covers only committed entries, so installing it can never
    /// conflict with safety; entries it covers are *not* re-emitted as
    /// `Output::Commit` — the carried `AppState` stands in for them.
    fn on_install_snapshot(
        &mut self,
        term: Term,
        leader: NodeId,
        blob: SnapshotBlob,
        out: &mut Vec<Output>,
    ) {
        if term < self.term {
            out.push(Output::Send(
                leader,
                Message::InstallSnapshotReply {
                    term: self.term,
                    from: self.id,
                    match_index: self.commit_index,
                },
            ));
            return;
        }
        // current leader's authority, exactly like AppendEntries
        if self.role != Role::Follower {
            self.become_follower(term, out);
        }
        self.prevote_active = false;
        self.heard_from_leader = true;
        self.leader_hint = Some(leader);
        out.push(Output::ResetElectionTimer);
        if blob.wclock >= self.my_wclock {
            self.my_wclock = blob.wclock;
        }
        if blob.last_index > self.commit_index {
            self.log.install_snapshot(blob.last_index, blob.last_term, blob.prefix_digest);
            self.commit_index = blob.last_index;
            // A §4.1.4 reconfiguration compacted into the prefix still
            // reaches us through the blob — but only when no log suffix
            // survived the install (Raft §7: configuration info in the log
            // supersedes the snapshot's). A retained suffix was appended
            // after the cut, and any reconfig in it was already adopted on
            // append; re-adopting the blob's older threshold would regress
            // it (a reordered/duplicated InstallSnapshot can arrive late).
            if self.log.is_empty() {
                if let Some(t) = blob.cabinet_t {
                    if let Ok(scheme) = WeightScheme::geometric(self.n, t) {
                        self.mode = Mode::Cabinet { scheme };
                    }
                }
                // Cluster config survives compaction the same way: adopt the
                // blob's config only when no (newer-by-definition) log
                // suffix survived the install.
                if let Some(c) = &blob.config {
                    self.adopt_config(Arc::clone(c));
                } else if !self.cfg_boot {
                    self.adopt_config(Arc::clone(&self.boot_config));
                }
            }
            self.snapshot_pending = None;
            self.snapshots_installed += 1;
            self.snapshot = Some(blob.clone());
            out.push(Output::SnapshotInstalled(blob));
            // the install advanced the apply point past any waiting grants
            self.flush_waiting_grants(out);
        }
        out.push(Output::Send(
            leader,
            Message::InstallSnapshotReply {
                term: self.term,
                from: self.id,
                match_index: self.commit_index,
            },
        ));
    }

    /// Leader side: a follower finished (or skipped) a snapshot install.
    /// `match_index` is its commit index — safe to track by leader
    /// completeness — and cannot touch any in-flight round (a follower's
    /// commit never exceeds the leader's, and every open round sits above
    /// it), so no wQ or quorum bookkeeping changes here.
    fn on_install_snapshot_reply(
        &mut self,
        term: Term,
        from: NodeId,
        match_index: LogIndex,
        out: &mut Vec<Output>,
    ) {
        if self.role != Role::Leader || term < self.term {
            return;
        }
        self.match_index[from] = self.match_index[from].max(match_index);
        self.next_index[from] = self.match_index[from] + 1;
        // ship the live suffix (the snapshot covers only the committed prefix)
        if self.next_index[from] <= self.log.last_index() {
            self.send_append(from, out);
        }
    }

    // ---- linearizable reads (ReadIndex + leader leases, §6.4) ------------

    /// A client read arrived at this node. Leaders serve it through the
    /// configured fast path; followers forward it to their last known
    /// leader (the grant comes back as [`Message::ReadGrant`]).
    fn on_read(&mut self, id: u64, out: &mut Vec<Output>) {
        if matches!(self.read_path, ReadPath::Log) {
            // log-path clusters replicate reads as ordinary proposals; a
            // stray Read input has no protocol to ride
            out.push(Output::ReadFailed { id });
            return;
        }
        if self.role == Role::Leader {
            self.leader_read(id, self.id, out);
            return;
        }
        match self.leader_hint {
            Some(l) if l != self.id => out.push(Output::Send(
                l,
                Message::ReadForward { term: self.term, from: self.id, id },
            )),
            _ => out.push(Output::ReadFailed { id }),
        }
    }

    /// Leader-side read admission (local or forwarded): serve from the
    /// lease when one is held, otherwise open (or join) a ReadIndex
    /// confirmation round over the current commit index.
    fn leader_read(&mut self, id: u64, origin: NodeId, out: &mut Vec<Output>) {
        // Raft §6.4 step 1: until this term's no-op barrier commits, the
        // leader's commit index may trail entries the previous term already
        // committed — serving a read index now could be stale.
        if self.commit_index < self.barrier_index {
            if origin == self.id {
                out.push(Output::ReadFailed { id });
            }
            // forwarded reads are dropped; the origin's client retries
            return;
        }
        if matches!(self.read_path, ReadPath::Lease) && self.lease_valid() {
            self.lease_reads += 1;
            if origin == self.id {
                out.push(Output::ReadReady { id, index: self.commit_index, lease: true });
            } else {
                out.push(Output::Send(
                    origin,
                    Message::ReadGrant {
                        term: self.term,
                        leader: self.id,
                        id,
                        read_index: self.commit_index,
                    },
                ));
            }
            return;
        }
        // ReadIndex — or an expired lease falling back to it: every read
        // opens a FRESH probe round. Joining an already-probed round would
        // let acks answering pre-arrival probes confirm the read — and a
        // node can ack a probe and then vote a new leader in, so such a
        // round can close after a newer leader has already committed past
        // us (a stale read). A fresh round's acks all answer probes sent at
        // or after the read arrived, so every acker was still rejecting new
        // leaders at ack time; with the election quorum taking n − t nodes,
        // at most t non-voters remain, and L3.2 (heaviest t < CT) keeps
        // them below the weighted threshold — the round cannot close once a
        // newer leader exists.
        self.open_confirm_round(vec![(id, origin)], out);
    }

    /// Open a leadership-confirmation probe round. Weights and CT are
    /// snapshotted exactly like a replication round's, so a mid-window
    /// re-deal or §4.1.4 reconfiguration never changes a round's rule.
    fn open_confirm_round(&mut self, reads: Vec<(u64, NodeId)>, out: &mut Vec<Output>) {
        self.read_seq += 1;
        let weights = self.weight_assign.clone();
        let mut acked = vec![false; self.n];
        acked[self.id] = true;
        let acc_weight = weights[self.id];
        let joint = self.joint_assign.as_ref().map(|(w, ct)| JointAcc {
            acc: w[self.id],
            weights: w.clone(),
            ct: *ct,
        });
        self.pending_confirm.push(ReadConfirm {
            seq: self.read_seq,
            sent_at_ms: self.now_ms,
            read_index: self.commit_index,
            reads,
            weights,
            acked,
            acc_weight,
            ct: self.ct(),
            joint,
        });
        let seq = self.read_seq;
        for peer in self.peers() {
            out.push(Output::Send(
                peer,
                Message::ReadIndex { term: self.term, leader: self.id, seq },
            ));
        }
    }

    /// Heartbeat-cadence read upkeep (non-log paths only): re-probe rounds
    /// still short of their quorum (loss recovery — probes and replies can
    /// be dropped by the nemesis), and in lease mode keep a renewal round in
    /// flight so an idle leader's lease never lapses.
    fn read_maintenance(&mut self, out: &mut Vec<Output>) {
        if matches!(self.read_path, ReadPath::Log) {
            return;
        }
        for rc in &self.pending_confirm {
            for peer in 0..self.n {
                if peer != self.id
                    && !rc.acked[peer]
                    && (self.cfg_boot || self.config.involves(peer))
                {
                    out.push(Output::Send(
                        peer,
                        Message::ReadIndex { term: self.term, leader: self.id, seq: rc.seq },
                    ));
                }
            }
        }
        if matches!(self.read_path, ReadPath::Lease)
            && self.commit_index >= self.barrier_index
            && self.pending_confirm.is_empty()
        {
            self.open_confirm_round(Vec::new(), out);
        }
    }

    /// Receiver side of a probe: acknowledging it is a statement that we
    /// still recognize this leader — which is leader contact, with all the
    /// usual consequences (timer reset, PreVote/lease stickiness).
    fn on_read_index(&mut self, term: Term, leader: NodeId, seq: u64, out: &mut Vec<Output>) {
        if term < self.term {
            // stale leader: our reply's higher term steps it down
            out.push(Output::Send(
                leader,
                Message::ReadIndexResp { term: self.term, from: self.id, seq },
            ));
            return;
        }
        if self.role != Role::Follower {
            self.become_follower(term, out);
        }
        self.prevote_active = false;
        self.heard_from_leader = true;
        self.leader_hint = Some(leader);
        out.push(Output::ResetElectionTimer);
        out.push(Output::Send(
            leader,
            Message::ReadIndexResp { term: self.term, from: self.id, seq },
        ));
    }

    /// Leader side: accumulate probe-ack weight; past CT the round's reads
    /// are confirmed (and in lease mode the lease extends from the probe's
    /// original send time).
    fn on_read_index_resp(&mut self, term: Term, from: NodeId, seq: u64, out: &mut Vec<Output>) {
        if self.role != Role::Leader || term < self.term {
            return; // a higher term already stepped us down (generic rule)
        }
        let Some(pos) = self.pending_confirm.iter().position(|rc| rc.seq == seq) else {
            return; // already confirmed, or cleared by a leadership change
        };
        {
            let rc = &mut self.pending_confirm[pos];
            if rc.acked[from] {
                return;
            }
            rc.acked[from] = true;
            rc.acc_weight += rc.weights[from];
            if let Some(j) = &mut rc.joint {
                j.acc += j.weights[from];
            }
            // joint phase: leadership must be confirmed in *both* configs
            // before the round's reads are safe
            let joint_ok = rc.joint.as_ref().map_or(true, |j| j.acc > j.ct);
            if rc.acc_weight <= rc.ct || !joint_ok {
                return;
            }
        }
        let rc = self.pending_confirm.remove(pos);
        self.readindex_rounds += 1;
        if matches!(self.read_path, ReadPath::Lease) {
            let until = rc.sent_at_ms + self.lease_duration_ms;
            if until > self.lease_until_ms {
                self.lease_until_ms = until;
            }
        }
        for (id, origin) in rc.reads {
            if origin == self.id {
                out.push(Output::ReadReady { id, index: rc.read_index, lease: false });
            } else {
                out.push(Output::Send(
                    origin,
                    Message::ReadGrant {
                        term: self.term,
                        leader: self.id,
                        id,
                        read_index: rc.read_index,
                    },
                ));
            }
        }
    }

    /// A follower forwarded a client read. Non-leaders drop it (the origin's
    /// client retries against the new leader).
    fn on_read_forward(&mut self, term: Term, from: NodeId, id: u64, out: &mut Vec<Output>) {
        let _ = term;
        if self.role != Role::Leader {
            return;
        }
        self.leader_read(id, from, out);
    }

    /// The leader granted one of our forwarded reads: serve it as soon as
    /// the local applied state reaches the read index.
    fn on_read_grant(
        &mut self,
        term: Term,
        leader: NodeId,
        id: u64,
        read_index: LogIndex,
        out: &mut Vec<Output>,
    ) {
        let _ = leader;
        if term < self.term {
            return; // a grant from a deposed regime must not serve a read
        }
        if self.commit_index >= read_index {
            out.push(Output::ReadReady { id, index: read_index, lease: false });
        } else {
            self.waiting_grants.push((id, read_index));
        }
    }

    /// Serve granted reads whose read index the local applied state has
    /// reached (called whenever the commit index advances).
    fn flush_waiting_grants(&mut self, out: &mut Vec<Output>) {
        if self.waiting_grants.is_empty() {
            return;
        }
        let commit = self.commit_index;
        let mut i = 0;
        while i < self.waiting_grants.len() {
            if self.waiting_grants[i].1 <= commit {
                let (id, index) = self.waiting_grants.swap_remove(i);
                out.push(Output::ReadReady { id, index, lease: false });
            } else {
                i += 1;
            }
        }
    }

    /// PreVote probe (Raft §9.6): grant iff the prospective term is ahead of
    /// ours, the candidate's log is up to date, we are not ourselves a
    /// working leader, and we have not heard from a leader since our own
    /// last election timeout (the stickiness clause — a healthy cabinet is
    /// never pre-voted away). Granting changes no persistent state — no term
    /// adoption, no voted_for, no timer reset — so duplicated or reordered
    /// probes are trivially idempotent.
    fn on_pre_vote(
        &mut self,
        term: Term,
        candidate: NodeId,
        last_log_index: LogIndex,
        last_log_term: Term,
        out: &mut Vec<Output>,
    ) {
        let up_to_date = self.log.candidate_up_to_date(last_log_index, last_log_term);
        let granted = self.role != Role::Leader
            && !self.heard_from_leader
            && term > self.term
            && up_to_date
            // a candidate outside the config (removed slot) can never win —
            // don't encourage it to campaign for real
            && (self.cfg_boot || self.config.involves(candidate));
        out.push(Output::Send(
            candidate,
            Message::PreVoteReply { term: self.term, from: self.id, granted, for_term: term },
        ));
    }

    fn on_pre_vote_reply(
        &mut self,
        term: Term,
        from: NodeId,
        granted: bool,
        for_term: Term,
        out: &mut Vec<Output>,
    ) {
        // the generic term rule has already stepped us down if the replier's
        // actual term was ahead (which also cancelled the pre-campaign)
        let _ = term;
        // count only grants for *this* campaign (for_term pins it; a stale
        // grant from an earlier pre-campaign must not contribute)
        if !self.prevote_active || !granted || for_term != self.term + 1 {
            return;
        }
        if !self.cfg_boot && !self.config.involves(from) {
            return; // a removed slot's pre-grant must not count
        }
        self.prevotes[from] = true;
        if self.grants_meet_quorum(&self.prevotes) {
            // a full election quorum is reachable and willing: campaign for
            // real (this is the only path that increments the term)
            self.start_candidacy(out);
        }
    }

    fn on_request_vote(
        &mut self,
        term: Term,
        candidate: NodeId,
        last_log_index: LogIndex,
        last_log_term: Term,
        out: &mut Vec<Output>,
    ) {
        let up_to_date = self.log.candidate_up_to_date(last_log_index, last_log_term);
        let can_vote =
            self.voted_for.is_none() || self.voted_for == Some(candidate);
        // Lease-mode vote stickiness (the §6.4.1 timing assumption made
        // explicit): while we have heard from a leader since our own last
        // election timeout, deny votes — otherwise a disruptor elected
        // inside another grantor's lease window could commit writes a lease
        // read would then miss. The log path keeps historical vote behavior.
        let sticky = matches!(self.read_path, ReadPath::Lease) && self.heard_from_leader;
        let granted = term >= self.term
            && can_vote
            && up_to_date
            && !sticky
            && (self.cfg_boot || self.config.involves(candidate));
        if granted {
            self.voted_for = Some(candidate);
            // persist-before-reply: the grant must be durable before the
            // reply below releases — this is the restart-amnesia
            // double-vote window (lose the vote, restart, grant the same
            // term to a second candidate, elect two leaders)
            self.emit_hard_state(out);
            out.push(Output::ResetElectionTimer);
        }
        out.push(Output::Send(
            candidate,
            Message::RequestVoteReply { term: self.term, from: self.id, granted },
        ));
    }

    fn on_vote_reply(
        &mut self,
        term: Term,
        from: NodeId,
        granted: bool,
        out: &mut Vec<Output>,
    ) {
        // only count replies for the current term — a delayed grant from an
        // earlier candidacy must not contribute to this one (the chaos tests
        // construct exactly that schedule)
        if self.role != Role::Candidate || term != self.term || !granted {
            return;
        }
        if !self.cfg_boot && !self.config.involves(from) {
            return; // a removed slot's vote must not count
        }
        self.votes[from] = true;
        if self.grants_meet_quorum(&self.votes) {
            self.become_leader(out);
        }
    }

    /// Election quorum check, config-aware: on the bootstrap config this is
    /// the historical `election_quorum(n)` count; under dynamic membership
    /// the quorum is over the *voter* set — and during a joint config it
    /// must be met in both halves independently (Raft §4.3).
    fn grants_meet_quorum(&self, grants: &[bool]) -> bool {
        if self.cfg_boot {
            let have = grants.iter().filter(|&&v| v).count();
            return have >= self.mode.election_quorum(self.n);
        }
        let m = self.config.voter_count();
        let have_new = self.config.voters().filter(|&v| grants[v]).count();
        let q_new = match &self.mode {
            Mode::Raft => m / 2 + 1,
            // the scheme is rebuilt per config, so scheme.t() matches m
            Mode::Cabinet { scheme } => m.saturating_sub(scheme.t()),
        };
        if have_new < q_new {
            return false;
        }
        if let Some(old) = &self.config.joint_old {
            let mo = old.len();
            let have_old = old.iter().filter(|&&v| grants[v]).count();
            let q_old = match &self.mode {
                Mode::Raft => mo / 2 + 1,
                Mode::Cabinet { scheme } => {
                    let t_old = scheme.t().min(mo.saturating_sub(1) / 2).max(1);
                    mo.saturating_sub(t_old)
                }
            };
            if have_old < q_old {
                return false;
            }
        }
        true
    }

    fn become_leader(&mut self, out: &mut Vec<Output>) {
        self.role = Role::Leader;
        self.prevote_active = false;
        self.next_index = vec![self.log.last_index() + 1; self.n];
        self.match_index = vec![0; self.n];
        self.match_index[self.id] = self.log.last_index();
        // The new leader resumes from the highest weight clock it has seen
        // (Theorem 4.2: weight clocks monotonically increase).
        self.wclock = self.wclock.max(self.my_wclock);
        self.weight_assign = if self.cfg_boot {
            initial_assignment(self.id, self.n, &self.mode)
        } else {
            config_assignment(self.id, &self.config, &self.mode, self.n)
        };
        self.reply_order.clear();
        self.replied = vec![false; self.n];
        self.inflight.clear();
        self.pending_reconfig = None;
        if !self.cfg_boot {
            // Membership recovery: the drain/warmup overlay died with the
            // old leader, but the committed config's member states carry
            // enough to resume the operation from its current phase.
            self.refresh_joint_assign();
            self.pending_config = None;
            self.admin_queue.clear();
            self.active_op = if self.config.is_joint() {
                Some(AdminPhase::Joint)
            } else if let Some(m) =
                self.config.members.iter().find(|m| m.state == MemberState::Draining)
            {
                Some(AdminPhase::Draining {
                    node: m.id,
                    remaining: self.drain_rounds,
                    w_start: self.weight_assign[m.id],
                })
            } else if let Some(m) =
                self.config.members.iter().find(|m| m.state == MemberState::Joining)
            {
                Some(AdminPhase::Warmup { node: m.id, acks: 0 })
            } else {
                None
            };
            // an inherited, still-uncommitted config entry gates the next
            // phase exactly like one we proposed ourselves
            self.pending_config = self
                .log
                .latest_config()
                .and_then(|(i, _)| (i > self.commit_index).then_some(i));
        }
        // read state: a new regime re-earns its lease and starts its own
        // confirmation rounds from scratch
        self.pending_confirm.clear();
        self.lease_until_ms = 0.0;
        self.leader_hint = None;
        out.push(Output::BecameLeader { term: self.term });
        out.push(Output::StartHeartbeat);
        // Commit a no-op barrier to establish leadership completeness.
        self.start_round();
        let my_w = self.weight_assign[self.id];
        let idx = self.log.append(
            Entry { term: self.term, index: 0, payload: Payload::Noop, wclock: self.wclock },
            my_w,
        );
        if self.durable {
            let e = self.log.get(idx).cloned().expect("barrier just appended");
            out.push(Output::PersistEntries {
                prev_index: idx - 1,
                weight: my_w,
                entries: vec![e],
            });
        }
        self.match_index[self.id] = idx;
        self.register_inflight(idx);
        // ReadIndex is only valid once this barrier commits (§6.4 step 1)
        self.barrier_index = idx;
        self.broadcast_append(out);
    }

    fn become_follower(&mut self, term: Term, out: &mut Vec<Output>) {
        let was_leader = self.role == Role::Leader;
        let adopted_term = term > self.term;
        if adopted_term {
            self.voted_for = None;
        }
        self.term = term;
        self.role = Role::Follower;
        self.prevote_active = false;
        if adopted_term {
            // the adopted term gates which votes we may grant — it must be
            // durable before any reply the caller pushes after us
            self.emit_hard_state(out);
        }
        // retreat-on-conflict: any in-flight rounds die with the leadership
        self.inflight.clear();
        // ... and so do outstanding read-confirmation rounds and the lease:
        // local reads fail loudly (their clients retry against the new
        // leader); forwarded reads are simply dropped, their origin retries
        for rc in self.pending_confirm.drain(..) {
            for (id, origin) in rc.reads {
                if origin == self.id {
                    out.push(Output::ReadFailed { id });
                }
            }
        }
        self.lease_until_ms = 0.0;
        // leader-local membership overlay dies with the leadership; the new
        // leader reconstructs it from the committed config
        self.pending_config = None;
        self.active_op = None;
        self.admin_queue.clear();
        self.joint_assign = None;
        if was_leader {
            out.push(Output::StopHeartbeat);
            out.push(Output::SteppedDown);
        }
        out.push(Output::ResetElectionTimer);
    }

    fn peers(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.n)
            .filter(move |&p| p != self.id && (self.cfg_boot || self.config.involves(p)))
    }

    // ---- dynamic membership internals ------------------------------------

    /// Make `cfg` the effective config on this node (leader: at propose;
    /// follower: at append — Raft's config-on-append rule). Rebuilds the
    /// Cabinet scheme for the new voter count, and on leaders re-deals the
    /// weight assignment and the joint old-half snapshot.
    fn adopt_config(&mut self, cfg: Arc<ClusterConfig>) {
        self.cfg_boot = cfg.is_bootstrap(self.n);
        if let Mode::Cabinet { scheme } = &self.mode {
            let m = cfg.voter_count();
            if m != scheme.n() && m >= 3 {
                let t = scheme.t().min(m.saturating_sub(1) / 2).max(1);
                if let Ok(s) = WeightScheme::geometric(m, t) {
                    self.mode = Mode::Cabinet { scheme: s };
                }
            }
        }
        self.config = cfg;
        if self.role == Role::Leader {
            self.weight_assign =
                config_assignment(self.id, &self.config, &self.mode, self.n);
            self.refresh_joint_assign();
        }
    }

    /// Recompute the leader's old-half weight snapshot for the joint phase.
    /// The old half gets its own geometric deal (leader first when it is an
    /// old voter, then ascending id); it only ever feeds acc-vs-CT checks,
    /// so responsiveness re-dealing it would add nothing.
    fn refresh_joint_assign(&mut self) {
        let Some(old) = self.config.joint_old.clone() else {
            self.joint_assign = None;
            return;
        };
        let mo = old.len();
        let mut w = vec![0.0; self.n];
        let ct = match &self.mode {
            Mode::Raft => {
                for &v in &old {
                    w[v] = 1.0;
                }
                mo as f64 / 2.0
            }
            Mode::Cabinet { scheme } => {
                let t_old = scheme.t().min(mo.saturating_sub(1) / 2).max(1);
                match WeightScheme::geometric(mo, t_old) {
                    Ok(s) => {
                        let mut rank = 0usize;
                        if old.contains(&self.id) {
                            w[self.id] = s.weight_of_rank(0);
                            rank = 1;
                        }
                        for &v in &old {
                            if v != self.id {
                                w[v] = s.weight_of_rank(rank);
                                rank += 1;
                            }
                        }
                        s.ct()
                    }
                    Err(_) => {
                        // degenerate old half (< 3 voters): unweighted
                        for &v in &old {
                            w[v] = 1.0;
                        }
                        mo as f64 / 2.0
                    }
                }
            }
        };
        self.joint_assign = Some((w, ct));
    }

    /// Re-derive the effective config after a log splice: the latest config
    /// entry still in the log wins; failing that, the snapshot's; failing
    /// that, the boot config (a conflicting splice rolled every config
    /// entry back — the Raft config-on-append rule demands the rollback).
    fn refresh_config_from_log(&mut self) {
        let cfg = self
            .log
            .latest_config()
            .map(|(_, c)| c)
            .or_else(|| self.snapshot.as_ref().and_then(|b| b.config.clone()))
            .unwrap_or_else(|| Arc::clone(&self.boot_config));
        if cfg != self.config {
            self.adopt_config(cfg);
        }
    }

    /// Driver-facing admin entry point (leader only).
    fn on_admin(&mut self, cmd: AdminCmd, out: &mut Vec<Output>) {
        if self.role != Role::Leader {
            return;
        }
        self.admin_queue.push_back(cmd);
        self.maybe_advance_membership(out);
    }

    /// Advance the membership state machine one phase. Called whenever the
    /// gate that was holding it may have opened: a config entry committed, a
    /// heartbeat fired (drain/warmup progress), or a command arrived.
    /// `pending_config == None` is the proof that the previous config entry
    /// committed, so each arm below runs exactly once per phase.
    fn maybe_advance_membership(&mut self, out: &mut Vec<Output>) {
        if self.role != Role::Leader || self.pending_config.is_some() {
            return;
        }
        match self.active_op {
            Some(AdminPhase::MarkDraining(node)) => {
                // the Draining mark committed: run the ramp
                self.active_op = Some(AdminPhase::Draining {
                    node,
                    remaining: self.drain_rounds,
                    w_start: self.weight_assign[node],
                });
            }
            Some(AdminPhase::Draining { node, remaining: 0, .. }) => {
                // drained to the floor: joint-remove it
                let members: Vec<MemberSpec> = self
                    .config
                    .members
                    .iter()
                    .filter(|m| m.id != node)
                    .copied()
                    .collect();
                let cfg = ClusterConfig {
                    epoch: self.config.epoch + 1,
                    members,
                    joint_old: Some(self.config.voters().collect()),
                };
                self.active_op = Some(AdminPhase::Joint);
                self.propose_config(cfg, out);
            }
            Some(AdminPhase::Draining { .. }) => {} // ramp still running
            Some(AdminPhase::Joint) => {
                // C_old,new committed under both halves: leave the joint
                let cfg = ClusterConfig {
                    epoch: self.config.epoch + 1,
                    members: self.config.members.clone(),
                    joint_old: None,
                };
                self.active_op = Some(AdminPhase::Leaving);
                self.propose_config(cfg, out);
            }
            Some(AdminPhase::Leaving) => {
                // C_new committed alone
                if let Some(m) =
                    self.config.members.iter().find(|m| m.state == MemberState::Joining)
                {
                    self.active_op = Some(AdminPhase::Warmup { node: m.id, acks: 0 });
                } else {
                    self.active_op = None;
                    if !self.config.is_voter(self.id) {
                        // The removed leader led through the joint phase
                        // (Raft §4.3) and now steps down — clearing its
                        // lease *before* the remaining voters can elect.
                        self.become_follower(self.term, out);
                        return;
                    }
                }
            }
            Some(AdminPhase::Warmup { node, acks }) if acks >= self.join_warmup => {
                // the joiner proved responsive: promote to Active
                let members: Vec<MemberSpec> = self
                    .config
                    .members
                    .iter()
                    .map(|m| {
                        if m.id == node {
                            MemberSpec { id: m.id, state: MemberState::Active }
                        } else {
                            *m
                        }
                    })
                    .collect();
                let cfg = ClusterConfig {
                    epoch: self.config.epoch + 1,
                    members,
                    joint_old: None,
                };
                self.active_op = Some(AdminPhase::Promoting(node));
                self.propose_config(cfg, out);
            }
            Some(AdminPhase::Warmup { .. }) => {} // still earning weight
            Some(AdminPhase::Promoting(_)) => {
                self.active_op = None;
            }
            None => {}
        }
        if self.active_op.is_none() && self.pending_config.is_none() {
            if let Some(cmd) = self.admin_queue.pop_front() {
                self.start_admin(cmd, out);
            }
        }
    }

    /// Begin a queued admin command. Invalid commands (unknown slot, already
    /// a member, would shrink the voter set below the scheme minimum) are
    /// dropped — drivers validate schedules up front.
    fn start_admin(&mut self, cmd: AdminCmd, out: &mut Vec<Output>) {
        match cmd {
            AdminCmd::Join(node) => {
                if node >= self.n || self.config.involves(node) {
                    return;
                }
                let mut members = self.config.members.clone();
                members.push(MemberSpec { id: node, state: MemberState::Joining });
                members.sort_by_key(|m| m.id);
                let cfg = ClusterConfig {
                    epoch: self.config.epoch + 1,
                    members,
                    joint_old: Some(self.config.voters().collect()),
                };
                self.active_op = Some(AdminPhase::Joint);
                self.propose_config(cfg, out);
            }
            AdminCmd::Leave(node) => {
                // keep ≥ 3 voters after removal (geometric scheme minimum)
                if !self.config.is_voter(node) || self.config.voter_count() <= 3 {
                    return;
                }
                let members: Vec<MemberSpec> = self
                    .config
                    .members
                    .iter()
                    .map(|m| {
                        if m.id == node {
                            MemberSpec { id: m.id, state: MemberState::Draining }
                        } else {
                            *m
                        }
                    })
                    .collect();
                let cfg = ClusterConfig {
                    epoch: self.config.epoch + 1,
                    members,
                    joint_old: None,
                };
                self.active_op = Some(AdminPhase::MarkDraining(node));
                self.propose_config(cfg, out);
            }
        }
    }

    /// Propose a config entry. The config takes effect immediately on this
    /// leader (config-on-append), so the entry's own round already runs
    /// under the new rule — in particular a C_old,new entry must commit
    /// under *both* halves, and the C_new entry that leaves the joint phase
    /// commits under C_new alone.
    fn propose_config(&mut self, cfg: ClusterConfig, out: &mut Vec<Output>) {
        let cfg = Arc::new(cfg);
        self.adopt_config(Arc::clone(&cfg));
        self.start_round();
        let entry = Entry {
            term: self.term,
            index: 0,
            payload: Payload::ConfigChange(Arc::clone(&cfg)),
            wclock: self.wclock,
        };
        let my_w = self.weight_assign[self.id];
        let idx = self.log.append(entry, my_w);
        self.match_index[self.id] = idx;
        self.register_inflight(idx);
        self.pending_config = Some(idx);
        self.broadcast_append(out);
    }
}

/// Initial weight assignment: descending by node id, but the given node
/// (the prospective leader) holds the top weight (§4.1.1 + Algorithm 1).
fn initial_assignment(id: NodeId, n: usize, mode: &Mode) -> Vec<f64> {
    match mode {
        Mode::Raft => vec![1.0; n],
        Mode::Cabinet { scheme } => {
            let mut assign = vec![0.0; n];
            assign[id] = scheme.weight_of_rank(0);
            let mut rank = 1;
            for node in 0..n {
                if node != id {
                    assign[node] = scheme.weight_of_rank(rank);
                    rank += 1;
                }
            }
            assign
        }
    }
}

/// Config-aware initial assignment over `n_slots` slots: the scheme deals
/// over the config's *voters* only (the given node first, then ascending
/// id), every non-member slot holds weight 0.0. Reduces to
/// [`initial_assignment`] on the bootstrap config.
fn config_assignment(
    id: NodeId,
    config: &ClusterConfig,
    mode: &Mode,
    n_slots: usize,
) -> Vec<f64> {
    let mut assign = vec![0.0; n_slots];
    match mode {
        Mode::Raft => {
            for v in config.voters() {
                assign[v] = 1.0;
            }
        }
        Mode::Cabinet { scheme } => {
            let mut rank = 0usize;
            if config.is_voter(id) {
                assign[id] = scheme.weight_of_rank(rank);
                rank += 1;
            }
            for v in config.voters() {
                if v != id {
                    assign[v] = scheme.weight_of_rank(rank);
                    rank += 1;
                }
            }
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Drive a full in-memory cluster synchronously: deliver all outputs
    /// until quiescent. Returns commits per node.
    struct TestCluster {
        nodes: Vec<Node>,
        commits: Vec<Vec<Entry>>,
        /// Served reads: (node, request id, read index, via lease).
        reads: Vec<(NodeId, u64, LogIndex, bool)>,
    }

    impl TestCluster {
        fn new(n: usize, mode_of: impl Fn(usize) -> Mode) -> Self {
            TestCluster {
                nodes: (0..n).map(|i| Node::new(i, n, mode_of(i))).collect(),
                commits: vec![Vec::new(); n],
                reads: Vec::new(),
            }
        }

        fn cabinet(n: usize, t: usize) -> Self {
            Self::new(n, |_| Mode::cabinet(n, t))
        }

        fn raft(n: usize) -> Self {
            Self::new(n, |_| Mode::Raft)
        }

        /// Elect node `id` by firing its election timer and pumping msgs.
        fn elect(&mut self, id: NodeId) {
            let outs = self.nodes[id].step(Input::ElectionTimeout);
            self.pump(id, outs);
            assert_eq!(self.nodes[id].role(), Role::Leader, "election failed");
        }

        fn propose(&mut self, leader: NodeId, payload: Payload) {
            let outs = self.nodes[leader].step(Input::Propose(payload));
            self.pump(leader, outs);
        }

        /// Fire the leader heartbeat so followers learn the commit index
        /// (commit propagation piggybacks on the next AppendEntries).
        fn heartbeat(&mut self, leader: NodeId) {
            let outs = self.nodes[leader].step(Input::HeartbeatTimeout);
            self.pump(leader, outs);
        }

        /// Synchronous message pump (in-order delivery, no drops).
        fn pump(&mut self, from: NodeId, outs: Vec<Output>) {
            let mut queue: Vec<(NodeId, NodeId, Message)> = Vec::new();
            self.collect(from, outs, &mut queue);
            while let Some((src, dst, msg)) = queue.pop() {
                let outs = self.nodes[dst].step(Input::Receive(src, msg));
                self.collect(dst, outs, &mut queue);
            }
        }

        fn collect(
            &mut self,
            src: NodeId,
            outs: Vec<Output>,
            queue: &mut Vec<(NodeId, NodeId, Message)>,
        ) {
            for o in outs {
                match o {
                    Output::Send(dst, msg) => queue.push((src, dst, msg)),
                    Output::Commit(e) => self.commits[src].push(e),
                    Output::ReadReady { id, index, lease } => {
                        self.reads.push((src, id, index, lease))
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn raft_elects_and_commits() {
        let mut c = TestCluster::raft(5);
        c.elect(0);
        c.propose(0, Payload::Bytes(std::sync::Arc::new(vec![1])));
        c.heartbeat(0);
        // every node commits noop + payload
        for (i, commits) in c.commits.iter().enumerate() {
            assert_eq!(commits.len(), 2, "node {i}");
        }
    }

    #[test]
    fn cabinet_elects_and_commits() {
        let mut c = TestCluster::cabinet(7, 2);
        c.elect(0);
        for k in 0..5 {
            c.propose(0, Payload::Bytes(std::sync::Arc::new(vec![k])));
        }
        c.heartbeat(0);
        for commits in &c.commits {
            assert_eq!(commits.len(), 6); // noop + 5
        }
        assert_eq!(c.nodes[0].wclock(), 6);
    }

    #[test]
    fn coding_cutover_boundary_picks_the_path() {
        let mut c = TestCluster::raft(5);
        c.elect(0);
        c.nodes[0].set_coding(Some((2, 100)));
        // 83-byte value ⇒ 99 wire bytes: one below the cutover, full copy.
        let outs = c.nodes[0].step(Input::Propose(Payload::Bytes(Arc::new(vec![0; 83]))));
        assert!(outs
            .iter()
            .any(|o| matches!(o, Output::Send(_, Message::AppendEntries { .. }))));
        assert!(outs
            .iter()
            .all(|o| !matches!(o, Output::Send(_, Message::AppendEntriesShard { .. }))));
        c.pump(0, outs);
        // 84-byte value ⇒ exactly 100 wire bytes: at the cutover, coded.
        let outs = c.nodes[0].step(Input::Propose(Payload::Bytes(Arc::new(vec![0; 84]))));
        assert!(outs
            .iter()
            .any(|o| matches!(o, Output::Send(_, Message::AppendEntriesShard { .. }))));
        assert!(outs
            .iter()
            .all(|o| !matches!(o, Output::Send(_, Message::AppendEntries { .. }))));
        c.pump(0, outs);
        c.heartbeat(0);
        for commits in &c.commits {
            assert_eq!(commits.len(), 3); // noop + full-copy + coded
        }
        // the leader keeps the full payload; followers hold shards
        assert!(matches!(c.commits[0][2].payload, Payload::Bytes(_)));
        for commits in &c.commits[1..] {
            assert!(matches!(commits[2].payload, Payload::Shard(_)));
        }
    }

    #[test]
    fn coded_commit_requires_k_distinct_shards() {
        let mut c = TestCluster::raft(5);
        c.elect(0);
        c.nodes[0].set_coding(Some((2, 64)));
        let outs = c.nodes[0].step(Input::Propose(Payload::Bytes(Arc::new(vec![7; 256]))));
        let sends: Vec<(NodeId, Message)> = outs
            .into_iter()
            .filter_map(|o| match o {
                Output::Send(dst, m) => Some((dst, m)),
                _ => None,
            })
            .collect();
        // Peers 1 and 4 hold the same shard slot (peer % 3 = 1): with the
        // leader that is a Raft count majority, but only ONE distinct shard
        // of the k = 2 needed — the weight rule alone would commit here.
        let mut deliver = |c: &mut TestCluster, dst: NodeId| {
            let msg = sends.iter().find(|(d, _)| *d == dst).unwrap().1.clone();
            let replies = c.nodes[dst].step(Input::Receive(0, msg));
            for r in replies {
                if let Output::Send(0, m) = r {
                    let outs = c.nodes[0].step(Input::Receive(dst, m));
                    c.collect(0, outs, &mut Vec::new());
                }
            }
        };
        deliver(&mut c, 1);
        deliver(&mut c, 4);
        assert_eq!(
            c.nodes[0].commit_index(),
            1,
            "weight majority with an unreconstructable shard set must not commit"
        );
        // A second distinct shard (peer 2 ⇒ slot 2) completes the set.
        deliver(&mut c, 2);
        assert_eq!(c.nodes[0].commit_index(), 2);
        assert_eq!(c.commits[0].len(), 2);
    }

    #[test]
    fn propose_all_coalesces_one_round() {
        let mut c = TestCluster::cabinet(5, 1);
        c.elect(0);
        let w0 = c.nodes[0].wclock();
        let mut outs = Vec::new();
        c.nodes[0].propose_all(
            (0..3u8).map(|i| Payload::Bytes(Arc::new(vec![i]))).collect(),
            &mut outs,
        );
        assert_eq!(c.nodes[0].wclock(), w0 + 1, "one round for the whole batch");
        for dst in 1..5usize {
            let appends: Vec<usize> = outs
                .iter()
                .filter_map(|o| match o {
                    Output::Send(d, Message::AppendEntries { entries, .. }) if *d == dst => {
                        Some(entries.len())
                    }
                    _ => None,
                })
                .collect();
            assert_eq!(appends, vec![3], "peer {dst} gets one AppendEntries with 3 entries");
        }
        c.pump(0, outs);
        c.heartbeat(0);
        for commits in &c.commits {
            assert_eq!(commits.len(), 4); // noop + batch of 3
        }
        // all batch entries share the round's wclock
        let ws: Vec<u64> = c.commits[0][1..].iter().map(|e| e.wclock).collect();
        assert_eq!(ws, vec![w0 + 1, w0 + 1, w0 + 1]);
    }

    #[test]
    fn propose_all_rejects_control_payloads() {
        let mut c = TestCluster::raft(3);
        c.elect(0);
        let mut outs = Vec::new();
        c.nodes[0].propose_all(vec![Payload::Noop, Payload::Reconfig { new_t: 2 }], &mut outs);
        assert!(outs
            .iter()
            .any(|o| matches!(o, Output::ProposalRejected(Payload::Reconfig { .. }))));
        c.pump(0, outs);
        c.heartbeat(0);
        assert_eq!(c.commits[0].len(), 2); // noop + the Noop from the batch
    }

    #[test]
    fn leader_keeps_top_weight() {
        let mut c = TestCluster::cabinet(7, 2);
        c.elect(3);
        c.propose(3, Payload::Noop);
        let w = c.nodes[3].weight_assignment();
        let max = w.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(w[3], max);
    }

    #[test]
    fn weights_are_a_permutation_of_the_scheme() {
        let mut c = TestCluster::cabinet(7, 2);
        c.elect(0);
        c.propose(0, Payload::Noop);
        c.propose(0, Payload::Noop);
        let scheme = WeightScheme::geometric(7, 2).unwrap();
        let mut got: Vec<f64> = c.nodes[0].weight_assignment().to_vec();
        got.sort_by(|a, b| b.total_cmp(a));
        for (g, w) in got.iter().zip(scheme.weights()) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn cabinet_members_are_t_plus_1() {
        let mut c = TestCluster::cabinet(7, 2);
        c.elect(0);
        c.propose(0, Payload::Noop);
        let members = c.nodes[0].cabinet_members();
        assert_eq!(members.len(), 3);
        assert!(members.contains(&0)); // leader always a member
    }

    #[test]
    fn nan_weight_survives_election_and_redeal() {
        // Regression: the weight-ordered sorts used partial_cmp().unwrap(),
        // so a single NaN weight panicked the FIFO re-deal and every
        // membership query. A NaN scheme is constructible through the public
        // API — validate() passes it vacuously (NaN comparisons are false) —
        // so the node must degrade (NaN ranks highest, rounds stall against
        // the NaN threshold) rather than crash mid-election.
        let scheme = WeightScheme::from_weights(vec![8.0, f64::NAN, 4.0, 2.0, 1.0], 1)
            .expect("NaN passes I1/I2 vacuously");
        let mut c = TestCluster::new(5, |_| Mode::Cabinet { scheme: scheme.clone() });
        c.elect(0); // count-based quorum (n - t): unaffected by NaN weights
        c.propose(0, Payload::Noop); // first weight re-deal
        c.propose(0, Payload::Noop); // re-deal again, sorting the NaN assignment
        let members = c.nodes[0].cabinet_members(); // weight-ordered query
        assert_eq!(members.len(), 2);
        assert!(c.nodes[0].weight_assignment().iter().any(|w| w.is_nan()));
    }

    #[test]
    fn follower_stores_weight_from_rpc() {
        let mut c = TestCluster::cabinet(5, 1);
        c.elect(0);
        c.propose(0, Payload::Noop);
        for i in 1..5 {
            assert!(c.nodes[i].my_weight() > 0.0);
            assert_eq!(c.nodes[i].my_wclock, c.nodes[0].wclock());
        }
    }

    #[test]
    fn proposal_rejected_at_follower() {
        let mut c = TestCluster::raft(3);
        c.elect(0);
        let outs = c.nodes[1].step(Input::Propose(Payload::Noop));
        assert!(matches!(outs[0], Output::ProposalRejected(_)));
    }

    #[test]
    fn election_quorum_sizes() {
        assert_eq!(Mode::Raft.election_quorum(10), 6);
        assert_eq!(Mode::cabinet(10, 3).election_quorum(10), 7);
        assert_eq!(Mode::cabinet(10, 1).election_quorum(10), 9);
    }

    #[test]
    fn higher_term_steps_leader_down() {
        let mut c = TestCluster::raft(3);
        c.elect(0);
        let outs = c.nodes[0].step(Input::Receive(
            1,
            Message::RequestVote { term: 99, candidate: 1, last_log_index: 5, last_log_term: 9 },
        ));
        assert_eq!(c.nodes[0].role(), Role::Follower);
        assert!(outs.iter().any(|o| matches!(o, Output::SteppedDown)));
    }

    #[test]
    fn stale_append_entries_rejected() {
        let mut c = TestCluster::raft(3);
        c.elect(0);
        let outs = c.nodes[1].step(Input::Receive(
            2,
            Message::AppendEntries {
                term: 0, // stale
                leader: 2,
                prev_log_index: 0,
                prev_log_term: 0,
                entries: vec![],
                leader_commit: 0,
                wclock: 0,
                weight: 1.0,
            },
        ));
        let reply = outs
            .iter()
            .find_map(|o| match o {
                Output::Send(_, Message::AppendEntriesReply { success, .. }) => Some(*success),
                _ => None,
            })
            .unwrap();
        assert!(!reply);
    }

    #[test]
    fn no_double_vote_in_same_term() {
        let mut n = Node::new(0, 3, Mode::Raft);
        let o1 = n.step(Input::Receive(
            1,
            Message::RequestVote { term: 1, candidate: 1, last_log_index: 0, last_log_term: 0 },
        ));
        let o2 = n.step(Input::Receive(
            2,
            Message::RequestVote { term: 1, candidate: 2, last_log_index: 0, last_log_term: 0 },
        ));
        let granted = |outs: &[Output]| {
            outs.iter()
                .find_map(|o| match o {
                    Output::Send(_, Message::RequestVoteReply { granted, .. }) => Some(*granted),
                    _ => None,
                })
                .unwrap()
        };
        assert!(granted(&o1));
        assert!(!granted(&o2));
    }

    #[test]
    fn vote_denied_to_stale_log() {
        let mut c = TestCluster::raft(3);
        c.elect(0);
        c.propose(0, Payload::Noop);
        // node 2 (up to date) denies a vote to an empty-log candidate
        let outs = c.nodes[2].step(Input::Receive(
            1,
            Message::RequestVote { term: 50, candidate: 1, last_log_index: 0, last_log_term: 0 },
        ));
        let granted = outs
            .iter()
            .find_map(|o| match o {
                Output::Send(_, Message::RequestVoteReply { granted, .. }) => Some(*granted),
                _ => None,
            })
            .unwrap();
        assert!(!granted);
    }

    #[test]
    fn reconfig_switches_scheme_cluster_wide() {
        let mut c = TestCluster::cabinet(11, 4);
        c.elect(0);
        c.propose(0, Payload::Reconfig { new_t: 2 });
        c.heartbeat(0);
        for node in &c.nodes {
            match node.mode() {
                Mode::Cabinet { scheme } => assert_eq!(scheme.t(), 2, "node {}", node.id()),
                _ => panic!("not cabinet"),
            }
        }
        // proposals accepted again after the transition
        c.propose(0, Payload::Noop);
        assert_eq!(c.nodes[0].commit_index(), 3);
    }

    #[test]
    fn reconfig_blocks_interim_proposals() {
        let mut n = Node::new(0, 5, Mode::cabinet(5, 2));
        // force leadership without a cluster: run election + fake votes
        let _ = n.step(Input::ElectionTimeout);
        let _ = n.step(Input::Receive(
            1,
            Message::RequestVoteReply { term: 1, from: 1, granted: true },
        ));
        let _ = n.step(Input::Receive(
            2,
            Message::RequestVoteReply { term: 1, from: 2, granted: true },
        ));
        assert_eq!(n.role(), Role::Leader);
        let _ = n.step(Input::Propose(Payload::Reconfig { new_t: 1 }));
        let outs = n.step(Input::Propose(Payload::Noop));
        assert!(matches!(outs[0], Output::ProposalRejected(_)));
    }

    #[test]
    fn fifo_reply_order_shapes_next_round() {
        // Drive the leader manually so we control reply arrival order.
        let n = 5;
        let mut leader = Node::new(0, n, Mode::cabinet(n, 1));
        let _ = leader.step(Input::ElectionTimeout);
        for p in [1, 2, 3] {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
        }
        assert_eq!(leader.role(), Role::Leader);
        // round 1: replies arrive 4, 3, 2, 1
        let _ = leader.step(Input::Propose(Payload::Noop));
        let wc = leader.wclock();
        let last = leader.log().last_index();
        for p in [4, 3, 2, 1] {
            let _ = leader.step(Input::Receive(
                p,
                Message::AppendEntriesReply {
                    term: 1,
                    from: p,
                    success: true,
                    match_index: last,
                    wclock: wc,
                },
            ));
        }
        // round 2: node 4 (fastest) must now hold the 2nd-highest weight
        let _ = leader.step(Input::Propose(Payload::Noop));
        let w = leader.weight_assignment();
        let scheme = WeightScheme::geometric(n, 1).unwrap();
        assert!((w[0] - scheme.weight_of_rank(0)).abs() < 1e-12);
        assert!((w[4] - scheme.weight_of_rank(1)).abs() < 1e-12);
        assert!((w[3] - scheme.weight_of_rank(2)).abs() < 1e-12);
        assert!((w[2] - scheme.weight_of_rank(3)).abs() < 1e-12);
        assert!((w[1] - scheme.weight_of_rank(4)).abs() < 1e-12);
    }

    #[test]
    fn cabinet_commits_with_cabinet_members_only() {
        // n=7, t=2: leader + 2 fastest replies must be enough to commit.
        let n = 7;
        let mut leader = Node::new(0, n, Mode::cabinet(n, 2));
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..=4 {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
        }
        assert_eq!(leader.role(), Role::Leader);
        // commit the noop first (needs any quorum) — replies from 1..=2
        let wc = leader.wclock();
        let last = leader.log().last_index();
        for p in [1, 2] {
            let _ = leader.step(Input::Receive(
                p,
                Message::AppendEntriesReply {
                    term: 1,
                    from: p,
                    success: true,
                    match_index: last,
                    wclock: wc,
                },
            ));
        }
        assert_eq!(leader.commit_index(), last, "cabinet quorum should commit");
        // next round: 1 and 2 are cabinet members; their replies commit
        let _ = leader.step(Input::Propose(Payload::Noop));
        let wc = leader.wclock();
        let last = leader.log().last_index();
        let o1 = leader.step(Input::Receive(
            1,
            Message::AppendEntriesReply { term: 1, from: 1, success: true, match_index: last, wclock: wc },
        ));
        assert!(
            !o1.iter().any(|o| matches!(o, Output::RoundCommitted { .. })),
            "one cabinet member must not be enough"
        );
        let o2 = leader.step(Input::Receive(
            2,
            Message::AppendEntriesReply { term: 1, from: 2, success: true, match_index: last, wclock: wc },
        ));
        assert!(
            o2.iter().any(|o| matches!(o, Output::RoundCommitted { .. })),
            "t+1 cabinet members (leader + 2) must commit"
        );
    }

    #[test]
    fn raft_needs_majority_not_two() {
        let n = 7;
        let mut leader = Node::new(0, n, Mode::Raft);
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..=3 {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
        }
        assert_eq!(leader.role(), Role::Leader);
        let last = leader.log().last_index();
        for (i, p) in [1, 2].iter().enumerate() {
            let outs = leader.step(Input::Receive(
                *p,
                Message::AppendEntriesReply {
                    term: 1,
                    from: *p,
                    success: true,
                    match_index: last,
                    wclock: 1,
                },
            ));
            let committed = outs.iter().any(|o| matches!(o, Output::Commit(_)));
            assert!(!committed, "reply {i} must not commit under majority rule");
        }
        let outs = leader.step(Input::Receive(
            3,
            Message::AppendEntriesReply {
                term: 1,
                from: 3,
                success: true,
                match_index: last,
                wclock: 1,
            },
        ));
        assert!(outs.iter().any(|o| matches!(o, Output::Commit(_))));
    }

    /// Build an n-node leader with all votes collected, replies pending.
    fn solo_leader(n: usize, mode: Mode) -> Node {
        let mut leader = Node::new(0, n, mode);
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..n {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
            if leader.role() == Role::Leader {
                break;
            }
        }
        assert_eq!(leader.role(), Role::Leader);
        leader
    }

    fn ack(leader: &mut Node, from: NodeId, match_index: u64, wclock: u64) -> Vec<Output> {
        leader.step(Input::Receive(
            from,
            Message::AppendEntriesReply { term: 1, from, success: true, match_index, wclock },
        ))
    }

    #[test]
    fn pipelined_proposals_track_inflight_window() {
        let mut leader = solo_leader(5, Mode::cabinet(5, 1));
        // commit the noop barrier first
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        ack(&mut leader, 2, noop, leader.wclock());
        assert_eq!(leader.commit_index(), noop);
        assert_eq!(leader.inflight_len(), 0);
        // keep 4 rounds in flight without waiting for any ack
        for k in 0..4u8 {
            let _ = leader.step(Input::Propose(Payload::Bytes(Arc::new(vec![k]))));
        }
        assert_eq!(leader.inflight_len(), 4);
        assert_eq!(leader.log().last_index(), noop + 4);
        // one follower acking the whole suffix commits all four at once
        let wc = leader.wclock();
        let outs = ack(&mut leader, 1, noop + 4, wc);
        let outs2 = ack(&mut leader, 2, noop + 4, wc);
        let committed: Vec<u64> = outs
            .iter()
            .chain(outs2.iter())
            .filter_map(|o| match o {
                Output::Commit(e) => Some(e.index),
                _ => None,
            })
            .collect();
        assert_eq!(committed, vec![noop + 1, noop + 2, noop + 3, noop + 4]);
        assert_eq!(leader.inflight_len(), 0);
    }

    #[test]
    fn later_round_quorum_commits_earlier_rounds() {
        // Out-of-order ack tolerance: acks that name only the latest index
        // still commit the whole prefix (the ackers hold it by log matching).
        let mut leader = solo_leader(7, Mode::cabinet(7, 2));
        let noop = leader.log().last_index();
        for k in 0..3u8 {
            let _ = leader.step(Input::Propose(Payload::Bytes(Arc::new(vec![k]))));
        }
        let last = leader.log().last_index();
        assert_eq!(last, noop + 3);
        let wc = leader.wclock();
        // two cabinet members ack straight at the tail — never the
        // intermediate indices — and everything through `last` commits
        let o1 = ack(&mut leader, 1, last, wc);
        assert!(o1.iter().all(|o| !matches!(o, Output::RoundCommitted { .. })));
        let o2 = ack(&mut leader, 2, last, wc);
        assert!(
            o2.iter().any(
                |o| matches!(o, Output::RoundCommitted { index, .. } if *index == last)
            ),
            "tail quorum must commit the full prefix"
        );
        assert_eq!(leader.commit_index(), last);
    }

    #[test]
    fn inflight_snapshots_survive_mid_pipeline_reweighting() {
        // Round k's quorum is judged under round k's weight deal even after
        // later proposals re-deal the weights.
        let n = 7;
        let mut leader = solo_leader(n, Mode::cabinet(n, 2));
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        ack(&mut leader, 2, noop, leader.wclock());
        assert_eq!(leader.commit_index(), noop);
        // round A: nodes 1 and 2 replied fastest last round, so they hold
        // the top follower weights in A's deal
        let _ = leader.step(Input::Propose(Payload::Noop));
        let wc_a = leader.wclock();
        let idx_a = leader.log().last_index();
        // round B proposed before any round-A ack: re-deals weights again
        // (same FIFO order — 1, 2 — but a fresh snapshot is taken)
        let _ = leader.step(Input::Propose(Payload::Noop));
        // cabinet members 1+2 acking round A under its own snapshot commit it
        ack(&mut leader, 1, idx_a, wc_a);
        let outs = ack(&mut leader, 2, idx_a, wc_a);
        assert!(
            outs.iter().any(
                |o| matches!(o, Output::RoundCommitted { index, .. } if *index == idx_a)
            ),
            "round A must commit under its propose-time weights"
        );
        assert_eq!(leader.commit_index(), idx_a);
        assert_eq!(leader.inflight_len(), 1, "round B still in flight");
    }

    #[test]
    fn raft_pipeline_still_needs_majority_per_index() {
        let mut leader = solo_leader(5, Mode::Raft);
        let noop = leader.log().last_index();
        for k in 0..2u8 {
            let _ = leader.step(Input::Propose(Payload::Bytes(Arc::new(vec![k]))));
        }
        let last = leader.log().last_index();
        assert_eq!(last, noop + 2);
        // one follower at the tail: 2/5 — not a majority
        let outs = ack(&mut leader, 1, last, 0);
        assert!(outs.iter().all(|o| !matches!(o, Output::Commit(_))));
        assert_eq!(leader.commit_index(), 0);
        // second follower: 3/5 majority commits the whole window
        let outs = ack(&mut leader, 2, last, 0);
        assert!(outs.iter().any(|o| matches!(o, Output::Commit(_))));
        assert_eq!(leader.commit_index(), last);
    }

    #[test]
    fn stepping_down_clears_inflight_window() {
        let mut leader = solo_leader(5, Mode::cabinet(5, 2));
        for _ in 0..3 {
            let _ = leader.step(Input::Propose(Payload::Noop));
        }
        assert!(leader.inflight_len() >= 3);
        let _ = leader.step(Input::Receive(
            1,
            Message::RequestVote { term: 99, candidate: 1, last_log_index: 50, last_log_term: 98 },
        ));
        assert_eq!(leader.role(), Role::Follower);
        assert_eq!(leader.inflight_len(), 0, "retreat must drop the window");
    }

    #[test]
    fn reconfig_mid_pipeline_keeps_old_round_thresholds() {
        // Rounds in flight when a reconfig is proposed commit under the CT
        // they were proposed with; the reconfig round itself uses the new
        // scheme (§4.1.4). The ack patterns are chosen to discriminate the
        // two snapshots: each quorum clears exactly one scheme's CT.
        let n = 11;
        let mut leader = solo_leader(n, Mode::cabinet(n, 4));
        let noop = leader.log().last_index();
        // commit the barrier (top-5 under t=4 clears its CT by I1)
        for p in 1..=4 {
            ack(&mut leader, p, noop, leader.wclock());
        }
        assert_eq!(leader.commit_index(), noop);
        // a normal round under t=4, then a reconfig to t=2 mid-pipeline
        let _ = leader.step(Input::Propose(Payload::Noop));
        let idx_old = leader.log().last_index();
        let wc_old = leader.wclock();
        let _ = leader.step(Input::Propose(Payload::Reconfig { new_t: 2 }));
        assert!(leader.reconfig_pending());
        let idx_rc = leader.log().last_index();
        // leader + 4 acks at idx_old: clears the OLD round's t=4 CT (top-5,
        // I1) and commits it — while the reconfig round, unacked, stays put
        for p in 1..=4usize {
            ack(&mut leader, p, idx_old, wc_old);
        }
        assert_eq!(leader.commit_index(), idx_old, "old round commits under old CT");
        assert!(leader.reconfig_pending(), "reconfig round must still be in flight");
        // leader + 2 acks at idx_rc: clears the NEW t=2 CT (top-3, I1) but
        // would NOT clear the old t=4 CT (top-3 < CT by I2) — committing
        // here proves the reconfig round is judged under its own snapshot
        for p in 1..=2usize {
            ack(&mut leader, p, idx_rc, wc_old + 1);
        }
        assert_eq!(leader.commit_index(), idx_rc, "t+1 of the new scheme commits");
        assert!(!leader.reconfig_pending());
        match leader.mode() {
            Mode::Cabinet { scheme } => assert_eq!(scheme.t(), 2),
            _ => panic!("not cabinet"),
        }
    }

    #[test]
    fn snapshot_threshold_compacts_cluster_wide() {
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_snapshot_every(Some(2));
        }
        c.elect(0);
        for k in 0..5 {
            c.propose(0, Payload::Bytes(Arc::new(vec![k])));
        }
        c.heartbeat(0); // commit propagation → followers compact too
        let leader_cut = c.nodes[0].log().last_compacted_index();
        assert!(leader_cut >= 4, "leader must have compacted, cut = {leader_cut}");
        assert!(c.nodes[0].snapshots_taken() >= 2);
        let last = c.nodes[0].log().last_index();
        for i in 1..5 {
            assert!(c.nodes[i].log().last_compacted_index() >= 2, "node {i}");
            assert!(c.nodes[i].log().len() <= 3, "node {i} retained too much");
            // digest chain: every log fingerprints identically at the tail
            assert_eq!(
                c.nodes[i].log().prefix_digest(last),
                c.nodes[0].log().prefix_digest(last),
                "node {i}"
            );
        }
    }

    #[test]
    fn restarted_follower_catches_up_via_install_snapshot() {
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_snapshot_every(Some(2));
        }
        c.elect(0);
        for k in 0..6 {
            c.propose(0, Payload::Bytes(Arc::new(vec![k])));
        }
        // node 1 loses everything (crash + restart with a fresh disk); the
        // leader has compacted far past node 1's needs, so log repair alone
        // cannot recover it
        c.nodes[1] = Node::new(1, 5, Mode::cabinet(5, 1));
        c.propose(0, Payload::Noop);
        c.heartbeat(0);
        assert_eq!(c.nodes[1].snapshots_installed(), 1, "must catch up via snapshot");
        assert_eq!(c.nodes[1].commit_index(), c.nodes[0].commit_index());
        assert_eq!(c.nodes[1].log().last_index(), c.nodes[0].log().last_index());
        let last = c.nodes[0].log().last_index();
        assert_eq!(
            c.nodes[1].log().prefix_digest(last),
            c.nodes[0].log().prefix_digest(last),
            "digest chain must survive snapshot install"
        );
    }

    #[test]
    fn driver_capture_handshake_defers_compaction() {
        let mut leader = solo_leader(5, Mode::cabinet(5, 1));
        leader.set_snapshot_every(Some(1));
        leader.set_snapshot_capture(SnapshotCapture::Driver);
        let noop = leader.log().last_index();
        let o1 = ack(&mut leader, 1, noop, leader.wclock());
        let o2 = ack(&mut leader, 2, noop, leader.wclock());
        let req = o1.iter().chain(o2.iter()).find_map(|o| match o {
            Output::SnapshotRequest { through } => Some(*through),
            _ => None,
        });
        assert_eq!(req, Some(noop), "threshold crossing must request a capture");
        // no compaction until the driver answers with captured state
        assert_eq!(leader.log().last_compacted_index(), 0);
        let _ = leader.step(Input::Propose(Payload::Noop));
        leader.complete_snapshot(noop, AppState::None);
        assert_eq!(leader.log().last_compacted_index(), noop);
        assert_eq!(leader.snapshots_taken(), 1);
        assert!(leader.snapshot().is_some());
    }

    #[test]
    fn snapshot_mid_window_leaves_inflight_rounds_intact() {
        let mut leader = solo_leader(5, Mode::cabinet(5, 1));
        leader.set_snapshot_every(Some(1));
        let noop = leader.log().last_index();
        // open a 3-deep pipelined window before any ack
        for k in 0..3u8 {
            let _ = leader.step(Input::Propose(Payload::Bytes(Arc::new(vec![k]))));
        }
        assert_eq!(leader.inflight_len(), 3);
        let wc = leader.wclock();
        // committing the noop compacts to it immediately (every = 1) ...
        ack(&mut leader, 1, noop, wc);
        ack(&mut leader, 2, noop, wc);
        assert_eq!(leader.commit_index(), noop);
        assert_eq!(leader.log().last_compacted_index(), noop);
        // ... but the open rounds and their weight/CT snapshots are intact
        assert_eq!(leader.inflight_len(), 3);
        let o1 = ack(&mut leader, 1, noop + 3, wc);
        let o2 = ack(&mut leader, 2, noop + 3, wc);
        assert!(
            o1.iter().chain(o2.iter()).any(
                |o| matches!(o, Output::RoundCommitted { index, .. } if *index == noop + 3)
            ),
            "window must commit normally across a compaction"
        );
        assert_eq!(leader.commit_index(), noop + 3);
        assert_eq!(leader.log().last_compacted_index(), noop + 3);
        assert_eq!(leader.inflight_len(), 0);
    }

    #[test]
    fn install_snapshot_does_not_regress_newer_appended_reconfig() {
        // Raft §7: configuration info in the log supersedes the snapshot's.
        // A follower that already adopted a Reconfig from an appended entry
        // above the snapshot cut must keep it when a reordered/late
        // InstallSnapshot (cut below the reconfig, carrying the old t)
        // arrives.
        let n = 7;
        let mut f = Node::new(1, n, Mode::cabinet(n, 3));
        let entries = vec![
            Entry { term: 1, index: 1, payload: Payload::Noop, wclock: 1 },
            Entry { term: 1, index: 2, payload: Payload::Noop, wclock: 2 },
            Entry { term: 1, index: 3, payload: Payload::Reconfig { new_t: 1 }, wclock: 3 },
        ];
        let _ = f.step(Input::Receive(
            0,
            Message::AppendEntries {
                term: 1,
                leader: 0,
                prev_log_index: 0,
                prev_log_term: 0,
                entries,
                leader_commit: 0,
                wclock: 3,
                weight: 1.0,
            },
        ));
        match f.mode() {
            Mode::Cabinet { scheme } => assert_eq!(scheme.t(), 1, "adopted on append"),
            _ => panic!("not cabinet"),
        }
        let digest_at_2 = f.log().prefix_digest(2);
        let _ = f.step(Input::Receive(
            0,
            Message::InstallSnapshot {
                term: 1,
                leader: 0,
                snapshot: SnapshotBlob {
                    last_index: 2,
                    last_term: 1,
                    prefix_digest: digest_at_2,
                    wclock: 2,
                    cabinet_t: Some(3), // the pre-reconfig threshold
                    config: None,
                    app: AppState::None,
                },
            },
        ));
        assert_eq!(f.commit_index(), 2, "snapshot still advances the commit");
        assert_eq!(f.log().last_index(), 3, "suffix above the cut retained");
        match f.mode() {
            Mode::Cabinet { scheme } => {
                assert_eq!(scheme.t(), 1, "newer log config must not regress")
            }
            _ => panic!("not cabinet"),
        }
    }

    #[test]
    fn stale_install_snapshot_is_skipped() {
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_snapshot_every(Some(2));
        }
        c.elect(0);
        for k in 0..4 {
            c.propose(0, Payload::Bytes(Arc::new(vec![k])));
        }
        c.heartbeat(0);
        let commit = c.nodes[2].commit_index();
        let blob = c.nodes[0].snapshot().expect("leader snapshotted").clone();
        // a duplicate delivery must neither install nor regress anything
        let outs = c.nodes[2].step(Input::Receive(
            0,
            Message::InstallSnapshot { term: c.nodes[0].term(), leader: 0, snapshot: blob },
        ));
        assert_eq!(c.nodes[2].commit_index(), commit);
        assert_eq!(c.nodes[2].snapshots_installed(), 0);
        assert!(outs
            .iter()
            .any(|o| matches!(o, Output::Send(0, Message::InstallSnapshotReply { .. }))));
    }

    // ---- PreVote (Raft §9.6, Cabinet n − t quorum) -----------------------

    #[test]
    fn prevote_timeout_does_not_bump_term() {
        let mut n = Node::new(0, 5, Mode::cabinet(5, 1));
        n.set_pre_vote(true);
        let outs = n.step(Input::ElectionTimeout);
        assert_eq!(n.term(), 0, "pre-campaign must not touch the term");
        assert_eq!(n.role(), Role::Follower);
        assert!(n.prevote_active());
        assert_eq!(n.elections_started(), 0);
        let probes = outs
            .iter()
            .filter(|o| matches!(o, Output::Send(_, Message::PreVote { term: 1, .. })))
            .count();
        assert_eq!(probes, 4, "probe every peer at the prospective term");
        // repeated timeouts keep probing without disturbing anything
        let _ = n.step(Input::ElectionTimeout);
        let _ = n.step(Input::ElectionTimeout);
        assert_eq!(n.term(), 0);
        assert_eq!(n.elections_started(), 0);
    }

    #[test]
    fn prevote_quorum_starts_real_candidacy() {
        // n=5, t=1: election quorum n − t = 4 (self + 3 pre-grants)
        let mut n = Node::new(0, 5, Mode::cabinet(5, 1));
        n.set_pre_vote(true);
        let _ = n.step(Input::ElectionTimeout);
        for p in [1usize, 2] {
            let outs = n.step(Input::Receive(
                p,
                Message::PreVoteReply { term: 0, from: p, granted: true, for_term: 1 },
            ));
            assert_eq!(n.term(), 0, "below pre-quorum: no candidacy");
            assert!(outs.iter().all(|o| !matches!(o, Output::Send(_, Message::RequestVote { .. }))));
        }
        let outs = n.step(Input::Receive(
            3,
            Message::PreVoteReply { term: 0, from: 3, granted: true, for_term: 1 },
        ));
        assert_eq!(n.role(), Role::Candidate);
        assert_eq!(n.term(), 1, "pre-quorum reached: real candidacy at term + 1");
        assert_eq!(n.elections_started(), 1);
        assert!(outs.iter().any(|o| matches!(o, Output::Send(_, Message::RequestVote { term: 1, .. }))));
    }

    #[test]
    fn stale_or_duplicate_prevote_replies_are_inert() {
        let mut n = Node::new(0, 5, Mode::Raft); // quorum 3
        n.set_pre_vote(true);
        let _ = n.step(Input::ElectionTimeout);
        // a grant for a *different* campaign term is ignored
        let _ = n.step(Input::Receive(
            1,
            Message::PreVoteReply { term: 0, from: 1, granted: true, for_term: 7 },
        ));
        assert_eq!(n.term(), 0);
        // duplicated grants from one node count once
        for _ in 0..3 {
            let _ = n.step(Input::Receive(
                1,
                Message::PreVoteReply { term: 0, from: 1, granted: true, for_term: 1 },
            ));
        }
        assert_eq!(n.term(), 0, "one grantor cannot fake a quorum");
        let _ = n.step(Input::Receive(
            2,
            Message::PreVoteReply { term: 0, from: 2, granted: true, for_term: 1 },
        ));
        assert_eq!(n.role(), Role::Candidate, "self + 2 distinct grants = quorum 3");
    }

    #[test]
    fn prevote_grant_is_stateless() {
        let mut n = Node::new(0, 3, Mode::Raft);
        let outs = n.step(Input::Receive(
            1,
            Message::PreVote { term: 1, candidate: 1, last_log_index: 0, last_log_term: 0 },
        ));
        let granted = outs
            .iter()
            .find_map(|o| match o {
                Output::Send(_, Message::PreVoteReply { granted, .. }) => Some(*granted),
                _ => None,
            })
            .unwrap();
        assert!(granted);
        assert_eq!(n.term(), 0, "prospective term never adopted");
        assert!(n.voted_for.is_none(), "pre-grant is not a vote");
        assert!(
            !outs.iter().any(|o| matches!(o, Output::ResetElectionTimer)),
            "pre-grant must not defer our own timeout"
        );
        // the real vote in the same term is still free
        let outs = n.step(Input::Receive(
            2,
            Message::RequestVote { term: 1, candidate: 2, last_log_index: 0, last_log_term: 0 },
        ));
        assert!(outs.iter().any(
            |o| matches!(o, Output::Send(_, Message::RequestVoteReply { granted: true, .. }))
        ));
    }

    #[test]
    fn prevote_denied_by_leader_and_to_stale_logs() {
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_pre_vote(true);
        }
        c.elect(0);
        c.propose(0, Payload::Noop);
        // the leader denies probes outright
        let outs = c.nodes[0].step(Input::Receive(
            1,
            Message::PreVote { term: 5, candidate: 1, last_log_index: 99, last_log_term: 9 },
        ));
        assert!(outs.iter().any(
            |o| matches!(o, Output::Send(_, Message::PreVoteReply { granted: false, .. }))
        ));
        // recent leader contact denies even an up-to-date probe (stickiness)
        let outs = c.nodes[2].step(Input::Receive(
            1,
            Message::PreVote { term: 5, candidate: 1, last_log_index: 99, last_log_term: 9 },
        ));
        assert!(outs.iter().any(
            |o| matches!(o, Output::Send(_, Message::PreVoteReply { granted: false, .. }))
        ));
        // isolate the up-to-dateness clause: after node 2's own timeout
        // (stickiness cleared), a stale-log probe is still denied...
        let _ = c.nodes[2].step(Input::ElectionTimeout);
        let outs = c.nodes[2].step(Input::Receive(
            1,
            Message::PreVote { term: 5, candidate: 1, last_log_index: 0, last_log_term: 0 },
        ));
        assert!(
            outs.iter().any(
                |o| matches!(o, Output::Send(_, Message::PreVoteReply { granted: false, .. }))
            ),
            "stale-log probe must be denied on the up-to-dateness clause alone"
        );
        // ...while an up-to-date probe from the same state is granted
        let (li, lt) = (c.nodes[2].log().last_index(), c.nodes[2].log().last_term());
        let outs = c.nodes[2].step(Input::Receive(
            1,
            Message::PreVote { term: 5, candidate: 1, last_log_index: li, last_log_term: lt },
        ));
        assert!(outs.iter().any(
            |o| matches!(o, Output::Send(_, Message::PreVoteReply { granted: true, .. }))
        ));
    }

    #[test]
    fn prevote_cluster_still_elects_and_commits() {
        let mut c = TestCluster::cabinet(7, 2);
        for node in &mut c.nodes {
            node.set_pre_vote(true);
        }
        c.elect(0); // timeout → pre-campaign → pre-quorum → candidacy → leader
        assert_eq!(c.nodes[0].term(), 1);
        for k in 0..3 {
            c.propose(0, Payload::Bytes(std::sync::Arc::new(vec![k])));
        }
        c.heartbeat(0);
        for commits in &c.commits {
            assert_eq!(commits.len(), 4); // noop + 3
        }
    }

    #[test]
    fn healed_minority_with_prevote_cannot_depose_the_leader() {
        // The Cabinet-specific hazard: a partitioned (high-weight) minority
        // repeatedly times out; on heal it must not be able to drag the
        // working cabinet into new terms. With PreVote the minority's terms
        // never moved, and its probes are denied on heal (stale log).
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_pre_vote(true);
        }
        c.elect(0);
        c.propose(0, Payload::Noop);
        let leader_term = c.nodes[0].term();
        // "partition": nodes 3 and 4 time out repeatedly with their probes
        // swallowed (we simply discard the outputs — the minority cannot
        // reach anyone)
        for _ in 0..5 {
            let _ = c.nodes[3].step(Input::ElectionTimeout);
            let _ = c.nodes[4].step(Input::ElectionTimeout);
        }
        assert_eq!(c.nodes[3].term(), leader_term, "no term inflation while cut off");
        assert_eq!(c.nodes[4].term(), leader_term);
        // heal: the minority's next pre-campaign reaches everyone — commits
        // in the majority moved the log past them, so every probe is denied
        let outs = c.nodes[3].step(Input::ElectionTimeout);
        c.pump(3, outs);
        assert_eq!(c.nodes[0].role(), Role::Leader, "leader must survive the heal");
        assert_eq!(c.nodes[0].term(), leader_term, "no disruption, no new term");
        assert_eq!(c.nodes[3].elections_started(), 0);
    }

    #[test]
    fn without_prevote_healed_minority_inflates_terms() {
        // The control for the test above: same schedule, PreVote off — the
        // minority's timeouts burn real terms and the heal deposes the
        // leader (the historical Raft behavior PreVote removes).
        let mut c = TestCluster::cabinet(5, 1);
        c.elect(0);
        c.propose(0, Payload::Noop);
        let leader_term = c.nodes[0].term();
        for _ in 0..5 {
            let _ = c.nodes[3].step(Input::ElectionTimeout);
        }
        assert!(c.nodes[3].term() > leader_term, "terms inflate while cut off");
        let outs = c.nodes[3].step(Input::ElectionTimeout);
        c.pump(3, outs);
        assert_ne!(
            (c.nodes[0].role(), c.nodes[0].term()),
            (Role::Leader, leader_term),
            "healed inflated-term node must have disrupted the old leadership"
        );
    }

    // ---- linearizable read paths (ReadIndex + leader leases) -------------

    #[test]
    fn readindex_read_confirms_with_weighted_quorum() {
        let n = 7;
        let mut leader = solo_leader(n, Mode::cabinet(n, 2));
        leader.set_read_path(ReadPath::ReadIndex);
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        ack(&mut leader, 2, noop, leader.wclock());
        assert_eq!(leader.commit_index(), noop, "barrier must commit first");
        let outs = leader.step(Input::Read { id: 7 });
        assert!(
            !outs.iter().any(|o| matches!(o, Output::ReadReady { .. })),
            "ReadIndex must not serve before leadership is confirmed"
        );
        let probes = outs
            .iter()
            .filter(|o| matches!(o, Output::Send(_, Message::ReadIndex { .. })))
            .count();
        assert_eq!(probes, n - 1, "probe every peer");
        let seq = outs
            .iter()
            .find_map(|o| match o {
                Output::Send(_, Message::ReadIndex { seq, .. }) => Some(*seq),
                _ => None,
            })
            .unwrap();
        // one cabinet member's ack is not enough weight...
        let o1 = leader.step(Input::Receive(
            1,
            Message::ReadIndexResp { term: 1, from: 1, seq },
        ));
        assert!(!o1.iter().any(|o| matches!(o, Output::ReadReady { .. })));
        // ...the second clears CT (leader + 2 = the t+1 cabinet, as for writes)
        let o2 = leader.step(Input::Receive(
            2,
            Message::ReadIndexResp { term: 1, from: 2, seq },
        ));
        let ready = o2.iter().find_map(|o| match o {
            Output::ReadReady { id, index, lease } => Some((*id, *index, *lease)),
            _ => None,
        });
        assert_eq!(ready, Some((7, noop, false)));
        assert_eq!(leader.readindex_rounds(), 1);
    }

    #[test]
    fn read_denied_before_barrier_commits() {
        let mut leader = solo_leader(5, Mode::cabinet(5, 1));
        leader.set_read_path(ReadPath::ReadIndex);
        // the term barrier has not committed: the leader's commit index may
        // trail entries the previous term already committed (§6.4 step 1)
        let outs = leader.step(Input::Read { id: 1 });
        assert!(outs.iter().any(|o| matches!(o, Output::ReadFailed { id: 1 })));
        assert_eq!(leader.pending_confirm_rounds(), 0);
    }

    #[test]
    fn follower_read_forwards_and_serves_after_grant() {
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_read_path(ReadPath::ReadIndex);
        }
        c.elect(0);
        c.propose(0, Payload::Bytes(Arc::new(vec![1])));
        c.heartbeat(0); // followers learn the commit index
        let commit = c.nodes[0].commit_index();
        // client read at follower 3: forward → probe quorum → grant → serve
        let outs = c.nodes[3].step(Input::Read { id: 42 });
        c.pump(3, outs);
        assert_eq!(c.reads, vec![(3, 42, commit, false)]);
    }

    #[test]
    fn lease_read_skips_confirmation_and_expired_lease_falls_back() {
        let n = 5;
        let mut leader = solo_leader(n, Mode::cabinet(n, 1));
        leader.set_read_path(ReadPath::Lease);
        leader.set_lease_duration_ms(100.0);
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        ack(&mut leader, 2, noop, leader.wclock());
        // heartbeat cadence issues a lease-renewal probe round
        let outs = leader.step(Input::HeartbeatTimeout);
        let seq = outs
            .iter()
            .find_map(|o| match o {
                Output::Send(_, Message::ReadIndex { seq, .. }) => Some(*seq),
                _ => None,
            })
            .expect("lease mode must probe at heartbeat cadence");
        assert!(!leader.lease_valid());
        let _ = leader.step(Input::Receive(1, Message::ReadIndexResp { term: 1, from: 1, seq }));
        let _ = leader.step(Input::Receive(2, Message::ReadIndexResp { term: 1, from: 2, seq }));
        assert!(leader.lease_valid(), "weighted probe quorum must grant the lease");
        // inside the lease: reads serve instantly, no probe round opened
        leader.observe_time(50.0);
        let outs = leader.step(Input::Read { id: 1 });
        assert!(outs
            .iter()
            .any(|o| matches!(o, Output::ReadReady { id: 1, lease: true, .. })));
        assert!(!outs.iter().any(|o| matches!(o, Output::Send(_, Message::ReadIndex { .. }))));
        assert_eq!(leader.lease_reads(), 1);
        // past the lease (an isolated leader stops getting fresh acks):
        // reads must fall back to ReadIndex, never serve on the dead lease
        leader.observe_time(250.0);
        assert!(!leader.lease_valid());
        let outs = leader.step(Input::Read { id: 2 });
        assert!(
            !outs.iter().any(|o| matches!(o, Output::ReadReady { .. })),
            "an expired lease must never serve"
        );
        let seq2 = outs
            .iter()
            .find_map(|o| match o {
                Output::Send(_, Message::ReadIndex { seq, .. }) => Some(*seq),
                _ => None,
            })
            .expect("expired lease must fall back to a probe round");
        assert!(seq2 > seq);
        // a fresh quorum confirms: the read serves and the lease renews
        // (with t = 1 the leader + the rank-1 follower already clear CT, so
        // the ReadReady may fire on the first resp)
        let o1 =
            leader.step(Input::Receive(1, Message::ReadIndexResp { term: 1, from: 1, seq: seq2 }));
        let o2 =
            leader.step(Input::Receive(2, Message::ReadIndexResp { term: 1, from: 2, seq: seq2 }));
        assert!(o1
            .iter()
            .chain(o2.iter())
            .any(|o| matches!(o, Output::ReadReady { id: 2, lease: false, .. })));
        assert!(leader.lease_valid(), "confirmation renews the lease from its send time");
    }

    #[test]
    fn lease_mode_vote_stickiness_follows_leader_contact() {
        let mut c = TestCluster::cabinet(5, 1);
        for node in &mut c.nodes {
            node.set_read_path(ReadPath::Lease);
        }
        c.elect(0);
        c.propose(0, Payload::Noop);
        let granted = |outs: &[Output]| {
            outs.iter()
                .find_map(|o| match o {
                    Output::Send(_, Message::RequestVoteReply { granted, .. }) => Some(*granted),
                    _ => None,
                })
                .unwrap()
        };
        // node 2 heard from the leader: even an up-to-date candidate is
        // denied — a vote inside a lease window could elect a disruptor
        // whose writes a lease read would then miss
        let (li, lt) = (c.nodes[2].log().last_index(), c.nodes[2].log().last_term());
        let outs = c.nodes[2].step(Input::Receive(
            1,
            Message::RequestVote { term: 5, candidate: 1, last_log_index: li, last_log_term: lt },
        ));
        assert!(!granted(&outs), "lease stickiness must deny votes after leader contact");
        // after node 2's own election timeout the stickiness clears
        let _ = c.nodes[2].step(Input::ElectionTimeout);
        let outs = c.nodes[2].step(Input::Receive(
            1,
            Message::RequestVote { term: 9, candidate: 1, last_log_index: li, last_log_term: lt },
        ));
        assert!(granted(&outs), "stickiness clears once the node itself times out");
    }

    #[test]
    fn stepping_down_fails_pending_reads() {
        let mut leader = solo_leader(5, Mode::cabinet(5, 1));
        leader.set_read_path(ReadPath::ReadIndex);
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        ack(&mut leader, 2, noop, leader.wclock());
        let _ = leader.step(Input::Read { id: 11 });
        assert_eq!(leader.pending_confirm_rounds(), 1);
        let outs = leader.step(Input::Receive(
            1,
            Message::RequestVote { term: 99, candidate: 1, last_log_index: 50, last_log_term: 98 },
        ));
        assert_eq!(leader.role(), Role::Follower);
        assert_eq!(leader.pending_confirm_rounds(), 0);
        assert!(
            outs.iter().any(|o| matches!(o, Output::ReadFailed { id: 11 })),
            "a local read pending confirmation must fail loudly on step-down"
        );
    }

    #[test]
    fn log_repair_backoff() {
        let mut c = TestCluster::raft(3);
        c.elect(0);
        c.propose(0, Payload::Noop);
        c.propose(0, Payload::Noop);
        // node 2's log is intact; simulate a fresh node 1 losing its log by
        // replacing it and letting the failure reply walk next_index back.
        c.nodes[1] = Node::new(1, 3, Mode::Raft);
        c.propose(0, Payload::Noop);
        // after the pump, node 1 must have caught up fully
        assert_eq!(c.nodes[1].log().last_index(), c.nodes[0].log().last_index());
        assert_eq!(c.nodes[1].commit_index(), c.nodes[0].commit_index());
    }

    // ---- dynamic membership -------------------------------------------

    /// A Cabinet cluster with `slots` node slots of which `founding` are
    /// initial members (the rest join later via `AdminCmd::Join`).
    fn membership_cluster(slots: usize, founding: usize, t: usize) -> TestCluster {
        let mut c = TestCluster::new(slots, |_| Mode::cabinet(slots, t));
        let cfg = Arc::new(ClusterConfig {
            epoch: 0,
            members: (0..founding)
                .map(|id| MemberSpec { id, state: MemberState::Active })
                .collect(),
            joint_old: None,
        });
        for node in &mut c.nodes {
            node.set_initial_config(Arc::clone(&cfg));
        }
        c
    }

    #[test]
    fn join_flow_admits_warms_up_and_promotes() {
        let mut c = membership_cluster(6, 5, 2);
        for node in &mut c.nodes {
            node.set_join_warmup(2);
        }
        c.elect(0);
        c.propose(0, Payload::Bytes(Arc::new(vec![1])));
        assert_eq!(c.nodes[0].config().voter_count(), 5);

        // Join slot 5: the synchronous pump commits the C_old,new entry and
        // the C_new entry back-to-back (commit → auto-propose next phase).
        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Join(5)));
        c.pump(0, outs);
        let cfg = c.nodes[0].config();
        assert!(!cfg.is_joint(), "joint phase must auto-complete");
        assert_eq!(cfg.state_of(5), Some(MemberState::Joining));
        assert_eq!(cfg.voter_count(), 6);

        // While Joining, every re-deal pins the newcomer at the scheme floor.
        c.propose(0, Payload::Bytes(Arc::new(vec![2])));
        let scheme = match c.nodes[0].mode() {
            Mode::Cabinet { scheme } => scheme.clone(),
            Mode::Raft => unreachable!(),
        };
        assert_eq!(scheme.n(), 6, "scheme rebuilt for the joined voter set");
        let w5 = c.nodes[0].weight_assignment()[5];
        assert!(
            (w5 - scheme.min_weight()).abs() < 1e-9,
            "joining member at the floor, got {w5}"
        );

        // Two acked rounds satisfy the warmup; the promotion entry commits
        // on the round after (proposed from the commit hook).
        for k in 0..4u8 {
            c.propose(0, Payload::Bytes(Arc::new(vec![10 + k])));
        }
        c.heartbeat(0);
        assert_eq!(c.nodes[0].config().state_of(5), Some(MemberState::Active));
        // join = enter-joint + leave-joint + promote
        assert_eq!(c.nodes[0].config().epoch, 3);
        // every node converged on the same config
        for node in &c.nodes {
            assert_eq!(node.config().epoch, 3, "node {}", node.id());
        }
        assert!(c.nodes[0].config_commits() >= 3);
    }

    #[test]
    fn leave_flow_drains_to_floor_then_removes() {
        let mut c = membership_cluster(5, 5, 1);
        for node in &mut c.nodes {
            node.set_drain_rounds(2);
        }
        c.elect(0);
        c.propose(0, Payload::Noop);

        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Leave(4)));
        c.pump(0, outs);
        // the Draining mark committed; the ramp holds the node as a voter
        assert_eq!(c.nodes[0].config().state_of(4), Some(MemberState::Draining));
        assert_eq!(c.nodes[0].config().epoch, 1);

        // each proposal ticks the ramp; after it hits the floor the next
        // commit proposes C_old,new and then C_new
        for k in 0..6u8 {
            c.propose(0, Payload::Bytes(Arc::new(vec![k])));
        }
        c.heartbeat(0);
        let cfg = c.nodes[0].config();
        assert!(!cfg.is_voter(4), "drained node removed");
        assert!(!cfg.is_joint());
        // leave = mark-draining + enter-joint + leave-joint
        assert_eq!(cfg.epoch, 3);
        assert_eq!(cfg.voter_count(), 4);
        assert_eq!(c.nodes[0].weight_assignment()[4], 0.0);
        match c.nodes[0].mode() {
            Mode::Cabinet { scheme } => assert_eq!(scheme.n(), 4),
            Mode::Raft => unreachable!(),
        }
        // proposals keep committing among the surviving four
        let before = c.commits[1].len();
        c.propose(0, Payload::Bytes(Arc::new(vec![99])));
        c.heartbeat(0);
        assert!(c.commits[1].len() > before);
    }

    #[test]
    fn removed_leader_steps_down_and_survivors_elect() {
        let mut c = membership_cluster(5, 5, 1);
        for node in &mut c.nodes {
            node.set_drain_rounds(1);
        }
        c.elect(0);
        c.propose(0, Payload::Noop);
        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Leave(0)));
        c.pump(0, outs);
        for k in 0..4u8 {
            let outs = c.nodes[0].step(Input::Propose(Payload::Bytes(Arc::new(vec![k]))));
            c.pump(0, outs);
            if c.nodes[0].role() != Role::Leader {
                break;
            }
        }
        // the leader led through the joint phase, then stepped down when the
        // C_new excluding it committed (lease cleared with the leadership)
        assert_eq!(c.nodes[0].role(), Role::Follower);
        assert!(!c.nodes[0].config().is_voter(0));
        // a surviving voter takes over and the cluster keeps committing
        c.elect(1);
        let before = c.commits[2].len();
        c.propose(1, Payload::Bytes(Arc::new(vec![7])));
        c.heartbeat(1);
        assert!(c.commits[2].len() > before);
        // the removed slot must never campaign again
        let outs = c.nodes[0].step(Input::ElectionTimeout);
        assert!(outs.is_empty(), "removed node ignores its election timer");
    }

    #[test]
    fn joint_round_requires_both_halves() {
        // Leader of 4 founding members (slots 0..4) admits slot 4. The
        // C_old,new round must NOT commit on new-half weight alone: the old
        // half (0..4) has to clear its own CT too.
        let slots = 5;
        let mut leader = Node::new(0, slots, Mode::cabinet(slots, 1));
        let cfg = Arc::new(ClusterConfig {
            epoch: 0,
            members: (0..4).map(|id| MemberSpec { id, state: MemberState::Active }).collect(),
            joint_old: None,
        });
        leader.set_initial_config(Arc::clone(&cfg));
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..4 {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
            if leader.role() == Role::Leader {
                break;
            }
        }
        assert_eq!(leader.role(), Role::Leader);
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        ack(&mut leader, 2, noop, leader.wclock());
        assert_eq!(leader.commit_index(), noop);

        let _ = leader.step(Input::Admin(AdminCmd::Join(4)));
        let joint_idx = leader.log().last_index();
        assert!(leader.config().is_joint());
        assert_eq!(leader.inflight_len(), 1);

        // acks from the joiner and one old voter; top-2 weight (I1) clears
        // the new half, and leader + rank-1 clears the old half too — if
        // either half were still short, an extra old voter closes it
        let wc = leader.wclock();
        ack(&mut leader, 4, joint_idx, wc);
        ack(&mut leader, 1, joint_idx, wc);
        if leader.commit_index() < joint_idx {
            ack(&mut leader, 2, joint_idx, wc);
        }
        assert!(leader.commit_index() >= joint_idx, "joint entry commits");
        // after the joint entry commits the leader auto-proposes C_new
        assert!(leader.log().last_index() > joint_idx, "auto LeaveJoint proposed");
    }

    #[test]
    fn joint_old_half_blocks_commit_without_old_voters() {
        // Directly exercise the both-halves rule: build a joint round where
        // only new-half-exclusive voters ack. Old half = {0,1,2}; new half
        // adds 3 and 4 as instant voters via a handcrafted joint config.
        let slots = 5;
        let mut leader = Node::new(0, slots, Mode::cabinet(slots, 1));
        let boot = Arc::new(ClusterConfig {
            epoch: 0,
            members: (0..3).map(|id| MemberSpec { id, state: MemberState::Active }).collect(),
            joint_old: None,
        });
        leader.set_initial_config(boot);
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..3 {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
            if leader.role() == Role::Leader {
                break;
            }
        }
        assert_eq!(leader.role(), Role::Leader);
        let noop = leader.log().last_index();
        ack(&mut leader, 1, noop, leader.wclock());
        assert_eq!(leader.commit_index(), noop);

        let _ = leader.step(Input::Admin(AdminCmd::Join(3)));
        let joint_idx = leader.log().last_index();
        assert!(leader.config().is_joint());

        // Only the joiner acks. The joiner is outside C_old, so the old
        // half holds the leader's pre-ack alone — and I2 (heaviest t < CT,
        // here t = 1) guarantees a lone weight can never clear the old CT.
        // Without the both-halves rule, leader + joiner could already close
        // the new half; the old half must block the commit.
        let wc = leader.wclock();
        ack(&mut leader, 3, joint_idx, wc);
        assert!(
            leader.commit_index() < joint_idx,
            "old half unsatisfied: the joint entry must not commit"
        );
        // an Active old-half voter closes both halves (I1: top-2 > CT)
        ack(&mut leader, 1, joint_idx, wc);
        assert!(leader.commit_index() >= joint_idx);
    }

    #[test]
    fn snapshot_blob_carries_config_and_install_adopts_it() {
        let mut c = membership_cluster(5, 4, 1);
        for node in &mut c.nodes {
            node.set_snapshot_every(Some(4));
            node.set_drain_rounds(1);
        }
        c.elect(0);
        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Join(4)));
        c.pump(0, outs);
        for k in 0..8u8 {
            c.propose(0, Payload::Bytes(Arc::new(vec![k])));
        }
        c.heartbeat(0);
        let blob = c.nodes[0].snapshot().expect("threshold crossed").clone();
        let cfg = blob.config.as_ref().expect("membership snapshot carries config");
        assert!(cfg.is_voter(4));

        // a blank slot catching up purely from the snapshot adopts it
        let mut fresh = Node::new(2, 5, Mode::cabinet(5, 1));
        fresh.set_initial_config(Arc::new(ClusterConfig {
            epoch: 0,
            members: (0..4).map(|id| MemberSpec { id, state: MemberState::Active }).collect(),
            joint_old: None,
        }));
        let _ = fresh.step(Input::Receive(
            0,
            Message::InstallSnapshot {
                term: c.nodes[0].term(),
                leader: 0,
                snapshot: blob.clone(),
            },
        ));
        assert_eq!(fresh.commit_index(), blob.last_index);
        assert_eq!(fresh.config().epoch, cfg.epoch);
        assert!(fresh.config().is_voter(4));
    }

    #[test]
    fn nonmember_slots_get_no_appends_until_joined() {
        let mut c = membership_cluster(6, 5, 1);
        c.elect(0);
        c.propose(0, Payload::Noop);
        assert_eq!(c.nodes[5].log().last_index(), 0, "non-member got replicated to");
        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Join(5)));
        c.pump(0, outs);
        c.propose(0, Payload::Noop);
        assert!(c.nodes[5].log().last_index() > 0, "joined slot catches up");
    }

    #[test]
    fn config_change_rejected_via_client_propose() {
        let mut c = membership_cluster(5, 5, 1);
        c.elect(0);
        let cfg = Arc::new(ClusterConfig::bootstrap(5));
        let outs = c.nodes[0].step(Input::Propose(Payload::ConfigChange(cfg)));
        assert!(
            matches!(outs[0], Output::ProposalRejected(_)),
            "configs only enter the log through Input::Admin"
        );
    }

    #[test]
    fn admin_commands_serialize_through_the_queue() {
        let mut c = membership_cluster(7, 5, 2);
        for node in &mut c.nodes {
            node.set_join_warmup(0);
            node.set_drain_rounds(1);
        }
        c.elect(0);
        c.propose(0, Payload::Noop);
        // replace = join(5) then leave(4), queued back to back
        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Join(5)));
        c.pump(0, outs);
        let outs = c.nodes[0].step(Input::Admin(AdminCmd::Leave(4)));
        c.pump(0, outs);
        for k in 0..10u8 {
            c.propose(0, Payload::Bytes(Arc::new(vec![k])));
            c.heartbeat(0);
        }
        let cfg = c.nodes[0].config();
        assert!(cfg.is_voter(5) && !cfg.is_voter(4), "rolling replace completed");
        assert_eq!(cfg.state_of(5), Some(MemberState::Active));
        assert_eq!(cfg.voter_count(), 5);
        assert!(!c.nodes[0].membership_active(), "queue drained");
    }
}
