//! `cargo bench` target regenerating Fig 16 — rotating delays (D3) series (quick scale; run
//! `cargo run --release --example figures -- fig16 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig16_dynamic_delays", || {
        last = Some(figures::fig16(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
