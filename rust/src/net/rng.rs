//! Deterministic RNG + samplers (offline substitute for the `rand` crate).
//!
//! Everything in the simulator and the workload generators draws from a
//! seeded [`Rng`] so every figure is exactly re-runnable. The generator is
//! xoshiro256++ seeded via SplitMix64 (Blackman & Vigna), which is also what
//! `rand_xoshiro` ships.

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Deterministic, fast, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-node / per-link RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the modulo bias below 2^-64 — fine for sims.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos();
            }
        }
    }

    /// Normal with mean/σ, truncated at ≥ 0 (delays can't be negative).
    pub fn normal_pos(&mut self, mean: f64, sigma: f64) -> f64 {
        (mean + sigma * self.normal()).max(0.0)
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-300);
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipfian sampler over [0, n) with exponent `theta` (YCSB uses θ = 0.99),
/// using the Gray et al. rejection-free method YCSB's own generator uses.
#[derive(Clone, Debug)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
    /// Precomputed 1 + 0.5^θ (hoisted out of `sample`; §Perf iteration 4).
    head2_cut: f64,
}

impl Zipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        let head2_cut = 1.0 + 0.5f64.powf(theta);
        Zipfian { n, theta, alpha, zetan, eta, zeta2, head2_cut }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact for small n; integral approximation beyond 10^6 keeps
        // construction O(1)-ish for the n used in benchmarks.
        if n <= 1_000_000 {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            let head: f64 = (1..=1_000_000u64)
                .map(|i| 1.0 / (i as f64).powf(theta))
                .sum();
            let a = 1_000_000f64;
            let b = n as f64;
            head + (b.powf(1.0 - theta) - a.powf(1.0 - theta)) / (1.0 - theta)
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < self.head2_cut {
            return 1;
        }
        let v = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        v.min(self.n - 1)
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// θ and ζ accessors used by the distribution tests.
    pub fn theta(&self) -> f64 {
        self.theta
    }
    pub fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut rng = Rng::new(4);
        for n in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..1000 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut rng = Rng::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[rng.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_close() {
        let mut rng = Rng::new(6);
        let mean: f64 = (0..100_000).map(|_| rng.f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..100_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn normal_pos_nonnegative() {
        let mut rng = Rng::new(8);
        for _ in 0..10_000 {
            assert!(rng.normal_pos(10.0, 100.0) >= 0.0);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(9);
        let mean: f64 =
            (0..100_000).map(|_| rng.exponential(5.0)).sum::<f64>() / 100_000.0;
        assert!((mean - 5.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(10);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipfian_skew() {
        // θ=0.99 over 1000 keys: head key must dominate the tail key.
        let z = Zipfian::new(1000, 0.99);
        let mut rng = Rng::new(11);
        let mut counts = vec![0u32; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > 100 * counts[900].max(1));
        // top-64 keys should absorb a large fraction of traffic
        let head: u32 = counts[..64].iter().sum();
        assert!(head as f64 > 0.5 * 200_000.0, "head={head}");
    }

    #[test]
    fn zipfian_bounds() {
        let z = Zipfian::new(17, 0.99);
        let mut rng = Rng::new(12);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 17);
        }
    }

    #[test]
    fn zipfian_uniformish_at_zero_theta() {
        let z = Zipfian::new(10, 0.01);
        let mut rng = Rng::new(13);
        let mut counts = vec![0u32; 10];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64) > 100_000.0 / 10.0 * 0.6, "counts={counts:?}");
        }
    }
}
