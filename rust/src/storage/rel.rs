//! Relational store — the PostgreSQL stand-in followers run (§5.1
//! "TPC-C+PostgreSQL"): warehouses → districts → orders/stock with
//! per-warehouse write locks.
//!
//! TPC-C's consensus-visible behaviour is lock-bound apply cost: NewOrder /
//! Payment / Delivery serialize on their home warehouse. The apply loop
//! mutates real tables; the cost model (base work × argument factor +
//! lock-contention term) is the same one the `tpcc_cost` AOT kernel
//! computes, and the stream digest ties replicas together.

use crate::storage::digest::{self, tpcc_costs};
use crate::workload::tpcc::{
    TpccBatch, TXN_DELIVERY, TXN_NEW_ORDER, TXN_NOP, TXN_ORDER_STATUS, TXN_PAYMENT,
    TXN_STOCK_LEVEL,
};

/// µs of follower CPU per cost-model work unit at Z3 speed (calibration —
/// see DESIGN.md §6).
pub const COST_UNIT_US: f64 = 3.0;

/// One district's mutable state.
#[derive(Clone, Debug)]
pub struct District {
    pub next_order_id: u32,
    pub ytd: u64,
}

/// One warehouse: 10 districts (TPC-C spec) + stock + ytd.
#[derive(Clone, Debug)]
pub struct Warehouse {
    pub districts: Vec<District>,
    pub stock: Vec<u32>,
    pub ytd: u64,
    pub delivered_orders: u32,
}

impl Warehouse {
    fn new(items: usize) -> Self {
        Warehouse {
            districts: (0..10).map(|_| District { next_order_id: 1, ytd: 0 }).collect(),
            stock: vec![100; items],
            ytd: 0,
            delivered_orders: 0,
        }
    }
}

/// Result of applying a TPC-C batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TpccApplyResult {
    /// Stream digest — must match across replicas.
    pub digest: u32,
    /// Apply cost in ms at unit (Z3) speed, contention included.
    pub cost_ms: f64,
    pub txns_applied: usize,
}

/// The follower's relational store.
#[derive(Clone, Debug)]
pub struct RelStore {
    warehouses: Vec<Warehouse>,
    items_per_warehouse: usize,
    applied_batches: u64,
    stream_digest: u32,
}

impl RelStore {
    /// §5.1 config: 10 warehouses per follower; 100 stocked items each is
    /// plenty for the cost paths exercised here.
    pub fn new(warehouses: usize) -> Self {
        RelStore {
            warehouses: (0..warehouses).map(|_| Warehouse::new(100)).collect(),
            items_per_warehouse: 100,
            applied_batches: 0,
            stream_digest: 0,
        }
    }

    /// Apply a committed batch: execute each txn against the tables and
    /// account the cost-model work (the same model as the AOT kernel).
    pub fn apply(&mut self, batch: &TpccBatch) -> TpccApplyResult {
        let nw = self.warehouses.len();
        let (_counts, costs, dig) =
            tpcc_costs(&batch.types, &batch.wids, &batch.args, nw.max(1));
        let mut applied = 0;
        for ((&t, &w), &a) in batch.types.iter().zip(&batch.wids).zip(&batch.args) {
            if t >= TXN_NOP {
                continue;
            }
            applied += 1;
            let wh = &mut self.warehouses[w as usize % nw];
            match t {
                TXN_NEW_ORDER => {
                    let d = (a as usize) % 10;
                    wh.districts[d].next_order_id += 1;
                    // consume stock for `a` order lines
                    for line in 0..a as usize {
                        let item = (a as usize * 31 + line) % wh.stock.len();
                        wh.stock[item] = wh.stock[item].saturating_sub(1).max(10);
                    }
                }
                TXN_PAYMENT => {
                    let d = (a as usize) % 10;
                    wh.ytd += a as u64;
                    wh.districts[d].ytd += a as u64;
                }
                TXN_DELIVERY => {
                    wh.delivered_orders += a;
                }
                TXN_ORDER_STATUS | TXN_STOCK_LEVEL => { /* read-only */ }
                _ => unreachable!(),
            }
        }
        let cost_units: f64 = costs.iter().map(|&c| c as f64).sum();
        self.stream_digest = self.stream_digest.wrapping_add(dig);
        self.applied_batches += 1;
        TpccApplyResult {
            digest: self.stream_digest,
            cost_ms: cost_units * COST_UNIT_US / 1000.0,
            txns_applied: applied,
        }
    }

    /// Simulator service-time model: cost (ms at unit speed) of a batch
    /// without mutating state.
    pub fn estimate_cost_ms(batch: &TpccBatch, warehouses: usize) -> f64 {
        let (_c, costs, _d) =
            tpcc_costs(&batch.types, &batch.wids, &batch.args, warehouses.max(1));
        costs.iter().map(|&c| c as f64).sum::<f64>() * COST_UNIT_US / 1000.0
    }

    /// Per-txn-type cost breakdown (work units) — the Fig. 10/11 series.
    pub fn cost_breakdown(batch: &TpccBatch, warehouses: usize) -> [f64; 5] {
        let (_c, costs, _d) =
            tpcc_costs(&batch.types, &batch.wids, &batch.args, warehouses.max(1));
        let mut by_type = [0f64; 5];
        for (&t, &c) in batch.types.iter().zip(&costs) {
            if t < TXN_NOP {
                by_type[t as usize] += c as f64;
            }
        }
        by_type
    }

    pub fn warehouses(&self) -> usize {
        self.warehouses.len()
    }
    pub fn warehouse(&self, w: usize) -> &Warehouse {
        &self.warehouses[w]
    }
    pub fn stream_digest(&self) -> u32 {
        self.stream_digest
    }
    pub fn applied_batches(&self) -> u64 {
        self.applied_batches
    }
    pub fn items_per_warehouse(&self) -> usize {
        self.items_per_warehouse
    }

    /// Serialize the full replica state (every warehouse's districts,
    /// stock, YTD counters, plus the stream digest and batch count) — the
    /// `InstallSnapshot` payload for the TPC-C path.
    pub fn to_snapshot_bytes(&self) -> Vec<u8> {
        use crate::storage::wire::{push_u32, push_u64};
        let mut out = Vec::with_capacity(32 + self.warehouses.len() * 256);
        push_u32(&mut out, self.warehouses.len() as u32);
        push_u32(&mut out, self.items_per_warehouse as u32);
        push_u32(&mut out, self.stream_digest);
        push_u64(&mut out, self.applied_batches);
        for wh in &self.warehouses {
            push_u32(&mut out, wh.districts.len() as u32);
            for d in &wh.districts {
                push_u32(&mut out, d.next_order_id);
                push_u64(&mut out, d.ytd);
            }
            push_u32(&mut out, wh.stock.len() as u32);
            for &s in &wh.stock {
                push_u32(&mut out, s);
            }
            push_u64(&mut out, wh.ytd);
            push_u32(&mut out, wh.delivered_orders);
        }
        out
    }

    /// Rebuild a replica from `to_snapshot_bytes` output. `None` on
    /// malformed input — the caller falls back to full log replay. Beyond
    /// framing, the `apply` invariants are enforced (≥ 1 warehouse, exactly
    /// 10 districts each — the TPC-C spec `d % 10` indexing — and non-empty
    /// stock of the declared size), so a decoded store can never panic on
    /// the next batch.
    pub fn from_snapshot_bytes(bytes: &[u8]) -> Option<RelStore> {
        use crate::storage::wire::{read_u32, read_u64};
        let mut at = 0usize;
        let n_wh = read_u32(bytes, &mut at)? as usize;
        let items = read_u32(bytes, &mut at)? as usize;
        let stream_digest = read_u32(bytes, &mut at)?;
        let applied_batches = read_u64(bytes, &mut at)?;
        if n_wh == 0 || items == 0 {
            return None;
        }
        let mut warehouses = Vec::with_capacity(n_wh.min(bytes.len() / 8 + 1));
        for _ in 0..n_wh {
            let n_d = read_u32(bytes, &mut at)? as usize;
            if n_d != 10 {
                return None; // apply indexes districts[arg % 10]
            }
            let mut districts = Vec::with_capacity(n_d);
            for _ in 0..n_d {
                let next_order_id = read_u32(bytes, &mut at)?;
                let ytd = read_u64(bytes, &mut at)?;
                districts.push(District { next_order_id, ytd });
            }
            let n_s = read_u32(bytes, &mut at)? as usize;
            if n_s != items {
                return None; // apply indexes stock[.. % stock.len()]
            }
            let mut stock = Vec::with_capacity(n_s.min(bytes.len() / 4 + 1));
            for _ in 0..n_s {
                stock.push(read_u32(bytes, &mut at)?);
            }
            let ytd = read_u64(bytes, &mut at)?;
            let delivered_orders = read_u32(bytes, &mut at)?;
            warehouses.push(Warehouse { districts, stock, ytd, delivered_orders });
        }
        if at != bytes.len() {
            return None; // trailing garbage
        }
        Some(RelStore {
            warehouses,
            items_per_warehouse: items,
            applied_batches,
            stream_digest,
        })
    }
}

/// Convenience re-export for cost-model constants.
pub use digest::{TPCC_ARG_COEF, TPCC_BASE_COST, TPCC_LOCK_COEF};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TpccGen;

    #[test]
    fn replicas_converge() {
        let mut gen = TpccGen::new(10, 1);
        let batches: Vec<TpccBatch> = (0..4).map(|_| gen.batch(500)).collect();
        let mut a = RelStore::new(10);
        let mut b = RelStore::new(10);
        for batch in &batches {
            let ra = a.apply(batch);
            let rb = b.apply(batch);
            assert_eq!(ra.digest, rb.digest);
            assert_eq!(ra.cost_ms, rb.cost_ms);
        }
    }

    #[test]
    fn new_order_advances_district() {
        let mut s = RelStore::new(4);
        let batch = TpccBatch { types: vec![TXN_NEW_ORDER], wids: vec![2], args: vec![7] };
        s.apply(&batch);
        assert_eq!(s.warehouse(2).districts[7].next_order_id, 2);
    }

    #[test]
    fn payment_accumulates_ytd() {
        let mut s = RelStore::new(4);
        let batch = TpccBatch {
            types: vec![TXN_PAYMENT, TXN_PAYMENT],
            wids: vec![1, 1],
            args: vec![5, 3],
        };
        s.apply(&batch);
        assert_eq!(s.warehouse(1).ytd, 8);
    }

    #[test]
    fn read_only_txns_leave_tables_unchanged() {
        let mut s = RelStore::new(4);
        let before_d: Vec<u32> =
            s.warehouse(0).districts.iter().map(|d| d.next_order_id).collect();
        let batch = TpccBatch {
            types: vec![TXN_ORDER_STATUS, TXN_STOCK_LEVEL],
            wids: vec![0, 0],
            args: vec![1, 1],
        };
        let r = s.apply(&batch);
        assert_eq!(r.txns_applied, 2);
        let after_d: Vec<u32> =
            s.warehouse(0).districts.iter().map(|d| d.next_order_id).collect();
        assert_eq!(before_d, after_d);
    }

    #[test]
    fn snapshot_roundtrip_is_lossless() {
        let mut gen = TpccGen::new(6, 5);
        let mut s = RelStore::new(6);
        for _ in 0..3 {
            s.apply(&gen.batch(400));
        }
        let bytes = s.to_snapshot_bytes();
        let restored = RelStore::from_snapshot_bytes(&bytes).expect("decode");
        assert_eq!(restored.stream_digest(), s.stream_digest());
        assert_eq!(restored.applied_batches(), s.applied_batches());
        assert_eq!(restored.warehouses(), s.warehouses());
        for w in 0..s.warehouses() {
            assert_eq!(restored.warehouse(w).ytd, s.warehouse(w).ytd, "wh {w}");
            assert_eq!(
                restored.warehouse(w).delivered_orders,
                s.warehouse(w).delivered_orders
            );
            for d in 0..10 {
                assert_eq!(
                    restored.warehouse(w).districts[d].next_order_id,
                    s.warehouse(w).districts[d].next_order_id
                );
            }
        }
        assert_eq!(restored.to_snapshot_bytes(), bytes, "deterministic encoding");
        assert!(RelStore::from_snapshot_bytes(&bytes[..bytes.len() - 1]).is_none());
    }

    #[test]
    fn contention_raises_batch_cost() {
        // all NewOrders on one warehouse vs spread over 10
        let n = 100;
        let hot = TpccBatch {
            types: vec![TXN_NEW_ORDER; n],
            wids: vec![0; n],
            args: vec![10; n],
        };
        let spread = TpccBatch {
            types: vec![TXN_NEW_ORDER; n],
            wids: (0..n as u32).map(|i| i % 10).collect(),
            args: vec![10; n],
        };
        assert!(
            RelStore::estimate_cost_ms(&hot, 10)
                > 1.5 * RelStore::estimate_cost_ms(&spread, 10)
        );
    }

    #[test]
    fn breakdown_covers_all_types() {
        let mut gen = TpccGen::new(10, 2);
        let batch = gen.batch(5000);
        let b = RelStore::cost_breakdown(&batch, 10);
        assert!(b.iter().all(|&x| x > 0.0), "{b:?}");
        // NewOrder dominates total work (45% mix at highest base cost)
        assert!(b[0] > b[2] && b[0] > b[4]);
    }
}
