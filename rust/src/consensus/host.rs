//! The one effect interpreter: a sans-io `ReplicaHost` shared by every
//! driver of the consensus state machine.
//!
//! [`crate::consensus::node::Node`] emits a `Vec<Output>` per step; what
//! those outputs *mean* — send an RPC, fsync a WAL record, (re)arm a timer,
//! hand a committed batch to the applier — used to be interpreted twice, in
//! two hand-maintained loops: the simulator's arm-by-arm match in
//! `sim::group` and the live runtime's `handle_outputs` closure in
//! `live::cluster`. Every protocol extension (snapshots, reads, membership,
//! the WAL) had to patch both in lockstep, and each new [`Output`] arm was
//! a chance for the two to drift.
//!
//! [`ReplicaHost::drive`] is the single interpretation now. It consumes a
//! step's outputs **in emission order** and translates each into one call
//! on the [`Effects`] trait — the narrow waist a driver implements against
//! its own fabric:
//!
//! * the simulator's adapter maps effects onto the virtual [`EventQueue`]
//!   (latency models, nemesis fates, fork-ordered RNG streams), a
//!   `Wal<MemDisk>` with virtual fsync latency, and the harness-level
//!   safety/metrics bookkeeping;
//! * the live runtime's adapter maps the same effects onto real channels
//!   behind the link table, `Instant` deadlines, the applier thread, and a
//!   `Wal<FsDisk>` whose appends block until durable.
//!
//! [`EventQueue`]: crate::sim::event::EventQueue
//!
//! Two invariants live *here*, not in the drivers:
//!
//! 1. **Persist-before-reply** (Raft §5.1). The node emits
//!    `PersistHardState`/`PersistEntries` before the `Send`s they guard;
//!    the host checks that ordering on every batch (debug assertion backed
//!    by [`check_persist_order`]) and completes each persist effect before
//!    forwarding any later `Send`. Persist effects return their completion
//!    latency in virtual ms — the host accumulates it as `persist_lag_ms`
//!    on every subsequent send, so a simulated fsync delays exactly the
//!    replies it guards. Drivers whose persist call blocks (real files)
//!    simply return 0.
//! 2. **No silently dropped events.** Observer-style effects (leader /
//!    commit / read / config notifications) return `false` when their
//!    consumer is gone — a disconnected event channel, a dead applier. The
//!    host counts those into [`ReplicaHost::dropped_events`], surfaced in
//!    the live runtime's `NodeReport`, so a wedged event pipe is a visible
//!    number instead of a scattering of `let _ =`.
//!
//! Adding a protocol feature that needs a new [`Output`] arm is now a
//! one-site change: extend the enum, give [`Effects`] a (possibly
//! defaulted) method, add the match arm below — both runtimes pick it up.

use crate::consensus::message::{
    Entry, Envelope, GroupId, LogIndex, NodeId, Payload, SnapshotBlob, Term, WClock,
};
use crate::consensus::node::Output;
use crate::storage::wal::HardState;

/// Evidence of a committed replication round, bundled from
/// [`Output::RoundCommitted`] — propose-time quorum evidence for checkers
/// plus the index/replier counts the metrics hooks want.
#[derive(Clone, Debug)]
pub struct RoundCommit {
    pub wclock: WClock,
    pub index: LogIndex,
    pub repliers: usize,
    pub quorum_weight: f64,
    pub epoch: u64,
    pub ct: f64,
    /// `(acc_old, ct_old)` when the round was proposed under a joint
    /// config and the old half's rule held too.
    pub joint: Option<(f64, f64)>,
    /// `(distinct acked shards, k)` when the round's entry shipped coded —
    /// the acked shard set's reconstruction evidence. `None` for full-copy
    /// rounds (every coded-off run).
    pub coded: Option<(u32, u32)>,
}

/// The effect surface one replica needs from its runtime. Implemented once
/// per driver (`sim::group`'s adapter against the virtual fabric,
/// `live::cluster`'s against threads and channels); [`ReplicaHost::drive`]
/// is the only caller.
///
/// Conventions:
/// * **Durability effects** (`persist_*`) return the virtual latency (ms)
///   until the record is durable — 0.0 when the call itself blocked until
///   durable, or when nothing was synced. The host adds it to the
///   `persist_lag_ms` of every *later* send in the same batch.
/// * **Observer effects** return `true` if the notification reached its
///   consumer; `false` feeds [`ReplicaHost::dropped_events`]. A driver
///   with in-process consumers just returns `true`.
/// * **Timer effects** are generation-style: `arm_election` supersedes any
///   previously armed election timer for this replica.
pub trait Effects {
    /// Forward an RPC. `persist_lag_ms` is the accumulated completion
    /// latency of every persist effect earlier in this batch — virtual
    /// fabrics delay delivery by it; blocking fabrics ignore it.
    fn send(&mut self, to: NodeId, env: Envelope, persist_lag_ms: f64);

    /// (Re)arm the randomized election timer, superseding the old one.
    fn arm_election(&mut self);
    /// Start (or re-arm) the periodic leader heartbeat.
    fn arm_heartbeat(&mut self);
    /// Stop the heartbeat (stepped down).
    fn disarm_heartbeat(&mut self);

    /// Make `HardState{term, voted_for}` durable. Returns fsync latency to
    /// charge this batch's later sends (see trait docs).
    fn persist_hard_state(&mut self, hs: HardState) -> f64;
    /// Make an entry splice durable: `entries` appended after `prev_index`
    /// with this node's stored `weight`. Returns fsync latency like
    /// [`Effects::persist_hard_state`].
    fn persist_entries(&mut self, prev_index: LogIndex, weight: f64, entries: &[Entry]) -> f64;

    /// Driver-capture handshake: capture replica state through `through`
    /// and answer with `Node::complete_snapshot`. Inline-capture drivers
    /// return `true` without doing anything.
    fn capture_snapshot(&mut self, through: LogIndex) -> bool;
    /// A leader snapshot was installed over the local log — restore the
    /// carried replica state before later commits apply.
    fn install_snapshot(&mut self, blob: SnapshotBlob) -> bool;

    /// A newly committed entry, in index order — apply it / record it.
    fn apply_batch(&mut self, entry: &Entry) -> bool;

    /// A linearizable read is servable from local state at `index`.
    fn read_ready(&mut self, id: u64, index: LogIndex, lease: bool) -> bool;
    /// A read could not be served here — the client should retry.
    fn read_failed(&mut self, id: u64) -> bool;

    /// This replica won an election for `term`.
    fn became_leader(&mut self, term: Term) -> bool;
    /// This replica lost leadership (role transition, not an event pipe —
    /// no drop accounting).
    fn stepped_down(&mut self);
    /// A replication round reached quorum at this (leader) replica.
    fn round_committed(&mut self, rc: RoundCommit) -> bool;
    /// A `ConfigChange` entry committed here (any role).
    fn config_committed(
        &mut self,
        epoch: u64,
        index: LogIndex,
        joint: bool,
        voters: Vec<NodeId>,
    ) -> bool;

    /// A proposal was rejected (not leader / reconfig in flight). Most
    /// drivers ignore it.
    fn proposal_rejected(&mut self, payload: Payload) {
        let _ = payload;
    }
}

/// Where a batch broke the persist-before-reply ordering: the first `Send`
/// and the offending persist output that trails it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PersistOrderViolation {
    /// Position of the first `Send` in the batch.
    pub send_pos: usize,
    /// Position of a `PersistHardState`/`PersistEntries` after that send.
    pub persist_pos: usize,
}

/// Check one step's output batch for the persist-before-reply invariant:
/// every `PersistHardState`/`PersistEntries` must precede every `Send` in
/// the batch, because the sends it guards — vote grants, append acks —
/// follow it in emission order and a driver interpreting in order would
/// otherwise release an acknowledgement before its durability record.
///
/// This is the exact property [`ReplicaHost::drive`] debug-asserts on
/// every batch, exported so property tests can drive it directly against
/// randomized `Node` schedules (see `rust/tests/host_interpreter.rs`).
pub fn check_persist_order(outs: &[Output]) -> Result<(), PersistOrderViolation> {
    let mut first_send = None;
    for (pos, o) in outs.iter().enumerate() {
        match o {
            Output::Send(..) => {
                if first_send.is_none() {
                    first_send = Some(pos);
                }
            }
            Output::PersistHardState { .. } | Output::PersistEntries { .. } => {
                if let Some(send_pos) = first_send {
                    return Err(PersistOrderViolation { send_pos, persist_pos: pos });
                }
            }
            _ => {}
        }
    }
    Ok(())
}

/// The shared sans-io interpreter: one per (driver, group-replica). Holds
/// only fabric-independent state — the group id every outbound [`Envelope`]
/// is stamped with, and the dropped-event counter the observer effects
/// feed. Everything else lives behind [`Effects`].
#[derive(Clone, Debug)]
pub struct ReplicaHost {
    group: GroupId,
    dropped_events: u64,
}

impl ReplicaHost {
    pub fn new(group: GroupId) -> Self {
        ReplicaHost { group, dropped_events: 0 }
    }

    pub fn group(&self) -> GroupId {
        self.group
    }

    /// Observer-effect notifications whose consumer was gone (`false`
    /// returns from [`Effects`]) — a wedged event channel or dead applier
    /// made visible instead of silently discarded.
    pub fn dropped_events(&self) -> u64 {
        self.dropped_events
    }

    /// Interpret one step's outputs in emission order. Drains `outs` so
    /// callers can hand the same scratch allocation to every step.
    pub fn drive<E: Effects>(&mut self, outs: &mut Vec<Output>, fx: &mut E) {
        self.drive_with_lag(outs, 0.0, fx);
    }

    /// [`ReplicaHost::drive`] with an initial persist lag — latency of
    /// durability work the driver already performed for this step (the
    /// simulator persists freshly captured snapshots before scanning
    /// outputs, and charges their fsyncs to the step's sends too).
    pub fn drive_with_lag<E: Effects>(
        &mut self,
        outs: &mut Vec<Output>,
        initial_lag_ms: f64,
        fx: &mut E,
    ) {
        #[cfg(debug_assertions)]
        if let Err(v) = check_persist_order(outs) {
            panic!(
                "persist-before-reply violated: Send at {} precedes persist at {} \
                 in a {}-output batch — a durability record must never trail the \
                 acknowledgement it guards",
                v.send_pos,
                v.persist_pos,
                outs.len()
            );
        }
        let mut lag = initial_lag_ms;
        for o in outs.drain(..) {
            match o {
                Output::PersistHardState { term, voted_for } => {
                    lag += fx.persist_hard_state(HardState { term, voted_for });
                }
                Output::PersistEntries { prev_index, weight, entries } => {
                    lag += fx.persist_entries(prev_index, weight, &entries);
                }
                Output::Send(to, msg) => {
                    fx.send(to, Envelope::new(self.group, msg), lag);
                }
                Output::ResetElectionTimer => fx.arm_election(),
                Output::StartHeartbeat => fx.arm_heartbeat(),
                Output::StopHeartbeat => fx.disarm_heartbeat(),
                Output::BecameLeader { term } => self.observe(fx.became_leader(term)),
                Output::SteppedDown => fx.stepped_down(),
                Output::Commit(e) => self.observe(fx.apply_batch(&e)),
                Output::RoundCommitted {
                    wclock,
                    index,
                    repliers,
                    quorum_weight,
                    epoch,
                    ct,
                    joint,
                    coded,
                } => self.observe(fx.round_committed(RoundCommit {
                    wclock,
                    index,
                    repliers,
                    quorum_weight,
                    epoch,
                    ct,
                    joint,
                    coded,
                })),
                Output::ConfigCommitted { epoch, index, joint, voters } => {
                    self.observe(fx.config_committed(epoch, index, joint, voters));
                }
                Output::SnapshotRequest { through } => {
                    self.observe(fx.capture_snapshot(through));
                }
                Output::SnapshotInstalled(blob) => self.observe(fx.install_snapshot(blob)),
                Output::ReadReady { id, index, lease } => {
                    self.observe(fx.read_ready(id, index, lease));
                }
                Output::ReadFailed { id } => self.observe(fx.read_failed(id)),
                Output::ProposalRejected(p) => fx.proposal_rejected(p),
            }
        }
    }

    fn observe(&mut self, delivered: bool) {
        if !delivered {
            self.dropped_events += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::message::Message;

    /// A minimal recorder for the in-module sanity tests (the full
    /// differential harness lives in `rust/tests/host_interpreter.rs`).
    struct Probe {
        trace: Vec<String>,
        fsync_ms: f64,
        deliver: bool,
    }

    impl Probe {
        fn new(fsync_ms: f64, deliver: bool) -> Self {
            Probe { trace: Vec::new(), fsync_ms, deliver }
        }
    }

    impl Effects for Probe {
        fn send(&mut self, to: NodeId, env: Envelope, persist_lag_ms: f64) {
            self.trace.push(format!(
                "send g{} to={} {} lag={persist_lag_ms}",
                env.group,
                to,
                env.msg.kind()
            ));
        }
        fn arm_election(&mut self) {
            self.trace.push("arm_election".into());
        }
        fn arm_heartbeat(&mut self) {
            self.trace.push("arm_heartbeat".into());
        }
        fn disarm_heartbeat(&mut self) {
            self.trace.push("disarm_heartbeat".into());
        }
        fn persist_hard_state(&mut self, hs: HardState) -> f64 {
            self.trace.push(format!("persist_hs term={}", hs.term));
            self.fsync_ms
        }
        fn persist_entries(&mut self, prev_index: LogIndex, _w: f64, entries: &[Entry]) -> f64 {
            self.trace.push(format!("persist_entries prev={prev_index} n={}", entries.len()));
            self.fsync_ms
        }
        fn capture_snapshot(&mut self, through: LogIndex) -> bool {
            self.trace.push(format!("capture through={through}"));
            self.deliver
        }
        fn install_snapshot(&mut self, blob: SnapshotBlob) -> bool {
            self.trace.push(format!("install last={}", blob.last_index));
            self.deliver
        }
        fn apply_batch(&mut self, entry: &Entry) -> bool {
            self.trace.push(format!("apply idx={}", entry.index));
            self.deliver
        }
        fn read_ready(&mut self, id: u64, index: LogIndex, lease: bool) -> bool {
            self.trace.push(format!("read_ready id={id} idx={index} lease={lease}"));
            self.deliver
        }
        fn read_failed(&mut self, id: u64) -> bool {
            self.trace.push(format!("read_failed id={id}"));
            self.deliver
        }
        fn became_leader(&mut self, term: Term) -> bool {
            self.trace.push(format!("became_leader term={term}"));
            self.deliver
        }
        fn stepped_down(&mut self) {
            self.trace.push("stepped_down".into());
        }
        fn round_committed(&mut self, rc: RoundCommit) -> bool {
            self.trace.push(format!("round_committed idx={}", rc.index));
            self.deliver
        }
        fn config_committed(
            &mut self,
            epoch: u64,
            _index: LogIndex,
            joint: bool,
            _voters: Vec<NodeId>,
        ) -> bool {
            self.trace.push(format!("config epoch={epoch} joint={joint}"));
            self.deliver
        }
    }

    fn vote_reply(granted: bool) -> Message {
        Message::RequestVoteReply { term: 3, from: 1, granted }
    }

    #[test]
    fn persist_lag_accumulates_onto_later_sends() {
        let mut host = ReplicaHost::new(2);
        let mut fx = Probe::new(1.5, true);
        let mut outs = vec![
            Output::PersistHardState { term: 3, voted_for: Some(0) },
            Output::Send(0, vote_reply(true)),
            Output::ResetElectionTimer,
        ];
        host.drive_with_lag(&mut outs, 0.5, &mut fx);
        assert!(outs.is_empty(), "drive drains the batch");
        assert_eq!(
            fx.trace,
            vec![
                "persist_hs term=3".to_string(),
                "send g2 to=0 RequestVoteReply lag=2".to_string(),
                "arm_election".to_string(),
            ]
        );
        assert_eq!(host.dropped_events(), 0);
    }

    #[test]
    fn dropped_observer_effects_are_counted() {
        let mut host = ReplicaHost::new(0);
        let mut fx = Probe::new(0.0, false);
        let mut outs = vec![
            Output::BecameLeader { term: 1 },
            Output::ReadFailed { id: 9 },
            Output::StopHeartbeat,
            Output::SteppedDown,
        ];
        host.drive(&mut outs, &mut fx);
        // BecameLeader + ReadFailed dropped; timer/role effects are not
        // observer notifications and never count
        assert_eq!(host.dropped_events(), 2);
    }

    #[test]
    fn persist_order_checker_flags_trailing_persists() {
        let ok = vec![
            Output::PersistHardState { term: 1, voted_for: None },
            Output::PersistEntries { prev_index: 0, weight: 1.0, entries: vec![] },
            Output::Send(1, vote_reply(true)),
            Output::Send(2, vote_reply(true)),
        ];
        assert_eq!(check_persist_order(&ok), Ok(()));

        let bad = vec![
            Output::Send(1, vote_reply(true)),
            Output::PersistHardState { term: 1, voted_for: None },
        ];
        assert_eq!(
            check_persist_order(&bad),
            Err(PersistOrderViolation { send_pos: 0, persist_pos: 1 })
        );

        // sends with no persists at all are trivially fine
        assert_eq!(check_persist_order(&[Output::Send(1, vote_reply(false))]), Ok(()));
        assert_eq!(check_persist_order(&[]), Ok(()));
    }
}
