//! Differential + property tests for the shared effect interpreter
//! (`consensus::host`): the one `ReplicaHost` both the simulator and the
//! live runtime drive their `Output` batches through.
//!
//! Three layers:
//!
//! 1. **Differential traces.** A `RecordingEffects` mock replays canned
//!    `Output` scripts — persist/send interleavings, the snapshot
//!    handshake, read grant/fail, config commits — once through a
//!    sim-shaped host drive (`drive_with_lag`, virtual fsync latencies)
//!    and once through a live-shaped drive (`drive`, blocking persists
//!    returning 0 lag). The *effect call sequence* must be identical:
//!    that is the unification this PR pins, and what catches a future
//!    `Output` arm added to one runtime but not the other.
//! 2. **Persist-before-reply property.** Seeded-chaos schedules over real
//!    durable `consensus::node::Node`s assert that every step's output
//!    batch satisfies `check_persist_order` — no `PersistHardState` /
//!    `PersistEntries` ever trails a `Send` it guards — and a
//!    deliberately reordered batch turns the checker (and, under debug
//!    assertions, the host itself) red.
//! 3. **Dropped-event accounting.** Observer effects returning `false`
//!    (a wedged event channel, a dead applier) are counted on the host;
//!    fire-and-forget effects are not.

use std::sync::Arc;

use cabinet::consensus::host::{
    check_persist_order, Effects, PersistOrderViolation, ReplicaHost, RoundCommit,
};
use cabinet::consensus::message::{
    AppState, ClusterConfig, Entry, Envelope, LogIndex, Message, NodeId, Payload, SnapshotBlob,
    Term,
};
use cabinet::consensus::node::{Input, Mode, Node, Output};
use cabinet::net::rng::Rng;
use cabinet::storage::wal::HardState;

// ---- the recording mock --------------------------------------------------

/// Records every effect call as a normalized `(op, lag)` pair. `fsync_ms`
/// is what the persist effects report back (the sim adapter returns the
/// virtual fsync latency; the live adapter blocks and returns 0.0), and
/// `deliver` is what the observer effects answer (false = consumer gone).
struct RecordingEffects {
    trace: Vec<(String, f64)>,
    fsync_ms: f64,
    deliver: bool,
}

impl RecordingEffects {
    fn new(fsync_ms: f64, deliver: bool) -> Self {
        RecordingEffects { trace: Vec::new(), fsync_ms, deliver }
    }

    fn op(&mut self, s: String) {
        self.trace.push((s, 0.0));
    }

    /// The effect call sequence with send lags erased — the shape both
    /// runtime adapters must share.
    fn ops(&self) -> Vec<String> {
        self.trace.iter().map(|(s, _)| s.clone()).collect()
    }
}

impl Effects for RecordingEffects {
    fn send(&mut self, to: NodeId, env: Envelope, persist_lag_ms: f64) {
        self.trace
            .push((format!("send g{} to={to} {}", env.group, env.msg.kind()), persist_lag_ms));
    }
    fn arm_election(&mut self) {
        self.op("arm_election".into());
    }
    fn arm_heartbeat(&mut self) {
        self.op("arm_heartbeat".into());
    }
    fn disarm_heartbeat(&mut self) {
        self.op("disarm_heartbeat".into());
    }
    fn persist_hard_state(&mut self, hs: HardState) -> f64 {
        self.op(format!("persist_hs term={} voted={:?}", hs.term, hs.voted_for));
        self.fsync_ms
    }
    fn persist_entries(&mut self, prev_index: LogIndex, weight: f64, entries: &[Entry]) -> f64 {
        self.op(format!("persist_entries prev={prev_index} w={weight} n={}", entries.len()));
        self.fsync_ms
    }
    fn capture_snapshot(&mut self, through: LogIndex) -> bool {
        self.op(format!("capture through={through}"));
        self.deliver
    }
    fn install_snapshot(&mut self, blob: SnapshotBlob) -> bool {
        self.op(format!("install last={} term={}", blob.last_index, blob.last_term));
        self.deliver
    }
    fn apply_batch(&mut self, entry: &Entry) -> bool {
        self.op(format!("apply idx={} term={}", entry.index, entry.term));
        self.deliver
    }
    fn read_ready(&mut self, id: u64, index: LogIndex, lease: bool) -> bool {
        self.op(format!("read_ready id={id} idx={index} lease={lease}"));
        self.deliver
    }
    fn read_failed(&mut self, id: u64) -> bool {
        self.op(format!("read_failed id={id}"));
        self.deliver
    }
    fn became_leader(&mut self, term: Term) -> bool {
        self.op(format!("became_leader term={term}"));
        self.deliver
    }
    fn stepped_down(&mut self) {
        self.op("stepped_down".into());
    }
    fn round_committed(&mut self, rc: RoundCommit) -> bool {
        self.op(format!(
            "round_committed idx={} repliers={} epoch={}",
            rc.index, rc.repliers, rc.epoch
        ));
        self.deliver
    }
    fn config_committed(
        &mut self,
        epoch: u64,
        index: LogIndex,
        joint: bool,
        voters: Vec<NodeId>,
    ) -> bool {
        self.op(format!("config epoch={epoch} idx={index} joint={joint} voters={voters:?}"));
        self.deliver
    }
    fn proposal_rejected(&mut self, _payload: Payload) {
        self.op("proposal_rejected".into());
    }
}

// ---- canned scripts ------------------------------------------------------

fn entry(index: u64, term: u64) -> Entry {
    Entry { term, index, payload: Payload::Noop, wclock: 0 }
}

fn blob(last_index: u64) -> SnapshotBlob {
    SnapshotBlob {
        last_index,
        last_term: 2,
        prefix_digest: 0xDEAD_BEEF,
        wclock: 3,
        cabinet_t: Some(1),
        config: None,
        app: AppState::Slots(Arc::new(vec![1, 2, 3])),
    }
}

fn vote_reply(from: NodeId, granted: bool) -> Message {
    Message::RequestVoteReply { term: 4, from, granted }
}

fn ack(from: NodeId, match_index: u64) -> Message {
    Message::AppendEntriesReply { term: 4, from, success: true, match_index, wclock: 1 }
}

/// Persist + send interleaving: the durable follower path — HardState and a
/// splice land before the acks that acknowledge them, then a timer re-arm.
fn script_persist_send() -> Vec<Output> {
    vec![
        Output::PersistHardState { term: 4, voted_for: Some(2) },
        Output::PersistEntries { prev_index: 7, weight: 1.25, entries: vec![entry(8, 4)] },
        Output::Send(2, ack(1, 8)),
        Output::Send(0, vote_reply(1, true)),
        Output::ResetElectionTimer,
    ]
}

/// Snapshot handshake: capture request, a follower-side install, and the
/// reply that reports the new match index.
fn script_snapshot_handshake() -> Vec<Output> {
    vec![
        Output::SnapshotRequest { through: 30 },
        Output::SnapshotInstalled(blob(30)),
        Output::Send(0, Message::InstallSnapshotReply { term: 4, from: 1, match_index: 30 }),
    ]
}

/// Read grant / fail pair plus the leader's grant RPC to a forwarder.
fn script_reads() -> Vec<Output> {
    vec![
        Output::ReadReady { id: 11, index: 9, lease: true },
        Output::Send(2, Message::ReadGrant { term: 4, leader: 1, id: 12, read_index: 9 }),
        Output::ReadFailed { id: 13 },
    ]
}

/// Commit-side observers: a joint + settled config commit, the round that
/// carried them, applied entries, and the leadership lifecycle around it.
fn script_commits_and_config() -> Vec<Output> {
    vec![
        Output::BecameLeader { term: 4 },
        Output::StartHeartbeat,
        Output::Commit(entry(9, 4)),
        Output::RoundCommitted {
            wclock: 1,
            index: 9,
            repliers: 3,
            quorum_weight: 2.5,
            epoch: 1,
            ct: 2.0,
            joint: Some((1.5, 1.0)),
            coded: None,
        },
        Output::ConfigCommitted { epoch: 1, index: 9, joint: true, voters: vec![0, 1, 2, 3] },
        Output::ConfigCommitted { epoch: 2, index: 10, joint: false, voters: vec![0, 1, 3] },
        Output::ProposalRejected(Payload::Noop),
        Output::StopHeartbeat,
        Output::SteppedDown,
    ]
}

fn scripts() -> Vec<(&'static str, Vec<Output>)> {
    vec![
        ("persist_send", script_persist_send()),
        ("snapshot_handshake", script_snapshot_handshake()),
        ("reads", script_reads()),
        ("commits_and_config", script_commits_and_config()),
    ]
}

// ---- differential traces -------------------------------------------------

/// The tentpole pin: a script driven the way the simulator drives the host
/// (initial persist lag, virtual fsync latencies) and the way the live
/// runtime does (no initial lag, blocking persists returning 0) produces
/// the *same effect call sequence*. Only the send lag annotations — the
/// sim's virtual-time bookkeeping — may differ.
#[test]
fn sim_and_live_shaped_drives_produce_identical_effect_sequences() {
    for (name, script) in scripts() {
        // sim-shaped: snapshot-persist lag charged up front, 2ms per fsync
        let mut sim_host = ReplicaHost::new(5);
        let mut sim_fx = RecordingEffects::new(2.0, true);
        let mut outs = script.clone();
        sim_host.drive_with_lag(&mut outs, 0.5, &mut sim_fx);
        assert!(outs.is_empty(), "{name}: drive must drain the batch");

        // live-shaped: appends block until durable, so zero reported lag
        let mut live_host = ReplicaHost::new(5);
        let mut live_fx = RecordingEffects::new(0.0, true);
        let mut outs = script.clone();
        live_host.drive(&mut outs, &mut live_fx);
        assert!(outs.is_empty(), "{name}: drive must drain the batch");

        assert_eq!(
            sim_fx.ops(),
            live_fx.ops(),
            "{name}: the two runtime shapes must interpret outputs identically"
        );
        // every live send carries zero lag (blocking persists report none)
        for (op, lag) in &live_fx.trace {
            if op.starts_with("send ") {
                assert_eq!(*lag, 0.0, "{name}: live-shaped sends never see persist lag");
            }
        }
        assert_eq!(sim_host.dropped_events(), 0);
        assert_eq!(live_host.dropped_events(), 0);
    }
}

/// Golden trace for the richest script: pins emission-order interpretation,
/// group stamping on every envelope, and lag accumulation across persists.
#[test]
fn persist_send_script_golden_trace() {
    let mut host = ReplicaHost::new(3);
    let mut fx = RecordingEffects::new(2.0, true);
    let mut outs = script_persist_send();
    host.drive_with_lag(&mut outs, 0.5, &mut fx);
    let expected_ops = vec![
        "persist_hs term=4 voted=Some(2)".to_string(),
        "persist_entries prev=7 w=1.25 n=1".to_string(),
        "send g3 to=2 AppendEntriesReply".to_string(),
        "send g3 to=0 RequestVoteReply".to_string(),
        "arm_election".to_string(),
    ];
    assert_eq!(fx.ops(), expected_ops);
    // 0.5 initial + 2.0 (HardState fsync) + 2.0 (splice fsync) on both sends
    assert_eq!(fx.trace[2].1, 4.5);
    assert_eq!(fx.trace[3].1, 4.5);
}

// ---- dropped-event accounting --------------------------------------------

#[test]
fn dropped_observer_events_are_counted_per_host() {
    // Every observer effect answers "consumer gone": each counts once.
    // Sends, timers, persists and role transitions never do.
    let mut host = ReplicaHost::new(0);
    let mut fx = RecordingEffects::new(0.0, false);
    for (_, script) in scripts() {
        let mut outs = script;
        host.drive(&mut outs, &mut fx);
    }
    // observer outputs across the four scripts: capture + install (snapshot
    // handshake), read_ready + read_failed (reads), became_leader + apply +
    // round_committed + 2×config_committed (commits_and_config)
    assert_eq!(host.dropped_events(), 9);

    // the same scripts with a healthy consumer count nothing
    let mut healthy = ReplicaHost::new(0);
    let mut fx = RecordingEffects::new(0.0, true);
    for (_, script) in scripts() {
        let mut outs = script;
        healthy.drive(&mut outs, &mut fx);
    }
    assert_eq!(healthy.dropped_events(), 0);
}

// ---- persist-before-reply property ---------------------------------------

/// Seeded-chaos schedule over a durable 3-node cluster: random deliveries,
/// timer fires, proposals and reads — asserting every single step's output
/// batch keeps its persists ahead of its sends. This is the invariant the
/// host's debug assertion enforces centrally; here it is checked against
/// the real `Node` emission sites.
#[test]
fn node_output_batches_keep_persists_before_sends() {
    for seed in [7u64, 23, 99, 1234] {
        for mode in [Mode::Raft, Mode::cabinet(3, 1)] {
            chaos_persist_order(3, mode, seed, 2500);
        }
    }
}

fn chaos_persist_order(n: usize, mode: Mode, seed: u64, steps: u64) {
    let mut nodes: Vec<Node> = (0..n)
        .map(|i| {
            let mut nd = Node::new(i, n, mode.clone());
            nd.set_durable(true);
            nd
        })
        .collect();
    let mut rng = Rng::new(seed);
    let mut queue: Vec<(NodeId, NodeId, Message)> = Vec::new();
    let mut batches_checked = 0u64;
    // bootstrap: node 0 campaigns first
    let mut pending: Vec<(NodeId, Input)> = vec![(0, Input::ElectionTimeout)];
    for step in 0..steps {
        let (node, input) = match pending.pop() {
            Some(p) => p,
            None => {
                let roll = rng.next_u64() % 100;
                if roll < 60 && !queue.is_empty() {
                    // deliver a random queued message (reordering included)
                    let i = (rng.next_u64() as usize) % queue.len();
                    let (from, to, msg) = queue.swap_remove(i);
                    (to, Input::Receive(from, msg))
                } else if roll < 75 {
                    let node = (rng.next_u64() as usize) % n;
                    (node, Input::HeartbeatTimeout)
                } else if roll < 85 {
                    let node = (rng.next_u64() as usize) % n;
                    (node, Input::ElectionTimeout)
                } else if roll < 95 {
                    let node = (rng.next_u64() as usize) % n;
                    (node, Input::Propose(Payload::Bytes(Arc::new(vec![step as u8]))))
                } else {
                    let node = (rng.next_u64() as usize) % n;
                    (node, Input::Read { id: step })
                }
            }
        };
        nodes[node].observe_time(step as f64);
        let outs = nodes[node].step(input);
        assert_eq!(
            check_persist_order(&outs),
            Ok(()),
            "node {node} step {step} (seed {seed}): a persist trailed a send in {outs:?}"
        );
        batches_checked += 1;
        for o in outs {
            if let Output::Send(to, msg) = o {
                queue.push((node, to, msg));
            }
        }
    }
    assert!(batches_checked == steps, "every step produced a checked batch");
}

/// Red case: a deliberately reordered batch — the ack released before the
/// splice that guards it — is flagged with exact positions.
#[test]
fn reordered_batch_is_rejected_by_the_checker() {
    let bad = vec![
        Output::Send(2, ack(1, 8)),
        Output::PersistEntries { prev_index: 7, weight: 1.0, entries: vec![entry(8, 4)] },
    ];
    assert_eq!(
        check_persist_order(&bad),
        Err(PersistOrderViolation { send_pos: 0, persist_pos: 1 })
    );

    // and with the persist ahead of the send, the same batch is fine
    let good = vec![bad[1].clone(), bad[0].clone()];
    assert_eq!(check_persist_order(&good), Ok(()));
}

/// The host turns the same violation into a loud failure under debug
/// assertions (how both runtimes run the tier-1 suite) instead of quietly
/// releasing an un-persisted acknowledgement.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "persist-before-reply violated")]
fn host_debug_asserts_on_reordered_batch() {
    let mut host = ReplicaHost::new(0);
    let mut fx = RecordingEffects::new(0.0, true);
    let mut outs = vec![
        Output::Send(2, ack(1, 8)),
        Output::PersistHardState { term: 4, voted_for: None },
    ];
    host.drive(&mut outs, &mut fx);
}

// ---- host equivalence with a real config-change payload -------------------

/// ConfigChange voters arrive by value through the one interpreter — drive
/// the same settled-config commit through two hosts and confirm byte-equal
/// observer arguments (guards against one runtime reordering or rewriting
/// config commits during future membership work).
#[test]
fn config_commit_arguments_are_stable_across_hosts() {
    let cfg = Arc::new(ClusterConfig::bootstrap(4));
    let script = vec![
        Output::Commit(Entry {
            term: 2,
            index: 5,
            payload: Payload::ConfigChange(cfg),
            wclock: 1,
        }),
        Output::ConfigCommitted { epoch: 3, index: 5, joint: false, voters: vec![0, 1, 2, 3] },
    ];
    let mut a = RecordingEffects::new(0.0, true);
    let mut b = RecordingEffects::new(0.0, true);
    ReplicaHost::new(1).drive(&mut script.clone(), &mut a);
    ReplicaHost::new(1).drive(&mut script.clone(), &mut b);
    assert_eq!(a.ops(), b.ops());
    assert_eq!(a.ops()[1], "config epoch=3 idx=5 joint=false voters=[0, 1, 2, 3]");
}
