//! `cabinet` CLI — the launcher for the reproduction:
//!
//! ```text
//! cabinet figures [figN|all] [--paper]     regenerate paper figures
//! cabinet sim --config exp.toml            run one experiment from a file
//! cabinet sim [--n N] [--t T] [...]        run one experiment from flags
//! cabinet weights --n N --t T              print a weight scheme
//! cabinet live [--n N] [--t T] [--rounds R]  run the live cluster demo
//! cabinet check-artifacts                  validate AOT artifacts via PJRT
//! cabinet bench-check BENCH_*.json ...     validate bench emission (CI)
//! ```

use std::collections::VecDeque;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use cabinet::bench::{figures, Scale};
use cabinet::config::sim_config_from_toml;
use cabinet::consensus::weights::{ratio_bounds, WeightScheme};
use cabinet::consensus::{Mode, Payload};
use cabinet::live::{ApplyService, Backend, LiveCluster, LiveTimers};
use cabinet::runtime::{artifacts_available, default_artifact_dir, Engine};
use cabinet::sim::{run, DigestMode, Protocol, ReadPath, SimConfig};
use cabinet::workload::{Workload, YcsbGen};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args: VecDeque<String> = std::env::args().skip(1).collect();
    let cmd = args.pop_front().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "figures" => cmd_figures(args),
        "sim" => cmd_sim(args),
        "weights" => cmd_weights(args),
        "live" => cmd_live(args),
        "check-artifacts" => cmd_check_artifacts(),
        "bench-check" => cmd_bench_check(args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `cabinet help`"),
    }
}

const HELP: &str = "cabinet — dynamically weighted consensus (paper reproduction)

USAGE:
  cabinet figures [fig3|fig4|fig8|...|all] [--paper]
  cabinet sim --config exp.toml
  cabinet sim [--proto raft|cabinet|hqc] [--n N] [--t T] [--het|--hom]
              [--rounds R] [--workload A..F|tpcc] [--delay d0|d1|d2|d3|d4]
              [--seed S] [--pipeline D] [--snapshot-every E] [--pre-vote]
              [--groups G] [--shard-by hash|warehouse]
              [--read-path log|readindex|lease] [--lease-drift-ms M]
              [--nemesis \"2000..6000=leader;8000..20000=followers:2\"]
              [--nemesis-drop P] [--nemesis-dup P] [--nemesis-reorder P]
              [--nemesis-reorder-ms M]
              [--members K] [--drain-rounds D] [--join-warmup W]
              [--join R=ID]... [--leave R=ID]... [--replace R=OLD>NEW]...
              [--wal] [--fsync-group G] [--fsync-ms M] [--torn-writes]
              [--coding-k K] [--coding-cutover BYTES] [--bandwidth BYTES_PER_MS]
              [--max-batch-bytes B] [--value-size BYTES]
  cabinet weights --n N --t T
  cabinet live [--n N] [--t T] [--rounds R] [--batch B]
  cabinet check-artifacts
  cabinet bench-check BENCH_suite.json [...]";

fn flag(args: &mut VecDeque<String>, name: &str) -> Option<String> {
    let pos = args.iter().position(|a| a == name)?;
    let v = args.get(pos + 1).cloned();
    args.remove(pos + 1);
    args.remove(pos);
    v
}

fn has_flag(args: &mut VecDeque<String>, name: &str) -> bool {
    if let Some(pos) = args.iter().position(|a| a == name) {
        args.remove(pos);
        true
    } else {
        false
    }
}

fn cmd_figures(mut args: VecDeque<String>) -> Result<()> {
    let paper = has_flag(&mut args, "--paper");
    let scale = if paper { Scale::Paper } else { Scale::Quick };
    let which = args.pop_front().unwrap_or_else(|| "all".into());
    let tables = match which.as_str() {
        "all" => figures::all_figures(scale),
        "fig3" => vec![figures::fig3()],
        "fig4" => vec![figures::fig4()],
        "fig8" => vec![figures::fig8(scale)],
        "fig9" => vec![figures::fig9(scale)],
        "fig10" => vec![figures::fig10(scale)],
        "fig11" => vec![figures::fig11(scale)],
        "fig12" => vec![figures::fig12(scale)],
        "fig13" => vec![figures::fig13()],
        "fig14" => vec![figures::fig14(scale)],
        "fig15" => vec![figures::fig15(scale)],
        "fig16" => vec![figures::fig16(scale)],
        "fig17" => vec![figures::fig17(scale), figures::fig17_series(scale)],
        "fig18" => vec![figures::fig18(scale)],
        "fig19" => vec![figures::fig19(scale)],
        "fig20" => vec![figures::fig20_pipeline_depth(scale)],
        "fig21" => vec![figures::fig21_compaction(scale)],
        "fig22" => vec![figures::fig22_partitions(scale)],
        "fig23" => vec![figures::fig23_read_paths(scale)],
        "fig24" => vec![figures::fig24_sharding(scale)],
        "fig25" => vec![figures::fig25_membership(scale)],
        "fig26" => vec![figures::fig26_fsync_group(scale)],
        "fig27" => vec![figures::fig27_value_size(scale)],
        other => bail!("unknown figure {other}"),
    };
    for t in tables {
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_sim(mut args: VecDeque<String>) -> Result<()> {
    let mut config = if let Some(path) = flag(&mut args, "--config") {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path}"))?;
        sim_config_from_toml(&text)?
    } else {
        let n: usize = flag(&mut args, "--n").map(|v| v.parse()).transpose()?.unwrap_or(11);
        let het = !has_flag(&mut args, "--hom") || has_flag(&mut args, "--het");
        let proto = match flag(&mut args, "--proto").as_deref().unwrap_or("cabinet") {
            "raft" => Protocol::Raft,
            "cabinet" => {
                let t: usize =
                    flag(&mut args, "--t").map(|v| v.parse()).transpose()?.unwrap_or(1);
                Protocol::Cabinet { t }
            }
            "hqc" => Protocol::Hqc { sizes: vec![n / 3, n / 3, n - 2 * (n / 3)] },
            other => bail!("unknown proto {other}"),
        };
        let mut c = SimConfig::new(proto, n, het);
        if let Some(r) = flag(&mut args, "--rounds") {
            c.rounds = r.parse()?;
        }
        if let Some(s) = flag(&mut args, "--seed") {
            c.seed = s.parse()?;
        }
        if let Some(p) = flag(&mut args, "--pipeline") {
            c.pipeline = p.parse()?;
            if c.pipeline < 1 {
                bail!("--pipeline must be >= 1");
            }
        }
        if let Some(e) = flag(&mut args, "--snapshot-every") {
            let every: u64 = e.parse()?;
            c.snapshot_every = (every > 0).then_some(every); // 0 = off
        }
        if has_flag(&mut args, "--pre-vote") {
            c.pre_vote = true;
        }
        {
            use cabinet::sim::StorageSpec;
            let wal = has_flag(&mut args, "--wal");
            let group = flag(&mut args, "--fsync-group");
            let fsync_ms = flag(&mut args, "--fsync-ms");
            let torn = has_flag(&mut args, "--torn-writes");
            if wal || group.is_some() || fsync_ms.is_some() || torn {
                let mut spec = StorageSpec::default();
                if let Some(g) = group {
                    spec.fsync_group = g.parse()?;
                    if spec.fsync_group < 1 {
                        bail!("--fsync-group must be >= 1");
                    }
                }
                if let Some(ms) = fsync_ms {
                    spec.fsync_ms = ms.parse()?;
                    if spec.fsync_ms < 0.0 {
                        bail!("--fsync-ms must be >= 0");
                    }
                }
                spec.torn_writes = torn;
                c.storage = Some(spec);
            }
        }
        if let Some(g) = flag(&mut args, "--groups") {
            // validated below (with --shard-by and --workload settled) via
            // the shared SimConfig::validate_sharding
            c.groups = g.parse()?;
        }
        if let Some(sb) = flag(&mut args, "--shard-by") {
            c.shard_by = Some(
                cabinet::workload::ShardBy::from_name(&sb)
                    .with_context(|| format!("unknown --shard-by {sb} (hash|warehouse)"))?,
            );
        }
        if let Some(rp) = flag(&mut args, "--read-path") {
            c.read_path = ReadPath::from_name(&rp)
                .with_context(|| format!("unknown --read-path {rp} (log|readindex|lease)"))?;
        }
        if let Some(ms) = flag(&mut args, "--lease-drift-ms") {
            c.lease_drift_ms = ms.parse()?;
            if c.lease_drift_ms < 0.0 || c.lease_drift_ms >= c.election_timeout_ms.0 {
                bail!(
                    "--lease-drift-ms must be in [0, {}) (minimum election timeout)",
                    c.election_timeout_ms.0
                );
            }
        }
        {
            use cabinet::net::nemesis::{NemesisSpec, PartitionSpec};
            let mut spec = NemesisSpec::default();
            if let Some(parts) = flag(&mut args, "--nemesis") {
                for p in parts.split(';').filter(|p| !p.trim().is_empty()) {
                    spec.partitions.push(PartitionSpec::parse(p.trim())?);
                }
            }
            if let Some(p) = flag(&mut args, "--nemesis-drop") {
                spec.drop_p = p.parse()?;
            }
            if let Some(p) = flag(&mut args, "--nemesis-dup") {
                spec.dup_p = p.parse()?;
            }
            if let Some(p) = flag(&mut args, "--nemesis-reorder") {
                spec.reorder_p = p.parse()?;
            }
            if let Some(m) = flag(&mut args, "--nemesis-reorder-ms") {
                spec.reorder_max_ms = m.parse()?;
            }
            if !spec.is_noop() {
                if spec.reorder_p > 0.0 && spec.reorder_max_ms == 0.0 {
                    spec.reorder_max_ms = 40.0; // sensible default bound
                }
                spec.validate(n)?;
                c.nemesis = Some(spec);
            }
        }
        if let Some(w) = flag(&mut args, "--workload") {
            if w.eq_ignore_ascii_case("tpcc") {
                c.workload = cabinet::sim::WorkloadSpec::tpcc2k();
            } else {
                let wl = Workload::from_name(&w).context("unknown workload")?;
                c.workload = cabinet::sim::WorkloadSpec::ycsb(wl, 5000);
            }
        }
        if let Some(d) = flag(&mut args, "--delay") {
            use cabinet::net::delay::DelayModel;
            c.delay = match d.as_str() {
                "d0" => DelayModel::None,
                "d1" => DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 },
                "d2" => DelayModel::Skew,
                "d3" => DelayModel::Rotating { period_rounds: 10 },
                "d4" => DelayModel::Bursting,
                other => bail!("unknown delay {other}"),
            };
        }
        {
            use cabinet::consensus::coding::CodingConfig;
            let k = flag(&mut args, "--coding-k");
            let cut = flag(&mut args, "--coding-cutover");
            if let Some(k) = k {
                let cutover_bytes = cut.map(|v| v.parse::<u64>()).transpose()?;
                c.coding = Some(CodingConfig { k: k.parse()?, cutover_bytes });
            } else if cut.is_some() {
                bail!("--coding-cutover requires --coding-k");
            }
            if let Some(b) = flag(&mut args, "--bandwidth") {
                c.bandwidth_bytes_per_ms = Some(b.parse()?);
            }
            if let Some(mb) = flag(&mut args, "--max-batch-bytes") {
                c.max_batch_bytes = Some(mb.parse()?);
            }
            if let Some(vs) = flag(&mut args, "--value-size") {
                c.value_size = vs.parse()?;
            }
        }
        {
            use cabinet::net::nemesis::{MembershipEvent, MembershipSpec};
            if let Some(k) = flag(&mut args, "--members") {
                c.initial_members = Some(k.parse()?);
            }
            if let Some(d) = flag(&mut args, "--drain-rounds") {
                c.drain_rounds = d.parse()?;
            }
            if let Some(w) = flag(&mut args, "--join-warmup") {
                c.join_warmup = w.parse()?;
            }
            // --join 4=5 / --leave 8=0 / --replace 12=1>6, each repeatable:
            // sugar over the config-file DSL (ROUND=join:ID etc.)
            let mut spec = MembershipSpec::default();
            for (flag_name, verb) in
                [("--join", "join"), ("--leave", "leave"), ("--replace", "replace")]
            {
                while let Some(v) = flag(&mut args, flag_name) {
                    let (round, arg) = v.split_once('=').with_context(|| {
                        format!("{flag_name} {v:?}: expected ROUND=ARG")
                    })?;
                    spec.events.push(MembershipEvent::parse(&format!(
                        "{round}={verb}:{arg}"
                    ))?);
                }
            }
            if !spec.is_noop() {
                c.membership = Some(spec);
            }
            if let Err(e) = c.validate_membership() {
                bail!("{e}");
            }
        }
        // sharding cross-checks — the one shared implementation, run after
        // --groups/--shard-by/--workload/--proto are all settled
        if let Err(e) = c.validate_sharding() {
            bail!("{e}");
        }
        if let Err(e) = c.validate_coding() {
            bail!("{e}");
        }
        c.digest_mode = DigestMode::Sample;
        c
    };
    // every nemesis run self-checks safety — TOML-configured ones included —
    // every fast-read-path run self-checks read linearizability, and every
    // membership run self-checks config-epoch coherence
    if config.nemesis.is_some()
        || !matches!(config.read_path, ReadPath::Log)
        || config.membership_on()
        || config.storage.map_or(false, |s| s.torn_writes)
    {
        config.track_safety = true;
    }
    let pipeline = config.pipeline;
    let r = run(&config);
    println!("experiment: {}", r.label);
    println!("rounds:     {}", r.rounds.len());
    if pipeline > 1 {
        println!("pipeline:   depth {pipeline}");
        println!("wall tput:  {} ops/s", cabinet::bench::fmt_tps(r.wall_tput_ops_s()));
    }
    println!("throughput: {} ops/s", cabinet::bench::fmt_tps(r.tput_ops_s));
    if r.bytes_sent > 0 {
        println!("bytes:      {} sent   {:.0} B/op", r.bytes_sent, r.bytes_per_op);
    }
    println!(
        "latency:    mean {:.1} ms   p50 {:.1} ms   p99 {:.1} ms",
        r.mean_latency_ms, r.p50_latency_ms, r.p99_latency_ms
    );
    println!("elections:  {} ({} candidacies, max term {})", r.elections, r.elections_started, r.terms_advanced);
    if config.groups > 1 {
        println!(
            "sharding:   {} groups   agg wall tput {} ops/s",
            config.groups,
            cabinet::bench::fmt_tps(r.agg_wall_tput_ops_s())
        );
        for g in &r.group_stats {
            println!(
                "  group {}: {} rounds  {} ops/s wall  leader {}  term {}  {} elections",
                g.group,
                g.rounds,
                cabinet::bench::fmt_tps(g.wall_tput_ops_s),
                g.leader.map(|l| l.to_string()).unwrap_or_else(|| "-".into()),
                g.term,
                g.elections
            );
        }
    }
    if r.reads_served > 0 {
        println!(
            "reads:      {} served ({} ops; {} via lease, {} readindex rounds, {} retried)",
            r.reads_served, r.read_ops_served, r.lease_reads, r.readindex_rounds, r.read_failures
        );
        println!(
            "read lat:   mean {:.1} ms   p50 {:.1} ms   p99 {:.1} ms   combined tput {} ops/s",
            r.read_mean_ms,
            r.read_p50_ms,
            r.read_p99_ms,
            cabinet::bench::fmt_tps(r.combined_wall_tput_ops_s())
        );
    }
    if let Some(stats) = &r.nemesis_stats {
        println!(
            "nemesis:    cut {}  lost {}  duplicated {}  reordered {}",
            stats.cut, stats.dropped, stats.duplicated, stats.reordered
        );
    }
    if r.config_commits > 0 {
        println!("membership: {} config commits observed", r.config_commits);
    }
    for (group, log) in r.safety_logs() {
        let report = cabinet::bench::safety_check(log);
        let scope = match group {
            Some(g) => format!("group {g}"),
            None => "cluster".into(),
        };
        if report.is_clean() {
            println!(
                "safety:     {scope} OK ({} commits, {} decisions, {} leader terms, {} reads)",
                report.commits_checked,
                report.decisions,
                report.leaders_checked,
                report.reads_checked
            );
            if report.epochs_checked > 0 {
                println!(
                    "            {scope} config epochs coherent ({} decisions, {} weighted-evidence commits)",
                    report.epochs_checked, report.evidence_checked
                );
            }
        } else {
            for v in &report.violations {
                eprintln!("SAFETY VIOLATION [{scope}]: {v}");
            }
            bail!("{} safety violations detected in {scope}", report.violations.len());
        }
    }
    if config.snapshot_every.is_some() {
        println!(
            "snapshots:  taken {}  installed {}  max retained log {}",
            r.snapshots_taken, r.snapshots_installed, r.max_retained_log
        );
    }
    if config.storage.is_some() {
        println!(
            "wal:        {} appends  {} fsyncs  {} recoveries ({} entries replayed)",
            r.wal_appends, r.wal_fsyncs, r.wal_recoveries, r.wal_recovered_entries
        );
    }
    if let Some(ok) = r.digests_match {
        println!("replica digests match: {ok}");
    }
    Ok(())
}

fn cmd_weights(mut args: VecDeque<String>) -> Result<()> {
    let n: usize = flag(&mut args, "--n").context("--n required")?.parse()?;
    let t: usize = flag(&mut args, "--t").context("--t required")?.parse()?;
    let ws = WeightScheme::geometric(n, t)?;
    let (lo, hi) = ratio_bounds(n, t);
    println!("{ws}");
    println!("feasible ratio interval: ({lo:.6}, {hi:.6})");
    println!("cabinet size: {} (t+1)", ws.cabinet_size());
    println!("election quorum: {} (n-t)", n - t);
    // cross-check against the AOT artifact when available
    let dir = default_artifact_dir();
    if artifacts_available(&dir) {
        let engine = Engine::load(&dir)?;
        let (r_hlo, w_hlo, ct_hlo) = engine.weight_scheme(n as i32, t as i32)?;
        let dr = (r_hlo - ws.ratio()).abs();
        let dct = (ct_hlo - ws.ct()).abs() / ws.ct();
        let dw = ws
            .weights()
            .iter()
            .zip(&w_hlo)
            .map(|(a, b)| (a - b).abs() / a)
            .fold(0.0f64, f64::max);
        println!("AOT artifact cross-check: |Δr|={dr:.2e} relΔct={dct:.2e} max relΔw={dw:.2e}");
    }
    Ok(())
}

fn cmd_live(mut args: VecDeque<String>) -> Result<()> {
    let n: usize = flag(&mut args, "--n").map(|v| v.parse()).transpose()?.unwrap_or(5);
    let t: usize = flag(&mut args, "--t").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let rounds: usize =
        flag(&mut args, "--rounds").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let batch: usize =
        flag(&mut args, "--batch").map(|v| v.parse()).transpose()?.unwrap_or(2000);

    let mut svc = ApplyService::spawn(default_artifact_dir());
    let backend = svc.backend();
    println!("apply backend: {backend:?}");
    if backend == Backend::Native {
        println!("(run `make artifacts` to exercise the PJRT path)");
    }
    let cluster =
        LiveCluster::start(n, Mode::cabinet(n, t), LiveTimers::default(), Some(svc.submitter()), 1);
    cluster.force_election(0);
    let leader =
        cluster.wait_for_leader(Duration::from_secs(5)).context("no leader elected")?;
    println!("leader: node {leader} (cabinet mode, n={n}, t={t})");
    let mut gen = YcsbGen::new(Workload::A, 100_000, 7);
    let t0 = std::time::Instant::now();
    for i in 0..rounds {
        let b = gen.batch(batch);
        cluster.propose(leader, Payload::Ycsb(std::sync::Arc::new(b)));
        cluster
            .wait_for_round((i + 2) as u64, Duration::from_secs(10))
            .context("round timed out")?;
    }
    let dt = t0.elapsed();
    println!(
        "{rounds} rounds × {batch} ops in {:.2}s → {} ops/s",
        dt.as_secs_f64(),
        cabinet::bench::fmt_tps(rounds as f64 * batch as f64 / dt.as_secs_f64())
    );
    std::thread::sleep(Duration::from_millis(200));
    let reports = cluster.shutdown();
    let digests: Vec<_> = reports.iter().filter_map(|r| r.final_digest).collect();
    let all_eq = digests.windows(2).all(|w| w[0] == w[1]);
    println!("replicas with applied state: {} / {n}; digests match: {all_eq}", digests.len());
    Ok(())
}

/// Validate `BENCH_<suite>.json` perf artifacts (the CI bench job runs this
/// after `cargo bench` to fail on malformed emission — no perf gating, the
/// trajectory is informational).
fn cmd_bench_check(args: VecDeque<String>) -> Result<()> {
    anyhow::ensure!(!args.is_empty(), "usage: cabinet bench-check BENCH_suite.json [...]");
    for path in &args {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let report = cabinet::bench::BenchReport::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path}: malformed bench artifact: {e}"))?;
        anyhow::ensure!(
            report.schema == cabinet::bench::report::BENCH_SCHEMA_VERSION,
            "{path}: schema {} != expected {}",
            report.schema,
            cabinet::bench::report::BENCH_SCHEMA_VERSION
        );
        anyhow::ensure!(!report.records.is_empty(), "{path}: no records emitted");
        println!(
            "{path}: ok — suite {:?}, {} records, rev {}, quick={}",
            report.suite,
            report.records.len(),
            report.git_rev,
            report.quick
        );
    }
    Ok(())
}

fn cmd_check_artifacts() -> Result<()> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        bail!("artifacts not found in {} — run `make artifacts`", dir.display());
    }
    let engine = Engine::load(&dir)?;
    println!("manifest: {:?}", engine.manifest);

    // YCSB artifact vs native mirror (bit-exact)
    let mut gen = YcsbGen::new(Workload::A, 100_000, 3);
    let batch = gen.batch(5000).padded_to(cabinet::storage::digest::YCSB_BATCH);
    let state = vec![0u32; cabinet::storage::digest::STATE_SLOTS];
    let (hlo_state, hlo_digest) =
        engine.ycsb_apply(&state, &batch.ops, &batch.keys, &batch.vals)?;
    let mut native = cabinet::storage::digest::DigestState::default();
    let native_digest = native.apply_ycsb(&batch.ops, &batch.keys, &batch.vals);
    anyhow::ensure!(hlo_digest == native_digest, "ycsb digest mismatch");
    anyhow::ensure!(hlo_state == native.slots(), "ycsb state mismatch");
    println!("ycsb_apply: HLO == native mirror (digest {hlo_digest:?})");

    // TPC-C artifact vs native mirror
    let mut tgen = cabinet::workload::TpccGen::new(64, 4);
    let tb = tgen.batch(2000).padded_to(cabinet::storage::digest::TPCC_BATCH);
    let (counts, costs, dig) = engine.tpcc_cost(&tb.types, &tb.wids, &tb.args)?;
    let (ncounts, ncosts, ndig) =
        cabinet::storage::digest::tpcc_costs(&tb.types, &tb.wids, &tb.args, 64);
    anyhow::ensure!(dig == ndig, "tpcc digest mismatch");
    anyhow::ensure!(counts == ncounts, "tpcc counts mismatch");
    let max_err = costs
        .iter()
        .zip(&ncosts)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_err < 1e-3, "tpcc cost mismatch {max_err}");
    println!("tpcc_cost: HLO == native mirror (digest {dig:#010x})");

    // weight-scheme artifact vs native solver
    for (n, t) in [(10i32, 3i32), (50, 5), (100, 40)] {
        let (r_hlo, _w, ct_hlo) = engine.weight_scheme(n, t)?;
        let ws = WeightScheme::geometric(n as usize, t as usize)?;
        anyhow::ensure!(
            (r_hlo - ws.ratio()).abs() < 1e-6,
            "ratio mismatch n={n} t={t}: {r_hlo} vs {}",
            ws.ratio()
        );
        anyhow::ensure!((ct_hlo - ws.ct()).abs() / ws.ct() < 1e-9, "ct mismatch");
    }
    println!("weight_scheme: HLO solver == native solver (n=10/50/100)");
    println!("all artifacts OK");
    Ok(())
}
