//! The simulated cluster: drives n sans-io consensus nodes (or the HQC
//! baseline) over the deterministic event queue, reproducing the paper's
//! benchmark-round pipeline (Fig. 7): the leader batches a workload round,
//! ships it via AppendEntries, followers *execute the transmitted workload*
//! and reply, and the round commits at the quorum rule's threshold.
//!
//! Virtual-time calibration (DESIGN.md §6): follower response time =
//! link delay (DelayModel) + RPC processing + batch apply cost / zone speed
//! (× contention). Batch apply cost comes from the same cost model as the
//! AOT kernels (`storage::doc` / `storage::rel`).

use std::collections::HashMap;
use std::sync::Arc;

use crate::bench::metrics::percentile_sorted;
use crate::consensus::hqc::{HqcMsg, HqcNode, HqcOutput, HqcTopology};
use crate::consensus::message::{Message, NodeId, Payload};
use crate::consensus::node::{Input, Mode, Node, Output, Role};
pub use crate::consensus::node::ReadPath;
use crate::net::delay::DelayModel;
use crate::net::fault::{ContentionSpec, KillSpec};
use crate::net::nemesis::{Fate, Nemesis, NemesisSpec, NemesisStats};
use crate::net::rng::Rng;
use crate::net::topology::ZoneAlloc;
use crate::sim::event::EventQueue;
use crate::storage::{DocStore, RelStore};
use crate::util::Fnv64;
use crate::workload::ycsb::{OP_READ, OP_SCAN};
use crate::workload::{TpccGen, Workload, YcsbBatch, YcsbGen};

/// Which consensus protocol the cluster runs.
#[derive(Clone, Debug)]
pub enum Protocol {
    Raft,
    /// Cabinet with failure threshold t.
    Cabinet { t: usize },
    /// HQC baseline with the given group sizes (replication-only).
    Hqc { sizes: Vec<usize> },
}

impl Protocol {
    pub fn label(&self) -> String {
        match self {
            Protocol::Raft => "raft".into(),
            Protocol::Cabinet { t } => format!("cab-t{t}"),
            Protocol::Hqc { sizes } => format!(
                "hqc-{}",
                sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("-")
            ),
        }
    }
}

/// Which workload the rounds carry.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    Ycsb { workload: Workload, batch: usize, records: u64 },
    Tpcc { batch: usize, warehouses: u32 },
}

impl WorkloadSpec {
    pub fn ycsb_a5k() -> Self {
        WorkloadSpec::Ycsb { workload: Workload::A, batch: 5000, records: 100_000 }
    }
    pub fn ycsb(workload: Workload, batch: usize) -> Self {
        WorkloadSpec::Ycsb { workload, batch, records: 100_000 }
    }
    pub fn tpcc2k() -> Self {
        WorkloadSpec::Tpcc { batch: 2000, warehouses: 10 }
    }
}

/// Replica digest tracking intensity (full tracking is O(nodes × ops)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DigestMode {
    /// No state-machine application (pure consensus timing) — benches.
    Off,
    /// Two replicas tracked and compared — cheap convergence check.
    Sample,
    /// Every replica tracked — integration tests.
    All,
}

/// A scheduled failure-threshold reconfiguration (Fig. 12).
#[derive(Clone, Copy, Debug)]
pub struct ReconfigSpec {
    pub round: u64,
    pub new_t: usize,
}

/// Kill-and-restart schedule for a single follower (the Fig. 21 compaction
/// catch-up scenario): the highest-id non-leader node is killed at the
/// start of `kill_round` and comes back at the start of `restart_round`
/// with completely fresh state (empty log, zero commit index) — as a real
/// replica would after losing its disk. With `snapshot_every` set, the
/// leader has compacted past the victim's log by then, so catch-up must go
/// through `InstallSnapshot`; with compaction off it replays the full log.
#[derive(Clone, Copy, Debug)]
pub struct RestartSpec {
    pub kill_round: u64,
    pub restart_round: u64,
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub protocol: Protocol,
    pub zones: ZoneAlloc,
    pub delay: DelayModel,
    pub workload: WorkloadSpec,
    pub rounds: u64,
    pub seed: u64,
    pub kills: Vec<KillSpec>,
    pub kill_leader_at_round: Option<u64>,
    pub contention: Option<ContentionSpec>,
    pub reconfigs: Vec<ReconfigSpec>,
    pub digest_mode: DigestMode,
    /// Election timeout range (ms) — randomized per arm.
    pub election_timeout_ms: (f64, f64),
    /// Leader heartbeat interval (ms).
    pub heartbeat_ms: f64,
    /// Fixed per-RPC processing cost (ms) at Z3 speed.
    pub rpc_proc_ms: f64,
    /// P2 ablation: freeze the initial weight assignment (no re-dealing).
    pub static_weights: bool,
    /// Max replication rounds the leader keeps in flight. 1 = the paper's
    /// lock-step benchmark pipeline (Fig. 7); >1 enables the pipelined
    /// driver, which overlaps replication of consecutive batches.
    pub pipeline: usize,
    /// Snapshot/compaction: every node takes a snapshot (and truncates its
    /// log prefix) every this many committed entries. None = unbounded log
    /// (the historical behavior).
    pub snapshot_every: Option<u64>,
    /// Optional kill-and-restart of one follower (Fig. 21 scenario).
    pub restart: Option<RestartSpec>,
    /// Adversarial network schedule (partitions, loss, duplication,
    /// reordering). None = the historical clean network. The nemesis draws
    /// from its own forked RNG stream, so enabling it never perturbs the
    /// delay/timer/kill streams.
    pub nemesis: Option<NemesisSpec>,
    /// PreVote (Raft §9.6 adapted to Cabinet's n − t election quorum) on
    /// every node. Off by default — the historical election behavior.
    pub pre_vote: bool,
    /// Record per-node commit sequences and per-term leaders for the
    /// `bench::safety` checker (off by default: O(commits × n) memory).
    pub track_safety: bool,
    /// Which path serves linearizable reads. `Log` (the default) replicates
    /// every read through the log — bit-for-bit the historical behavior;
    /// `ReadIndex`/`Lease` split each YCSB batch into its mutating part
    /// (replicated) and its read-only part (served through the fast path).
    pub read_path: ReadPath,
    /// Clock-drift margin subtracted from the minimum election timeout to
    /// bound the leader lease (`lease` read path only).
    pub lease_drift_ms: f64,
}

/// One linearizable read served through a non-log read path — the evidence
/// the read-linearizability checker (`bench::safety::check`) validates
/// against the commit timeline.
#[derive(Clone, Copy, Debug)]
pub struct ReadRecord {
    /// Node that served the read locally.
    pub node: NodeId,
    pub id: u64,
    /// Virtual time the client invoked the read.
    pub invoked_ms: f64,
    /// Virtual time the read became servable (`Output::ReadReady`).
    pub served_ms: f64,
    /// Log index whose applied state the read observed.
    pub read_index: u64,
    /// Served via the lease fast path (no confirmation round).
    pub lease: bool,
}

/// Evidence collected for the deterministic safety checker
/// (`bench::safety::check`): every `Output::Commit` each node emitted, in
/// emission order, every `Output::BecameLeader` observation, the
/// write-completion timeline, and every served linearizable read.
#[derive(Clone, Debug)]
pub struct SafetyLog {
    /// Per node: (log index, term) of every committed entry, in commit order.
    pub commits: Vec<Vec<(u64, u64)>>,
    /// Every leadership establishment: (term, node).
    pub leaders: Vec<(u64, NodeId)>,
    /// (virtual time, log index) of every leader-observed round commit —
    /// the write-completion timeline reads are checked against.
    pub commit_times: Vec<(f64, u64)>,
    /// Every read served through a non-log read path.
    pub reads: Vec<ReadRecord>,
}

impl SafetyLog {
    pub fn new(n: usize) -> Self {
        SafetyLog {
            commits: vec![Vec::new(); n],
            leaders: Vec::new(),
            commit_times: Vec::new(),
            reads: Vec::new(),
        }
    }
}

impl SimConfig {
    /// Paper-style defaults for a YCSB-A run.
    pub fn new(protocol: Protocol, n: usize, heterogeneous: bool) -> Self {
        SimConfig {
            protocol,
            zones: if heterogeneous {
                ZoneAlloc::heterogeneous(n)
            } else {
                ZoneAlloc::homogeneous(n)
            },
            delay: DelayModel::None,
            workload: WorkloadSpec::ycsb_a5k(),
            rounds: 20,
            seed: 42,
            kills: Vec::new(),
            kill_leader_at_round: None,
            contention: None,
            reconfigs: Vec::new(),
            digest_mode: DigestMode::Off,
            election_timeout_ms: (2500.0, 4000.0),
            heartbeat_ms: 400.0,
            rpc_proc_ms: 0.15,
            static_weights: false,
            pipeline: 1,
            snapshot_every: None,
            restart: None,
            nemesis: None,
            pre_vote: false,
            track_safety: false,
            read_path: ReadPath::Log,
            lease_drift_ms: 50.0,
        }
    }

    pub fn n(&self) -> usize {
        self.zones.n()
    }

    /// The leader-lease bound this config grants: the minimum election
    /// timeout minus the clock-drift margin (§6.4.1). One definition for
    /// every node-construction site — fresh starts and restarts must agree.
    pub fn lease_duration_ms(&self) -> f64 {
        (self.election_timeout_ms.0 - self.lease_drift_ms).max(0.0)
    }
}

/// Per-round measurement (one line of the paper's real-time series).
#[derive(Clone, Copy, Debug)]
pub struct RoundStat {
    pub round: u64,
    /// Log index of the entry that carried this round's batch.
    pub entry_index: u64,
    /// Virtual time the round was proposed (ms).
    pub start_ms: f64,
    /// Commit latency for the round (ms).
    pub latency_ms: f64,
    /// Throughput implied by this round (ops/s).
    pub tput_ops_s: f64,
    /// Live ops in the batch.
    pub ops: usize,
    /// Repliers counted into the quorum when it closed.
    pub repliers: usize,
}

/// Aggregated run result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub label: String,
    pub rounds: Vec<RoundStat>,
    /// Overall throughput: total ops / total virtual time (ops/s).
    pub tput_ops_s: f64,
    /// Mean / p50 / p99 round-commit latency (ms).
    pub mean_latency_ms: f64,
    pub p50_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Replica digest convergence (None when DigestMode::Off).
    pub digests_match: Option<bool>,
    /// Leader elections observed (≥ 1: the bootstrap election).
    pub elections: u64,
    /// Snapshots taken across all nodes (0 when compaction is off; resets
    /// with a node on restart, so this is a lower bound under `restart`).
    pub snapshots_taken: u64,
    /// Leader snapshots installed by catching-up followers.
    pub snapshots_installed: u64,
    /// Peak retained (in-memory) log length observed on any node — the
    /// quantity `snapshot_every` bounds, sampled once per proposal tick.
    pub max_retained_log: u64,
    /// Real (term-incrementing) candidacies started across all nodes — the
    /// PreVote acceptance metric (a lower bound when `restart` replaced a
    /// node mid-run, since the fresh node's counter restarts at zero).
    pub elections_started: u64,
    /// Highest term any node reached by the end of the run — the
    /// term-churn metric PreVote bounds.
    pub terms_advanced: u64,
    /// Nemesis counters (None when no nemesis was configured).
    pub nemesis_stats: Option<NemesisStats>,
    /// Safety evidence for `bench::safety::check` (None unless
    /// `track_safety` was set).
    pub safety: Option<SafetyLog>,
    /// Read requests served through a non-log read path (0 on `log` runs:
    /// reads then ride the replicated batches).
    pub reads_served: u64,
    /// Individual read ops those requests carried.
    pub read_ops_served: u64,
    /// Requests served via the lease fast path (no confirmation round).
    pub lease_reads: u64,
    /// ReadIndex confirmation rounds leaders ran (renewals included).
    pub readindex_rounds: u64,
    /// Read attempts that failed and were retried (leadership churn).
    pub read_failures: u64,
    /// Read-request latency stats (ms) — 0 when no reads were served.
    pub read_mean_ms: f64,
    pub read_p50_ms: f64,
    pub read_p99_ms: f64,
    /// Virtual time the last read finished (extends the combined span).
    pub read_done_ms: f64,
}

impl SimResult {
    fn from_rounds(label: String, rounds: Vec<RoundStat>, digests: Option<bool>, elections: u64) -> Self {
        let total_ops: usize = rounds.iter().map(|r| r.ops).sum();
        let total_ms: f64 = rounds.iter().map(|r| r.latency_ms).sum();
        let mut lats: Vec<f64> = rounds.iter().map(|r| r.latency_ms).collect();
        // total_cmp, not partial_cmp: a NaN latency must never panic the
        // aggregation (it sorts to the end and shows up in max/p99 instead)
        lats.sort_by(|a, b| a.total_cmp(b));
        // nearest-rank percentiles come from the one shared implementation —
        // a private reimplementation here silently diverged once already
        let pct = |p: f64| percentile_sorted(&lats, p);
        SimResult {
            label,
            tput_ops_s: if total_ms > 0.0 { total_ops as f64 / (total_ms / 1000.0) } else { 0.0 },
            mean_latency_ms: if lats.is_empty() { 0.0 } else { lats.iter().sum::<f64>() / lats.len() as f64 },
            p50_latency_ms: pct(0.50),
            p99_latency_ms: pct(0.99),
            rounds,
            digests_match: digests,
            elections,
            snapshots_taken: 0,
            snapshots_installed: 0,
            max_retained_log: 0,
            elections_started: 0,
            terms_advanced: 0,
            nemesis_stats: None,
            safety: None,
            reads_served: 0,
            read_ops_served: 0,
            lease_reads: 0,
            readindex_rounds: 0,
            read_failures: 0,
            read_mean_ms: 0.0,
            read_p50_ms: 0.0,
            read_p99_ms: 0.0,
            read_done_ms: 0.0,
        }
    }

    /// Committed throughput over the run's wall-clock span (ops/s): total
    /// live ops divided by (last commit time − first propose time). Unlike
    /// `tput_ops_s` (which sums per-round latencies, the right measure for
    /// the lock-step pipeline), this credits the overlap a pipelined run
    /// achieves, so it is the comparison metric for the Fig. 20 depth sweep.
    pub fn wall_tput_ops_s(&self) -> f64 {
        let Some(first) = self.rounds.iter().map(|r| r.start_ms).reduce(f64::min) else {
            return 0.0;
        };
        let end = self
            .rounds
            .iter()
            .map(|r| r.start_ms + r.latency_ms)
            .fold(first, f64::max);
        let span_ms = end - first;
        if span_ms <= 0.0 {
            return 0.0;
        }
        let ops: usize = self.rounds.iter().map(|r| r.ops).sum();
        ops as f64 / (span_ms / 1000.0)
    }

    /// Committed + read throughput over the union span (ops/s): replicated
    /// live ops plus read ops served through a fast path, divided by the
    /// span from the first propose to the last commit *or* read completion.
    /// On `log` runs reads ride the batches, so this equals
    /// [`SimResult::wall_tput_ops_s`] — making it the one comparable metric
    /// across read paths (the Fig. 23 column).
    pub fn combined_wall_tput_ops_s(&self) -> f64 {
        let Some(first) = self.rounds.iter().map(|r| r.start_ms).reduce(f64::min) else {
            return 0.0;
        };
        let end = self
            .rounds
            .iter()
            .map(|r| r.start_ms + r.latency_ms)
            .fold(first, f64::max)
            .max(self.read_done_ms);
        let span_ms = end - first;
        if span_ms <= 0.0 {
            return 0.0;
        }
        let ops: usize = self.rounds.iter().map(|r| r.ops).sum();
        (ops as u64 + self.read_ops_served) as f64 / (span_ms / 1000.0)
    }

    /// Bit-exact digest of the commit sequence (round numbers and the log
    /// indices they committed at, in commit order) — the deterministic-replay
    /// regression tests compare these across runs of the same seed.
    pub fn commit_sequence_digest(&self) -> u64 {
        let mut h = Fnv64::new();
        for r in &self.rounds {
            h.write_u64(r.round);
            h.write_u64(r.entry_index);
            h.write_u64(r.ops as u64);
        }
        h.finish()
    }

    /// Bit-exact digest over every per-round metric (virtual times included)
    /// plus the aggregates — two runs agree on this iff they took the exact
    /// same virtual-time trajectory.
    pub fn metrics_digest(&self) -> u64 {
        let mut h = Fnv64::new();
        for r in &self.rounds {
            h.write_u64(r.round);
            h.write_u64(r.entry_index);
            h.write_u64(r.start_ms.to_bits());
            h.write_u64(r.latency_ms.to_bits());
            h.write_u64(r.tput_ops_s.to_bits());
            h.write_u64(r.ops as u64);
            h.write_u64(r.repliers as u64);
        }
        h.write_u64(self.tput_ops_s.to_bits());
        h.write_u64(self.mean_latency_ms.to_bits());
        h.write_u64(self.p99_latency_ms.to_bits());
        h.write_u64(self.elections);
        h.write_u64(self.elections_started);
        h.write_u64(self.terms_advanced);
        // Read-path metrics fold in only when reads were actually served, so
        // `read_path = "log"` digests stay bit-identical to pre-read-path
        // builds (the replay-determinism acceptance criterion).
        if self.reads_served > 0 {
            h.write_u64(self.reads_served);
            h.write_u64(self.read_ops_served);
            h.write_u64(self.lease_reads);
            h.write_u64(self.readindex_rounds);
            h.write_u64(self.read_failures);
            h.write_u64(self.read_mean_ms.to_bits());
            h.write_u64(self.read_p99_ms.to_bits());
            h.write_u64(self.read_done_ms.to_bits());
        }
        h.finish()
    }
}

// ---------------------------------------------------------------------------
// Raft / Cabinet simulation
// ---------------------------------------------------------------------------

enum Ev {
    Deliver { to: NodeId, from: NodeId, msg: Message },
    ElectionTimer { node: NodeId, generation: u64 },
    HeartbeatTimer { node: NodeId, generation: u64 },
    /// Harness: try to propose the next round at the current leader.
    ProposeNext,
    /// Harness: a client read request arrives at `node` (non-log paths).
    ReadAt { id: u64, node: NodeId },
    /// Harness: re-drive a read that has not been served yet (a forward or
    /// grant was lost, or leadership moved mid-confirmation).
    ReadRetry { id: u64 },
}

/// Client-side retry cadence for unserved reads (virtual ms).
const READ_RETRY_MS: f64 = 400.0;
/// Concurrent read requests per round on a non-log read path — an open-loop
/// fan-out client: each round's read-only ops are split across this many
/// parallel requests at rotated nodes (followers included), so read work is
/// spread across the cluster instead of riding every replication round.
const READ_FAN: u64 = 4;

/// One in-flight client read request.
struct ReadReq {
    invoked_ms: f64,
    /// Read ops this request carries (for throughput accounting).
    ops: usize,
    /// Apply cost of those ops at unit speed (charged at the serving node).
    cost_ms: f64,
    /// Round the request belongs to (target rotation slot).
    round: u64,
    /// Position in the fan (rotates the serving node).
    k: u64,
}

/// Client-side read bookkeeping shared by both round drivers.
#[derive(Default)]
struct ReadCtl {
    next_id: u64,
    outstanding: HashMap<u64, ReadReq>,
    latencies: Vec<f64>,
    reads_served: u64,
    read_ops_served: u64,
    lease_reads: u64,
    failures: u64,
    /// Virtual time the last read finished (combined-throughput span end).
    done_ms: f64,
}

impl ReadCtl {
    /// Fan a round's read-only sub-batch out as [`READ_FAN`] concurrent
    /// requests at rotated alive targets (followers serve local reads too),
    /// each with a standing retry timer. The first request absorbs the
    /// division remainder so op totals stay exact.
    fn issue_fan(
        &mut self,
        q: &mut EventQueue<Ev>,
        alive: &[bool],
        invoked_ms: f64,
        round: u64,
        reads: &YcsbBatch,
    ) {
        let live = reads.live_ops();
        let fan = READ_FAN.min(live.max(1) as u64);
        let ops_per = live / fan as usize;
        let cost_per = DocStore::estimate_cost_ms(reads) / fan as f64;
        for k in 0..fan {
            let ops = if k == 0 { live - ops_per * (fan as usize - 1) } else { ops_per };
            let Some(target) = pick_read_target(round + k, alive) else { continue };
            let id = self.next_id;
            self.next_id += 1;
            self.outstanding
                .insert(id, ReadReq { invoked_ms, ops, cost_ms: cost_per, round, k });
            q.push_after(0.0, Ev::ReadAt { id, node: target });
            q.push_after(READ_RETRY_MS, Ev::ReadRetry { id });
        }
    }
}

/// Deterministic read-target rotation over the alive nodes.
fn pick_read_target(slot: u64, alive: &[bool]) -> Option<NodeId> {
    let n = alive.len();
    (0..n).map(|d| (slot as usize + d) % n).find(|&i| alive[i])
}

/// Split a YCSB batch into its mutating part (replicated through the log)
/// and its read-only part (READ + SCAN, served through the read path).
fn split_ycsb(b: &YcsbBatch) -> (YcsbBatch, YcsbBatch) {
    let empty = YcsbBatch {
        workload: b.workload,
        ops: Vec::new(),
        keys: Vec::new(),
        vals: Vec::new(),
    };
    let (mut writes, mut reads) = (empty.clone(), empty);
    for i in 0..b.ops.len() {
        let dst = if b.ops[i] == OP_READ || b.ops[i] == OP_SCAN { &mut reads } else { &mut writes };
        dst.ops.push(b.ops[i]);
        dst.keys.push(b.keys[i]);
        dst.vals.push(b.vals[i]);
    }
    (writes, reads)
}

/// Generate the next round's batch; on a non-log read path, split out the
/// read-only ops. Returns (payload, tracked batch, apply cost of the
/// replicated part, replicated live ops, read-only sub-batch). TPC-C rounds
/// stay fully log-replicated (transactions are read-write).
fn next_round_batch(
    driver: &mut WorkloadDriver,
    read_path: ReadPath,
) -> (Payload, Batch, f64, usize, Option<YcsbBatch>) {
    let (payload, batch, cost, ops) = driver.next_batch();
    if matches!(read_path, ReadPath::Log) {
        return (payload, batch, cost, ops, None);
    }
    match payload {
        Payload::Ycsb(full) => {
            let (writes, reads) = split_ycsb(&full);
            let writes = Arc::new(writes);
            let cost = DocStore::estimate_cost_ms(&writes);
            let ops = writes.live_ops();
            let reads = (!reads.is_empty()).then_some(reads);
            (Payload::Ycsb(writes.clone()), Batch::Ycsb(writes), cost, ops, reads)
        }
        other => (other, batch, cost, ops, None),
    }
}

enum Batch {
    Ycsb(Arc<crate::workload::YcsbBatch>),
    Tpcc(Arc<crate::workload::TpccBatch>),
}

struct WorkloadDriver {
    ycsb: Option<YcsbGen>,
    tpcc: Option<TpccGen>,
    batch_size: usize,
    warehouses: u32,
}

impl WorkloadDriver {
    fn new(spec: &WorkloadSpec, seed: u64) -> Self {
        match spec {
            WorkloadSpec::Ycsb { workload, batch, records } => WorkloadDriver {
                ycsb: Some(YcsbGen::new(*workload, *records, seed)),
                tpcc: None,
                batch_size: *batch,
                warehouses: 0,
            },
            WorkloadSpec::Tpcc { batch, warehouses } => {
                debug_assert!(*warehouses >= 1, "warehouses is validated at config parse");
                WorkloadDriver {
                    ycsb: None,
                    tpcc: Some(TpccGen::new(*warehouses, seed)),
                    batch_size: *batch,
                    warehouses: *warehouses,
                }
            }
        }
    }

    /// Generate the next round's batch; returns (payload, base apply cost in
    /// ms at unit speed, live op count).
    fn next_batch(&mut self) -> (Payload, Batch, f64, usize) {
        if let Some(gen) = self.ycsb.as_mut() {
            let b = Arc::new(gen.batch(self.batch_size));
            let cost = DocStore::estimate_cost_ms(&b);
            let ops = b.live_ops();
            (Payload::Ycsb(b.clone()), Batch::Ycsb(b), cost, ops)
        } else {
            let gen = self.tpcc.as_mut().unwrap();
            let b = Arc::new(gen.batch(self.batch_size));
            let cost = RelStore::estimate_cost_ms(&b, self.warehouses as usize);
            let ops = b.live_txns();
            (Payload::Tpcc(b.clone()), Batch::Tpcc(b), cost, ops)
        }
    }
}

/// Fig. 21 kill/restart schedule, shared by both round drivers: kill the
/// highest-id non-leader follower at the start of `kill_round`, bring it
/// back with completely fresh state (empty log, zero commit) at the start
/// of `restart_round`. The restarted node re-arms a randomized election
/// timer; with compaction on, catch-up goes through `InstallSnapshot`.
#[allow(clippy::too_many_arguments)]
fn maybe_kill_restart(
    restart_pending: &mut Option<RestartSpec>,
    restart_victim: &mut Option<NodeId>,
    next_round: u64,
    leader: NodeId,
    config: &SimConfig,
    mode: &Mode,
    nodes: &mut [Node],
    alive: &mut [bool],
    el_gen: &mut [u64],
    timer_rng: &mut Rng,
    q: &mut EventQueue<Ev>,
    safety: &mut Option<SafetyLog>,
) {
    let Some(rs) = *restart_pending else { return };
    let n = nodes.len();
    if rs.kill_round == next_round && restart_victim.is_none() {
        if let Some(v) = (0..n).rev().find(|&i| i != leader && alive[i]) {
            alive[v] = false;
            *restart_victim = Some(v);
        }
    }
    if rs.restart_round == next_round {
        *restart_pending = None; // one-shot
        if let Some(v) = *restart_victim {
            let mut fresh = Node::new(v, n, mode.clone());
            fresh.set_static_weights(config.static_weights);
            fresh.set_snapshot_every(config.snapshot_every);
            fresh.set_pre_vote(config.pre_vote);
            fresh.set_read_path(config.read_path);
            fresh.set_lease_duration_ms(config.lease_duration_ms());
            if matches!(config.read_path, ReadPath::Lease) {
                // a restarted voter may have acked a probe whose lease is
                // still live — hold its vote for one full election timeout
                fresh.hold_votes_until_timeout();
            }
            nodes[v] = fresh;
            // a fresh node legitimately re-commits from the bottom of the
            // log — restart its safety-evidence stream with it, or the
            // checker would flag the replay as a commit regression
            if let Some(sl) = safety.as_mut() {
                sl.commits[v].clear();
            }
            alive[v] = true;
            el_gen[v] += 1;
            let d =
                timer_rng.range_f64(config.election_timeout_ms.0, config.election_timeout_ms.1);
            q.push_after(d, Ev::ElectionTimer { node: v, generation: el_gen[v] });
        }
    }
}

/// Track the peak retained (post-compaction) log length across all nodes —
/// the quantity `snapshot_every` bounds.
fn sample_retained(nodes: &[Node], max_retained: &mut u64) {
    for node in nodes {
        *max_retained = (*max_retained).max(node.log().len() as u64);
    }
}

/// Fold the read-client bookkeeping and node-side read counters into the
/// result (no-op on log-path runs: everything stays zero).
fn finish_reads(result: &mut SimResult, readctl: ReadCtl, nodes: &[Node]) {
    result.reads_served = readctl.reads_served;
    result.read_ops_served = readctl.read_ops_served;
    result.lease_reads = readctl.lease_reads;
    result.read_failures = readctl.failures;
    result.readindex_rounds = nodes.iter().map(|nd| nd.readindex_rounds()).sum();
    result.read_done_ms = readctl.done_ms;
    let mut lats = readctl.latencies;
    lats.sort_by(|a, b| a.total_cmp(b));
    if !lats.is_empty() {
        result.read_mean_ms = lats.iter().sum::<f64>() / lats.len() as f64;
        result.read_p50_ms = percentile_sorted(&lats, 0.50);
        result.read_p99_ms = percentile_sorted(&lats, 0.99);
    }
}

/// Run one experiment; deterministic in (config, seed).
///
/// `pipeline = 1` runs the paper's lock-step round driver (bit-for-bit the
/// historical behavior, so every existing figure stays valid); `pipeline > 1`
/// runs the pipelined driver, which keeps up to that many replication rounds
/// in flight at the leader.
pub fn run(config: &SimConfig) -> SimResult {
    match &config.protocol {
        Protocol::Hqc { sizes } => run_hqc(config, sizes.clone()),
        Protocol::Raft | Protocol::Cabinet { .. } => {
            if config.pipeline > 1 {
                run_quorum_pipelined(config)
            } else {
                run_quorum(config)
            }
        }
    }
}

#[allow(clippy::too_many_lines)]
fn run_quorum(config: &SimConfig) -> SimResult {
    let n = config.n();
    let mode = match &config.protocol {
        Protocol::Raft => Mode::Raft,
        Protocol::Cabinet { t } => Mode::cabinet(n, *t),
        Protocol::Hqc { .. } => unreachable!(),
    };
    let mut root_rng = Rng::new(config.seed);
    let mut net_rng = root_rng.fork(1);
    let mut timer_rng = root_rng.fork(2);
    let mut kill_rng = root_rng.fork(3);
    let mut driver = WorkloadDriver::new(&config.workload, root_rng.fork(4).next_u64());
    // the nemesis gets its own stream (fork 5): enabling it never perturbs
    // the delay/timer/kill streams, and fork(5) is only drawn when present,
    // so nemesis-free runs reproduce the historical trajectories bit-for-bit
    let mut nemesis = config.nemesis.as_ref().map(|spec| {
        spec.validate(n).expect("invalid nemesis spec");
        Nemesis::new(spec.clone(), n, root_rng.fork(5))
    });
    let mut safety = if config.track_safety { Some(SafetyLog::new(n)) } else { None };

    let mut nodes: Vec<Node> = (0..n)
        .map(|i| {
            let mut node = Node::new(i, n, mode.clone());
            node.set_static_weights(config.static_weights);
            node.set_snapshot_every(config.snapshot_every);
            node.set_pre_vote(config.pre_vote);
            node.set_read_path(config.read_path);
            node.set_lease_duration_ms(config.lease_duration_ms());
            node
        })
        .collect();
    let mut alive = vec![true; n];
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut readctl = ReadCtl::default();

    // timer generations (stale-timer cancellation)
    let mut el_gen = vec![0u64; n];
    let mut hb_gen = vec![0u64; n];

    // Fig. 21 restart schedule + retained-log peak tracking
    let mut restart_pending = config.restart;
    let mut restart_victim: Option<NodeId> = None;
    let mut max_retained: u64 = 0;

    // digest-tracked replica stores
    let tracked: Vec<usize> = match config.digest_mode {
        DigestMode::Off => vec![],
        DigestMode::Sample => vec![0, n - 1],
        DigestMode::All => (0..n).collect(),
    };
    let is_tpcc = matches!(config.workload, WorkloadSpec::Tpcc { .. });
    let mut doc_stores: Vec<DocStore> = tracked.iter().map(|_| DocStore::new()).collect();
    // relational stores exist only for TPC-C runs — `warehouses >= 1` is a
    // config-parse invariant now, not a construction-site patch-up
    let mut rel_stores: Vec<RelStore> = if is_tpcc {
        tracked.iter().map(|_| RelStore::new(driver.warehouses as usize)).collect()
    } else {
        Vec::new()
    };

    // round bookkeeping
    let mut round: u64 = 0; // completed rounds
    let mut stats: Vec<RoundStat> = Vec::with_capacity(config.rounds as usize);
    let mut current_leader: Option<NodeId> = None;
    let mut elections: u64 = 0;
    let mut pending: Option<(u64, f64, usize, f64, Batch)> = None; // (round, start, ops, leader_apply_done, batch)
    let mut pending_entry_index: u64 = 0;
    let mut reconfig_queue: Vec<ReconfigSpec> = config.reconfigs.clone();
    reconfig_queue.sort_by_key(|r| r.round);
    let mut kills = config.kills.clone();
    kills.sort_by_key(|k| k.round);
    let mut kill_leader_at = config.kill_leader_at_round; // one-shot

    // bootstrap: node 0 starts the first election immediately; everyone else
    // arms a randomized election timer
    for node in 0..n {
        let delay = if node == 0 {
            0.0
        } else {
            timer_rng.range_f64(config.election_timeout_ms.0, config.election_timeout_ms.1)
        };
        el_gen[node] += 1;
        q.push_after(delay, Ev::ElectionTimer { node, generation: el_gen[node] });
    }
    q.push_after(1.0, Ev::ProposeNext);

    // batch cost of the in-flight round, for follower service times
    let mut inflight_cost_ms: f64 = 0.0;

    // hard stop: virtual-time budget per run keeps pathological configs finite
    let max_virtual_ms = 1e9;

    // reads may still be draining after the last round commits
    while round < config.rounds || !readctl.outstanding.is_empty() {
        let Some((now, ev)) = q.pop() else { break };
        if now > max_virtual_ms {
            break;
        }
        match ev {
            Ev::ElectionTimer { node, generation } => {
                if !alive[node] || generation != el_gen[node] {
                    continue;
                }
                nodes[node].observe_time(now);
                let outs = nodes[node].step(Input::ElectionTimeout);
                handle_outputs(
                    node, outs, config, &mut q, &mut net_rng, &mut timer_rng, &alive,
                    &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, pending_entry_index, &mut stats, &mut round,
                    inflight_cost_ms, &tracked, &mut doc_stores, &mut rel_stores, is_tpcc,
                    &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::HeartbeatTimer { node, generation } => {
                if !alive[node] || generation != hb_gen[node] {
                    continue;
                }
                nodes[node].observe_time(now);
                let outs = nodes[node].step(Input::HeartbeatTimeout);
                handle_outputs(
                    node, outs, config, &mut q, &mut net_rng, &mut timer_rng, &alive,
                    &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, pending_entry_index, &mut stats, &mut round,
                    inflight_cost_ms, &tracked, &mut doc_stores, &mut rel_stores, is_tpcc,
                    &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::Deliver { to, from, msg } => {
                if !alive[to] {
                    continue;
                }
                // follower service time: RPC processing + batch apply,
                // scaled by zone speed and contention
                let service = service_ms(config, to, &msg, round, inflight_cost_ms);
                if service > 0.0 {
                    // re-deliver after the service time so the reply
                    // reflects the node's processing speed
                    // (modeled by delaying the node's outputs)
                }
                nodes[to].observe_time(now);
                let outs = nodes[to].step(Input::Receive(from, msg));
                // outputs (replies) leave after the service time
                handle_outputs_delayed(
                    to, outs, service, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, pending_entry_index, &mut stats, &mut round,
                    inflight_cost_ms, &tracked, &mut doc_stores, &mut rel_stores, is_tpcc,
                    &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::ReadAt { id, node } => {
                if !readctl.outstanding.contains_key(&id) {
                    continue; // already served
                }
                if !alive[node] {
                    continue; // the standing retry timer re-targets it
                }
                nodes[node].observe_time(now);
                let service = config.rpc_proc_ms / effective_speed(config, node, round);
                let outs = nodes[node].step(Input::Read { id });
                handle_outputs_delayed(
                    node, outs, service, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, pending_entry_index, &mut stats, &mut round,
                    inflight_cost_ms, &tracked, &mut doc_stores, &mut rel_stores, is_tpcc,
                    &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::ReadRetry { id } => {
                if let Some(req) = readctl.outstanding.get(&id) {
                    let target = current_leader
                        .filter(|&l| alive[l])
                        .or_else(|| pick_read_target(req.round + req.k, &alive));
                    if let Some(target) = target {
                        q.push_after(0.0, Ev::ReadAt { id, node: target });
                    }
                    q.push_after(READ_RETRY_MS, Ev::ReadRetry { id });
                }
            }
            Ev::ProposeNext => {
                sample_retained(&nodes, &mut max_retained);
                if round >= config.rounds {
                    continue; // only reads are draining now
                }
                if pending.is_some() {
                    continue; // a round is already in flight
                }
                let Some(leader) = current_leader.filter(|&l| alive[l]) else {
                    q.push_after(50.0, Ev::ProposeNext);
                    continue;
                };
                if nodes[leader].role() != Role::Leader {
                    q.push_after(50.0, Ev::ProposeNext);
                    continue;
                }
                let next_round = round + 1;

                maybe_kill_restart(
                    &mut restart_pending, &mut restart_victim, next_round, leader,
                    config, &mode, &mut nodes, &mut alive, &mut el_gen,
                    &mut timer_rng, &mut q, &mut safety,
                );

                // scheduled kills fire at the start of their round
                while let Some(k) = kills.first() {
                    if k.round != next_round {
                        break;
                    }
                    let weights = nodes[leader].weight_assignment().to_vec();
                    for v in k.victims(&weights, leader, &alive, &mut kill_rng) {
                        alive[v] = false;
                    }
                    kills.remove(0);
                }
                if kill_leader_at == Some(next_round) {
                    kill_leader_at = None; // fire exactly once
                    alive[leader] = false;
                    current_leader = None;
                    q.push_after(50.0, Ev::ProposeNext);
                    continue;
                }
                // scheduled reconfiguration (not counted as a round)
                if let Some(rc) = reconfig_queue.first().copied() {
                    if rc.round == next_round {
                        reconfig_queue.remove(0);
                        let outs =
                            nodes[leader].step(Input::Propose(Payload::Reconfig { new_t: rc.new_t }));
                        handle_outputs(
                            leader, outs, config, &mut q, &mut net_rng, &mut timer_rng,
                            &alive, &mut el_gen, &mut hb_gen, &mut current_leader,
                            &mut elections, &mut pending, pending_entry_index, &mut stats,
                            &mut round, inflight_cost_ms, &tracked, &mut doc_stores,
                            &mut rel_stores, is_tpcc, &mut nemesis, &mut safety,
                            &mut readctl,
                        );
                        q.push_after(1.0, Ev::ProposeNext);
                        continue;
                    }
                }

                let (payload, batch, cost_ms, ops, read_batch) =
                    next_round_batch(&mut driver, config.read_path);
                inflight_cost_ms = cost_ms;
                // Fig. 7: the leader batches + coordinates; *followers*
                // execute the workload. Leader-side work is the batching /
                // RPC-issue overhead only.
                let leader_speed = effective_speed(config, leader, next_round);
                let leader_apply_done = now + config.rpc_proc_ms / leader_speed;
                nodes[leader].observe_time(now);
                let outs = nodes[leader].step(Input::Propose(payload));
                pending = Some((next_round, now, ops, leader_apply_done, batch));
                pending_entry_index = nodes[leader].log().last_index();
                handle_outputs(
                    leader, outs, config, &mut q, &mut net_rng, &mut timer_rng, &alive,
                    &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, pending_entry_index, &mut stats, &mut round,
                    inflight_cost_ms, &tracked, &mut doc_stores, &mut rel_stores, is_tpcc,
                    &mut nemesis, &mut safety, &mut readctl,
                );
                // the round's read-only ops go through the selected fast
                // path: a fan of concurrent read requests across the
                // cluster (followers serve local reads too)
                if let Some(rb) = read_batch {
                    readctl.issue_fan(&mut q, &alive, now, next_round, &rb);
                }
            }
        }
    }

    // convergence check across tracked replicas
    let digests = if tracked.is_empty() {
        None
    } else if is_tpcc {
        let d0 = rel_stores[0].stream_digest();
        Some(rel_stores.iter().all(|s| s.stream_digest() == d0))
    } else {
        let d0 = doc_stores[0].state_digest();
        Some(doc_stores.iter().all(|s| s.state_digest() == d0))
    };

    sample_retained(&nodes, &mut max_retained);
    let mut result = SimResult::from_rounds(config.protocol.label(), stats, digests, elections);
    result.snapshots_taken = nodes.iter().map(|nd| nd.snapshots_taken()).sum();
    result.snapshots_installed = nodes.iter().map(|nd| nd.snapshots_installed()).sum();
    result.max_retained_log = max_retained;
    result.elections_started = nodes.iter().map(|nd| nd.elections_started()).sum();
    result.terms_advanced = nodes.iter().map(|nd| nd.term()).max().unwrap_or(0);
    result.nemesis_stats = nemesis.as_ref().map(|nm| nm.stats);
    result.safety = safety;
    finish_reads(&mut result, readctl, &nodes);
    result
}

// ---------------------------------------------------------------------------
// Pipelined Raft / Cabinet simulation (pipeline depth > 1)
// ---------------------------------------------------------------------------

/// One workload round the pipelined harness has proposed but whose commit it
/// has not yet observed.
struct PendingRound {
    round: u64,
    entry_index: u64,
    /// Term of the entry at propose time — (index, term) is exact entry
    /// identity (Raft log matching), so a leader change can tell surviving
    /// rounds from overwritten ones.
    term: u64,
    start_ms: f64,
    ops: usize,
    leader_apply_done: f64,
    batch: Batch,
}

/// The pipelined round driver: the leader keeps up to `config.pipeline`
/// replication rounds in flight. Proposals are issued back-to-back until the
/// window fills; every `RoundCommitted` from the current leader retires the
/// committed prefix of the window (the consensus layer advances the commit
/// index out-of-order-ack-tolerantly, see `consensus::node`) and immediately
/// refills it. Virtual-time apply costs overlap: a follower is charged each
/// batch's apply cost exactly once — on the AppendEntries that first ships
/// it — so a window of overlapping retransmissions does not re-execute work.
#[allow(clippy::too_many_lines)]
fn run_quorum_pipelined(config: &SimConfig) -> SimResult {
    let n = config.n();
    let depth = config.pipeline.max(1);
    let mode = match &config.protocol {
        Protocol::Raft => Mode::Raft,
        Protocol::Cabinet { t } => Mode::cabinet(n, *t),
        Protocol::Hqc { .. } => unreachable!(),
    };
    let mut root_rng = Rng::new(config.seed);
    let mut net_rng = root_rng.fork(1);
    let mut timer_rng = root_rng.fork(2);
    let mut kill_rng = root_rng.fork(3);
    let mut driver = WorkloadDriver::new(&config.workload, root_rng.fork(4).next_u64());
    // own stream (fork 5) — see run_quorum for the determinism argument
    let mut nemesis = config.nemesis.as_ref().map(|spec| {
        spec.validate(n).expect("invalid nemesis spec");
        Nemesis::new(spec.clone(), n, root_rng.fork(5))
    });
    let mut safety = if config.track_safety { Some(SafetyLog::new(n)) } else { None };

    let mut nodes: Vec<Node> = (0..n)
        .map(|i| {
            let mut node = Node::new(i, n, mode.clone());
            node.set_static_weights(config.static_weights);
            node.set_snapshot_every(config.snapshot_every);
            node.set_pre_vote(config.pre_vote);
            node.set_read_path(config.read_path);
            node.set_lease_duration_ms(config.lease_duration_ms());
            node
        })
        .collect();
    let mut alive = vec![true; n];
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut readctl = ReadCtl::default();
    let mut el_gen = vec![0u64; n];
    let mut hb_gen = vec![0u64; n];

    // Fig. 21 restart schedule + retained-log peak tracking
    let mut restart_pending = config.restart;
    let mut restart_victim: Option<NodeId> = None;
    let mut max_retained: u64 = 0;

    let tracked: Vec<usize> = match config.digest_mode {
        DigestMode::Off => vec![],
        DigestMode::Sample => vec![0, n - 1],
        DigestMode::All => (0..n).collect(),
    };
    let is_tpcc = matches!(config.workload, WorkloadSpec::Tpcc { .. });
    let mut doc_stores: Vec<DocStore> = tracked.iter().map(|_| DocStore::new()).collect();
    // relational stores exist only for TPC-C runs — `warehouses >= 1` is a
    // config-parse invariant now, not a construction-site patch-up
    let mut rel_stores: Vec<RelStore> = if is_tpcc {
        tracked.iter().map(|_| RelStore::new(driver.warehouses as usize)).collect()
    } else {
        Vec::new()
    };

    let mut round: u64 = 0; // completed rounds
    let mut proposed: u64 = 0; // rounds handed to the leader
    let mut stats: Vec<RoundStat> = Vec::with_capacity(config.rounds as usize);
    let mut current_leader: Option<NodeId> = None;
    let mut elections: u64 = 0;
    let mut pending: Vec<PendingRound> = Vec::with_capacity(depth);
    // entry index → batch apply cost at unit speed (for follower service
    // times); retained for the whole run so retransmits resolve too
    let mut batch_costs: HashMap<u64, f64> = HashMap::new();
    let mut reconfig_queue: Vec<ReconfigSpec> = config.reconfigs.clone();
    reconfig_queue.sort_by_key(|r| r.round);
    let mut kills = config.kills.clone();
    kills.sort_by_key(|k| k.round);
    let mut kill_leader_at = config.kill_leader_at_round; // one-shot

    for node in 0..n {
        let delay = if node == 0 {
            0.0
        } else {
            timer_rng.range_f64(config.election_timeout_ms.0, config.election_timeout_ms.1)
        };
        el_gen[node] += 1;
        q.push_after(delay, Ev::ElectionTimer { node, generation: el_gen[node] });
    }
    q.push_after(1.0, Ev::ProposeNext);

    let max_virtual_ms = 1e9;
    // leadership epoch tracking: when a new leader takes over, pending
    // rounds whose entries did not survive into its log are void
    let mut known_leader: Option<NodeId> = None;

    while round < config.rounds || !readctl.outstanding.is_empty() {
        match q.next_time() {
            Some(t) if t <= max_virtual_ms => {}
            _ => break, // queue drained or virtual-time budget exhausted
        }
        let Some((now, ev)) = q.pop() else { break };
        match ev {
            Ev::ElectionTimer { node, generation } => {
                if !alive[node] || generation != el_gen[node] {
                    continue;
                }
                nodes[node].observe_time(now);
                let outs = nodes[node].step(Input::ElectionTimeout);
                handle_outputs_pipelined(
                    node, outs, 0.0, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, &mut stats, &mut round, &tracked, &mut doc_stores,
                    &mut rel_stores, is_tpcc, &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::HeartbeatTimer { node, generation } => {
                if !alive[node] || generation != hb_gen[node] {
                    continue;
                }
                nodes[node].observe_time(now);
                let outs = nodes[node].step(Input::HeartbeatTimeout);
                handle_outputs_pipelined(
                    node, outs, 0.0, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, &mut stats, &mut round, &tracked, &mut doc_stores,
                    &mut rel_stores, is_tpcc, &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::Deliver { to, from, msg } => {
                if !alive[to] {
                    continue;
                }
                let service =
                    service_ms_pipelined(config, &nodes[to], to, &msg, round, &batch_costs);
                nodes[to].observe_time(now);
                let outs = nodes[to].step(Input::Receive(from, msg));
                handle_outputs_pipelined(
                    to, outs, service, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, &mut stats, &mut round, &tracked, &mut doc_stores,
                    &mut rel_stores, is_tpcc, &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::ReadAt { id, node } => {
                if !readctl.outstanding.contains_key(&id) {
                    continue;
                }
                if !alive[node] {
                    continue; // the standing retry timer re-targets it
                }
                nodes[node].observe_time(now);
                let service = config.rpc_proc_ms / effective_speed(config, node, round);
                let outs = nodes[node].step(Input::Read { id });
                handle_outputs_pipelined(
                    node, outs, service, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, &mut stats, &mut round, &tracked, &mut doc_stores,
                    &mut rel_stores, is_tpcc, &mut nemesis, &mut safety, &mut readctl,
                );
            }
            Ev::ReadRetry { id } => {
                if let Some(req) = readctl.outstanding.get(&id) {
                    let target = current_leader
                        .filter(|&l| alive[l])
                        .or_else(|| pick_read_target(req.round + req.k, &alive));
                    if let Some(target) = target {
                        q.push_after(0.0, Ev::ReadAt { id, node: target });
                    }
                    q.push_after(READ_RETRY_MS, Ev::ReadRetry { id });
                }
            }
            Ev::ProposeNext => {
                sample_retained(&nodes, &mut max_retained);
                if pending.len() >= depth || proposed >= config.rounds {
                    continue; // window full (a commit re-arms the proposer)
                }
                let Some(leader) = current_leader.filter(|&l| alive[l]) else {
                    q.push_after(50.0, Ev::ProposeNext);
                    continue;
                };
                if nodes[leader].role() != Role::Leader {
                    q.push_after(50.0, Ev::ProposeNext);
                    continue;
                }
                if nodes[leader].reconfig_pending() {
                    // §4.1.4: the pipeline drains across a reconfiguration
                    q.push_after(5.0, Ev::ProposeNext);
                    continue;
                }
                let next_round = proposed + 1;

                maybe_kill_restart(
                    &mut restart_pending, &mut restart_victim, next_round, leader,
                    config, &mode, &mut nodes, &mut alive, &mut el_gen,
                    &mut timer_rng, &mut q, &mut safety,
                );

                // scheduled kills fire at the start of their round
                while let Some(k) = kills.first() {
                    if k.round != next_round {
                        break;
                    }
                    let weights = nodes[leader].weight_assignment().to_vec();
                    for v in k.victims(&weights, leader, &alive, &mut kill_rng) {
                        alive[v] = false;
                    }
                    kills.remove(0);
                }
                if kill_leader_at == Some(next_round) {
                    kill_leader_at = None; // fire exactly once
                    alive[leader] = false;
                    current_leader = None;
                    // rounds that died in the old leader's window get
                    // regenerated (fresh batches) under the next leader.
                    // Every pending round incremented `proposed` when it was
                    // pushed, so the subtraction is exact — a saturating_sub
                    // here would only mask a broken window invariant.
                    debug_assert!(
                        proposed >= pending.len() as u64,
                        "window accounting underflow: proposed {proposed} < pending {}",
                        pending.len()
                    );
                    proposed -= pending.len() as u64;
                    pending.clear();
                    q.push_after(50.0, Ev::ProposeNext);
                    continue;
                }
                // scheduled reconfiguration (not counted as a round) — may
                // land while earlier rounds are still in flight; their
                // propose-time weight/CT snapshots keep them correct
                if let Some(rc) = reconfig_queue.first().copied() {
                    if rc.round == next_round {
                        reconfig_queue.remove(0);
                        let outs = nodes[leader]
                            .step(Input::Propose(Payload::Reconfig { new_t: rc.new_t }));
                        handle_outputs_pipelined(
                            leader, outs, 0.0, config, &mut q, &mut net_rng,
                            &mut timer_rng, &alive, &mut el_gen, &mut hb_gen,
                            &mut current_leader, &mut elections, &mut pending,
                            &mut stats, &mut round, &tracked, &mut doc_stores,
                            &mut rel_stores, is_tpcc, &mut nemesis, &mut safety,
                            &mut readctl,
                        );
                        q.push_after(1.0, Ev::ProposeNext);
                        continue;
                    }
                }

                let (payload, batch, cost_ms, ops, read_batch) =
                    next_round_batch(&mut driver, config.read_path);
                let leader_speed = effective_speed(config, leader, next_round);
                let leader_apply_done = now + config.rpc_proc_ms / leader_speed;
                nodes[leader].observe_time(now);
                let outs = nodes[leader].step(Input::Propose(payload));
                let entry_index = nodes[leader].log().last_index();
                batch_costs.insert(entry_index, cost_ms);
                proposed = next_round;
                pending.push(PendingRound {
                    round: next_round,
                    entry_index,
                    term: nodes[leader].term(),
                    start_ms: now,
                    ops,
                    leader_apply_done,
                    batch,
                });
                handle_outputs_pipelined(
                    leader, outs, 0.0, config, &mut q, &mut net_rng, &mut timer_rng,
                    &alive, &mut el_gen, &mut hb_gen, &mut current_leader, &mut elections,
                    &mut pending, &mut stats, &mut round, &tracked, &mut doc_stores,
                    &mut rel_stores, is_tpcc, &mut nemesis, &mut safety, &mut readctl,
                );
                // this round's read-only ops go through the selected fast path
                if let Some(rb) = read_batch {
                    readctl.issue_fan(&mut q, &alive, now, next_round, &rb);
                }
                if pending.len() < depth && proposed < config.rounds {
                    // back-to-back proposal to fill the window
                    q.push_after(0.2, Ev::ProposeNext);
                }
            }
        }
        // A leadership change voids every pending round whose entry did not
        // survive into the new leader's log — (index, term) is exact entry
        // identity by Raft log matching. The winner overwrites dead slots,
        // so retiring them on its commits would misattribute fresh entries
        // to old batches. Dropped rounds are regenerated with fresh batches.
        // This runs before any RoundCommitted from the new leader can be
        // processed (its quorum needs at least one more network round trip).
        if current_leader != known_leader {
            if let Some(x) = current_leader {
                pending.retain(|p| {
                    let survived =
                        nodes[x].log().term_at(p.entry_index) == Some(p.term);
                    if !survived {
                        proposed -= 1;
                    }
                    survived
                });
            }
            known_leader = current_leader;
        }
    }

    let digests = if tracked.is_empty() {
        None
    } else if is_tpcc {
        let d0 = rel_stores[0].stream_digest();
        Some(rel_stores.iter().all(|s| s.stream_digest() == d0))
    } else {
        let d0 = doc_stores[0].state_digest();
        Some(doc_stores.iter().all(|s| s.state_digest() == d0))
    };

    sample_retained(&nodes, &mut max_retained);
    let mut result = SimResult::from_rounds(config.protocol.label(), stats, digests, elections);
    result.snapshots_taken = nodes.iter().map(|nd| nd.snapshots_taken()).sum();
    result.snapshots_installed = nodes.iter().map(|nd| nd.snapshots_installed()).sum();
    result.max_retained_log = max_retained;
    result.elections_started = nodes.iter().map(|nd| nd.elections_started()).sum();
    result.terms_advanced = nodes.iter().map(|nd| nd.term()).max().unwrap_or(0);
    result.nemesis_stats = nemesis.as_ref().map(|nm| nm.stats);
    result.safety = safety;
    finish_reads(&mut result, readctl, &nodes);
    result
}

/// Pipelined-driver service time: apply cost accrues per batch entry the
/// node will actually append — the message must pass the term and
/// log-consistency checks, and each entry is charged at its own round's
/// cost only the first time it ships. Overlapping retransmissions inside
/// the window and rejected appends (stale term / log mismatch after a
/// failover) never re-charge an executed batch.
fn service_ms_pipelined(
    config: &SimConfig,
    receiver: &Node,
    node: NodeId,
    msg: &Message,
    round: u64,
    batch_costs: &HashMap<u64, f64>,
) -> f64 {
    match msg {
        Message::AppendEntries { term, prev_log_index, prev_log_term, entries, .. }
            if !entries.is_empty() =>
        {
            let speed = effective_speed(config, node, round);
            let accepted = *term >= receiver.term()
                && receiver.log().matches(*prev_log_index, *prev_log_term);
            let apply: f64 = if accepted {
                let last = receiver.log().last_index();
                entries
                    .iter()
                    .filter(|e| {
                        e.index > last
                            && matches!(e.payload, Payload::Ycsb(_) | Payload::Tpcc(_))
                    })
                    .map(|e| batch_costs.get(&e.index).copied().unwrap_or(0.0))
                    .sum()
            } else {
                0.0
            };
            (config.rpc_proc_ms + apply) / speed
        }
        _ => config.rpc_proc_ms / effective_speed(config, node, round),
    }
}

/// Route one node's outputs for the pipelined driver; sends leave
/// `extra_delay` ms after now (the node's service time).
///
/// Deliberately a separate copy of the lock-step `handle_outputs_delayed`
/// (only the `RoundCommitted` arm differs): the lock-step handler is frozen
/// so `pipeline = 1` keeps reproducing the historical figures bit-for-bit,
/// and sharing the routing scaffold would couple every future pipelined
/// change to that guarantee.
#[allow(clippy::too_many_arguments)]
fn handle_outputs_pipelined(
    node: NodeId,
    outs: Vec<Output>,
    extra_delay: f64,
    config: &SimConfig,
    q: &mut EventQueue<Ev>,
    net_rng: &mut Rng,
    timer_rng: &mut Rng,
    alive: &[bool],
    el_gen: &mut [u64],
    hb_gen: &mut [u64],
    current_leader: &mut Option<NodeId>,
    elections: &mut u64,
    pending: &mut Vec<PendingRound>,
    stats: &mut Vec<RoundStat>,
    round: &mut u64,
    tracked: &[usize],
    doc_stores: &mut [DocStore],
    rel_stores: &mut [RelStore],
    is_tpcc: bool,
    nemesis: &mut Option<Nemesis>,
    safety: &mut Option<SafetyLog>,
    readctl: &mut ReadCtl,
) {
    let n = config.n();
    let now = q.now();
    for o in outs {
        match o {
            Output::Send(to, msg) => {
                if !alive[to] {
                    continue;
                }
                let shaped_end =
                    if node == current_leader.unwrap_or(usize::MAX) { to } else { node };
                let lat = config.delay.link_latency(
                    shaped_end,
                    n,
                    now,
                    *round,
                    msg.wire_size(),
                    net_rng,
                );
                let fate = match nemesis.as_mut() {
                    Some(nm) => nm.fate(now, node, to, *current_leader),
                    None => Fate::deliver(),
                };
                if fate.copies == 0 {
                    continue; // partitioned or lost
                }
                if fate.copies > 1 {
                    q.push_after(
                        extra_delay + lat + fate.extra_delay_ms[1],
                        Ev::Deliver { to, from: node, msg: msg.clone() },
                    );
                }
                q.push_after(
                    extra_delay + lat + fate.extra_delay_ms[0],
                    Ev::Deliver { to, from: node, msg },
                );
            }
            Output::ResetElectionTimer => {
                el_gen[node] += 1;
                let d = timer_rng
                    .range_f64(config.election_timeout_ms.0, config.election_timeout_ms.1);
                q.push_after(d, Ev::ElectionTimer { node, generation: el_gen[node] });
            }
            Output::StartHeartbeat => {
                hb_gen[node] += 1;
                q.push_after(
                    config.heartbeat_ms,
                    Ev::HeartbeatTimer { node, generation: hb_gen[node] },
                );
            }
            Output::StopHeartbeat => {
                hb_gen[node] += 1;
            }
            Output::BecameLeader { term } => {
                *current_leader = Some(node);
                *elections += 1;
                if let Some(sl) = safety.as_mut() {
                    sl.leaders.push((term, node));
                }
            }
            Output::SteppedDown => {
                if *current_leader == Some(node) {
                    *current_leader = None;
                }
            }
            Output::RoundCommitted { index, repliers, .. } => {
                if Some(node) != *current_leader {
                    continue;
                }
                // write-completion timeline for the read checker (barrier
                // no-ops included — read indices can point at them)
                if let Some(sl) = safety.as_mut() {
                    sl.commit_times.push((now, index));
                }
                // retire the committed prefix of the window, in order
                while pending.first().map_or(false, |p| p.entry_index <= index) {
                    let p = pending.remove(0);
                    let commit_time = now.max(p.leader_apply_done);
                    let latency = commit_time - p.start_ms;
                    stats.push(RoundStat {
                        round: p.round,
                        entry_index: p.entry_index,
                        start_ms: p.start_ms,
                        latency_ms: latency,
                        tput_ops_s: p.ops as f64 / (latency / 1000.0),
                        ops: p.ops,
                        repliers,
                    });
                    if p.round > *round {
                        *round = p.round;
                    }
                    apply_tracked(&p.batch, tracked, doc_stores, rel_stores, is_tpcc);
                }
                q.push_after(0.2, Ev::ProposeNext); // client turnaround
            }
            Output::Commit(e) => {
                // per-node commit evidence for the bench::safety checker
                if let Some(sl) = safety.as_mut() {
                    sl.commits[node].push((e.index, e.term));
                }
            }
            Output::ProposalRejected(_) => {}
            // nodes snapshot inline (SnapshotCapture::Inline) — these are
            // informational; installs are counted via node counters
            Output::SnapshotRequest { .. } | Output::SnapshotInstalled(_) => {}
            Output::ReadReady { id, index, lease } => {
                serve_read(readctl, safety, config, node, id, index, lease, now, *round);
            }
            Output::ReadFailed { id } => {
                if readctl.outstanding.contains_key(&id) {
                    readctl.failures += 1; // the standing retry re-drives it
                }
            }
        }
    }
}

/// Retire one served read: record its latency and checker evidence.
#[allow(clippy::too_many_arguments)]
fn serve_read(
    readctl: &mut ReadCtl,
    safety: &mut Option<SafetyLog>,
    config: &SimConfig,
    node: NodeId,
    id: u64,
    index: u64,
    lease: bool,
    now: f64,
    round: u64,
) {
    let Some(req) = readctl.outstanding.remove(&id) else {
        return; // a duplicate grant after a retry already served it
    };
    let done = now + req.cost_ms / effective_speed(config, node, round);
    readctl.latencies.push(done - req.invoked_ms);
    readctl.reads_served += 1;
    readctl.read_ops_served += req.ops as u64;
    if lease {
        readctl.lease_reads += 1;
    }
    if done > readctl.done_ms {
        readctl.done_ms = done;
    }
    if let Some(sl) = safety.as_mut() {
        sl.reads.push(ReadRecord {
            node,
            id,
            invoked_ms: req.invoked_ms,
            served_ms: now,
            read_index: index,
            lease,
        });
    }
}

/// Service time charged on a node for processing a message (ms).
fn service_ms(config: &SimConfig, node: NodeId, msg: &Message, round: u64, batch_cost_ms: f64) -> f64 {
    match msg {
        Message::AppendEntries { entries, .. } if !entries.is_empty() => {
            let speed = effective_speed(config, node, round);
            let has_batch = entries
                .iter()
                .any(|e| matches!(e.payload, Payload::Ycsb(_) | Payload::Tpcc(_)));
            let apply = if has_batch { batch_cost_ms } else { 0.0 };
            (config.rpc_proc_ms + apply) / speed
        }
        _ => config.rpc_proc_ms / effective_speed(config, node, round),
    }
}

/// Zone speed × contention factor at the given round.
fn effective_speed(config: &SimConfig, node: NodeId, round: u64) -> f64 {
    let mut speed = config.zones.speed(node);
    if let Some(c) = &config.contention {
        speed /= c.factor(round);
    }
    speed
}

/// Route one node's outputs into the event queue (no extra send delay).
#[allow(clippy::too_many_arguments)]
fn handle_outputs(
    node: NodeId,
    outs: Vec<Output>,
    config: &SimConfig,
    q: &mut EventQueue<Ev>,
    net_rng: &mut Rng,
    timer_rng: &mut Rng,
    alive: &[bool],
    el_gen: &mut [u64],
    hb_gen: &mut [u64],
    current_leader: &mut Option<NodeId>,
    elections: &mut u64,
    pending: &mut Option<(u64, f64, usize, f64, Batch)>,
    pending_entry_index: u64,
    stats: &mut Vec<RoundStat>,
    round: &mut u64,
    inflight_cost_ms: f64,
    tracked: &[usize],
    doc_stores: &mut [DocStore],
    rel_stores: &mut [RelStore],
    is_tpcc: bool,
    nemesis: &mut Option<Nemesis>,
    safety: &mut Option<SafetyLog>,
    readctl: &mut ReadCtl,
) {
    handle_outputs_delayed(
        node, outs, 0.0, config, q, net_rng, timer_rng, alive, el_gen, hb_gen,
        current_leader, elections, pending, pending_entry_index, stats, round,
        inflight_cost_ms, tracked, doc_stores, rel_stores, is_tpcc, nemesis, safety,
        readctl,
    )
}

/// Route outputs; sends leave `extra_delay` ms after now (service time).
#[allow(clippy::too_many_arguments)]
fn handle_outputs_delayed(
    node: NodeId,
    outs: Vec<Output>,
    extra_delay: f64,
    config: &SimConfig,
    q: &mut EventQueue<Ev>,
    net_rng: &mut Rng,
    timer_rng: &mut Rng,
    alive: &[bool],
    el_gen: &mut [u64],
    hb_gen: &mut [u64],
    current_leader: &mut Option<NodeId>,
    elections: &mut u64,
    pending: &mut Option<(u64, f64, usize, f64, Batch)>,
    pending_entry_index: u64,
    stats: &mut Vec<RoundStat>,
    round: &mut u64,
    inflight_cost_ms: f64,
    tracked: &[usize],
    doc_stores: &mut [DocStore],
    rel_stores: &mut [RelStore],
    is_tpcc: bool,
    nemesis: &mut Option<Nemesis>,
    safety: &mut Option<SafetyLog>,
    readctl: &mut ReadCtl,
) {
    let n = config.n();
    let now = q.now();
    for o in outs {
        match o {
            Output::Send(to, msg) => {
                if !alive[to] {
                    continue;
                }
                // link delay is sampled on the non-leader endpoint (the
                // paper's netem delays are installed on follower nodes)
                let shaped_end = if node == current_leader.unwrap_or(usize::MAX) { to } else { node };
                let lat = config.delay.link_latency(
                    shaped_end,
                    n,
                    now,
                    *round,
                    msg.wire_size(),
                    net_rng,
                );
                let fate = match nemesis.as_mut() {
                    Some(nm) => nm.fate(now, node, to, *current_leader),
                    None => Fate::deliver(),
                };
                if fate.copies == 0 {
                    continue; // partitioned or lost
                }
                if fate.copies > 1 {
                    q.push_after(
                        extra_delay + lat + fate.extra_delay_ms[1],
                        Ev::Deliver { to, from: node, msg: msg.clone() },
                    );
                }
                q.push_after(
                    extra_delay + lat + fate.extra_delay_ms[0],
                    Ev::Deliver { to, from: node, msg },
                );
            }
            Output::ResetElectionTimer => {
                el_gen[node] += 1;
                let d = timer_rng
                    .range_f64(config.election_timeout_ms.0, config.election_timeout_ms.1);
                q.push_after(d, Ev::ElectionTimer { node, generation: el_gen[node] });
            }
            Output::StartHeartbeat => {
                hb_gen[node] += 1;
                q.push_after(
                    config.heartbeat_ms,
                    Ev::HeartbeatTimer { node, generation: hb_gen[node] },
                );
            }
            Output::StopHeartbeat => {
                hb_gen[node] += 1;
            }
            Output::BecameLeader { term } => {
                *current_leader = Some(node);
                *elections += 1;
                if let Some(sl) = safety.as_mut() {
                    sl.leaders.push((term, node));
                }
            }
            Output::SteppedDown => {
                if *current_leader == Some(node) {
                    *current_leader = None;
                }
            }
            Output::RoundCommitted { index, repliers, .. } => {
                // write-completion timeline for the read checker (recorded
                // for every leader-observed commit, barrier no-ops included)
                if Some(node) == *current_leader {
                    if let Some(sl) = safety.as_mut() {
                        sl.commit_times.push((now, index));
                    }
                }
                // only the harness round (pending batch) counts
                if let Some((rnd, start, ops, leader_apply_done, _)) = pending.as_ref() {
                    if index >= pending_entry_index && Some(node) == *current_leader {
                        let commit_time = now.max(*leader_apply_done);
                        let latency = commit_time - start;
                        stats.push(RoundStat {
                            round: *rnd,
                            entry_index: pending_entry_index,
                            start_ms: *start,
                            latency_ms: latency,
                            tput_ops_s: *ops as f64 / (latency / 1000.0),
                            ops: *ops,
                            repliers,
                        });
                        *round = *rnd;
                        // apply to tracked replicas (replica convergence)
                        if let Some((_, _, _, _, batch)) = pending.take() {
                            apply_tracked(&batch, tracked, doc_stores, rel_stores, is_tpcc);
                        }
                        q.push_after(0.2, Ev::ProposeNext); // client turnaround
                    }
                }
            }
            Output::Commit(e) => {
                // per-node commit evidence for the bench::safety checker
                if let Some(sl) = safety.as_mut() {
                    sl.commits[node].push((e.index, e.term));
                }
            }
            Output::ProposalRejected(_) => {}
            // nodes snapshot inline (SnapshotCapture::Inline) — these are
            // informational; installs are counted via node counters
            Output::SnapshotRequest { .. } | Output::SnapshotInstalled(_) => {}
            Output::ReadReady { id, index, lease } => {
                serve_read(readctl, safety, config, node, id, index, lease, now, *round);
            }
            Output::ReadFailed { id } => {
                if readctl.outstanding.contains_key(&id) {
                    readctl.failures += 1; // the standing retry re-drives it
                }
            }
        }
    }
    let _ = inflight_cost_ms;
}

fn apply_tracked(
    batch: &Batch,
    tracked: &[usize],
    doc_stores: &mut [DocStore],
    rel_stores: &mut [RelStore],
    is_tpcc: bool,
) {
    if tracked.is_empty() {
        return;
    }
    match batch {
        Batch::Ycsb(b) => {
            for store in doc_stores.iter_mut() {
                store.apply(b);
            }
        }
        Batch::Tpcc(b) => {
            if is_tpcc {
                for store in rel_stores.iter_mut() {
                    store.apply(b);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// HQC simulation (replication-only baseline, Fig. 17)
// ---------------------------------------------------------------------------

enum HqcEv {
    Deliver { to: NodeId, from: NodeId, msg: HqcMsg },
}

fn run_hqc(config: &SimConfig, sizes: Vec<usize>) -> SimResult {
    let n = config.n();
    let topo = HqcTopology::split(n, &sizes);
    let mut nodes: Vec<HqcNode> = (0..n).map(|i| HqcNode::new(i, topo.clone())).collect();
    let mut root_rng = Rng::new(config.seed);
    let mut net_rng = root_rng.fork(1);
    let mut driver = WorkloadDriver::new(&config.workload, root_rng.fork(4).next_u64());
    let mut q: EventQueue<HqcEv> = EventQueue::new();
    let mut stats = Vec::new();

    for round in 1..=config.rounds {
        let (_payload, _batch, cost_ms, ops) = driver.next_batch();
        let start = q.now();
        let outs = nodes[topo.root].propose(round);
        let mut committed_at: Option<f64> = None;
        let root = topo.root;
        let inject = |src: NodeId, outs: Vec<HqcOutput>, q: &mut EventQueue<HqcEv>, net_rng: &mut Rng, now: f64| {
            let mut done = None;
            for o in outs {
                match o {
                    HqcOutput::Send(to, msg) => {
                        let shaped = if src == root { to } else { src };
                        // every HQC hop carries the batch (root→leaders and
                        // leaders→members both ship workload data)
                        let wire = 12 * driver.batch_size + 64;
                        let lat = config.delay.link_latency(shaped, n, now, round, wire, net_rng);
                        q.push_after(lat, HqcEv::Deliver { to, from: src, msg });
                    }
                    HqcOutput::Committed { .. } => done = Some(now),
                }
            }
            done
        };
        let now0 = q.now();
        if let Some(t) = inject(topo.root, outs, &mut q, &mut net_rng, now0) {
            committed_at = Some(t);
        }
        while committed_at.is_none() {
            let Some((now, HqcEv::Deliver { to, from, msg })) = q.pop() else { break };
            // members execute the batch before acking
            let service = match msg {
                HqcMsg::GroupAppend { .. } | HqcMsg::Propose { .. } => {
                    let speed = effective_speed(config, to, round);
                    (config.rpc_proc_ms + cost_ms) / speed
                }
                _ => config.rpc_proc_ms / effective_speed(config, to, round),
            };
            let outs = nodes[to].receive(from, msg);
            // outputs leave after the service time
            let depart = now + service;
            let mut q2: Vec<(NodeId, HqcOutput)> = outs.into_iter().map(|o| (to, o)).collect();
            for (src, o) in q2.drain(..) {
                match o {
                    HqcOutput::Send(dst, m) => {
                        let shaped = if src == root { dst } else { src };
                        let wire = 12 * driver.batch_size + 64;
                        let lat =
                            config.delay.link_latency(shaped, n, depart, round, wire, &mut net_rng);
                        q.push_at(depart + lat, HqcEv::Deliver { to: dst, from: src, msg: m });
                    }
                    HqcOutput::Committed { .. } => committed_at = Some(depart),
                }
            }
        }
        let end = committed_at.unwrap_or(q.now());
        // the root coordinates only (Fig. 7) — batching overhead
        let root_done = start + config.rpc_proc_ms / effective_speed(config, root, round);
        let latency = (end.max(root_done) - start).max(0.01);
        stats.push(RoundStat {
            round,
            entry_index: round,
            start_ms: start,
            latency_ms: latency,
            tput_ops_s: ops as f64 / (latency / 1000.0),
            ops,
            repliers: 0,
        });
    }

    SimResult::from_rounds(config.protocol.label(), stats, None, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(protocol: Protocol, n: usize, het: bool, rounds: u64) -> SimResult {
        let mut c = SimConfig::new(protocol, n, het);
        c.rounds = rounds;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
        run(&c)
    }

    #[test]
    fn raft_completes_rounds() {
        let r = quick(Protocol::Raft, 5, false, 10);
        assert_eq!(r.rounds.len(), 10);
        assert!(r.tput_ops_s > 0.0);
        assert_eq!(r.elections, 1);
    }

    #[test]
    fn cabinet_completes_rounds() {
        let r = quick(Protocol::Cabinet { t: 2 }, 7, true, 10);
        assert_eq!(r.rounds.len(), 10);
        assert!(r.tput_ops_s > 0.0);
    }

    #[test]
    fn hqc_completes_rounds() {
        let mut c = SimConfig::new(Protocol::Hqc { sizes: vec![3, 3, 5] }, 11, false, );
        c.rounds = 5;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
        let r = run(&c);
        assert_eq!(r.rounds.len(), 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = quick(Protocol::Cabinet { t: 1 }, 5, true, 5);
        let b = quick(Protocol::Cabinet { t: 1 }, 5, true, 5);
        let la: Vec<f64> = a.rounds.iter().map(|r| r.latency_ms).collect();
        let lb: Vec<f64> = b.rounds.iter().map(|r| r.latency_ms).collect();
        assert_eq!(la, lb);
    }

    #[test]
    fn cabinet_beats_raft_heterogeneous() {
        let raft = quick(Protocol::Raft, 20, true, 10);
        let cab = quick(Protocol::Cabinet { t: 2 }, 20, true, 10);
        assert!(
            cab.tput_ops_s > raft.tput_ops_s,
            "cab={} raft={}",
            cab.tput_ops_s,
            raft.tput_ops_s
        );
    }

    #[test]
    fn replica_digests_converge() {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 7, true, );
        c.rounds = 8;
        c.digest_mode = DigestMode::All;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 300, records: 10_000 };
        let r = run(&c);
        assert_eq!(r.digests_match, Some(true));
    }

    #[test]
    fn weak_kills_do_not_hurt() {
        use crate::net::fault::{KillSpec, KillStrategy};
        let mut base = SimConfig::new(Protocol::Cabinet { t: 2 }, 11, true, );
        base.rounds = 12;
        base.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
        let clean = run(&base);
        let mut killed = base.clone();
        killed.kills = vec![KillSpec::new(5, 2, KillStrategy::Weak)];
        let kr = run(&killed);
        assert_eq!(kr.rounds.len(), 12);
        // weak kills leave throughput within noise of the clean run
        assert!(kr.tput_ops_s > 0.8 * clean.tput_ops_s);
    }

    #[test]
    fn survives_leader_kill() {
        let mut c = SimConfig::new(Protocol::Raft, 5, false, );
        c.rounds = 8;
        c.kill_leader_at_round = Some(4);
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 200, records: 10_000 };
        let r = run(&c);
        assert_eq!(r.rounds.len(), 8, "rounds must continue after failover");
        assert!(r.elections >= 2, "a second election must have happened");
    }

    #[test]
    fn tpcc_rounds_work() {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 1 }, 5, true, );
        c.rounds = 5;
        c.workload = WorkloadSpec::Tpcc { batch: 200, warehouses: 10 };
        c.digest_mode = DigestMode::Sample;
        let r = run(&c);
        assert_eq!(r.rounds.len(), 5);
        assert_eq!(r.digests_match, Some(true));
    }

    fn quick_depth(protocol: Protocol, n: usize, depth: usize, rounds: u64) -> SimResult {
        let mut c = SimConfig::new(protocol, n, true);
        c.rounds = rounds;
        c.pipeline = depth;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
        run(&c)
    }

    #[test]
    fn pipelined_completes_all_rounds_in_order() {
        for depth in [2usize, 4, 8] {
            let r = quick_depth(Protocol::Cabinet { t: 2 }, 7, depth, 12);
            assert_eq!(r.rounds.len(), 12, "depth {depth}");
            for w in r.rounds.windows(2) {
                assert!(w[0].round < w[1].round, "depth {depth}: out-of-order retirement");
                assert!(w[0].entry_index < w[1].entry_index, "depth {depth}");
            }
        }
    }

    #[test]
    fn pipelined_deterministic_given_seed() {
        for depth in [2usize, 4] {
            let a = quick_depth(Protocol::Cabinet { t: 1 }, 5, depth, 8);
            let b = quick_depth(Protocol::Cabinet { t: 1 }, 5, depth, 8);
            assert_eq!(a.metrics_digest(), b.metrics_digest(), "depth {depth}");
        }
    }

    #[test]
    fn pipelining_overlaps_rounds_under_delay() {
        // Under the Fig. 14 delay model the lock-step driver spends most of
        // each round waiting on the network; a depth-4 window must overlap
        // that wait and raise committed wall-clock throughput.
        let mk = |depth: usize| {
            let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 11, true);
            c.rounds = 12;
            c.pipeline = depth;
            c.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
            c.workload =
                WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
            run(&c)
        };
        let lock_step = mk(1);
        let deep = mk(4);
        assert_eq!(lock_step.rounds.len(), 12);
        assert_eq!(deep.rounds.len(), 12);
        let gain = deep.wall_tput_ops_s() / lock_step.wall_tput_ops_s();
        assert!(gain > 1.5, "depth-4 wall tput gain {gain:.2} (expected > 1.5x)");
    }

    #[test]
    fn pipelined_replica_digests_converge() {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 7, true);
        c.rounds = 8;
        c.pipeline = 4;
        c.digest_mode = DigestMode::All;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 300, records: 10_000 };
        let r = run(&c);
        assert_eq!(r.rounds.len(), 8);
        assert_eq!(r.digests_match, Some(true));
    }

    #[test]
    fn pipelined_survives_kills_and_leader_failover() {
        use crate::net::fault::{KillSpec, KillStrategy};
        let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 11, true);
        c.rounds = 12;
        c.pipeline = 4;
        c.kills = vec![KillSpec::new(5, 2, KillStrategy::Weak)];
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 300, records: 10_000 };
        let r = run(&c);
        assert_eq!(r.rounds.len(), 12, "weak kills must not stall the pipeline");

        let mut c = SimConfig::new(Protocol::Raft, 5, false);
        c.rounds = 8;
        c.pipeline = 4;
        c.kill_leader_at_round = Some(4);
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 200, records: 10_000 };
        let r = run(&c);
        assert_eq!(r.rounds.len(), 8, "rounds must continue after failover");
        assert!(r.elections >= 2, "a second election must have happened");
    }

    #[test]
    fn compaction_bounds_log_and_preserves_commit_sequence() {
        let mk = |every: Option<u64>| {
            let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 7, true);
            c.rounds = 30;
            c.pipeline = 4;
            c.snapshot_every = every;
            c.workload =
                WorkloadSpec::Ycsb { workload: Workload::A, batch: 200, records: 10_000 };
            run(&c)
        };
        let on = mk(Some(4));
        let off = mk(None);
        assert_eq!(on.rounds.len(), 30);
        assert_eq!(off.rounds.len(), 30);
        // compaction must not change what commits, in which order
        assert_eq!(on.commit_sequence_digest(), off.commit_sequence_digest());
        assert!(on.snapshots_taken > 0, "threshold crossings must snapshot");
        assert!(
            on.max_retained_log <= 4 + 2 * 4 + 8,
            "retained log {} exceeds interval + window bound",
            on.max_retained_log
        );
        assert!(off.max_retained_log >= 30, "off-run must keep the whole log");
    }

    #[test]
    fn restarted_follower_installs_snapshot() {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 1 }, 5, true);
        c.rounds = 30;
        c.pipeline = 2;
        c.snapshot_every = Some(4);
        c.restart = Some(RestartSpec { kill_round: 5, restart_round: 15 });
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 100, records: 5_000 };
        let r = run(&c);
        assert_eq!(r.rounds.len(), 30, "rounds must continue across kill + restart");
        assert!(
            r.snapshots_installed >= 1,
            "the restarted follower must catch up via InstallSnapshot"
        );
    }

    fn read_cfg(path: ReadPath, depth: usize, workload: Workload, seed: u64) -> SimConfig {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 7, true);
        c.rounds = 10;
        c.pipeline = depth;
        c.seed = seed;
        c.read_path = path;
        c.track_safety = true;
        c.workload = WorkloadSpec::Ycsb { workload, batch: 400, records: 10_000 };
        run(&c)
    }

    #[test]
    fn read_paths_complete_and_check_clean() {
        for depth in [1usize, 4] {
            for path in [ReadPath::ReadIndex, ReadPath::Lease] {
                let r = read_cfg(path, depth, Workload::B, 11);
                assert_eq!(r.rounds.len(), 10, "{path:?} depth {depth}: rounds incomplete");
                assert!(r.reads_served > 0, "{path:?} depth {depth}: no reads served");
                assert!(r.read_ops_served > 0);
                if matches!(path, ReadPath::Lease) {
                    assert!(r.lease_reads > 0, "depth {depth}: lease fast path unused");
                } else {
                    assert_eq!(r.lease_reads, 0);
                    assert!(r.readindex_rounds > 0);
                }
                let report =
                    crate::bench::safety::check(r.safety.as_ref().expect("tracked"));
                assert!(report.is_clean(), "{path:?} depth {depth}: {:?}", report.violations);
                assert!(report.reads_checked as u64 >= r.reads_served);
            }
        }
    }

    #[test]
    fn read_path_runs_deterministic() {
        for path in [ReadPath::ReadIndex, ReadPath::Lease] {
            let a = read_cfg(path, 2, Workload::C, 5);
            let b = read_cfg(path, 2, Workload::C, 5);
            assert_eq!(a.metrics_digest(), b.metrics_digest(), "{path:?}");
            assert_eq!(a.commit_sequence_digest(), b.commit_sequence_digest(), "{path:?}");
            assert_eq!(a.reads_served, b.reads_served, "{path:?}");
        }
    }

    #[test]
    fn log_path_ignores_read_knobs() {
        // read_path = "log" must be bit-identical regardless of the lease
        // knobs: no reads are issued, no read machinery runs
        let mk = |drift: f64| {
            let mut c = SimConfig::new(Protocol::Cabinet { t: 1 }, 5, true);
            c.rounds = 8;
            c.lease_drift_ms = drift;
            c.workload =
                WorkloadSpec::Ycsb { workload: Workload::B, batch: 300, records: 10_000 };
            run(&c)
        };
        let a = mk(50.0);
        let b = mk(500.0);
        assert_eq!(a.metrics_digest(), b.metrics_digest());
        assert_eq!(a.reads_served, 0);
        assert_eq!(a.readindex_rounds, 0);
    }

    #[test]
    fn ycsb_c_read_paths_beat_log_replication() {
        // the acceptance shape at sim level: on the LAN baseline (the
        // paper's testbed) a read-only workload is dominated by the cost of
        // shipping + applying reads at every follower — which is exactly
        // what the fast paths skip
        let mk = |path: ReadPath| {
            let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 7, true);
            c.rounds = 12;
            c.pipeline = 2;
            c.read_path = path;
            c.workload =
                WorkloadSpec::Ycsb { workload: Workload::C, batch: 2000, records: 10_000 };
            c.track_safety = true;
            let r = run(&c);
            assert_eq!(r.rounds.len(), 12, "{path:?}");
            let report = crate::bench::safety::check(r.safety.as_ref().unwrap());
            assert!(report.is_clean(), "{path:?}: {:?}", report.violations);
            r.combined_wall_tput_ops_s()
        };
        let log = mk(ReadPath::Log);
        let ri = mk(ReadPath::ReadIndex);
        let lease = mk(ReadPath::Lease);
        assert!(ri > log, "readindex {ri:.0} must beat log {log:.0}");
        assert!(lease >= 0.95 * ri, "lease {lease:.0} must not trail readindex {ri:.0}");
    }

    #[test]
    fn reconfig_changes_throughput() {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 5 }, 11, true, );
        c.rounds = 20;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 500, records: 10_000 };
        c.reconfigs = vec![ReconfigSpec { round: 11, new_t: 1 }];
        let r = run(&c);
        assert_eq!(r.rounds.len(), 20);
        let first: f64 = r.rounds[2..10].iter().map(|x| x.latency_ms).sum::<f64>() / 8.0;
        let second: f64 = r.rounds[12..20].iter().map(|x| x.latency_ms).sum::<f64>() / 8.0;
        assert!(second < first, "t=1 rounds should be faster: {second} vs {first}");
    }
}
