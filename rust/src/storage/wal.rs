//! Durable segmented write-ahead log + snapshot files (std-only).
//!
//! The persistence layer behind `Node::set_durable`: every `HardState
//! {term, voted_for}` change and every log splice is framed into a
//! segmented append-only WAL, and completed snapshots are written to their
//! own files so recovery can drop the covered prefix. Three properties the
//! rest of the system leans on:
//!
//!   * **Chained FNV digests.** Each frame folds `(kind, payload)` into a
//!     running FNV-1a state seeded by the previous frame's digest — the
//!     same resumable-fold scheme `Log::prefix_digest` uses for the
//!     in-memory log. The chain threads *across* segment boundaries (a
//!     segment header records the seed it continues from), so recovery can
//!     detect a torn or corrupted tail at any byte offset and truncate to
//!     the last valid frame.
//!   * **Group-commit fsync.** Entry records batch up to
//!     [`WalConfig::fsync_group`] appends per fsync, amortizing durability
//!     across the pipeline window (fig 26 sweeps 1/8/64). HardState records
//!     always force an fsync: a vote must never outrun its own durability —
//!     that is exactly the restart-amnesia double-vote bug this module
//!     exists to close.
//!   * **Crash-consistent snapshots.** A snapshot file is written and
//!     synced *before* any WAL segment is pruned, and older snapshot files
//!     are removed only after the new one is durable, so recovery always
//!     finds either the new snapshot or the old one plus the segments that
//!     covered the gap.
//!
//! Two backends implement the [`Disk`] trait: [`MemDisk`] (the simulator's
//! per-node disk — tracks a durable watermark per file and can `crash` with
//! torn-write faults that keep a corrupted fragment of the unsynced tail)
//! and [`FsDisk`] (real files for the live runtime — unsynced appends sit
//! in a heap buffer standing in for the page cache, so dropping the disk
//! mid-run loses exactly what a `kill -9` would).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

use crate::consensus::message::{
    AppState, ClusterConfig, Entry, LogIndex, MemberSpec, MemberState, NodeId, Payload,
    ShardData, SnapshotBlob, Term,
};
use crate::storage::wire::{push_u32, push_u64, read_u32, read_u64};
use crate::util::Fnv64;
use crate::workload::{TpccBatch, Workload, YcsbBatch};

/// Segment header magic (8 bytes, versioned).
pub const WAL_MAGIC: [u8; 8] = *b"CABWAL1\0";
/// Snapshot file magic (8 bytes, versioned).
pub const SNAP_MAGIC: [u8; 8] = *b"CABSNP1\0";
/// Segment header: magic + segment id + chain seed.
const SEG_HEADER_LEN: usize = 8 + 8 + 8;
/// Frame overhead: u32 length prefix + u64 chain digest suffix.
const FRAME_OVERHEAD: usize = 4 + 8;

const KIND_HARD_STATE: u8 = 1;
const KIND_SPLICE: u8 = 2;

/// The durable per-node consensus state Raft requires to be stable before
/// any reply leaves the node (§5.1: `currentTerm` and `votedFor`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HardState {
    pub term: Term,
    pub voted_for: Option<NodeId>,
}

/// WAL tuning knobs (the `[storage]` config table).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WalConfig {
    /// Entry records batched per group-commit fsync (1 = sync every
    /// append; HardState records always sync regardless).
    pub fsync_group: usize,
    /// Roll to a fresh segment once the current one exceeds this many
    /// bytes.
    pub segment_bytes: usize,
}

impl Default for WalConfig {
    fn default() -> Self {
        WalConfig { fsync_group: 8, segment_bytes: 64 * 1024 }
    }
}

// ---------------------------------------------------------------------------
// Disk backends
// ---------------------------------------------------------------------------

/// Minimal storage backend the WAL drives: append/sync/read segment files
/// plus whole-file snapshot writes. `append` lands in the backend's cache
/// (lost or torn on crash); `sync` makes everything appended so far
/// durable; snapshot writes are durable before they return.
pub trait Disk {
    fn append(&mut self, seg: u64, bytes: &[u8]);
    fn sync(&mut self, seg: u64);
    /// Whole-segment read (durable bytes plus any still-cached tail).
    fn read_segment(&self, seg: u64) -> Option<Vec<u8>>;
    /// Segment ids, ascending.
    fn segments(&self) -> Vec<u64>;
    fn remove_segment(&mut self, seg: u64);
    /// Truncate a segment to `len` bytes (recovery cutting a torn tail).
    fn truncate_segment(&mut self, seg: u64, len: usize);
    /// Write a snapshot file; durable before returning.
    fn write_snapshot(&mut self, id: u64, bytes: &[u8]);
    /// Snapshot ids, ascending.
    fn snapshots(&self) -> Vec<u64>;
    fn read_snapshot(&self, id: u64) -> Option<Vec<u8>>;
    fn remove_snapshot(&mut self, id: u64);
}

#[derive(Clone, Debug, Default)]
struct MemFile {
    /// Bytes that survived an fsync.
    durable: Vec<u8>,
    /// Appended-but-unsynced tail (the simulated page cache).
    tail: Vec<u8>,
}

/// In-memory [`Disk`] for the simulator: one instance per simulated node.
/// `crash` models a power cut — the unsynced tail is lost, or (with a
/// fault stream) partially kept and possibly corrupted, producing exactly
/// the torn tails recovery must truncate.
#[derive(Clone, Debug, Default)]
pub struct MemDisk {
    files: BTreeMap<u64, MemFile>,
    snaps: BTreeMap<u64, Vec<u8>>,
    /// fsyncs the backend actually performed (test hook).
    pub syncs: u64,
}

impl MemDisk {
    pub fn new() -> Self {
        Self::default()
    }

    /// Simulate a crash: every file loses its unsynced tail. With a fault
    /// stream, a coin-flip keeps a random prefix of the tail instead —
    /// possibly with one corrupted byte — emulating a torn/partial write
    /// that reached the platter before the cut.
    pub fn crash(&mut self, mut faults: Option<&mut crate::net::rng::Rng>) {
        for file in self.files.values_mut() {
            let tail = std::mem::take(&mut file.tail);
            if tail.is_empty() {
                continue;
            }
            if let Some(rng) = faults.as_deref_mut() {
                if rng.chance(0.5) {
                    let keep = rng.below(tail.len() as u64 + 1) as usize;
                    let mut kept = tail[..keep].to_vec();
                    if keep > 0 && rng.chance(0.5) {
                        let i = rng.below(keep as u64) as usize;
                        kept[i] ^= (rng.next_u64() as u8) | 1; // guaranteed flip
                    }
                    file.durable.extend_from_slice(&kept);
                }
            }
        }
    }

    /// Total durable bytes across segments (test hook).
    pub fn durable_bytes(&self) -> usize {
        self.files.values().map(|f| f.durable.len()).sum()
    }
}

impl Disk for MemDisk {
    fn append(&mut self, seg: u64, bytes: &[u8]) {
        self.files.entry(seg).or_default().tail.extend_from_slice(bytes);
    }

    fn sync(&mut self, seg: u64) {
        if let Some(f) = self.files.get_mut(&seg) {
            let tail = std::mem::take(&mut f.tail);
            f.durable.extend_from_slice(&tail);
        }
        self.syncs += 1;
    }

    fn read_segment(&self, seg: u64) -> Option<Vec<u8>> {
        self.files.get(&seg).map(|f| {
            let mut v = f.durable.clone();
            v.extend_from_slice(&f.tail);
            v
        })
    }

    fn segments(&self) -> Vec<u64> {
        self.files.keys().copied().collect()
    }

    fn remove_segment(&mut self, seg: u64) {
        self.files.remove(&seg);
    }

    fn truncate_segment(&mut self, seg: u64, len: usize) {
        if let Some(f) = self.files.get_mut(&seg) {
            f.tail.clear();
            f.durable.truncate(len);
        }
    }

    fn write_snapshot(&mut self, id: u64, bytes: &[u8]) {
        self.snaps.insert(id, bytes.to_vec());
        self.syncs += 1;
    }

    fn snapshots(&self) -> Vec<u64> {
        self.snaps.keys().copied().collect()
    }

    fn read_snapshot(&self, id: u64) -> Option<Vec<u8>> {
        self.snaps.get(&id).cloned()
    }

    fn remove_snapshot(&mut self, id: u64) {
        self.snaps.remove(&id);
    }
}

/// Real-file [`Disk`] for the live runtime. Appends buffer in memory (the
/// stand-in for the page cache) and reach the file — followed by
/// `sync_all` — only on `sync`, so dropping the struct mid-run loses the
/// unsynced tail exactly like a `kill -9`.
#[derive(Debug)]
pub struct FsDisk {
    dir: PathBuf,
    tails: BTreeMap<u64, Vec<u8>>,
}

impl FsDisk {
    pub fn open(dir: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(FsDisk { dir, tails: BTreeMap::new() })
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    fn seg_path(&self, seg: u64) -> PathBuf {
        self.dir.join(format!("wal-{seg:08}.seg"))
    }

    fn snap_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("snap-{id:08}.bin"))
    }

    fn list(&self, prefix: &str, suffix: &str) -> Vec<u64> {
        let mut ids = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if let Some(mid) =
                    name.strip_prefix(prefix).and_then(|s| s.strip_suffix(suffix))
                {
                    if let Ok(id) = mid.parse::<u64>() {
                        ids.push(id);
                    }
                }
            }
        }
        ids.sort_unstable();
        ids
    }
}

impl Disk for FsDisk {
    fn append(&mut self, seg: u64, bytes: &[u8]) {
        self.tails.entry(seg).or_default().extend_from_slice(bytes);
    }

    fn sync(&mut self, seg: u64) {
        let Some(tail) = self.tails.get_mut(&seg) else { return };
        if tail.is_empty() {
            return;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.seg_path(seg))
            .expect("wal: open segment");
        f.write_all(tail).expect("wal: append segment");
        f.sync_all().expect("wal: fsync segment");
        tail.clear();
    }

    fn read_segment(&self, seg: u64) -> Option<Vec<u8>> {
        let mut v = std::fs::read(self.seg_path(seg)).unwrap_or_default();
        if let Some(tail) = self.tails.get(&seg) {
            v.extend_from_slice(tail);
        }
        (!v.is_empty()).then_some(v)
    }

    fn segments(&self) -> Vec<u64> {
        let mut ids = self.list("wal-", ".seg");
        for &seg in self.tails.keys() {
            if !ids.contains(&seg) {
                ids.push(seg);
            }
        }
        ids.sort_unstable();
        ids
    }

    fn remove_segment(&mut self, seg: u64) {
        self.tails.remove(&seg);
        let _ = std::fs::remove_file(self.seg_path(seg));
    }

    fn truncate_segment(&mut self, seg: u64, len: usize) {
        self.tails.remove(&seg);
        if let Ok(f) =
            std::fs::OpenOptions::new().write(true).open(self.seg_path(seg))
        {
            let _ = f.set_len(len as u64);
            let _ = f.sync_all();
        }
    }

    fn write_snapshot(&mut self, id: u64, bytes: &[u8]) {
        let mut f =
            std::fs::File::create(self.snap_path(id)).expect("wal: create snapshot");
        f.write_all(bytes).expect("wal: write snapshot");
        f.sync_all().expect("wal: fsync snapshot");
    }

    fn snapshots(&self) -> Vec<u64> {
        self.list("snap-", ".bin")
    }

    fn read_snapshot(&self, id: u64) -> Option<Vec<u8>> {
        std::fs::read(self.snap_path(id)).ok()
    }

    fn remove_snapshot(&mut self, id: u64) {
        let _ = std::fs::remove_file(self.snap_path(id));
    }
}

// ---------------------------------------------------------------------------
// The WAL
// ---------------------------------------------------------------------------

/// Everything recovery reconstructed from disk, in replay order: adopt the
/// hard state, install the snapshot (if any), then replay the splices —
/// `Log::splice` is idempotent and conflict-truncating, so replaying the
/// record sequence rebuilds the same log the pre-crash splice sequence
/// built.
#[derive(Clone, Debug, Default)]
pub struct Recovered {
    pub hard_state: HardState,
    pub snapshot: Option<SnapshotBlob>,
    /// `(prev_index, stored_weight, entries)` per durable splice record.
    pub splices: Vec<(LogIndex, f64, Vec<Entry>)>,
    /// Valid frames replayed.
    pub frames: usize,
    /// Bytes discarded from a torn/corrupt tail.
    pub torn_bytes: usize,
}

impl Recovered {
    pub fn entries(&self) -> usize {
        self.splices.iter().map(|(_, _, es)| es.len()).sum()
    }
}

/// A segmented, digest-chained write-ahead log over a [`Disk`] backend.
#[derive(Debug)]
pub struct Wal<D: Disk> {
    disk: D,
    cfg: WalConfig,
    /// Current (tail) segment id.
    seg: u64,
    /// Bytes written to the current segment, header included.
    seg_len: usize,
    /// Running frame-chain state (continues across segments).
    chain: u64,
    /// Entry records appended since the last fsync.
    pending: usize,
    /// Anything (frames or headers) written since the last fsync.
    dirty: bool,
    /// Latest HardState written (re-stamped at each segment roll so every
    /// segment is self-contained once older ones are pruned).
    hard_state: HardState,
    /// `last_index` of the newest durable snapshot file (0 = none).
    snap_index: u64,
    /// Records appended (HardState + splice).
    pub appends: u64,
    /// Group-commit fsyncs issued.
    pub fsyncs: u64,
}

impl<D: Disk> Wal<D> {
    /// Open a WAL on `disk`: recover whatever is durable (empty disk ⇒ a
    /// fresh log) and position the write head on a fresh segment after the
    /// last valid frame. The recovered state is returned alongside.
    pub fn open(disk: D, cfg: WalConfig) -> (Self, Recovered) {
        let mut disk = disk;
        let mut rec = Recovered::default();

        // Newest decodable snapshot wins; older/corrupt ones are ignored.
        let mut snap_index = 0;
        for id in disk.snapshots().into_iter().rev() {
            if let Some(bytes) = disk.read_snapshot(id) {
                if let Some(blob) = decode_snapshot(&bytes) {
                    snap_index = id;
                    rec.snapshot = Some(blob);
                    break;
                }
            }
        }

        // Replay segments in order until the first invalid byte; truncate
        // the torn tail and drop anything after it (later segments can
        // only exist if the prior one was synced whole, so a bad frame
        // mid-chain means everything beyond it is unsynced residue).
        let segs = disk.segments();
        let mut chain = Fnv64::new().finish();
        let mut first = true;
        let mut last_valid_seg: Option<u64> = None;
        let mut stop = false;
        for &s in &segs {
            if stop {
                disk.remove_segment(s);
                continue;
            }
            let bytes = disk.read_segment(s).unwrap_or_default();
            let (consumed, seg_chain, seg_stop) =
                replay_segment(&bytes, s, &mut chain, first, &mut rec);
            first = false;
            if consumed == 0 {
                // header never made it — nothing durable here or beyond
                rec.torn_bytes += bytes.len();
                disk.remove_segment(s);
                stop = true;
                continue;
            }
            chain = seg_chain;
            if consumed < bytes.len() {
                rec.torn_bytes += bytes.len() - consumed;
                disk.truncate_segment(s, consumed);
            }
            last_valid_seg = Some(s);
            if seg_stop {
                stop = true;
            }
        }

        let seg = last_valid_seg.map_or(0, |s| s + 1);
        let mut wal = Wal {
            disk,
            cfg,
            seg,
            seg_len: 0,
            chain,
            pending: 0,
            dirty: false,
            hard_state: rec.hard_state,
            snap_index,
            appends: 0,
            fsyncs: 0,
        };
        wal.write_header();
        if last_valid_seg.is_some() {
            // Re-stamp the recovered HardState so the fresh segment is
            // self-contained, and make the recovery point durable.
            wal.append_hard_state(wal.hard_state);
        }
        (wal, rec)
    }

    /// Tear the backend out (a simulated crash hands the disk — minus its
    /// unsynced tails — to the next incarnation's [`Wal::open`]).
    pub fn into_disk(self) -> D {
        self.disk
    }

    pub fn disk(&self) -> &D {
        &self.disk
    }

    /// `last_index` of the newest durable snapshot (0 = none).
    pub fn snapshot_index(&self) -> u64 {
        self.snap_index
    }

    pub fn hard_state(&self) -> HardState {
        self.hard_state
    }

    fn write_header(&mut self) {
        let mut buf = Vec::with_capacity(SEG_HEADER_LEN);
        buf.extend_from_slice(&WAL_MAGIC);
        push_u64(&mut buf, self.seg);
        push_u64(&mut buf, self.chain);
        self.disk.append(self.seg, &buf);
        self.seg_len = SEG_HEADER_LEN;
        self.dirty = true;
    }

    fn push_frame(&mut self, kind: u8, payload: &[u8]) {
        let mut buf = Vec::with_capacity(payload.len() + 1 + FRAME_OVERHEAD);
        push_u32(&mut buf, payload.len() as u32 + 1);
        buf.push(kind);
        buf.extend_from_slice(payload);
        let mut h = Fnv64::from_state(self.chain);
        h.write_bytes(&[kind]);
        h.write_bytes(payload);
        self.chain = h.finish();
        push_u64(&mut buf, self.chain);
        self.seg_len += buf.len();
        self.disk.append(self.seg, &buf);
        self.dirty = true;
        self.appends += 1;
    }

    /// Force-sync anything pending. Returns true when an fsync was
    /// actually issued (drivers charge fsync latency on true).
    pub fn sync(&mut self) -> bool {
        if !self.dirty {
            return false;
        }
        self.disk.sync(self.seg);
        self.dirty = false;
        self.pending = 0;
        self.fsyncs += 1;
        true
    }

    /// Roll to a fresh segment once the current one is over the size
    /// threshold. The full segment is synced first, so a later segment's
    /// existence certifies its predecessor's completeness.
    fn maybe_roll(&mut self) {
        if self.seg_len < self.cfg.segment_bytes {
            return;
        }
        self.sync();
        self.seg += 1;
        self.write_header();
        let hs = self.hard_state;
        let mut payload = Vec::with_capacity(16);
        encode_hard_state(&mut payload, hs);
        self.push_frame(KIND_HARD_STATE, &payload);
    }

    /// Append a HardState record and fsync immediately — a vote or term
    /// adoption must be durable before the reply leaves the node. Returns
    /// true when an fsync was issued (always, unless redundant).
    pub fn append_hard_state(&mut self, hs: HardState) -> bool {
        self.hard_state = hs;
        let mut payload = Vec::with_capacity(16);
        encode_hard_state(&mut payload, hs);
        self.push_frame(KIND_HARD_STATE, &payload);
        self.maybe_roll();
        self.sync()
    }

    /// Append a splice record (entries appended after `prev_index` with
    /// stored weight `weight`), group-committing the fsync: the sync is
    /// issued only every [`WalConfig::fsync_group`] records. Returns true
    /// when this append triggered an fsync.
    pub fn append_splice(
        &mut self,
        prev_index: LogIndex,
        weight: f64,
        entries: &[Entry],
    ) -> bool {
        let mut payload = Vec::with_capacity(32 + entries.len() * 40);
        push_u64(&mut payload, prev_index);
        push_u64(&mut payload, weight.to_bits());
        push_u32(&mut payload, entries.len() as u32);
        for e in entries {
            encode_entry(&mut payload, e);
        }
        self.push_frame(KIND_SPLICE, &payload);
        self.pending += 1;
        self.maybe_roll();
        if self.pending >= self.cfg.fsync_group.max(1) {
            return self.sync();
        }
        false
    }

    /// Persist a completed snapshot: write its file durably, then prune
    /// every *previous* segment (their records are covered by the blob or
    /// superseded by the current segment's) and every older snapshot. The
    /// prune order makes the sequence crash-consistent at every point.
    pub fn record_snapshot(&mut self, blob: &SnapshotBlob) {
        if blob.last_index <= self.snap_index {
            return;
        }
        let bytes = encode_snapshot(blob);
        self.disk.write_snapshot(blob.last_index, &bytes);
        self.fsyncs += 1;
        self.sync();
        for s in self.disk.segments() {
            if s < self.seg {
                self.disk.remove_segment(s);
            }
        }
        for id in self.disk.snapshots() {
            if id < blob.last_index {
                self.disk.remove_snapshot(id);
            }
        }
        self.snap_index = blob.last_index;
    }
}

/// Replay one segment's frames into `rec`. Returns `(consumed_bytes,
/// chain_out, stop)`: `consumed_bytes` is 0 when the header itself is
/// invalid, `stop` is true when a bad frame means later segments must be
/// discarded. On the first retained segment the header's chain seed is
/// adopted (earlier segments were pruned by a snapshot); afterwards it
/// must equal the running chain.
fn replay_segment(
    bytes: &[u8],
    seg: u64,
    chain_in: &mut u64,
    first: bool,
    rec: &mut Recovered,
) -> (usize, u64, bool) {
    if bytes.len() < SEG_HEADER_LEN || bytes[..8] != WAL_MAGIC {
        return (0, *chain_in, true);
    }
    let mut at = 8;
    let id = read_u64(bytes, &mut at).unwrap_or(u64::MAX);
    let seed = read_u64(bytes, &mut at).unwrap_or(0);
    if id != seg || (!first && seed != *chain_in) {
        return (0, *chain_in, true);
    }
    let mut chain = seed;
    let mut consumed = SEG_HEADER_LEN;
    while at < bytes.len() {
        let frame_start = at;
        let Some(len) = read_u32(bytes, &mut at) else { break };
        let len = len as usize;
        if len == 0 || at.checked_add(len + 8).map_or(true, |end| end > bytes.len()) {
            break; // torn tail: an incomplete frame
        }
        let kind = bytes[at];
        let payload = &bytes[at + 1..at + len];
        at += len;
        let Some(digest) = read_u64(bytes, &mut at) else { break };
        let mut h = Fnv64::from_state(chain);
        h.write_bytes(&[kind]);
        h.write_bytes(payload);
        if h.finish() != digest {
            break; // corrupt: the chain does not continue here
        }
        let decoded = match kind {
            KIND_HARD_STATE => decode_hard_state(payload)
                .map(|hs| rec.hard_state = hs)
                .is_some(),
            KIND_SPLICE => decode_splice(payload)
                .map(|s| rec.splices.push(s))
                .is_some(),
            _ => false,
        };
        if !decoded {
            break; // digest matched but payload is foreign — treat as torn
        }
        chain = h.finish();
        rec.frames += 1;
        consumed = at;
        let _ = frame_start;
    }
    (consumed, chain, consumed < bytes.len())
}

// ---------------------------------------------------------------------------
// Record codecs (little-endian, via storage::wire)
// ---------------------------------------------------------------------------

fn encode_hard_state(buf: &mut Vec<u8>, hs: HardState) {
    push_u64(buf, hs.term);
    push_u64(buf, hs.voted_for.map_or(0, |v| v as u64 + 1));
}

fn decode_hard_state(bytes: &[u8]) -> Option<HardState> {
    let mut at = 0;
    let term = read_u64(bytes, &mut at)?;
    let voted = read_u64(bytes, &mut at)?;
    Some(HardState {
        term,
        voted_for: (voted > 0).then(|| (voted - 1) as NodeId),
    })
}

fn decode_splice(bytes: &[u8]) -> Option<(LogIndex, f64, Vec<Entry>)> {
    let mut at = 0;
    let prev = read_u64(bytes, &mut at)?;
    let weight = f64::from_bits(read_u64(bytes, &mut at)?);
    let count = read_u32(bytes, &mut at)? as usize;
    let mut entries = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        entries.push(decode_entry(bytes, &mut at)?);
    }
    Some((prev, weight, entries))
}

const PAYLOAD_NOOP: u8 = 0;
const PAYLOAD_YCSB: u8 = 1;
const PAYLOAD_TPCC: u8 = 2;
const PAYLOAD_RECONFIG: u8 = 3;
const PAYLOAD_CONFIG: u8 = 4;
const PAYLOAD_BYTES: u8 = 5;
const PAYLOAD_SHARD: u8 = 6;

fn push_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
    for &x in xs {
        push_u32(buf, x);
    }
}

fn read_u32s(bytes: &[u8], at: &mut usize, n: usize) -> Option<Vec<u32>> {
    let mut v = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        v.push(read_u32(bytes, at)?);
    }
    Some(v)
}

fn encode_entry(buf: &mut Vec<u8>, e: &Entry) {
    push_u64(buf, e.term);
    push_u64(buf, e.index);
    push_u64(buf, e.wclock);
    match &e.payload {
        Payload::Noop => buf.push(PAYLOAD_NOOP),
        Payload::Ycsb(b) => {
            buf.push(PAYLOAD_YCSB);
            let wl = Workload::ALL.iter().position(|&w| w == b.workload).unwrap_or(0);
            buf.push(wl as u8);
            push_u64(buf, b.value_size);
            push_u32(buf, b.ops.len() as u32);
            push_u32s(buf, &b.ops);
            push_u32s(buf, &b.keys);
            push_u32s(buf, &b.vals);
        }
        Payload::Tpcc(b) => {
            buf.push(PAYLOAD_TPCC);
            push_u32(buf, b.types.len() as u32);
            push_u32s(buf, &b.types);
            push_u32s(buf, &b.wids);
            push_u32s(buf, &b.args);
        }
        Payload::Reconfig { new_t } => {
            buf.push(PAYLOAD_RECONFIG);
            push_u64(buf, *new_t as u64);
        }
        Payload::ConfigChange(c) => {
            buf.push(PAYLOAD_CONFIG);
            encode_config(buf, c);
        }
        Payload::Bytes(b) => {
            buf.push(PAYLOAD_BYTES);
            push_u32(buf, b.len() as u32);
            buf.extend_from_slice(b);
        }
        Payload::Shard(s) => {
            buf.push(PAYLOAD_SHARD);
            push_u32(buf, s.shard_id);
            push_u32(buf, s.k);
            push_u64(buf, s.total_bytes);
            push_u32(buf, s.data.len() as u32);
            buf.extend_from_slice(&s.data);
        }
    }
}

fn decode_entry(bytes: &[u8], at: &mut usize) -> Option<Entry> {
    let term = read_u64(bytes, at)?;
    let index = read_u64(bytes, at)?;
    let wclock = read_u64(bytes, at)?;
    let tag = *bytes.get(*at)?;
    *at += 1;
    let payload = match tag {
        PAYLOAD_NOOP => Payload::Noop,
        PAYLOAD_YCSB => {
            let wl = *Workload::ALL.get(*bytes.get(*at)? as usize)?;
            *at += 1;
            let value_size = read_u64(bytes, at)?;
            let n = read_u32(bytes, at)? as usize;
            let ops = read_u32s(bytes, at, n)?;
            let keys = read_u32s(bytes, at, n)?;
            let vals = read_u32s(bytes, at, n)?;
            Payload::Ycsb(Arc::new(YcsbBatch { workload: wl, ops, keys, vals, value_size }))
        }
        PAYLOAD_TPCC => {
            let n = read_u32(bytes, at)? as usize;
            let types = read_u32s(bytes, at, n)?;
            let wids = read_u32s(bytes, at, n)?;
            let args = read_u32s(bytes, at, n)?;
            Payload::Tpcc(Arc::new(TpccBatch { types, wids, args }))
        }
        PAYLOAD_RECONFIG => Payload::Reconfig { new_t: read_u64(bytes, at)? as usize },
        PAYLOAD_CONFIG => Payload::ConfigChange(Arc::new(decode_config(bytes, at)?)),
        PAYLOAD_BYTES => {
            let n = read_u32(bytes, at)? as usize;
            let end = at.checked_add(n)?;
            let v = bytes.get(*at..end)?.to_vec();
            *at = end;
            Payload::Bytes(Arc::new(v))
        }
        PAYLOAD_SHARD => {
            let shard_id = read_u32(bytes, at)?;
            let k = read_u32(bytes, at)?;
            let total_bytes = read_u64(bytes, at)?;
            let n = read_u32(bytes, at)? as usize;
            let end = at.checked_add(n)?;
            let data = bytes.get(*at..end)?.to_vec();
            *at = end;
            Payload::Shard(Arc::new(ShardData { shard_id, k, total_bytes, data: Arc::new(data) }))
        }
        _ => return None,
    };
    Some(Entry { term, index, payload, wclock })
}

fn encode_config(buf: &mut Vec<u8>, c: &ClusterConfig) {
    push_u64(buf, c.epoch);
    push_u32(buf, c.members.len() as u32);
    for m in &c.members {
        push_u64(buf, m.id as u64);
        buf.push(match m.state {
            MemberState::Joining => 0,
            MemberState::Active => 1,
            MemberState::Draining => 2,
        });
    }
    match &c.joint_old {
        None => buf.push(0),
        Some(old) => {
            buf.push(1);
            push_u32(buf, old.len() as u32);
            for &v in old {
                push_u64(buf, v as u64);
            }
        }
    }
}

fn decode_config(bytes: &[u8], at: &mut usize) -> Option<ClusterConfig> {
    let epoch = read_u64(bytes, at)?;
    let m = read_u32(bytes, at)? as usize;
    let mut members = Vec::with_capacity(m.min(4096));
    for _ in 0..m {
        let id = read_u64(bytes, at)? as NodeId;
        let state = match *bytes.get(*at)? {
            0 => MemberState::Joining,
            1 => MemberState::Active,
            2 => MemberState::Draining,
            _ => return None,
        };
        *at += 1;
        members.push(MemberSpec { id, state });
    }
    let joint_old = match *bytes.get(*at)? {
        0 => {
            *at += 1;
            None
        }
        1 => {
            *at += 1;
            let k = read_u32(bytes, at)? as usize;
            let mut old = Vec::with_capacity(k.min(4096));
            for _ in 0..k {
                old.push(read_u64(bytes, at)? as NodeId);
            }
            Some(old)
        }
        _ => return None,
    };
    Some(ClusterConfig { epoch, members, joint_old })
}

/// Snapshot file: magic + body + FNV digest over the body. A torn or
/// corrupt file fails the digest and recovery falls back to an older one.
pub fn encode_snapshot(blob: &SnapshotBlob) -> Vec<u8> {
    let mut body = Vec::with_capacity(64 + blob.app.wire_size());
    push_u64(&mut body, blob.last_index);
    push_u64(&mut body, blob.last_term);
    push_u64(&mut body, blob.prefix_digest);
    push_u64(&mut body, blob.wclock);
    match blob.cabinet_t {
        None => body.push(0),
        Some(t) => {
            body.push(1);
            push_u64(&mut body, t as u64);
        }
    }
    match &blob.config {
        None => body.push(0),
        Some(c) => {
            body.push(1);
            encode_config(&mut body, c);
        }
    }
    match &blob.app {
        AppState::None => body.push(0),
        AppState::Ycsb(b) => {
            body.push(1);
            push_u32(&mut body, b.len() as u32);
            body.extend_from_slice(b);
        }
        AppState::Tpcc(b) => {
            body.push(2);
            push_u32(&mut body, b.len() as u32);
            body.extend_from_slice(b);
        }
        AppState::Slots(s) => {
            body.push(3);
            push_u32(&mut body, s.len() as u32);
            push_u32s(&mut body, s);
        }
    }
    let mut out = Vec::with_capacity(8 + body.len() + 8);
    out.extend_from_slice(&SNAP_MAGIC);
    out.extend_from_slice(&body);
    let mut h = Fnv64::new();
    h.write_bytes(&body);
    push_u64(&mut out, h.finish());
    out
}

pub fn decode_snapshot(bytes: &[u8]) -> Option<SnapshotBlob> {
    if bytes.len() < 16 || bytes[..8] != SNAP_MAGIC {
        return None;
    }
    let body = &bytes[8..bytes.len() - 8];
    let mut tail = bytes.len() - 8;
    let digest = read_u64(bytes, &mut tail)?;
    let mut h = Fnv64::new();
    h.write_bytes(body);
    if h.finish() != digest {
        return None;
    }
    let mut at = 0;
    let last_index = read_u64(body, &mut at)?;
    let last_term = read_u64(body, &mut at)?;
    let prefix_digest = read_u64(body, &mut at)?;
    let wclock = read_u64(body, &mut at)?;
    let cabinet_t = match *body.get(at)? {
        0 => {
            at += 1;
            None
        }
        1 => {
            at += 1;
            Some(read_u64(body, &mut at)? as usize)
        }
        _ => return None,
    };
    let config = match *body.get(at)? {
        0 => {
            at += 1;
            None
        }
        1 => {
            at += 1;
            Some(Arc::new(decode_config(body, &mut at)?))
        }
        _ => return None,
    };
    let app = match *body.get(at)? {
        0 => {
            at += 1;
            AppState::None
        }
        1 => {
            at += 1;
            let n = read_u32(body, &mut at)? as usize;
            let end = at.checked_add(n)?;
            let v = body.get(at..end)?.to_vec();
            at = end;
            AppState::Ycsb(Arc::new(v))
        }
        2 => {
            at += 1;
            let n = read_u32(body, &mut at)? as usize;
            let end = at.checked_add(n)?;
            let v = body.get(at..end)?.to_vec();
            at = end;
            AppState::Tpcc(Arc::new(v))
        }
        3 => {
            at += 1;
            let n = read_u32(body, &mut at)? as usize;
            AppState::Slots(Arc::new(read_u32s(body, &mut at, n)?))
        }
        _ => return None,
    };
    Some(SnapshotBlob {
        last_index,
        last_term,
        prefix_digest,
        wclock,
        cabinet_t,
        config,
        app,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::rng::Rng;

    fn entry(term: Term, index: LogIndex, wclock: u64) -> Entry {
        Entry { term, index, payload: Payload::Noop, wclock }
    }

    fn ycsb_entry(term: Term, index: LogIndex) -> Entry {
        Entry {
            term,
            index,
            wclock: index,
            payload: Payload::Ycsb(Arc::new(YcsbBatch {
                workload: Workload::A,
                ops: vec![0, 1, 1],
                keys: vec![7, 8, 9],
                vals: vec![0, 10, 11],
                value_size: 0,
            })),
        }
    }

    #[test]
    fn empty_disk_opens_fresh() {
        let (wal, rec) = Wal::open(MemDisk::new(), WalConfig::default());
        assert_eq!(rec.hard_state, HardState::default());
        assert!(rec.snapshot.is_none());
        assert!(rec.splices.is_empty());
        assert_eq!(rec.torn_bytes, 0);
        assert_eq!(wal.snapshot_index(), 0);
    }

    #[test]
    fn hard_state_round_trip_survives_crash() {
        let (mut wal, _) = Wal::open(MemDisk::new(), WalConfig::default());
        let hs = HardState { term: 7, voted_for: Some(3) };
        assert!(wal.append_hard_state(hs), "hard state must force a sync");
        let mut disk = wal.into_disk();
        disk.crash(None); // clean power cut: unsynced tails drop
        let (_, rec) = Wal::open(disk, WalConfig::default());
        assert_eq!(rec.hard_state, hs);
    }

    #[test]
    fn splice_records_round_trip_with_payloads() {
        let (mut wal, _) = Wal::open(MemDisk::new(), WalConfig::default());
        wal.append_splice(0, 2.5, &[entry(1, 1, 1), ycsb_entry(1, 2)]);
        wal.append_splice(
            2,
            1.0,
            &[Entry {
                term: 2,
                index: 3,
                wclock: 3,
                payload: Payload::Bytes(Arc::new(vec![1, 2, 3])),
            }],
        );
        wal.sync();
        let (_, rec) = Wal::open(wal.into_disk(), WalConfig::default());
        assert_eq!(rec.splices.len(), 2);
        let (prev, w, es) = &rec.splices[0];
        assert_eq!((*prev, *w, es.len()), (0, 2.5, 2));
        match &es[1].payload {
            Payload::Ycsb(b) => {
                assert_eq!(b.keys, vec![7, 8, 9]);
                assert_eq!(b.workload, Workload::A);
            }
            other => panic!("wrong payload: {other:?}"),
        }
        match &rec.splices[1].2[0].payload {
            Payload::Bytes(b) => assert_eq!(**b, vec![1, 2, 3]),
            other => panic!("wrong payload: {other:?}"),
        }
    }

    #[test]
    fn shard_and_sized_ycsb_payloads_round_trip() {
        let (mut wal, _) = Wal::open(MemDisk::new(), WalConfig::default());
        let shard = ShardData {
            shard_id: 2,
            k: 3,
            total_bytes: 65_536,
            data: Arc::new(vec![0xab; 97]),
        };
        let sized = YcsbBatch {
            workload: Workload::B,
            ops: vec![1, 1],
            keys: vec![4, 5],
            vals: vec![6, 7],
            value_size: 65_536,
        };
        wal.append_splice(
            0,
            1.0,
            &[
                Entry { term: 1, index: 1, wclock: 1, payload: Payload::Shard(Arc::new(shard.clone())) },
                Entry { term: 1, index: 2, wclock: 1, payload: Payload::Ycsb(Arc::new(sized)) },
            ],
        );
        wal.sync();
        let (_, rec) = Wal::open(wal.into_disk(), WalConfig::default());
        let es = &rec.splices[0].2;
        match &es[0].payload {
            Payload::Shard(s) => assert_eq!(**s, shard),
            other => panic!("wrong payload: {other:?}"),
        }
        match &es[1].payload {
            Payload::Ycsb(b) => {
                assert_eq!(b.value_size, 65_536);
                assert_eq!(b.keys, vec![4, 5]);
            }
            other => panic!("wrong payload: {other:?}"),
        }
    }

    #[test]
    fn group_commit_batches_fsyncs() {
        let cfg = WalConfig { fsync_group: 8, segment_bytes: 1 << 20 };
        let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
        let mut synced = 0;
        for i in 0..16u64 {
            if wal.append_splice(i, 1.0, &[entry(1, i + 1, 1)]) {
                synced += 1;
            }
        }
        assert_eq!(synced, 2, "16 appends at group 8 = 2 fsyncs");
        let cfg1 = WalConfig { fsync_group: 1, segment_bytes: 1 << 20 };
        let (mut wal1, _) = Wal::open(MemDisk::new(), cfg1);
        let all: usize = (0..16u64)
            .map(|i| wal1.append_splice(i, 1.0, &[entry(1, i + 1, 1)]) as usize)
            .sum();
        assert_eq!(all, 16, "group 1 syncs every append");
    }

    #[test]
    fn unsynced_tail_is_lost_on_crash() {
        let cfg = WalConfig { fsync_group: 64, segment_bytes: 1 << 20 };
        let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
        wal.append_splice(0, 1.0, &[entry(1, 1, 1)]);
        wal.sync();
        wal.append_splice(1, 1.0, &[entry(1, 2, 1)]); // unsynced
        let mut disk = wal.into_disk();
        disk.crash(None);
        let (_, rec) = Wal::open(disk, WalConfig::default());
        assert_eq!(rec.splices.len(), 1, "only the synced record survives");
        assert_eq!(rec.torn_bytes, 0, "a clean cut leaves no torn bytes");
    }

    #[test]
    fn torn_tail_truncates_to_last_valid_frame() {
        let cfg = WalConfig { fsync_group: 1, segment_bytes: 1 << 20 };
        let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
        for i in 0..5u64 {
            wal.append_splice(i, 1.0, &[entry(1, i + 1, 1)]);
        }
        let mut disk = wal.into_disk();
        // Corrupt the durable tail directly: flip a byte inside the last
        // frame of segment 0.
        let seg = disk.segments()[0];
        let len = disk.read_segment(seg).unwrap().len();
        if let Some(f) = disk.files.get_mut(&seg) {
            let i = f.durable.len() - 3;
            f.durable[i] ^= 0xFF;
        }
        let (_, rec) = Wal::open(disk, WalConfig::default());
        assert_eq!(rec.splices.len(), 4, "the corrupted frame is cut");
        assert!(rec.torn_bytes > 0 && rec.torn_bytes < len);
    }

    #[test]
    fn torn_tail_property_random_offsets() {
        // Property test (satellite): truncate/corrupt the segment tail at
        // random byte offsets over random kill points; recovery must keep
        // a clean prefix of the record sequence — never garbage, never a
        // reordering — and the surviving splices must replay in order.
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..200u64 {
            let cfg = WalConfig { fsync_group: 4, segment_bytes: 512 };
            let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
            let records = 1 + (case % 17);
            for i in 0..records {
                wal.append_splice(i, 1.0, &[entry(1, i + 1, i + 1)]);
            }
            let mut disk = wal.into_disk();
            disk.crash(Some(&mut rng)); // torn-write faults on
            let (_, rec) = Wal::open(disk, WalConfig::default());
            assert!(
                rec.splices.len() as u64 <= records,
                "recovery must never invent records"
            );
            for (i, (prev, _, es)) in rec.splices.iter().enumerate() {
                assert_eq!(*prev, i as u64, "splices must replay in order");
                assert_eq!(es[0].index, i as u64 + 1);
            }
        }
    }

    #[test]
    fn recovery_is_idempotent_after_truncation() {
        let cfg = WalConfig { fsync_group: 1, segment_bytes: 256 };
        let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
        for i in 0..20u64 {
            wal.append_splice(i, 1.0, &[entry(1, i + 1, 1)]);
        }
        let mut disk = wal.into_disk();
        let mut rng = Rng::new(9);
        disk.crash(Some(&mut rng));
        let (wal2, rec1) = Wal::open(disk, cfg);
        // a second crash+recovery with nothing written in between must see
        // exactly the same state (truncation left a valid log)
        let mut disk = wal2.into_disk();
        disk.crash(None);
        let (_, rec2) = Wal::open(disk, cfg);
        assert_eq!(rec1.splices.len(), rec2.splices.len());
        assert_eq!(rec1.hard_state, rec2.hard_state);
        assert_eq!(rec2.torn_bytes, 0);
    }

    #[test]
    fn segments_roll_and_chain_across_boundaries() {
        let cfg = WalConfig { fsync_group: 1, segment_bytes: 200 };
        let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
        for i in 0..30u64 {
            wal.append_splice(i, 1.0, &[entry(1, i + 1, 1)]);
        }
        assert!(wal.disk().segments().len() > 1, "rolls past 200 bytes");
        let (_, rec) = Wal::open(wal.into_disk(), cfg);
        assert_eq!(rec.splices.len(), 30);
        assert_eq!(rec.torn_bytes, 0);
    }

    #[test]
    fn snapshot_prunes_old_segments_and_survives() {
        let cfg = WalConfig { fsync_group: 1, segment_bytes: 200 };
        let (mut wal, _) = Wal::open(MemDisk::new(), cfg);
        for i in 0..30u64 {
            wal.append_hard_state(HardState { term: i, voted_for: Some(1) });
            wal.append_splice(i, 1.0, &[entry(i, i + 1, 1)]);
        }
        let before = wal.disk().segments().len();
        let blob = SnapshotBlob {
            last_index: 25,
            last_term: 24,
            prefix_digest: 0xFEED,
            wclock: 25,
            cabinet_t: Some(2),
            config: None,
            app: AppState::Slots(Arc::new(vec![1, 2, 3])),
        };
        wal.record_snapshot(&blob);
        assert!(wal.disk().segments().len() < before, "old segments pruned");
        let (_, rec) = Wal::open(wal.into_disk(), cfg);
        let snap = rec.snapshot.expect("snapshot recovered");
        assert_eq!(snap.last_index, 25);
        assert_eq!(snap.prefix_digest, 0xFEED);
        assert_eq!(snap.cabinet_t, Some(2));
        match snap.app {
            AppState::Slots(s) => assert_eq!(*s, vec![1, 2, 3]),
            other => panic!("wrong app state: {other:?}"),
        }
        assert_eq!(
            rec.hard_state,
            HardState { term: 29, voted_for: Some(1) },
            "hard state survives pruning via the segment-roll re-stamp"
        );
    }

    #[test]
    fn corrupt_snapshot_falls_back_to_older() {
        let mut disk = MemDisk::new();
        let old = SnapshotBlob {
            last_index: 10,
            last_term: 2,
            prefix_digest: 1,
            wclock: 10,
            cabinet_t: None,
            config: None,
            app: AppState::None,
        };
        disk.write_snapshot(10, &encode_snapshot(&old));
        let mut bad = encode_snapshot(&SnapshotBlob { last_index: 20, ..old.clone() });
        let k = bad.len() - 12;
        bad[k] ^= 0x55;
        disk.write_snapshot(20, &bad);
        let (_, rec) = Wal::open(disk, WalConfig::default());
        assert_eq!(rec.snapshot.expect("fallback").last_index, 10);
    }

    #[test]
    fn config_payload_round_trip() {
        let mut c = ClusterConfig::bootstrap(5);
        c.epoch = 3;
        c.members[1].state = MemberState::Draining;
        c.joint_old = Some(vec![0, 1, 2]);
        let e = Entry {
            term: 4,
            index: 9,
            wclock: 9,
            payload: Payload::ConfigChange(Arc::new(c.clone())),
        };
        let mut buf = Vec::new();
        encode_entry(&mut buf, &e);
        let mut at = 0;
        let back = decode_entry(&buf, &mut at).expect("decodes");
        match back.payload {
            Payload::ConfigChange(got) => assert_eq!(*got, c),
            other => panic!("wrong payload: {other:?}"),
        }
        assert_eq!(at, buf.len());
    }

    #[test]
    fn fs_disk_round_trip_and_crash_semantics() {
        let dir = std::env::temp_dir().join(format!(
            "cabinet-wal-test-{}-{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let disk = FsDisk::open(dir.clone()).expect("temp dir");
        let cfg = WalConfig { fsync_group: 4, segment_bytes: 1 << 16 };
        let (mut wal, _) = Wal::open(disk, cfg);
        wal.append_hard_state(HardState { term: 3, voted_for: Some(0) });
        for i in 0..4u64 {
            wal.append_splice(i, 1.0, &[ycsb_entry(3, i + 1)]);
        }
        wal.append_splice(4, 1.0, &[entry(3, 5, 5)]); // group not full: unsynced
        drop(wal); // kill -9: the buffered tail never reaches the file
        let disk = FsDisk::open(dir.clone()).expect("reopen");
        let (_, rec) = Wal::open(disk, cfg);
        assert_eq!(rec.hard_state, HardState { term: 3, voted_for: Some(0) });
        assert_eq!(rec.splices.len(), 4, "the unsynced 5th record is gone");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
