//! Shape assertions for the paper's evaluation claims (§5): not absolute
//! numbers (the substrate is a simulator, not the authors' testbed) but who
//! wins, by roughly what factor, and where the crossovers fall.

use cabinet::bench::figures::{self, Scale};
use cabinet::net::delay::DelayModel;
use cabinet::net::fault::{ContentionSpec, KillSpec, KillStrategy};
use cabinet::sim::{run, Protocol, ReadPath, SimConfig, WorkloadSpec};
use cabinet::workload::Workload;

fn quick(proto: Protocol, n: usize, het: bool) -> SimConfig {
    let mut c = SimConfig::new(proto, n, het);
    c.rounds = 12;
    c
}

/// §5.2 headline: cab f10% ≈ 3× Raft throughput in het n=50 (paper: 27,999
/// vs 10,136 TPS). We accept 2–4×.
#[test]
fn headline_cab_f10_vs_raft_het() {
    let raft = run(&quick(Protocol::Raft, 50, true));
    let cab = run(&quick(Protocol::Cabinet { t: 5 }, 50, true));
    let ratio = cab.tput_ops_s / raft.tput_ops_s;
    assert!(
        (2.0..4.0).contains(&ratio),
        "tput ratio {ratio:.2} outside 2–4x (cab {} vs raft {})",
        cab.tput_ops_s,
        raft.tput_ops_s
    );
    let lat_ratio = raft.mean_latency_ms / cab.mean_latency_ms;
    assert!(lat_ratio > 2.0, "latency ratio {lat_ratio:.2}");
}

/// Fig. 8: both algorithms are nearly scale-invariant (one RPC round), and
/// cabinet ≥ raft at every scale; at n=3 they coincide (quorum 2).
#[test]
fn fig8_scaling_shape() {
    let mut prev_raft_hom = None;
    for n in [11usize, 50, 100] {
        let raft = run(&quick(Protocol::Raft, n, true));
        let t = cabinet::consensus::weights::threshold_pct(n, 10);
        let cab = run(&quick(Protocol::Cabinet { t }, n, true));
        assert!(
            cab.tput_ops_s >= raft.tput_ops_s,
            "n={n}: cab {} < raft {}",
            cab.tput_ops_s,
            raft.tput_ops_s
        );
        // "performance loss when scaling up is minimal" — checked in the
        // homogeneous setting (het majorities reach into slower zones as n
        // grows, which is exactly Cabinet's motivation)
        let raft_hom = run(&quick(Protocol::Raft, n, false));
        if let Some(prev) = prev_raft_hom {
            let drop: f64 = raft_hom.tput_ops_s / prev;
            assert!(drop > 0.8, "n={n}: hom raft dropped {drop:.2} vs previous scale");
        }
        prev_raft_hom = Some(raft_hom.tput_ops_s);
    }
    // n=3: identical quorums → near-identical performance
    let raft3 = run(&quick(Protocol::Raft, 3, true));
    let cab3 = run(&quick(Protocol::Cabinet { t: 1 }, 3, true));
    let ratio = cab3.tput_ops_s / raft3.tput_ops_s;
    assert!((0.85..1.2).contains(&ratio), "n=3 ratio {ratio}");
}

/// Fig. 9: heterogeneous beats homogeneous for Cabinet (paper: 2.3× in
/// YCSB); Raft gains much less from heterogeneity.
#[test]
fn fig9_het_advantage() {
    let cab_het = run(&quick(Protocol::Cabinet { t: 5 }, 50, true));
    let cab_hom = run(&quick(Protocol::Cabinet { t: 5 }, 50, false));
    let het_gain = cab_het.tput_ops_s / cab_hom.tput_ops_s;
    assert!(
        (1.5..4.5).contains(&het_gain),
        "cabinet het/hom gain {het_gain:.2} (paper ≈2.3x)"
    );
    let raft_het = run(&quick(Protocol::Raft, 50, true));
    let raft_hom = run(&quick(Protocol::Raft, 50, false));
    let raft_gain = raft_het.tput_ops_s / raft_hom.tput_ops_s;
    assert!(raft_gain < het_gain, "raft shouldn't benefit more than cabinet");
}

/// Fig. 9/10: smaller failure threshold ⇒ higher throughput (monotone-ish:
/// f10% strictly beats f40%).
#[test]
fn smaller_t_is_faster() {
    let f10 = run(&quick(Protocol::Cabinet { t: 5 }, 50, true));
    let f40 = run(&quick(Protocol::Cabinet { t: 20 }, 50, true));
    assert!(
        f10.tput_ops_s > f40.tput_ops_s,
        "f10 {} !> f40 {}",
        f10.tput_ops_s,
        f40.tput_ops_s
    );
}

/// Fig. 10/11: the TPC-C gap is smaller than the YCSB gap (lock-bound
/// transactions parallelize worse — paper: 1.4× vs 2.3× het gain).
#[test]
fn tpcc_gain_smaller_than_ycsb() {
    let mut ycsb = quick(Protocol::Cabinet { t: 5 }, 50, true);
    ycsb.workload = WorkloadSpec::ycsb(Workload::A, 5000);
    let mut ycsb_hom = ycsb.clone();
    ycsb_hom.zones = cabinet::net::topology::ZoneAlloc::homogeneous(50);
    let mut tpcc = quick(Protocol::Cabinet { t: 5 }, 50, true);
    tpcc.workload = WorkloadSpec::tpcc2k();
    let mut tpcc_hom = tpcc.clone();
    tpcc_hom.zones = cabinet::net::topology::ZoneAlloc::homogeneous(50);

    let ycsb_gain = run(&ycsb).tput_ops_s / run(&ycsb_hom).tput_ops_s;
    let tpcc_gain = run(&tpcc).tput_ops_s / run(&tpcc_hom).tput_ops_s;
    // both gain from heterogeneity; YCSB by at least as much
    assert!(ycsb_gain >= tpcc_gain * 0.9, "ycsb {ycsb_gain:.2} vs tpcc {tpcc_gain:.2}");
}

/// Fig. 12: throughput increases as t drops (covered by the figure itself).
#[test]
fn fig12_dynamic_threshold() {
    let t = figures::fig12(Scale::Quick);
    let first = t.num(0, "tput_ops_s").unwrap();
    let last = t.num(t.rows.len() - 1, "tput_ops_s").unwrap();
    assert!(last > 1.3 * first, "tput must rise substantially: {first} → {last}");
}

/// Fig. 14: D2 skew hurts Raft much more than Cabinet (paper: cab f10%
/// under D2 ≈ its D1-100ms level, Raft degrades to its D1-500ms level).
#[test]
fn fig14_skew_resilience() {
    let mut raft_d2 = quick(Protocol::Raft, 50, true);
    raft_d2.delay = DelayModel::Skew;
    let mut cab_d2 = quick(Protocol::Cabinet { t: 5 }, 50, true);
    cab_d2.delay = DelayModel::Skew;
    let r = run(&raft_d2);
    let c = run(&cab_d2);
    assert!(
        c.tput_ops_s > 1.5 * r.tput_ops_s,
        "under skew cab {} !>> raft {}",
        c.tput_ops_s,
        r.tput_ops_s
    );
}

/// Fig. 16: under rotating delays Cabinet dips when the fast nodes become
/// slow, then recovers within a few rounds (weights re-dealt).
#[test]
fn fig16_recovery_after_rotation() {
    let mut c = quick(Protocol::Cabinet { t: 5 }, 50, true);
    c.rounds = 24;
    c.delay = DelayModel::Rotating { period_rounds: 8 };
    let r = run(&c);
    assert_eq!(r.rounds.len(), 24);
    // the first round after a rotation (round 9) should be slower than the
    // steady state reached a few rounds later
    let dip = r.rounds[8].latency_ms; // round 9
    let recovered = r.rounds[14].latency_ms; // round 15
    assert!(
        recovered < dip,
        "no recovery: dip {dip:.0}ms, later {recovered:.0}ms"
    );
}

/// Fig. 17: HQC has the worst latency under bursting delays (multi-round
/// message passing amplifies spikes — paper: 4.3× Cabinet).
#[test]
fn fig17_hqc_worst_under_bursts() {
    let mut raft = quick(Protocol::Raft, 11, true);
    raft.delay = DelayModel::Bursting;
    let mut cab = quick(Protocol::Cabinet { t: 1 }, 11, true);
    cab.delay = DelayModel::Bursting;
    let mut hqc = quick(Protocol::Hqc { sizes: vec![3, 3, 5] }, 11, true);
    hqc.delay = DelayModel::Bursting;
    let r = run(&raft);
    let c = run(&cab);
    let h = run(&hqc);
    assert!(h.mean_latency_ms > r.mean_latency_ms, "hqc must be worst");
    assert!(r.mean_latency_ms > c.mean_latency_ms, "cab must be best");
    let ratio = h.mean_latency_ms / c.mean_latency_ms;
    assert!(ratio > 2.0, "hqc/cab latency ratio {ratio:.1} (paper ≈4.3x)");
}

/// Fig. 18: contention dips all algorithms but does not change the ranking.
#[test]
fn fig18_contention_preserves_ranking() {
    let mk = |proto: Protocol| {
        let mut c = quick(proto, 11, true);
        c.rounds = 16;
        c.contention = Some(ContentionSpec::new(8, 2.5));
        run(&c)
    };
    let raft = mk(Protocol::Raft);
    let cab = mk(Protocol::Cabinet { t: 1 });
    assert!(cab.tput_ops_s > raft.tput_ops_s);
    // both see a dip after round 8
    for r in [&raft, &cab] {
        let before: f64 =
            r.rounds[2..8].iter().map(|s| s.latency_ms).sum::<f64>() / 6.0;
        let after: f64 =
            r.rounds[9..15].iter().map(|s| s.latency_ms).sum::<f64>() / 6.0;
        assert!(after > 1.5 * before, "no contention dip: {before} → {after}");
    }
}

/// Fig. 19: weak kills ≈ no impact; strong kills dip then recover via
/// reassignment; recovered throughput still beats Raft.
#[test]
fn fig19_kill_strategies() {
    let kill_round = 6u64;
    let mk = |strategy: KillStrategy, count: usize| {
        let mut c = quick(Protocol::Cabinet { t: 2 }, 11, true);
        c.rounds = 12;
        c.kills = vec![KillSpec::new(kill_round, count, strategy)];
        run(&c)
    };
    let clean = run(&{
        let mut c = quick(Protocol::Cabinet { t: 2 }, 11, true);
        c.rounds = 12;
        c
    });
    let weak = mk(KillStrategy::Weak, 2);
    let strong = mk(KillStrategy::Strong, 2);

    // weak kills: performance unaffected (within 15%)
    assert!(
        weak.tput_ops_s > 0.85 * clean.tput_ops_s,
        "weak kills hurt: {} vs {}",
        weak.tput_ops_s,
        clean.tput_ops_s
    );
    // strong kills: the kill round is slower than steady state...
    let dip = strong.rounds.iter().find(|s| s.round == kill_round).unwrap().latency_ms;
    let steady = strong.rounds[1].latency_ms;
    assert!(dip > steady, "strong kill should dip: {dip} vs {steady}");
    // ...but recovery happens within a couple of rounds
    let recovered = strong
        .rounds
        .iter()
        .filter(|s| s.round >= kill_round + 2)
        .map(|s| s.latency_ms)
        .sum::<f64>()
        / strong.rounds.iter().filter(|s| s.round >= kill_round + 2).count() as f64;
    assert!(recovered < dip, "no recovery after strong kill");
    // recovered throughput still ≥ raft's clean run
    let raft = run(&quick(Protocol::Raft, 11, true));
    assert!(
        strong.tput_ops_s > raft.tput_ops_s * 0.9,
        "post-crash cabinet {} should stay competitive with raft {}",
        strong.tput_ops_s,
        raft.tput_ops_s
    );
}

/// Cabinet exceeds Raft's fault-tolerance bound in the best case (Example
/// (d) in §4.1.2): with t=2 and n=11, killing 8 weak nodes (> f=5) still
/// commits.
#[test]
fn best_case_fault_tolerance_beyond_majority() {
    let mut c = quick(Protocol::Cabinet { t: 2 }, 11, true);
    c.rounds = 12;
    c.kills = vec![KillSpec::new(4, 8, KillStrategy::Weak)];
    let r = run(&c);
    assert_eq!(r.rounds.len(), 12, "consensus must continue with 8/11 dead");
}

/// Raft, by contrast, stalls when a majority dies.
#[test]
fn raft_stalls_beyond_majority() {
    let mut c = quick(Protocol::Raft, 11, true);
    c.rounds = 12;
    c.kills = vec![KillSpec::new(4, 8, KillStrategy::Random)];
    let r = run(&c);
    assert!(r.rounds.len() < 12, "raft cannot commit with 8/11 dead");
}

/// Fig. 3/4 golden tables render with the right verdicts.
#[test]
fn fig3_fig4_tables() {
    let t3 = figures::fig3();
    assert!(t3.rows[0][3].contains("UNSAFE"));
    assert!(t3.rows[1][3].contains("REJECTED"));
    assert!(t3.rows[2][3].contains("OK"));
    let t4 = figures::fig4();
    for (row, r_expect) in [(1usize, 1.38), (2, 1.19), (3, 1.08)] {
        let r = t4.num(row, "r").unwrap();
        assert!((r - r_expect).abs() < 0.02, "fig4 row {row}: {r} vs {r_expect}");
    }
}

/// Replica convergence holds in a fully tracked run.
#[test]
fn digests_converge_all_replicas() {
    assert!(figures::convergence_check());
}

/// Fig. 20 shape: full table (2 algos × 4 depths), every row commits the
/// whole round budget, depth 1 reproduces the lock-step driver's output on
/// the same seed, and depth ≥ 4 strictly raises committed wall-clock
/// throughput under the Fig. 14 delay model.
#[test]
fn fig20_pipeline_depth_shape() {
    let t = figures::fig20_pipeline_depth(Scale::Quick);
    assert_eq!(t.rows.len(), 2 * figures::FIG20_DEPTHS.len());
    let expected_rounds = Scale::Quick.rounds().to_string();
    for (i, row) in t.rows.iter().enumerate() {
        assert_eq!(row[2], expected_rounds, "row {i}: pipeline stalled");
    }
    for (block, algo) in ["raft", "cab f10%"].iter().enumerate() {
        let base = block * figures::FIG20_DEPTHS.len();
        assert_eq!(t.rows[base][0], *algo);
        let d1 = t.num(base, "wall_tput_ops_s").unwrap();
        let d4 = t.num(base + 2, "wall_tput_ops_s").unwrap();
        let d8 = t.num(base + 3, "wall_tput_ops_s").unwrap();
        assert!(d4 > d1, "{algo}: depth-4 wall tput {d4} !> depth-1 {d1}");
        assert!(d8 > d1, "{algo}: depth-8 wall tput {d8} !> depth-1 {d1}");
    }
}

/// Fig. 21 shape: every snapshot interval completes the full round budget
/// despite the mid-run kill + restart; compaction bounds the retained log
/// where the off-row grows with the run; the tightest interval forces an
/// InstallSnapshot catch-up; and committed wall-clock throughput stays in
/// family with the compaction-off baseline.
#[test]
fn fig21_compaction_shape() {
    let t = figures::fig21_compaction(Scale::Quick);
    let intervals = figures::fig21_intervals(Scale::Quick);
    assert_eq!(t.rows.len(), intervals.len());
    let rounds = Scale::Quick.rounds().max(16).to_string();
    for (i, row) in t.rows.iter().enumerate() {
        assert_eq!(row[1], rounds, "row {i}: rounds incomplete");
    }
    let max_log: Vec<f64> =
        (0..t.rows.len()).map(|i| t.num(i, "max_log").unwrap()).collect();
    assert!(
        max_log[1] < max_log[0],
        "compaction must bound the retained log: {max_log:?}"
    );
    assert!(
        max_log[1] <= (2 + 2 * 4 + 8) as f64,
        "interval-2 retained log too long: {}",
        max_log[1]
    );
    assert!(
        t.num(1, "installs").unwrap() >= 1.0,
        "the restarted follower must catch up via InstallSnapshot"
    );
    let off = t.num(0, "wall_tput_ops_s").unwrap();
    let on = t.num(1, "wall_tput_ops_s").unwrap();
    assert!(
        on > 0.5 * off && on < 2.0 * off,
        "compaction moved committed throughput: off {off} vs on {on}"
    );
}

/// Fig. 22 shape — and the heal-after-partition acceptance criterion in one
/// pass (each cell is ~8× a normal quick figure run, so the criteria are
/// asserted from the one table instead of re-running cells): every row
/// commits its whole round budget through the partition/heal schedule, the
/// safety checker reports zero violations everywhere, and PreVote strictly
/// lowers the term churn on the identical schedule (a healed minority
/// cannot inflate terms and depose the working cabinet).
#[test]
fn fig22_partitions_shape() {
    let t = figures::fig22_partitions(Scale::Quick);
    assert_eq!(t.rows.len(), 4, "2 algos x prevote off/on");
    for (i, row) in t.rows.iter().enumerate() {
        assert_eq!(row[2], "100", "row {i}: rounds incomplete through partitions");
        assert_eq!(
            row[8], "0",
            "row {i}: safety violations under the nemesis schedule"
        );
    }
    for (block, algo) in ["raft", "cab f20%"].iter().enumerate() {
        let base = block * 2;
        assert_eq!(t.rows[base][0], *algo);
        assert_eq!(t.rows[base][1], "off");
        assert_eq!(t.rows[base + 1][1], "on");
        let terms_off = t.num(base, "terms").unwrap();
        let terms_on = t.num(base + 1, "terms").unwrap();
        assert!(
            terms_on < terms_off,
            "{algo}: PreVote must strictly bound term churn ({terms_on} !< {terms_off})"
        );
        let elections_off = t.num(base, "elections").unwrap();
        let elections_on = t.num(base + 1, "elections").unwrap();
        assert!(
            elections_on <= elections_off,
            "{algo}: PreVote must not add candidacies ({elections_on} > {elections_off})"
        );
    }
}

/// Fig. 23 shape — the read-path acceptance criteria in one pass: every row
/// commits its full round budget through the leader-isolation window with
/// zero read-linearizability violations; non-log rows actually serve reads
/// through their fast path; and on YCSB-C the combined throughput satisfies
/// `lease ≥ readindex > log` at every scale, for both quorum rules.
#[test]
fn fig23_read_paths_shape() {
    let t = figures::fig23_read_paths(Scale::Quick);
    // one B cell (n=11) + two C cells (n=5, 11), each 2 algos × 3 paths
    assert_eq!(t.rows.len(), 18);
    for (i, row) in t.rows.iter().enumerate() {
        assert_eq!(row[5], "40", "row {i}: rounds incomplete through the isolation window");
        assert_eq!(row[11], "0", "row {i}: read-linearizability violations");
        match row[3].as_str() {
            "log" => {
                assert_eq!(t.num(i, "reads"), Some(0.0), "row {i}: log path issued reads");
            }
            "readindex" => {
                assert!(t.num(i, "reads").unwrap() > 0.0, "row {i}: no reads served");
                assert!(t.num(i, "ri_rounds").unwrap() > 0.0, "row {i}: no probe rounds");
                assert_eq!(t.num(i, "lease"), Some(0.0), "row {i}: spurious lease serve");
            }
            "lease" => {
                let reads = t.num(i, "reads").unwrap();
                let lease = t.num(i, "lease").unwrap();
                assert!(reads > 0.0, "row {i}: no reads served");
                assert!(
                    lease >= reads / 2.0,
                    "row {i}: lease fast path barely used ({lease} of {reads})"
                );
            }
            other => panic!("row {i}: unknown path {other}"),
        }
    }
    // acceptance: lease ≥ readindex > log on YCSB-C, every scale, both algos
    for base in (0..t.rows.len()).step_by(3) {
        if t.rows[base][0] != "C" {
            continue;
        }
        let log = t.num(base, "tput_ops_s").unwrap();
        let ri = t.num(base + 1, "tput_ops_s").unwrap();
        let lease = t.num(base + 2, "tput_ops_s").unwrap();
        let who = format!("{} n={}", t.rows[base][2], t.rows[base][1]);
        assert!(ri > log, "{who}: readindex {ri} must beat log {log}");
        assert!(lease >= 0.95 * ri, "{who}: lease {lease} must not trail readindex {ri}");
    }
}

/// Fig. 24 acceptance shape: sharding is horizontal scale. Aggregate
/// wall-clock throughput must be non-decreasing in G on the d0 LAN baseline
/// (each group replicates a full-size shard batch; groups overlap on the
/// shared fabric), and the printed D1-100ms table must show the aggregate
/// increasing from G=1 to G=4 at n=11 — the headline acceptance criterion —
/// with every group committing all its rounds and per-shard leaders spread
/// across nodes.
#[test]
fn fig24_sharding_shape() {
    use cabinet::net::delay::DelayModel;

    // d0: non-decreasing aggregate throughput in G
    let d0: Vec<f64> = [1usize, 2, 4]
        .iter()
        .map(|&g| figures::fig24_run(g, DelayModel::None, Scale::Quick).agg_wall_tput_ops_s())
        .collect();
    assert!(
        d0[1] >= d0[0] && d0[2] >= d0[1],
        "d0 aggregate throughput must be non-decreasing in G: {d0:?}"
    );
    assert!(
        d0[2] > 1.5 * d0[0],
        "4 shards on d0 should aggregate well beyond one ({:.0} vs {:.0})",
        d0[2],
        d0[0]
    );

    // the printed D1-100ms table: the acceptance criterion rows
    let t = figures::fig24_sharding(Scale::Quick);
    assert_eq!(t.rows.len(), 4);
    let committed = |i: usize| t.num(i, "committed").unwrap();
    let tput = |i: usize| t.num(i, "agg_tput_ops_s").unwrap();
    for (i, &g) in [1usize, 2, 4, 8].iter().enumerate() {
        assert_eq!(t.rows[i][0], g.to_string());
        assert_eq!(
            committed(i),
            (g as f64) * 12.0,
            "G={g}: every shard must commit its rounds"
        );
    }
    let (g1, g2, g4) = (tput(0), tput(1), tput(2));
    assert!(
        g4 > g1,
        "aggregate throughput must increase from G=1 ({g1:.0}) to G=4 ({g4:.0})"
    );
    assert!(g2 > g1, "G=2 ({g2:.0}) must beat G=1 ({g1:.0})");
    // per-shard leaders spread across the cluster (group g bootstraps
    // node g mod n)
    for (i, &g) in [2usize, 4, 8].iter().enumerate() {
        let leaders = t.num(i + 1, "leaders").unwrap();
        assert!(
            leaders >= (g as f64) / 2.0,
            "G={g}: leaders collapsed onto {leaders} nodes"
        );
    }
}

/// Fig. 25 acceptance shape: the rolling replace of every founding voter
/// completes (all 30 config entries commit), the cluster never stalls
/// longer than one election timeout between commits — replaced leaders
/// cost one failover each, never more — and the config-epoch /
/// joint-quorum-evidence checker stays clean on both rows.
#[test]
fn fig25_membership_shape() {
    let t = figures::fig25_membership(Scale::Quick);
    assert_eq!(t.rows.len(), 2);
    for i in 0..2 {
        assert_eq!(
            t.num(i, "committed").unwrap(),
            60.0,
            "every client round must commit: {:?}",
            t.rows[i]
        );
        assert!(
            t.rows[i][6].starts_with("OK"),
            "safety checker must stay clean: {:?}",
            t.rows[i]
        );
    }
    // steady row: zero config traffic; rolling row: 5 replaces × 6 config
    // entries (join: enter/leave/promote + leave: mark/enter/leave) — at
    // least, since failover re-observations can count entries again
    assert_eq!(t.num(0, "cfg_commits").unwrap(), 0.0);
    assert!(
        t.num(1, "cfg_commits").unwrap() >= 30.0,
        "rolling replace did not complete: {:?}",
        t.rows[1]
    );
    // availability: no commit-to-commit gap beyond one election timeout
    // (the 2500–4000 ms draw) plus commit slack
    let gap = t.num(1, "max_gap_ms").unwrap();
    assert!(gap <= 5000.0, "availability gap {gap} ms exceeds one election timeout");
}

/// Fig. 26 acceptance shape: every row commits all 16 rounds through the
/// mid-run kill + recovery; the WAL-off baseline touches no WAL; every
/// WAL row recovers entries at the restart instead of rebooting amnesiac;
/// and group commit is visible — fsync_group 64 issues strictly fewer
/// fsyncs than syncing every append, and never pays a higher p99.
#[test]
fn fig26_fsync_group_shape() {
    let t = figures::fig26_fsync_group(Scale::Quick);
    assert_eq!(t.rows.len(), 4); // off, 1, 8, 64
    for i in 0..4 {
        assert_eq!(
            t.num(i, "committed").unwrap(),
            16.0,
            "every round must commit through recovery: {:?}",
            t.rows[i]
        );
    }
    assert_eq!(t.num(0, "appends").unwrap(), 0.0, "WAL-off row must not append");
    assert_eq!(t.num(0, "recovered").unwrap(), 0.0);
    for i in 1..4 {
        assert!(t.num(i, "appends").unwrap() > 0.0, "row {i} must append");
        assert!(t.num(i, "fsyncs").unwrap() > 0.0, "row {i} must fsync");
    }
    // per-append durability recovers every committed entry at the restart;
    // larger groups may legitimately lose the unsynced tail (the batching
    // trade-off the figure exists to show) but never recover more
    let r1 = t.num(1, "recovered").unwrap();
    assert!(r1 > 0.0, "fsync_group 1 restart must replay entries: {:?}", t.rows[1]);
    assert!(t.num(3, "recovered").unwrap() <= r1, "batching cannot recover more than group 1");
    let every = t.num(1, "fsyncs").unwrap();
    let batched = t.num(3, "fsyncs").unwrap();
    assert!(
        batched < every,
        "group commit must batch fsyncs: {batched} at group 64 vs {every} at group 1"
    );
    assert!(
        t.num(1, "p99_ms").unwrap() >= t.num(3, "p99_ms").unwrap(),
        "per-append fsync must not beat group commit on p99: {:?} vs {:?}",
        t.rows[1],
        t.rows[3]
    );
}

/// Fig. 27 acceptance shape: on the 25 MB/s bandwidth-constrained model,
/// values below the adaptive cutover (35 KB at this bandwidth) take the
/// full-copy path on both variants — identical wire traffic — while at
/// 64 KiB and above the coded variant ships shards instead of full copies
/// and must beat full-copy on both bytes/op (toward 1/k) and committed
/// wall-clock throughput (transfer time dominates the round trip).
#[test]
fn fig27_coded_replication_shape() {
    let t = figures::fig27_value_size(Scale::Quick);
    let sizes = figures::fig27_value_sizes(Scale::Quick);
    // 2 algos × {full, coded} per value size
    assert_eq!(t.rows.len(), 4 * sizes.len());
    let cutover = cabinet::consensus::coding::adaptive_cutover(25_000.0);
    for (i, &vs) in sizes.iter().enumerate() {
        let base = i * 4;
        assert_eq!(t.rows[base][1], "raft full");
        assert_eq!(t.rows[base + 1][1], "raft coded");
        assert_eq!(t.rows[base + 2][1], "cab f20% full");
        assert_eq!(t.rows[base + 3][1], "cab f20% coded");
        for off in [0usize, 2] {
            let row_full = base + off;
            let row_coded = base + off + 1;
            // full-copy rows carry no cutover; coded rows resolve the
            // adaptive one from the configured bandwidth
            assert_eq!(t.rows[row_full][5], "-", "row {row_full}: cutover on full");
            assert_eq!(
                t.rows[row_coded][5],
                cutover.to_string(),
                "row {row_coded}: adaptive cutover mismatch"
            );
            let full = t.num(row_full, "bytes_per_op").unwrap();
            let coded = t.num(row_coded, "bytes_per_op").unwrap();
            let who = &t.rows[row_coded][1];
            // the gate sees the whole batch payload's wire size (batch 16),
            // not the single-value size
            let wire = (12 + vs) * 16 + 16;
            if wire < cutover {
                // below the cutover the coded variant is the full-copy
                // path bit-for-bit — identical delivered traffic
                assert!(
                    (full - coded).abs() < 0.5,
                    "{who} @ {vs}B (batch wire {wire}B) below cutover diverged: {full} vs {coded}"
                );
            } else {
                assert!(
                    coded < 0.8 * full,
                    "{who} @ {vs}B: coded {coded} B/op must undercut full {full} B/op"
                );
                let tput_full = t.num(row_full, "wall_tput_ops_s").unwrap();
                let tput_coded = t.num(row_coded, "wall_tput_ops_s").unwrap();
                assert!(
                    tput_coded > tput_full,
                    "{who} @ {vs}B: coded tput {tput_coded} must beat full {tput_full}"
                );
            }
        }
    }
}

/// The `[storage]` table round-trips through the TOML config path into a
/// running simulation: the WAL runs, the scheduled kill + restart recovers
/// from the simulated disk, and every round still commits.
#[test]
fn storage_config_roundtrip_runs_and_recovers() {
    let cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 1\nn = 7\nrounds = 14\n\
         [workload]\nkind = \"ycsb\"\nworkload = \"A\"\nbatch = 300\n\
         [faults]\nrestart_kill_round = 3\nrestart_round = 8\n\
         [storage]\nfsync_group = 1\nfsync_ms = 0.4\n",
    )
    .unwrap();
    let st = cfg.storage.expect("storage spec parsed");
    assert_eq!(st.fsync_group, 1);
    assert!(!st.torn_writes);
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 14, "TOML-built storage config must complete");
    assert!(r.wal_appends > 0 && r.wal_fsyncs > 0);
    assert!(r.wal_recoveries >= 1, "the restart must recover from the WAL");
    assert!(r.wal_recovered_entries > 0);
}

/// The `[membership]` table round-trips through the TOML config path into a
/// running simulation: the scheduled join commits, epochs advance, and the
/// checker validates the config decisions it recorded.
#[test]
fn membership_config_roundtrip_runs_clean() {
    let mut cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 1\nn = 7\nrounds = 14\n\
         [workload]\nkind = \"ycsb\"\nworkload = \"A\"\nbatch = 300\n\
         [membership]\nmembers = 5\ndrain_rounds = 2\njoin_warmup = 1\n\
         events = [\"3=join:5\", \"8=leave:0\"]\n",
    )
    .unwrap();
    assert!(cfg.membership_on());
    cfg.track_safety = true;
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 14, "TOML-built membership config must complete");
    assert!(r.config_commits >= 6, "join + leave must both settle: {}", r.config_commits);
    let report = cabinet::bench::safety_check(r.safety.as_ref().unwrap());
    assert!(report.is_clean(), "{:?}", report.violations);
    assert!(report.epochs_checked >= 6);
    assert!(report.evidence_checked > 0);
}

/// The `[sharding]` table round-trips through the TOML config path, a
/// TOML-built sharded run completes with per-group rollups, and invalid
/// layouts are rejected.
#[test]
fn sharding_config_roundtrip_and_rejection() {
    use cabinet::workload::ShardBy;
    let cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 2\nn = 11\nrounds = 4\n\
         [workload]\nkind = \"ycsb\"\nworkload = \"A\"\nbatch = 300\n\
         [sharding]\ngroups = 4\nshard_by = \"hash\"\n",
    )
    .unwrap();
    assert_eq!(cfg.groups, 4);
    assert_eq!(cfg.shard_by, Some(ShardBy::KeyHash));
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 4 * 4, "TOML-built sharded config must complete");
    assert_eq!(r.group_stats.len(), 4);
    assert!(r.agg_wall_tput_ops_s() > 0.0);

    // warehouse-range sharding for TPC-C
    let cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 1\nn = 5\nrounds = 3\n\
         [workload]\nkind = \"tpcc\"\nwarehouses = 10\nbatch = 200\n\
         [sharding]\ngroups = 2\nshard_by = \"warehouse\"\n",
    )
    .unwrap();
    assert_eq!(cfg.effective_shard_by(), ShardBy::Warehouse);
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 2 * 3);

    // rejections: bad counts, over-sharding, mismatched dimension, HQC
    let bad = [
        "[sharding]\ngroups = 0\n",
        "n = 5\n[sharding]\ngroups = 6\n",
        "n = 5\n[workload]\nkind = \"ycsb\"\nrecords = 2\n[sharding]\ngroups = 3\n",
        "n = 5\n[workload]\nkind = \"tpcc\"\nwarehouses = 2\n[sharding]\ngroups = 3\n",
        "[sharding]\ngroups = 2\nshard_by = \"warehouse\"\n",
        "n = 8\n[workload]\nkind = \"tpcc\"\nwarehouses = 8\n[sharding]\ngroups = 2\nshard_by = \"hash\"\n",
        "protocol = \"hqc\"\nn = 9\nsizes = [3, 3, 3]\n[sharding]\ngroups = 3\n",
        "n = 11\n[sharding]\ngroups = 2\n[nemesis]\ndrop_p = 0.05\ngroups = [5]\n",
    ];
    for toml in bad {
        assert!(
            cabinet::config::sim_config_from_toml(toml).is_err(),
            "should have been rejected:\n{toml}"
        );
    }
}

/// The `read_path`/`lease_drift_ms` knobs round-trip through the TOML config
/// path, a TOML-built read-path run actually serves reads cleanly, and bad
/// values are rejected.
#[test]
fn read_path_config_roundtrip_and_rejection() {
    let mut cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 1\nn = 5\nrounds = 6\nread_path = \"lease\"\n\
         lease_drift_ms = 60\n[workload]\nkind = \"ycsb\"\nworkload = \"B\"\nbatch = 300\n",
    )
    .unwrap();
    assert_eq!(cfg.read_path, ReadPath::Lease);
    assert_eq!(cfg.lease_drift_ms, 60.0);
    cfg.track_safety = true;
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 6, "TOML-built read-path config must complete");
    assert!(r.reads_served > 0, "the read path must have served reads");
    let report = cabinet::bench::safety_check(r.safety.as_ref().unwrap());
    assert!(report.is_clean(), "{:?}", report.violations);
    assert!(report.reads_checked > 0);
    // rejected: unknown path, drift swallowing the entire lease bound
    assert!(cabinet::config::sim_config_from_toml("read_path = \"quorum\"\n").is_err());
    assert!(cabinet::config::sim_config_from_toml(
        "read_path = \"lease\"\nlease_drift_ms = 99999\n"
    )
    .is_err());
}

/// The `[nemesis]` table and `pre_vote` knob round-trip through the TOML
/// config path, and invalid schedules are rejected.
#[test]
fn nemesis_config_roundtrip_and_rejection() {
    use cabinet::net::nemesis::PartitionKind;
    let cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 2\nn = 11\nrounds = 9\npre_vote = true\n\
         [nemesis]\ndrop_p = 0.03\ndup_p = 0.02\nreorder_p = 0.05\nreorder_max_ms = 25\n\
         partitions = [\"500..1500=followers:2\", \"2000..2500=oneway:1,2\"]\n",
    )
    .unwrap();
    assert!(cfg.pre_vote);
    let nm = cfg.nemesis.as_ref().unwrap();
    assert_eq!(nm.drop_p, 0.03);
    assert_eq!(nm.reorder_max_ms, 25.0);
    assert_eq!(nm.partitions[0].kind, PartitionKind::Followers { count: 2 });
    assert_eq!(nm.partitions[1].kind, PartitionKind::OneWay { group: vec![1, 2] });
    // a TOML-built nemesis config must actually run
    let mut cfg = cfg;
    cfg.workload = WorkloadSpec::ycsb(Workload::A, 300);
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 9, "TOML-built nemesis config must complete");
    assert!(r.nemesis_stats.is_some());

    // rejection: overlapping windows, probability out of range, bad ids
    assert!(cabinet::config::sim_config_from_toml(
        "[nemesis]\npartitions = [\"0..1000=leader\", \"500..2000=followers:1\"]\n"
    )
    .is_err());
    assert!(cabinet::config::sim_config_from_toml("[nemesis]\ndrop_p = 1.01\n").is_err());
    assert!(cabinet::config::sim_config_from_toml("[nemesis]\ndrop_p = -0.1\n").is_err());
    // reorder_p without a positive delay bound is a silent no-op — rejected
    assert!(cabinet::config::sim_config_from_toml("[nemesis]\nreorder_p = 0.1\n").is_err());
    assert!(
        cabinet::config::sim_config_from_toml("n = 5\n[nemesis]\npartitions = [\"0..9=split:7\"]\n")
            .is_err()
    );
}

/// The snapshot knobs round-trip through the TOML config path.
#[test]
fn snapshot_config_roundtrip() {
    let cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 2\nn = 11\nsnapshot_every = 32\nrounds = 9\n\
         [faults]\nrestart_kill_round = 3\nrestart_round = 6\n",
    )
    .unwrap();
    assert_eq!(cfg.snapshot_every, Some(32));
    let rs = cfg.restart.unwrap();
    assert_eq!((rs.kill_round, rs.restart_round), (3, 6));
}

// Note: "depth 1 reproduces the lock-step driver" holds by construction —
// `sim::group::GroupEngine` keeps the frozen lock-step window as its own
// branch (`pipeline <= 1`), transplanted line-for-line from the historical
// driver — so there is deliberately no test comparing depth-1 runs against
// each other; such a comparison is tautological. The same applies to
// "groups = 1 reproduces the single-group driver": the scheduler steps one
// engine whose fork order and push order are the historical ones, and the
// whole replay/nemesis determinism suite runs through that path.

/// The `pipeline` knob round-trips through the TOML config path.
#[test]
fn pipeline_config_roundtrip() {
    let cfg = cabinet::config::sim_config_from_toml(
        "protocol = \"cabinet\"\nt = 2\nn = 11\npipeline = 4\nrounds = 9\n",
    )
    .unwrap();
    assert_eq!(cfg.pipeline, 4);
    assert_eq!(cfg.rounds, 9);
    let r = run(&cfg);
    assert_eq!(r.rounds.len(), 9, "TOML-built pipelined config must run");
    // default stays lock-step; invalid depths are rejected
    let d = cabinet::config::sim_config_from_toml("protocol = \"raft\"\n").unwrap();
    assert_eq!(d.pipeline, 1);
    assert!(cabinet::config::sim_config_from_toml("pipeline = 0\n").is_err());
}

/// Ablation: dynamic reassignment (P2) must clearly beat frozen weights
/// under rotating delays.
#[test]
fn ablation_reassignment_matters() {
    let mk = |static_w: bool| {
        let mut c = quick(Protocol::Cabinet { t: 5 }, 50, true);
        c.rounds = 24;
        c.delay = DelayModel::Rotating { period_rounds: 6 };
        c.static_weights = static_w;
        run(&c)
    };
    let dynamic = mk(false);
    let frozen = mk(true);
    assert!(
        dynamic.tput_ops_s > 1.5 * frozen.tput_ops_s,
        "P2 gain missing: dynamic {} vs static {}",
        dynamic.tput_ops_s,
        frozen.tput_ops_s
    );
}
