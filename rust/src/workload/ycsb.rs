//! YCSB core workloads A–F (Cooper et al., SoCC'10), as used in §5.
//!
//! Each workload is a distribution over four op types (READ / UPDATE / SCAN
//! / INSERT, plus READ-MODIFY-WRITE for F) with zipfian (θ = 0.99) or latest
//! key popularity. Batches are generated as flat u32 arrays — exactly the
//! layout the AOT `ycsb_apply` artifact consumes (see
//! `python/compile/kernels/__init__.py` for the shared spec).

use crate::net::rng::{Rng, Zipfian};

/// Op codes — shared spec with the Pallas kernel (`kernels.OP_*`).
pub const OP_READ: u32 = 0;
pub const OP_UPDATE: u32 = 1;
pub const OP_SCAN: u32 = 2;
pub const OP_INSERT: u32 = 3;
pub const OP_RMW: u32 = 4;
pub const OP_NOP: u32 = 5;

/// The six standard YCSB workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Workload {
    /// A — update heavy: 50% read, 50% update, zipfian.
    A,
    /// B — read mostly: 95% read, 5% update, zipfian.
    B,
    /// C — read only: 100% read, zipfian.
    C,
    /// D — read latest: 95% read, 5% insert, latest distribution.
    D,
    /// E — short ranges: 95% scan, 5% insert, zipfian.
    E,
    /// F — read-modify-write: 50% read, 50% RMW, zipfian.
    F,
}

impl Workload {
    pub const ALL: [Workload; 6] =
        [Workload::A, Workload::B, Workload::C, Workload::D, Workload::E, Workload::F];

    pub fn name(self) -> &'static str {
        match self {
            Workload::A => "A",
            Workload::B => "B",
            Workload::C => "C",
            Workload::D => "D",
            Workload::E => "E",
            Workload::F => "F",
        }
    }

    pub fn from_name(s: &str) -> Option<Workload> {
        Self::ALL.iter().copied().find(|w| w.name().eq_ignore_ascii_case(s))
    }

    /// (read, update, scan, insert, rmw) proportions per the YCSB spec.
    pub fn mix(self) -> [f64; 5] {
        match self {
            Workload::A => [0.50, 0.50, 0.0, 0.0, 0.0],
            Workload::B => [0.95, 0.05, 0.0, 0.0, 0.0],
            Workload::C => [1.00, 0.0, 0.0, 0.0, 0.0],
            Workload::D => [0.95, 0.0, 0.0, 0.05, 0.0],
            Workload::E => [0.0, 0.0, 0.95, 0.05, 0.0],
            Workload::F => [0.50, 0.0, 0.0, 0.0, 0.50],
        }
    }

    /// Write fraction (ops that mutate replica state).
    pub fn write_fraction(self) -> f64 {
        let m = self.mix();
        m[1] + m[3] + m[4]
    }
}

/// One generated op batch in kernel layout (struct-of-arrays).
#[derive(Clone, Debug, PartialEq)]
pub struct YcsbBatch {
    pub workload: Workload,
    pub ops: Vec<u32>,
    pub keys: Vec<u32>,
    pub vals: Vec<u32>,
    /// Modeled value size in bytes per op — the data-heavy dimension
    /// (1 KB–1 MB in fig27). Values stay one u32 seed word in memory; the
    /// wire/bandwidth model charges `12 + value_size` bytes per op. 0 (the
    /// default every generator emits) reproduces the historical
    /// `12·len + 16` wire model byte-for-byte.
    pub value_size: u64,
}

impl YcsbBatch {
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Count of live (non-NOP) ops.
    pub fn live_ops(&self) -> usize {
        self.ops.iter().filter(|&&o| o < OP_NOP).count()
    }

    /// Pad (with NOPs) or truncate to exactly `n` ops — the fixed batch
    /// shape the AOT artifact expects.
    pub fn padded_to(&self, n: usize) -> YcsbBatch {
        let mut b = self.clone();
        b.ops.resize(n, OP_NOP);
        b.keys.resize(n, 0);
        b.vals.resize(n, 0);
        b
    }
}

/// YCSB batch generator: zipfian (or latest) keys over a keyspace.
#[derive(Clone, Debug)]
pub struct YcsbGen {
    workload: Workload,
    zipf: Zipfian,
    rng: Rng,
    record_count: u64,
    insert_seq: u64,
}

impl YcsbGen {
    /// YCSB defaults: θ = 0.99 over `record_count` keys.
    pub fn new(workload: Workload, record_count: u64, seed: u64) -> Self {
        YcsbGen {
            workload,
            zipf: Zipfian::new(record_count, 0.99),
            rng: Rng::new(seed),
            record_count,
            insert_seq: record_count,
        }
    }

    fn next_key(&mut self) -> u32 {
        match self.workload {
            // D: "latest" — skewed towards recently inserted records.
            Workload::D => {
                let back = self.zipf.sample(&mut self.rng);
                (self.insert_seq.saturating_sub(1 + back)) as u32
            }
            _ => self.zipf.sample(&mut self.rng) as u32,
        }
    }

    fn next_op(&mut self) -> u32 {
        let m = self.workload.mix();
        let x = self.rng.f64();
        let mut acc = 0.0;
        for (code, share) in [OP_READ, OP_UPDATE, OP_SCAN, OP_INSERT, OP_RMW]
            .into_iter()
            .zip(m)
        {
            acc += share;
            if x < acc {
                return code;
            }
        }
        OP_READ
    }

    /// Generate a batch of exactly `size` live ops.
    pub fn batch(&mut self, size: usize) -> YcsbBatch {
        let mut ops = Vec::with_capacity(size);
        let mut keys = Vec::with_capacity(size);
        let mut vals = Vec::with_capacity(size);
        for _ in 0..size {
            let op = self.next_op();
            let key = if op == OP_INSERT {
                let k = self.insert_seq as u32;
                self.insert_seq += 1;
                k
            } else {
                self.next_key()
            };
            ops.push(op);
            keys.push(key);
            vals.push(self.rng.next_u32());
        }
        YcsbBatch { workload: self.workload, ops, keys, vals, value_size: 0 }
    }

    /// Generate a batch of exactly `size` live ops restricted to shard
    /// `group` of `groups` under the hash partition
    /// ([`crate::workload::shard::key_shard`]) — the per-group load for a
    /// sharded deployment, modelling each shard serving its own clients.
    ///
    /// Keys are rejection-sampled from the workload's own distribution, so
    /// within a shard the popularity skew matches the unsharded workload.
    /// After a bounded number of rejections the draw falls back to a
    /// deterministic linear probe of the keyspace; the probe always
    /// terminates because `groups <= record_count` (a config-parse
    /// invariant) and [`key_shard`](crate::workload::shard::key_shard) pins
    /// keys `0..groups` round-robin, so every shard owns at least one key
    /// inside the probed cycle. Inserts advance the shared fresh-key
    /// sequence until it lands in this shard — `key_shard`'s per-block
    /// pinning bounds that ascending scan at G² steps (in practice ~G),
    /// mirroring what the other groups' generators skip. With
    /// `groups <= 1` this is exactly [`YcsbGen::batch`].
    pub fn batch_sharded(&mut self, size: usize, group: usize, groups: usize) -> YcsbBatch {
        use crate::workload::shard::key_shard;
        if groups <= 1 {
            return self.batch(size);
        }
        debug_assert!(group < groups);
        debug_assert!(groups as u64 <= self.record_count, "groups exceed key count");
        let mut ops = Vec::with_capacity(size);
        let mut keys = Vec::with_capacity(size);
        let mut vals = Vec::with_capacity(size);
        for _ in 0..size {
            let op = self.next_op();
            let key = if op == OP_INSERT {
                loop {
                    let k = self.insert_seq as u32;
                    self.insert_seq += 1;
                    if key_shard(k, groups) == group {
                        break k;
                    }
                }
            } else {
                let mut k = self.next_key();
                let mut rejects = 0usize;
                while key_shard(k, groups) != group {
                    rejects += 1;
                    if rejects < 64 {
                        k = self.next_key();
                    } else {
                        // deterministic fallback: walk the keyspace
                        k = ((k as u64 + 1) % self.record_count) as u32;
                    }
                }
                k
            };
            ops.push(op);
            keys.push(key);
            vals.push(self.rng.next_u32());
        }
        YcsbBatch { workload: self.workload, ops, keys, vals, value_size: 0 }
    }

    pub fn record_count(&self) -> u64 {
        self.record_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op_shares(b: &YcsbBatch) -> [f64; 5] {
        let mut counts = [0usize; 5];
        for &o in &b.ops {
            counts[o as usize] += 1;
        }
        counts.map(|c| c as f64 / b.len() as f64)
    }

    #[test]
    fn mixes_sum_to_one() {
        for w in Workload::ALL {
            let s: f64 = w.mix().iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "{w:?}");
        }
    }

    #[test]
    fn workload_a_is_half_updates() {
        let mut g = YcsbGen::new(Workload::A, 100_000, 1);
        let b = g.batch(20_000);
        let s = op_shares(&b);
        assert!((s[OP_READ as usize] - 0.5).abs() < 0.02);
        assert!((s[OP_UPDATE as usize] - 0.5).abs() < 0.02);
    }

    #[test]
    fn workload_c_is_read_only() {
        let mut g = YcsbGen::new(Workload::C, 1000, 2);
        let b = g.batch(5000);
        assert!(b.ops.iter().all(|&o| o == OP_READ));
    }

    #[test]
    fn workload_e_is_scan_heavy() {
        let mut g = YcsbGen::new(Workload::E, 1000, 3);
        let b = g.batch(20_000);
        let s = op_shares(&b);
        assert!((s[OP_SCAN as usize] - 0.95).abs() < 0.02);
        assert!((s[OP_INSERT as usize] - 0.05).abs() < 0.02);
    }

    #[test]
    fn workload_f_has_rmw() {
        let mut g = YcsbGen::new(Workload::F, 1000, 4);
        let b = g.batch(20_000);
        let s = op_shares(&b);
        assert!((s[OP_RMW as usize] - 0.5).abs() < 0.02);
    }

    #[test]
    fn inserts_use_fresh_keys() {
        let mut g = YcsbGen::new(Workload::D, 1000, 5);
        let b = g.batch(10_000);
        let inserted: Vec<u32> = b
            .ops
            .iter()
            .zip(&b.keys)
            .filter(|(o, _)| **o == OP_INSERT)
            .map(|(_, k)| *k)
            .collect();
        let mut sorted = inserted.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), inserted.len(), "insert keys must be unique");
        assert!(inserted.iter().all(|&k| k >= 1000));
    }

    #[test]
    fn zipfian_keys_are_skewed() {
        let mut g = YcsbGen::new(Workload::A, 10_000, 6);
        let b = g.batch(50_000);
        let hot = b.keys.iter().filter(|&&k| k < 100).count();
        assert!(hot as f64 > 0.3 * b.len() as f64, "hot={hot}");
    }

    #[test]
    fn deterministic_given_seed() {
        let b1 = YcsbGen::new(Workload::A, 1000, 7).batch(100);
        let b2 = YcsbGen::new(Workload::A, 1000, 7).batch(100);
        assert_eq!(b1, b2);
    }

    #[test]
    fn padding_adds_nops() {
        let mut g = YcsbGen::new(Workload::B, 1000, 8);
        let b = g.batch(100).padded_to(256);
        assert_eq!(b.len(), 256);
        assert_eq!(b.live_ops(), 100);
        assert!(b.ops[100..].iter().all(|&o| o == OP_NOP));
    }

    #[test]
    fn padding_truncates_too() {
        let mut g = YcsbGen::new(Workload::B, 1000, 9);
        let b = g.batch(300).padded_to(256);
        assert_eq!(b.len(), 256);
    }

    #[test]
    fn sharded_batch_stays_in_shard() {
        use crate::workload::shard::key_shard;
        let groups = 4;
        for group in 0..groups {
            // D exercises inserts + the latest distribution
            for wl in [Workload::A, Workload::D] {
                let mut g = YcsbGen::new(wl, 1000, 10 + group as u64);
                let b = g.batch_sharded(2000, group, groups);
                assert_eq!(b.len(), 2000);
                assert!(
                    b.keys.iter().all(|&k| key_shard(k, groups) == group),
                    "{wl:?}: key escaped shard {group}"
                );
            }
        }
    }

    #[test]
    fn sharded_single_group_is_plain_batch() {
        // groups = 1 must consume the RNG identically to batch() — the
        // sharded sim's G=1 bit-for-bit guarantee leans on this
        let a = YcsbGen::new(Workload::A, 1000, 11).batch(500);
        let b = YcsbGen::new(Workload::A, 1000, 11).batch_sharded(500, 0, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn sharded_batch_deterministic() {
        let a = YcsbGen::new(Workload::B, 1000, 12).batch_sharded(300, 2, 4);
        let b = YcsbGen::new(Workload::B, 1000, 12).batch_sharded(300, 2, 4);
        assert_eq!(a, b);
    }
}
