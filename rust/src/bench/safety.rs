//! The deterministic safety checker: validates the evidence a run leaves
//! behind ([`sim::SafetyLog`] — per-node commit sequences plus per-term
//! leadership observations) against the three properties every
//! adversarial-network scenario must preserve:
//!
//! 1. **Prefix consistency** — no two nodes ever commit different terms at
//!    the same log index (Theorem 4.2 / Raft's State Machine Safety), and
//!    each node's committed indices form a strictly increasing sequence
//!    (no replays; forward jumps are legitimate — an installed snapshot
//!    covers its prefix without re-emitting commits).
//! 2. **Single leader per term** — at most one node ever establishes
//!    leadership in any given term (Election Safety).
//! 3. **Monotone applied state** — a node's commit index never regresses
//!    (a duplicated or reordered InstallSnapshot / AppendEntries must not
//!    rewind what was applied).
//! 4. **Read linearizability** — every read served through a non-log read
//!    path (ReadIndex or leader lease) observes a read index that is at
//!    least every write completed *strictly before* the read was invoked
//!    (no stale reads — the property an expired lease on a deposed leader
//!    would break) and at most the highest index committed by the time the
//!    read was served (no reading uncommitted futures).
//! 5. **Weighted-rule evidence across config epochs** — every
//!    leader-observed round commit closed strictly above the commit
//!    threshold of the config it was proposed under, including the *old*
//!    half when that config was joint (a commit that satisfied only one
//!    half of C_old,new is a membership-change split brain), and the
//!    propose-time epochs are non-decreasing along the log.
//! 6. **Config-epoch coherence** — every committed config entry decides one
//!    (epoch, joint) pair per log index across all observers, and epochs
//!    never regress along the log.
//! 7. **One vote per term** — a voter grants at most one candidate in any
//!    term (Raft's vote-persistence invariant). An amnesiac restart that
//!    forgets `voted_for` and re-grants the same term to a second candidate
//!    is exactly the double-vote the durable WAL (`storage::wal`) closes.
//! 8. **Coded reconstruction** — every commit of a coded round carries a
//!    shard set of at least `k` distinct shards (`consensus::coding`'s
//!    k-of-m property). A coded round that closed its weighted quorum with
//!    only `k − 1` distinct shards committed an entry no follower set can
//!    reconstruct — durability theater, flagged even though the weight
//!    cleared CT.
//!
//! The checker is pure data → verdict: the simulator collects the log when
//! `SimConfig::track_safety` is set, the chaos harness in
//! `rust/tests/consensus_safety.rs` assembles one by hand, and fig22 runs
//! it over every row it prints.

use crate::sim::SafetyLog;

/// The checker's verdict: every violated property, spelled out.
#[derive(Clone, Debug)]
pub struct SafetyReport {
    /// Human-readable violations; empty = the run was safe.
    pub violations: Vec<String>,
    /// Total commit records examined.
    pub commits_checked: usize,
    /// Distinct (index → term) decisions reconciled across nodes.
    pub decisions: usize,
    /// Leadership establishments examined.
    pub leaders_checked: usize,
    /// Linearizable reads validated against the commit timeline.
    pub reads_checked: usize,
    /// Per-commit quorum-evidence records validated (weighted rule, both
    /// halves of a joint config).
    pub evidence_checked: usize,
    /// Distinct committed config entries validated for epoch coherence.
    pub epochs_checked: usize,
    /// Vote grants validated for one-candidate-per-(term, voter).
    pub votes_checked: usize,
}

impl SafetyReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Validate a run's safety evidence. See the module docs for the property
/// list. Returns every violation found (never panics — callers assert).
pub fn check(log: &SafetyLog) -> SafetyReport {
    let mut violations = Vec::new();
    let mut commits_checked = 0usize;

    // 1a + 3: per-node commit sequences are strictly increasing by index —
    // commit order is apply order, so this is both "no gaps below a later
    // commit on the same node" and "applied state never regresses".
    for (node, commits) in log.commits.iter().enumerate() {
        commits_checked += commits.len();
        for w in commits.windows(2) {
            if w[1].0 <= w[0].0 {
                violations.push(format!(
                    "node {node}: commit index regressed {} -> {} (terms {} -> {})",
                    w[0].0, w[1].0, w[0].1, w[1].1
                ));
            }
        }
    }

    // 1b: cross-node prefix consistency — one decided term per index.
    // (index, term, first decider) sorted by index; a second term at the
    // same index is a split-brain decision.
    let mut decided: Vec<(u64, u64, usize)> = Vec::new();
    for (node, commits) in log.commits.iter().enumerate() {
        for &(index, term) in commits {
            decided.push((index, term, node));
        }
    }
    decided.sort_unstable();
    let mut decisions = 0usize;
    let mut i = 0;
    while i < decided.len() {
        let (index, term, node) = decided[i];
        decisions += 1;
        let mut j = i + 1;
        while j < decided.len() && decided[j].0 == index {
            if decided[j].1 != term {
                violations.push(format!(
                    "index {index}: node {node} committed term {term} but node {} \
                     committed term {}",
                    decided[j].2, decided[j].1
                ));
                // report each divergent pair once, not once per replica
                break;
            }
            j += 1;
        }
        while j < decided.len() && decided[j].0 == index {
            j += 1;
        }
        i = j;
    }

    // 4: read linearizability. Build the running-max commit timeline (commit
    // times can interleave across leader changes), then check every read
    // against its invocation-time floor and response-time ceiling.
    let mut timeline: Vec<(f64, u64)> = log.commit_times.clone();
    timeline.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut time_axis: Vec<f64> = Vec::with_capacity(timeline.len());
    let mut max_idx: Vec<u64> = Vec::with_capacity(timeline.len());
    let mut running = 0u64;
    for (t, i) in &timeline {
        running = running.max(*i);
        time_axis.push(*t);
        max_idx.push(running);
    }
    // highest index committed at a time satisfying `pred` (strictly-before
    // for the invocation floor, at-or-before for the response ceiling —
    // writes concurrent with the read may legitimately land on either side)
    let committed = |t: f64, strict: bool| -> u64 {
        let k = if strict {
            time_axis.partition_point(|&x| x < t)
        } else {
            time_axis.partition_point(|&x| x <= t)
        };
        if k == 0 {
            0
        } else {
            max_idx[k - 1]
        }
    };
    let mut reads_checked = 0usize;
    for r in &log.reads {
        reads_checked += 1;
        let floor = committed(r.invoked_ms, true);
        if r.read_index < floor {
            violations.push(format!(
                "read {} at node {}: STALE — read_index {} < {} committed before \
                 invocation at {:.1} ms (lease = {})",
                r.id, r.node, r.read_index, floor, r.invoked_ms, r.lease
            ));
        }
        let ceiling = committed(r.served_ms, false);
        if r.read_index > ceiling {
            violations.push(format!(
                "read {} at node {}: read_index {} beyond {} committed by its \
                 response at {:.1} ms",
                r.id, r.node, r.read_index, ceiling, r.served_ms
            ));
        }
    }

    // 5: weighted-rule evidence — every recorded commit closed strictly
    // above its propose-time threshold, in both halves when the config was
    // joint. Negated comparisons so a NaN accumulator fails the check
    // instead of slipping past it.
    let mut evidence_checked = 0usize;
    for e in &log.commit_evidence {
        evidence_checked += 1;
        if !(e.acc > e.ct) {
            violations.push(format!(
                "index {}: committed with quorum weight {} <= threshold {} (epoch {})",
                e.index, e.acc, e.ct, e.epoch
            ));
        }
        if let Some((jacc, jct)) = e.joint {
            if !(jacc > jct) {
                violations.push(format!(
                    "index {}: joint commit old-half weight {jacc} <= threshold {jct} \
                     (epoch {})",
                    e.index, e.epoch
                ));
            }
        }
        // 8: coded reconstruction — a coded round's acked shard set must
        // reach k distinct shards or the committed entry is unrecoverable
        if let Some((distinct, k)) = e.coded {
            if distinct < k {
                violations.push(format!(
                    "index {}: coded commit with only {distinct} distinct shard(s) \
                     acked < k = {k} — entry cannot be reconstructed (epoch {})",
                    e.index, e.epoch
                ));
            }
        }
    }
    // propose-time epochs are non-decreasing along the log: an entry at a
    // higher index can never have been proposed under an older config
    let mut ev_epochs: Vec<(u64, u64)> =
        log.commit_evidence.iter().map(|e| (e.index, e.epoch)).collect();
    ev_epochs.sort_unstable();
    ev_epochs.dedup();
    for w in ev_epochs.windows(2) {
        if w[1].0 == w[0].0 {
            violations.push(format!(
                "index {}: committed under two epochs ({} and {})",
                w[0].0, w[0].1, w[1].1
            ));
        } else if w[1].1 < w[0].1 {
            violations.push(format!(
                "propose epoch regressed {} -> {} (indices {} -> {})",
                w[0].1, w[1].1, w[0].0, w[1].0
            ));
        }
    }

    // 6: config-epoch coherence — one (epoch, joint) decision per config
    // index across every observer, epochs monotone along the log.
    let mut cfg: Vec<(u64, u64, bool)> = log.config_epochs.clone();
    // sort by index first; identical observations from different nodes
    // collapse to one record
    cfg.sort_unstable_by_key(|&(epoch, index, joint)| (index, epoch, joint));
    cfg.dedup();
    let epochs_checked = cfg.len();
    for w in cfg.windows(2) {
        let (e0, i0, _) = w[0];
        let (e1, i1, _) = w[1];
        if i1 == i0 {
            violations.push(format!(
                "config index {i0}: divergent decisions (epoch {e0} vs epoch {e1})"
            ));
        } else if e1 < e0 {
            violations.push(format!(
                "config epoch regressed {e0} -> {e1} (indices {i0} -> {i1})"
            ));
        }
    }

    // 7: one vote per term — each (term, voter) pair grants at most one
    // candidate. Re-granting the *same* candidate is a legitimate reply
    // retransmit; a different candidate is the restart-amnesia double vote.
    let votes_checked = log.votes.len();
    let mut granted: Vec<(u64, usize, usize)> = Vec::new();
    for &(term, voter, candidate) in &log.votes {
        match granted.iter().find(|(t, v, _)| *t == term && *v == voter) {
            Some(&(_, _, prev)) if prev != candidate => {
                violations.push(format!(
                    "term {term}: node {voter} voted for both node {prev} and node \
                     {candidate} (double vote — amnesiac restart?)"
                ));
            }
            Some(_) => {} // duplicate grant to the same candidate is fine
            None => granted.push((term, voter, candidate)),
        }
    }

    // 2: single leader per term.
    let mut by_term: Vec<(u64, usize)> = Vec::new();
    for &(term, node) in &log.leaders {
        match by_term.iter().find(|(t, _)| *t == term) {
            Some(&(_, prev)) if prev != node => {
                violations.push(format!(
                    "term {term}: both node {prev} and node {node} became leader"
                ));
            }
            Some(_) => {} // re-observing the same leader is fine
            None => by_term.push((term, node)),
        }
    }

    SafetyReport {
        violations,
        commits_checked,
        decisions,
        leaders_checked: log.leaders.len(),
        reads_checked,
        evidence_checked,
        epochs_checked,
        votes_checked,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::sim::ReadRecord;

    fn log2(a: Vec<(u64, u64)>, b: Vec<(u64, u64)>) -> SafetyLog {
        let mut log = SafetyLog::new(2);
        log.commits = vec![a, b];
        log
    }

    fn read(invoked: f64, served: f64, read_index: u64, lease: bool) -> ReadRecord {
        ReadRecord { node: 1, id: 0, invoked_ms: invoked, served_ms: served, read_index, lease }
    }

    #[test]
    fn clean_log_passes() {
        let mut log = log2(
            vec![(1, 1), (2, 1), (3, 2)],
            vec![(1, 1), (2, 1)],
        );
        log.leaders = vec![(1, 0), (2, 1), (2, 1)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.commits_checked, 5);
        assert_eq!(r.decisions, 3);
        assert_eq!(r.leaders_checked, 3);
    }

    #[test]
    fn divergent_terms_at_same_index_flagged() {
        let log = log2(vec![(1, 1), (2, 1)], vec![(1, 1), (2, 2)]);
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("index 2"), "{:?}", r.violations);
    }

    #[test]
    fn commit_regression_flagged() {
        let log = log2(vec![(1, 1), (3, 1), (2, 1)], vec![]);
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("regressed"), "{:?}", r.violations);
        // duplicate re-commit of the same index is also a regression
        let log = log2(vec![(1, 1), (1, 1)], vec![]);
        assert!(!check(&log).is_clean());
    }

    #[test]
    fn two_leaders_in_one_term_flagged() {
        let mut log = SafetyLog::new(2);
        log.leaders = vec![(3, 0), (4, 1), (3, 1)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("term 3"), "{:?}", r.violations);
    }

    #[test]
    fn linearizable_reads_pass() {
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 1), (20.0, 2), (30.0, 3)];
        log.reads = vec![
            // invoked after index 2 committed, observes 2: fine
            read(25.0, 26.0, 2, false),
            // observes 3 the moment it lands: fine (ceiling is inclusive)
            read(25.0, 30.0, 3, true),
            // a write commits at the exact invocation instant — concurrent,
            // so observing the pre-state is linearizable (floor is strict)
            read(20.0, 21.0, 1, false),
            // invoked before anything committed, observes nothing: fine
            read(5.0, 6.0, 0, false),
        ];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.reads_checked, 4);
    }

    #[test]
    fn stale_read_flagged() {
        // the stale-lease scenario: index 2 committed (by a new leader) at
        // t=20, a read invoked at t=25 still observes index 1
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 1), (20.0, 2)];
        log.reads = vec![read(25.0, 26.0, 1, true)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("STALE"), "{:?}", r.violations);
    }

    #[test]
    fn read_ahead_of_commit_flagged() {
        // a read cannot observe an index nothing had committed by its
        // response time
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 1)];
        log.reads = vec![read(11.0, 12.0, 5, false)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("beyond"), "{:?}", r.violations);
    }

    #[test]
    fn out_of_order_commit_times_use_running_max() {
        // commit observations can interleave across leader changes; the
        // floor must be the running max, not the last record
        let mut log = SafetyLog::new(2);
        log.commit_times = vec![(10.0, 3), (15.0, 2), (20.0, 4)];
        log.reads = vec![read(16.0, 17.0, 3, false)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
    }

    #[test]
    fn empty_log_is_clean() {
        let r = check(&SafetyLog::new(3));
        assert!(r.is_clean());
        assert_eq!(r.commits_checked, 0);
        assert_eq!(r.evidence_checked, 0);
        assert_eq!(r.epochs_checked, 0);
        assert_eq!(r.votes_checked, 0);
    }

    #[test]
    fn double_vote_in_one_term_flagged() {
        // the restart-amnesia scenario: node 2 grants term 5 to candidate 0,
        // reboots with voted_for forgotten, grants term 5 to candidate 1
        let mut log = SafetyLog::new(3);
        log.votes = vec![(5, 2, 0), (5, 2, 1)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("double vote"), "{:?}", r.violations);
        assert_eq!(r.votes_checked, 2);
    }

    #[test]
    fn repeated_grant_to_same_candidate_is_clean() {
        // a retransmitted RequestVote legitimately re-grants the same
        // candidate; distinct terms are independent decisions
        let mut log = SafetyLog::new(3);
        log.votes = vec![(5, 2, 0), (5, 2, 0), (6, 2, 1), (5, 1, 0)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.votes_checked, 4);
    }

    fn evidence(index: u64, epoch: u64, acc: f64, ct: f64) -> crate::sim::CommitEvidence {
        crate::sim::CommitEvidence { index, epoch, acc, ct, joint: None, coded: None }
    }

    #[test]
    fn quorum_evidence_passes_and_fails() {
        let mut log = SafetyLog::new(2);
        log.commit_evidence = vec![
            evidence(1, 0, 3.0, 2.5),
            crate::sim::CommitEvidence {
                index: 2,
                epoch: 1,
                acc: 3.0,
                ct: 2.5,
                joint: Some((2.6, 2.5)),
                coded: None,
            },
        ];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.evidence_checked, 2);

        // below-threshold commit flagged
        let mut bad = SafetyLog::new(2);
        bad.commit_evidence = vec![evidence(1, 0, 2.0, 2.5)];
        assert!(!check(&bad).is_clean());
        // NaN accumulator flagged (negated comparison)
        let mut nan = SafetyLog::new(2);
        nan.commit_evidence = vec![evidence(1, 0, f64::NAN, 2.5)];
        assert!(!check(&nan).is_clean());
        // joint commit that satisfied only the new half flagged
        let mut half = SafetyLog::new(2);
        half.commit_evidence = vec![crate::sim::CommitEvidence {
            index: 1,
            epoch: 1,
            acc: 3.0,
            ct: 2.5,
            joint: Some((1.0, 2.0)),
            coded: None,
        }];
        let r = check(&half);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("old-half"), "{:?}", r.violations);
    }

    fn coded_evidence(index: u64, distinct: u32, k: u32) -> crate::sim::CommitEvidence {
        crate::sim::CommitEvidence {
            index,
            epoch: 0,
            acc: 3.0,
            ct: 2.5,
            joint: None,
            coded: Some((distinct, k)),
        }
    }

    #[test]
    fn coded_commit_requires_reconstructing_shard_set() {
        // healthy coded commits: exactly k and more-than-k distinct shards
        let mut log = SafetyLog::new(2);
        log.commit_evidence = vec![coded_evidence(1, 3, 3), coded_evidence(2, 4, 3)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.evidence_checked, 2);

        // the red case: the weighted quorum cleared CT (acc > ct above) but
        // only k − 1 distinct shards were acked — no follower set can
        // reconstruct the entry, so the commit is a durability violation
        let mut bad = SafetyLog::new(2);
        bad.commit_evidence = vec![coded_evidence(1, 2, 3)];
        let r = check(&bad);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("cannot be reconstructed"), "{:?}", r.violations);

        // full-copy rounds (coded: None) are exempt from the shard conjunct
        let mut plain = SafetyLog::new(2);
        plain.commit_evidence = vec![evidence(1, 0, 3.0, 2.5)];
        assert!(check(&plain).is_clean());
    }

    #[test]
    fn propose_epoch_regression_flagged() {
        let mut log = SafetyLog::new(2);
        log.commit_evidence = vec![evidence(1, 2, 3.0, 2.5), evidence(5, 1, 3.0, 2.5)];
        let r = check(&log);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("epoch regressed"), "{:?}", r.violations);
    }

    #[test]
    fn config_epochs_dedupe_and_flag_divergence() {
        let mut log = SafetyLog::new(3);
        // three nodes observing the same two config commits: clean, two
        // distinct decisions
        log.config_epochs = vec![(1, 4, true), (1, 4, true), (2, 7, false), (1, 4, true)];
        let r = check(&log);
        assert!(r.is_clean(), "{:?}", r.violations);
        assert_eq!(r.epochs_checked, 2);

        let mut div = SafetyLog::new(3);
        div.config_epochs = vec![(1, 4, true), (2, 4, true)];
        let r = check(&div);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("divergent"), "{:?}", r.violations);

        let mut reg = SafetyLog::new(3);
        reg.config_epochs = vec![(3, 4, false), (1, 9, false)];
        let r = check(&reg);
        assert!(!r.is_clean());
        assert!(r.violations[0].contains("config epoch regressed"), "{:?}", r.violations);
    }
}
