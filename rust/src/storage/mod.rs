//! Follower state machines: the document store (MongoDB stand-in), the
//! relational store (PostgreSQL stand-in), and the shared digest spec that
//! ties the native mirrors to the AOT Pallas kernels bit-for-bit.

pub mod digest;
pub mod doc;
pub mod rel;

pub use digest::DigestState;
pub use doc::{ApplyResult, DocStore};
pub use rel::{RelStore, TpccApplyResult};
