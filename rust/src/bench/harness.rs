//! Criterion-style micro-bench harness (offline substitute — the vendored
//! crate set has no criterion). Used by the `benches/*.rs` targets with
//! `harness = false`: warmup, timed samples, mean/σ/min/max report in a
//! criterion-like output format so `cargo bench` output stays familiar.

use std::time::{Duration, Instant};

/// One bench runner with a shared configuration.
pub struct Bencher {
    /// Minimum sample count.
    pub samples: usize,
    /// Warmup iterations before sampling.
    pub warmup: usize,
    /// Target total measurement time; sampling stops after whichever of
    /// (samples, target) is satisfied last… practically: run `samples`
    /// iterations but keep going until `min_time` has elapsed.
    pub min_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { samples: 10, warmup: 2, min_time: Duration::from_millis(200) }
    }
}

/// Result of one bench.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub samples: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

/// Was a quick-profile run requested? `CABINET_BENCH_QUICK=1` (any value
/// but "0"/"") or a `--quick` CLI argument selects the short profile — the
/// CI bench job runs this way to emit a trajectory point per push without
/// paying for full sampling.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("CABINET_BENCH_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { samples: 5, warmup: 1, min_time: Duration::from_millis(50) }
    }

    /// Quick profile when [`quick_requested`], full profile otherwise.
    pub fn from_env() -> Self {
        if quick_requested() {
            Bencher::quick()
        } else {
            Bencher::default()
        }
    }

    /// Measure `f`, printing a criterion-style line. Returns the stats so
    /// callers (and EXPERIMENTS.md scripts) can post-process.
    pub fn iter<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times: Vec<f64> = Vec::with_capacity(self.samples);
        let start = Instant::now();
        while times.len() < self.samples || start.elapsed() < self.min_time {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
            if times.len() >= self.samples * 50 {
                break; // enough
            }
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let stats = BenchStats {
            samples: times.len(),
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(times.iter().cloned().fold(f64::MAX, f64::min)),
            max: Duration::from_secs_f64(times.iter().cloned().fold(f64::MIN, f64::max)),
        };
        println!(
            "{name:<48} time: [{} {} {}]  ({} samples)",
            fmt_dur(stats.min),
            fmt_dur(stats.mean),
            fmt_dur(stats.max),
            stats.samples
        );
        stats
    }

    /// [`Bencher::iter`], recording the result into `report` as well — the
    /// one-liner the `benches/*.rs` targets use to build their
    /// `BENCH_<suite>.json` emission while keeping the familiar printed
    /// output.
    pub fn iter_rec<T>(
        &self,
        report: &mut crate::bench::report::BenchReport,
        name: &str,
        f: impl FnMut() -> T,
    ) -> BenchStats {
        let stats = self.iter(name, f);
        report.push(name, &stats);
        stats
    }
}

/// Human-readable duration (criterion-style units).
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let stats = b.iter("noop", || 1 + 1);
        assert!(stats.samples >= 5);
        assert!(stats.mean <= Duration::from_millis(1));
    }

    #[test]
    fn mean_between_min_max() {
        let b = Bencher::quick();
        let stats = b.iter("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(stats.min <= stats.mean && stats.mean <= stats.max);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.000 µs");
        assert_eq!(fmt_dur(Duration::from_nanos(42)), "42.0 ns");
    }
}
