"""YCSB Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Everything is uint32 modular arithmetic, so equality is bit-exact (no
allclose tolerance). Hypothesis sweeps shapes (state sizes, batch sizes,
block sizes) and adversarial value ranges.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    OP_INSERT,
    OP_NOP,
    OP_READ,
    OP_RMW,
    OP_SCAN,
    OP_UPDATE,
    STATE_SLOTS,
    YCSB_BATCH,
    YCSB_BLOCK,
    ref,
    ycsb_apply_pallas,
)

U32 = np.uint32


def _rand(rng, n, hi=2**32):
    return jnp.array(rng.integers(0, hi, n, dtype=U32))


def _run_both(state, ops, keys, vals, block):
    ns_r, d_r = ref.ycsb_apply_ref(state, ops, keys, vals)
    ns_p, d_p = ycsb_apply_pallas(state, ops, keys, vals, block=block)
    return ns_r, d_r, ns_p, d_p


def test_artifact_shape_bit_exact():
    """The exact (S, B, block) configuration the AOT artifact uses."""
    rng = np.random.default_rng(7)
    state = _rand(rng, STATE_SLOTS)
    ops = _rand(rng, YCSB_BATCH, hi=OP_NOP + 2)
    keys = _rand(rng, YCSB_BATCH)
    vals = _rand(rng, YCSB_BATCH)
    ns_r, d_r, ns_p, d_p = _run_both(state, ops, keys, vals, YCSB_BLOCK)
    np.testing.assert_array_equal(np.array(ns_r), np.array(ns_p))
    np.testing.assert_array_equal(np.array(d_r), np.array(d_p))


def test_all_nop_batch_is_identity():
    rng = np.random.default_rng(8)
    state = _rand(rng, 1024)
    ops = jnp.full((512,), OP_NOP, U32)
    keys = _rand(rng, 512)
    vals = _rand(rng, 512)
    ns, dig = ycsb_apply_pallas(state, ops, keys, vals, block=128)
    np.testing.assert_array_equal(np.array(ns), np.array(state))
    assert int(dig[1]) == 0  # no reads → zero read digest


def test_reads_do_not_mutate_state():
    rng = np.random.default_rng(9)
    state = _rand(rng, 1024)
    ops = jnp.array(rng.choice([OP_READ, OP_SCAN], 512).astype(U32))
    ns, dig = ycsb_apply_pallas(state, ops, _rand(rng, 512), _rand(rng, 512), block=128)
    np.testing.assert_array_equal(np.array(ns), np.array(state))
    assert int(dig[1]) != 0


def test_writes_commute_batch_order_invariant():
    """Permuting the batch must not change the result (commutative apply)."""
    rng = np.random.default_rng(10)
    state = _rand(rng, 512)
    ops = _rand(rng, 256, hi=OP_NOP)
    keys = _rand(rng, 256, hi=64)  # force slot collisions
    vals = _rand(rng, 256)
    perm = rng.permutation(256)
    ns1, d1 = ycsb_apply_pallas(state, ops, keys, vals, block=64)
    ns2, d2 = ycsb_apply_pallas(
        state, ops[perm], keys[perm], vals[perm], block=64
    )
    np.testing.assert_array_equal(np.array(ns1), np.array(ns2))
    np.testing.assert_array_equal(np.array(d1), np.array(d2))


def test_block_size_invariance():
    """Different tilings of the same batch are bit-identical."""
    rng = np.random.default_rng(11)
    state = _rand(rng, 2048)
    ops = _rand(rng, 1024, hi=OP_NOP + 1)
    keys = _rand(rng, 1024)
    vals = _rand(rng, 1024)
    results = [
        ycsb_apply_pallas(state, ops, keys, vals, block=b)
        for b in (128, 256, 512, 1024)
    ]
    for ns, dig in results[1:]:
        np.testing.assert_array_equal(np.array(results[0][0]), np.array(ns))
        np.testing.assert_array_equal(np.array(results[0][1]), np.array(dig))


def test_single_op_types():
    """Each op code in isolation mutates (or not) per spec and matches ref."""
    state = jnp.zeros((256,), U32)
    for op, mutates in [
        (OP_READ, False),
        (OP_UPDATE, True),
        (OP_SCAN, False),
        (OP_INSERT, True),
        (OP_RMW, True),
        (OP_NOP, False),
    ]:
        ops = jnp.full((8,), OP_NOP, U32).at[0].set(U32(op))
        keys = jnp.zeros((8,), U32).at[0].set(U32(42))
        vals = jnp.zeros((8,), U32).at[0].set(U32(7))
        ns, dig = ycsb_apply_pallas(state, ops, keys, vals, block=8)
        changed = bool((np.array(ns) != 0).any())
        assert changed == mutates, f"op={op}"
        ns_r, dig_r = ref.ycsb_apply_ref(state, ops, keys, vals)
        np.testing.assert_array_equal(np.array(ns), np.array(ns_r))
        np.testing.assert_array_equal(np.array(dig), np.array(dig_r))


@settings(max_examples=25, deadline=None)
@given(
    log_slots=st.integers(6, 13),
    blocks=st.integers(1, 8),
    block=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
    extreme=st.booleans(),
)
def test_hypothesis_shape_sweep(log_slots, blocks, block, seed, extreme):
    """Property: Pallas == oracle for arbitrary shapes and value ranges."""
    rng = np.random.default_rng(seed)
    n_slots = 1 << log_slots
    batch = blocks * block
    state = _rand(rng, n_slots)
    if extreme:
        # adversarial values: all-max keys/vals, op codes far out of range
        ops = jnp.array(rng.choice([0, 4, 5, 2**32 - 1], batch).astype(U32))
        keys = jnp.full((batch,), 2**32 - 1, U32)
        vals = jnp.full((batch,), 2**32 - 1, U32)
    else:
        ops = _rand(rng, batch, hi=OP_NOP + 3)
        keys = _rand(rng, batch)
        vals = _rand(rng, batch)
    ns_r, d_r, ns_p, d_p = _run_both(state, ops, keys, vals, block)
    np.testing.assert_array_equal(np.array(ns_r), np.array(ns_p))
    np.testing.assert_array_equal(np.array(d_r), np.array(d_p))


def test_digest_sensitivity():
    """Flipping one op value flips the digest."""
    rng = np.random.default_rng(12)
    state = _rand(rng, 512)
    ops = _rand(rng, 128, hi=OP_NOP)
    keys = _rand(rng, 128)
    vals = _rand(rng, 128)
    _, d1 = ycsb_apply_pallas(state, ops, keys, vals, block=64)
    vals2 = np.array(vals)
    vals2[17] ^= 1
    _, d2 = ycsb_apply_pallas(state, ops, keys, jnp.array(vals2), block=64)
    assert not np.array_equal(np.array(d1), np.array(d2))
