//! `cargo bench` target regenerating Fig 8 — YCSB-A vs cluster size (quick scale; run
//! `cargo run --release --example figures -- fig8 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig08_scaling", || {
        last = Some(figures::fig8(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
