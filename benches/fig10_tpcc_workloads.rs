//! `cargo bench` target regenerating Fig 10 — TPC-C per-txn at n=50 (quick scale; run
//! `cargo run --release --example figures -- fig10 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig10_tpcc_workloads", || {
        last = Some(figures::fig10(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
