//! Deterministic shard router for the sharded (multi-group) deployments.
//!
//! Each of the G consensus groups replicates only its own shard of the
//! keyspace: YCSB keys are hash-partitioned (keys 0..G pinned round-robin,
//! the rest a SplitMix64 mix modulo G — so the zipfian head keys spread
//! across shards instead of all landing in group 0, and no shard is ever
//! empty), TPC-C warehouses are range-partitioned (group g owns the
//! contiguous warehouse range `[g·W/G, (g+1)·W/G)`, the classic layout for
//! a workload whose transactions are warehouse-local).
//!
//! Routing is a pure function of (key, G) / (warehouse, G): every layer —
//! the per-group workload generators in [`crate::workload::ycsb`] /
//! [`crate::workload::tpcc`], the sim's `GroupEngine`s, the live cluster —
//! agrees on shard ownership without coordination, and a run stays a pure
//! function of (config, seed).

use crate::net::rng::splitmix64;

/// Which dimension the workload is partitioned on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardBy {
    /// Hash-partition YCSB keys across groups (SplitMix64 mix mod G).
    KeyHash,
    /// Range-partition TPC-C warehouses across groups.
    Warehouse,
}

impl ShardBy {
    pub fn name(self) -> &'static str {
        match self {
            ShardBy::KeyHash => "hash",
            ShardBy::Warehouse => "warehouse",
        }
    }

    pub fn from_name(s: &str) -> Option<ShardBy> {
        match s.to_ascii_lowercase().as_str() {
            "hash" | "keyhash" | "key-hash" => Some(ShardBy::KeyHash),
            "warehouse" | "range" => Some(ShardBy::Warehouse),
            _ => None,
        }
    }
}

/// Hash-partition: the group that owns `key` among `groups` shards.
///
/// Mostly a SplitMix64 mix modulo G, with two deterministic pinning rules
/// a pure hash cannot provide (for small keyspaces some residue classes
/// are simply never hit, which would hang the generators):
///
/// * keys `0..G` are pinned round-robin (key g → shard g) — the zipfian
///   head, YCSB's hottest keys 0, 1, 2, …, spreads exactly evenly, and
///   every shard owns a key whenever `records >= groups` (the parse-time
///   invariant), so the generators' cyclic fallback walk over the keyspace
///   terminates;
/// * one key per G-aligned block is pinned (`k % G == (k / G) % G` →
///   shard `(k / G) % G`) — every shard appears pinned within any G
///   consecutive blocks, so an *ascending* scan (the fresh-insert advance,
///   whose keys grow beyond the head) provably reaches every shard within
///   G² keys.
///
/// Everything else goes through the mix (not the raw key mod G, so
/// warm-but-not-hottest consecutive keys still scatter). The map is a
/// fixed pure function of (key, G): ownership is stable across runs,
/// nodes and layers.
#[inline]
pub fn key_shard(key: u32, groups: usize) -> usize {
    debug_assert!(groups >= 1);
    if groups <= 1 {
        return 0;
    }
    let g = groups as u64;
    let k = key as u64;
    if k < g {
        return k as usize;
    }
    if k % g == (k / g) % g {
        return ((k / g) % g) as usize;
    }
    let mut s = k;
    (splitmix64(&mut s) % g) as usize
}

/// Range-partition: the warehouse interval `[lo, hi)` group `g` owns. With
/// `warehouses >= groups` (a config-parse invariant) every group's range is
/// non-empty.
#[inline]
pub fn warehouse_range(group: usize, groups: usize, warehouses: u32) -> (u32, u32) {
    debug_assert!(groups >= 1 && group < groups);
    let w = warehouses as u64;
    let lo = (group as u64 * w) / groups as u64;
    let hi = ((group as u64 + 1) * w) / groups as u64;
    (lo as u32, hi as u32)
}

/// The group that owns warehouse `wid` under the range partition — the
/// inverse of [`warehouse_range`].
#[inline]
pub fn warehouse_shard(wid: u32, groups: usize, warehouses: u32) -> usize {
    debug_assert!(wid < warehouses);
    if groups <= 1 {
        return 0;
    }
    // ⌊(wid+1)·G − 1) / W⌋ inverts lo = ⌊g·W/G⌋ for any W ≥ G
    (((wid as u64 + 1) * groups as u64 - 1) / warehouses as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for sb in [ShardBy::KeyHash, ShardBy::Warehouse] {
            assert_eq!(ShardBy::from_name(sb.name()), Some(sb));
        }
        assert_eq!(ShardBy::from_name("range"), Some(ShardBy::Warehouse));
        assert_eq!(ShardBy::from_name("nope"), None);
    }

    #[test]
    fn key_shard_in_range_and_stable() {
        for groups in [1usize, 2, 4, 8] {
            for key in 0..10_000u32 {
                let s = key_shard(key, groups);
                assert!(s < groups);
                assert_eq!(s, key_shard(key, groups), "ownership must be stable");
            }
        }
    }

    #[test]
    fn key_shard_spreads_hot_head() {
        // the zipfian head (keys 0..G) is pinned exactly round-robin
        let groups = 4;
        for key in 0..groups as u32 {
            assert_eq!(key_shard(key, groups), key as usize);
        }
    }

    #[test]
    fn every_shard_nonempty_at_minimum_keyspace() {
        // the invariant the generators' fallback walk relies on: with
        // records >= groups, every shard owns at least one key — even at
        // the records == groups floor, for every G the config layer admits
        for groups in 1..=128usize {
            let mut seen = vec![false; groups];
            for key in 0..groups as u32 {
                seen[key_shard(key, groups)] = true;
            }
            assert!(
                seen.iter().all(|&s| s),
                "G={groups}: a shard owns no key in 0..G"
            );
        }
    }

    #[test]
    fn ascending_scan_reaches_every_shard_within_g_squared() {
        // the invariant the fresh-insert advance relies on: from ANY start
        // (insert keys live beyond the pinned head), an ascending scan of
        // at most G² keys hits every shard — the per-block pinning rule
        for groups in [2usize, 3, 4, 8, 16] {
            for start in [0u64, 1, 999, 100_000, u32::MAX as u64 - 4096] {
                let mut seen = vec![false; groups];
                let bound = (groups * groups) as u64;
                for k in start..start + bound {
                    seen[key_shard(k as u32, groups)] = true;
                }
                assert!(
                    seen.iter().all(|&s| s),
                    "G={groups} start={start}: a shard unreachable within G²"
                );
            }
        }
    }

    #[test]
    fn key_shard_roughly_balanced() {
        let groups = 8;
        let mut counts = [0usize; 8];
        for key in 0..100_000u32 {
            counts[key_shard(key, groups)] += 1;
        }
        for &c in &counts {
            let share = c as f64 / 100_000.0;
            assert!((share - 1.0 / 8.0).abs() < 0.02, "skewed shard: {counts:?}");
        }
    }

    #[test]
    fn warehouse_ranges_tile_exactly() {
        for (groups, w) in [(1usize, 10u32), (2, 10), (4, 10), (3, 7), (10, 10)] {
            let mut next = 0u32;
            for g in 0..groups {
                let (lo, hi) = warehouse_range(g, groups, w);
                assert_eq!(lo, next, "gap before group {g}");
                assert!(hi > lo, "empty range for group {g} (G={groups}, W={w})");
                for wid in lo..hi {
                    assert_eq!(warehouse_shard(wid, groups, w), g, "inverse mismatch");
                }
                next = hi;
            }
            assert_eq!(next, w, "ranges must cover every warehouse");
        }
    }
}
