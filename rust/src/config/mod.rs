//! Experiment configuration: a TOML-subset file format plus conversion to
//! `sim::SimConfig`, used by the `cabinet sim --config` CLI path.

pub mod toml;

use anyhow::{bail, Context, Result};

use crate::consensus::coding::CodingConfig;
use crate::net::delay::DelayModel;
use crate::net::fault::{ContentionSpec, KillSpec, KillStrategy};
use crate::net::nemesis::{MembershipEvent, MembershipSpec, NemesisSpec, PartitionSpec};
use crate::net::topology::ZoneAlloc;
use crate::sim::{
    DigestMode, Protocol, ReadPath, ReconfigSpec, RestartSpec, SimConfig, StorageSpec,
    WorkloadSpec,
};
use crate::workload::{ShardBy, Workload};

/// Build a `SimConfig` from a TOML-subset experiment file. Layout:
///
/// ```toml
/// protocol = "cabinet"   # raft | cabinet | hqc
/// t = 5                  # cabinet only
/// sizes = [3, 3, 5]      # hqc only
/// n = 50
/// heterogeneous = true
/// rounds = 100
/// seed = 42
/// pipeline = 4           # in-flight replication rounds (default 1 = lock-step)
/// snapshot_every = 64    # snapshot + compact every N committed entries (0 = off)
/// pre_vote = true        # PreVote elections (Raft §9.6, n − t quorum); default off
/// read_path = "lease"    # linearizable reads: log (default) | readindex | lease
/// lease_drift_ms = 50    # clock-drift margin under the lease bound
/// max_batch_bytes = 1048576  # leader-side adaptive batching: coalesce queued
///                            # ops into one AppendEntries per follower per
///                            # tick, up to this many payload bytes (omit =
///                            # the historical one-round-per-tick proposer)
///
/// [workload]
/// kind = "ycsb"          # ycsb | tpcc
/// workload = "A"         # ycsb only
/// batch = 5000
/// records = 100000       # ycsb only: keyspace size
/// value_size = 65536     # ycsb only: modeled bytes per written value, up to
///                        # 16 MiB (0 = the historical 12-byte-op wire model)
///
/// [delay]
/// model = "d0"           # d0 | d1 | d2 | d3 | d4
/// mean_ms = 100          # d1 only
/// spread_ms = 20         # d1 only
/// period_rounds = 10     # d3 only
/// bandwidth_bytes_per_ms = 25000  # per-link bandwidth for the transfer term
///                                 # (default: the ≈400 MB/s testbed NIC)
///
/// [coding]
/// k = 3                  # payload-adaptive coded replication: entries at or
///                        # above the cutover ship as k data + 1 XOR parity
///                        # shards (needs k >= 2 and k + 1 <= n - 1)
/// cutover_bytes = 65536  # code entries at/above this payload size (omit =
///                        # adaptive from the link bandwidth)
/// enabled = true         # explicit off switch; stray knobs under
///                        # enabled = false are a config error
///
/// [faults]
/// kill_round = 20
/// kill_count = 2
/// kill_strategy = "strong"   # strong | weak | random
/// contention_round = 20
/// contention_slowdown = 2.5
/// restart_kill_round = 10    # kill one follower ...
/// restart_round = 30         # ... and restart it fresh (both or neither)
///
/// [sharding]
/// groups = 4                 # independent consensus groups over one fabric
///                            # (1 = the historical single-group deployment)
/// shard_by = "hash"          # hash (YCSB keys) | warehouse (TPC-C ranges);
///                            # default follows the workload kind
///
/// [nemesis]
/// drop_p = 0.05              # per-message loss probability, [0, 1]
/// dup_p = 0.02               # per-message duplication probability
/// reorder_p = 0.10           # per-message bounded-extra-delay probability
/// reorder_max_ms = 40        # upper bound on the extra delay (virtual ms)
/// partitions = ["2000..6000=leader", "8000..20000=followers:2"]
///                            # windows: START..END=leader | followers:K
///                            #          | split:ids | oneway:ids
/// groups = [0, 2]            # sharded runs: restrict the schedule to these
///                            # group indices (default: every group)
///
/// [membership]
/// members = 5                # founding voters (slots members..n join later;
///                            # default: all n slots are founding members)
/// drain_rounds = 4           # weight ramp-down before a leave's joint drop
/// join_warmup = 4            # acked rounds before a joiner turns Active
/// events = ["4=join:5", "10=leave:0", "16=replace:1>6"]
///                            # ROUND=join:ID | leave:ID | replace:OLD>NEW
///
/// [storage]
/// wal = true                 # durable segmented WAL per node (off = the
///                            # historical amnesiac restarts)
/// fsync_group = 8            # entry appends per group-commit fsync (>= 1;
///                            # HardState records always sync)
/// fsync_ms = 0.5             # simulated fsync latency charged to the node
/// torn_writes = false        # crash faults keep a corrupted partial tail
/// ```
pub fn sim_config_from_toml(text: &str) -> Result<SimConfig> {
    let doc = toml::parse(text)?;
    let root = doc.get("").context("missing root table")?;

    let n = root.get("n").and_then(|v| v.as_int()).unwrap_or(11) as usize;
    let het = root.get("heterogeneous").and_then(|v| v.as_bool()).unwrap_or(true);
    let protocol = match root.get("protocol").and_then(|v| v.as_str()).unwrap_or("cabinet") {
        "raft" => Protocol::Raft,
        "cabinet" => {
            let t = root.get("t").and_then(|v| v.as_int()).unwrap_or(1) as usize;
            Protocol::Cabinet { t }
        }
        "hqc" => {
            let sizes: Vec<usize> = root
                .get("sizes")
                .and_then(|v| v.as_array())
                .map(|a| a.iter().filter_map(|v| v.as_int()).map(|i| i as usize).collect())
                .unwrap_or_else(|| vec![n / 3, n / 3, n - 2 * (n / 3)]);
            Protocol::Hqc { sizes }
        }
        other => bail!("unknown protocol {other}"),
    };

    let mut config = SimConfig::new(protocol, n, het);
    config.rounds = root.get("rounds").and_then(|v| v.as_int()).unwrap_or(20) as u64;
    config.seed = root.get("seed").and_then(|v| v.as_int()).unwrap_or(42) as u64;
    if let Some(depth) = root.get("pipeline").and_then(|v| v.as_int()) {
        if depth < 1 {
            bail!("pipeline depth must be >= 1, got {depth}");
        }
        config.pipeline = depth as usize;
    }
    if let Some(mb) = root.get("max_batch_bytes").and_then(|v| v.as_int()) {
        if mb < 1 {
            bail!("max_batch_bytes must be >= 1, got {mb}");
        }
        config.max_batch_bytes = Some(mb as u64);
    }
    if let Some(every) = root.get("snapshot_every").and_then(|v| v.as_int()) {
        if every < 0 {
            bail!("snapshot_every must be >= 0, got {every}");
        }
        if every > 0 {
            config.snapshot_every = Some(every as u64);
        }
    }
    config.pre_vote = root.get("pre_vote").and_then(|v| v.as_bool()).unwrap_or(false);
    if let Some(rp) = root.get("read_path").and_then(|v| v.as_str()) {
        config.read_path = ReadPath::from_name(rp)
            .with_context(|| format!("unknown read_path {rp} (log | readindex | lease)"))?;
    }
    if let Some(ms) = root.get("lease_drift_ms").and_then(|v| v.as_float()) {
        if ms < 0.0 {
            bail!("lease_drift_ms must be >= 0, got {ms}");
        }
        config.lease_drift_ms = ms;
    }
    if matches!(config.read_path, ReadPath::Lease)
        && config.lease_drift_ms >= config.election_timeout_ms.0
    {
        bail!(
            "lease_drift_ms ({}) must stay below the minimum election timeout ({}) — \
             the lease bound would be empty",
            config.lease_drift_ms,
            config.election_timeout_ms.0
        );
    }
    let _ = ZoneAlloc::heterogeneous(n); // n validated by construction

    if let Some(w) = doc.get("workload") {
        let batch = w.get("batch").and_then(|v| v.as_int()).unwrap_or(5000) as usize;
        match w.get("kind").and_then(|v| v.as_str()).unwrap_or("ycsb") {
            "ycsb" => {
                let name = w.get("workload").and_then(|v| v.as_str()).unwrap_or("A");
                let wl = Workload::from_name(name)
                    .with_context(|| format!("unknown YCSB workload {name}"))?;
                let records = w.get("records").and_then(|v| v.as_int()).unwrap_or(100_000);
                if records < 1 {
                    bail!("records must be >= 1, got {records}");
                }
                config.workload =
                    WorkloadSpec::Ycsb { workload: wl, batch, records: records as u64 };
                if let Some(vs) = w.get("value_size").and_then(|v| v.as_int()) {
                    if vs < 0 {
                        bail!("value_size must be >= 0, got {vs}");
                    }
                    config.value_size = vs as u64;
                }
            }
            "tpcc" => {
                if w.get("value_size").is_some() {
                    bail!("value_size applies to YCSB only (TPC-C's wire model is op-count based)");
                }
                let wh = w.get("warehouses").and_then(|v| v.as_int()).unwrap_or(10);
                // parse-time validation, not a construction-site .max(1)
                // patch-up: a zero-warehouse experiment is a config error
                if wh < 1 {
                    bail!("warehouses must be >= 1, got {wh}");
                }
                config.workload = WorkloadSpec::Tpcc { batch, warehouses: wh as u32 };
            }
            other => bail!("unknown workload kind {other}"),
        }
    }

    if let Some(s) = doc.get("sharding") {
        let groups = s.get("groups").and_then(|v| v.as_int()).unwrap_or(1);
        // negative values would wrap through the usize cast below; the rest
        // of the validation (range, protocol, workload bounds) is the one
        // shared `SimConfig::validate_sharding` implementation
        if groups < 1 {
            bail!("groups must be >= 1, got {groups}");
        }
        config.groups = groups as usize;
        if let Some(sb) = s.get("shard_by").and_then(|v| v.as_str()) {
            config.shard_by = Some(
                ShardBy::from_name(sb)
                    .with_context(|| format!("unknown shard_by {sb} (hash | warehouse)"))?,
            );
        }
        if let Err(e) = config.validate_sharding() {
            bail!("[sharding] {e}");
        }
    }

    if let Some(d) = doc.get("delay") {
        config.delay = match d.get("model").and_then(|v| v.as_str()).unwrap_or("d0") {
            "d0" => DelayModel::None,
            "d1" => DelayModel::Uniform {
                mean_ms: d.get("mean_ms").and_then(|v| v.as_float()).unwrap_or(100.0),
                spread_ms: d.get("spread_ms").and_then(|v| v.as_float()).unwrap_or(20.0),
            },
            "d2" => DelayModel::Skew,
            "d3" => DelayModel::Rotating {
                period_rounds: d.get("period_rounds").and_then(|v| v.as_int()).unwrap_or(10)
                    as u64,
            },
            "d4" => DelayModel::Bursting,
            other => bail!("unknown delay model {other}"),
        };
        if let Some(b) = d.get("bandwidth_bytes_per_ms").and_then(|v| v.as_float()) {
            config.bandwidth_bytes_per_ms = Some(b);
        }
    }

    if let Some(c) = doc.get("coding") {
        let on = c.get("enabled").and_then(|v| v.as_bool()).unwrap_or(true);
        if on {
            let k = c.get("k").and_then(|v| v.as_int()).unwrap_or(3);
            if k < 2 {
                bail!("[coding] k must be >= 2, got {k}");
            }
            let cutover = match c.get("cutover_bytes").and_then(|v| v.as_int()) {
                Some(b) if b < 1 => bail!("[coding] cutover_bytes must be >= 1, got {b}"),
                Some(b) => Some(b as u64),
                None => None,
            };
            config.coding = Some(CodingConfig { k: k as u32, cutover_bytes: cutover });
        } else if c.get("k").is_some() || c.get("cutover_bytes").is_some() {
            bail!("[coding] enabled = false cannot be combined with other coding knobs");
        }
    }
    // one shared validator covers the coding table plus the batching /
    // bandwidth / value-size knobs parsed above
    if let Err(e) = config.validate_coding() {
        bail!("{e}");
    }

    if let Some(f) = doc.get("faults") {
        if let Some(round) = f.get("kill_round").and_then(|v| v.as_int()) {
            let count = f.get("kill_count").and_then(|v| v.as_int()).unwrap_or(1) as usize;
            let strategy = match f
                .get("kill_strategy")
                .and_then(|v| v.as_str())
                .unwrap_or("random")
            {
                "strong" => KillStrategy::Strong,
                "weak" => KillStrategy::Weak,
                "random" => KillStrategy::Random,
                other => bail!("unknown kill strategy {other}"),
            };
            config.kills.push(KillSpec::new(round as u64, count, strategy));
        }
        if let Some(round) = f.get("contention_round").and_then(|v| v.as_int()) {
            let slow =
                f.get("contention_slowdown").and_then(|v| v.as_float()).unwrap_or(2.5);
            config.contention = Some(ContentionSpec::new(round as u64, slow));
        }
        let rk = f.get("restart_kill_round").and_then(|v| v.as_int());
        let rr = f.get("restart_round").and_then(|v| v.as_int());
        match (rk, rr) {
            (Some(k), Some(r)) => {
                if r <= k {
                    bail!("restart_round ({r}) must come after restart_kill_round ({k})");
                }
                config.restart =
                    Some(RestartSpec { kill_round: k as u64, restart_round: r as u64 });
            }
            (None, None) => {}
            _ => bail!("restart_kill_round and restart_round must be set together"),
        }
    }

    if let Some(nm) = doc.get("nemesis") {
        let mut spec = NemesisSpec::default();
        if let Some(p) = nm.get("drop_p").and_then(|v| v.as_float()) {
            spec.drop_p = p;
        }
        if let Some(p) = nm.get("dup_p").and_then(|v| v.as_float()) {
            spec.dup_p = p;
        }
        if let Some(p) = nm.get("reorder_p").and_then(|v| v.as_float()) {
            spec.reorder_p = p;
        }
        if let Some(ms) = nm.get("reorder_max_ms").and_then(|v| v.as_float()) {
            spec.reorder_max_ms = ms;
        }
        if let Some(parts) = nm.get("partitions").and_then(|v| v.as_array()) {
            for p in parts {
                let s = p
                    .as_str()
                    .context("[nemesis] partitions entries must be strings")?;
                spec.partitions.push(PartitionSpec::parse(s)?);
            }
        }
        spec.validate(n)?;
        if !spec.is_noop() {
            config.nemesis = Some(spec);
        }
        if let Some(gs) = nm.get("groups").and_then(|v| v.as_array()) {
            if config.nemesis.is_none() {
                bail!("[nemesis] groups requires a non-empty nemesis schedule");
            }
            let mut scope = Vec::new();
            for g in gs {
                let g = g
                    .as_int()
                    .context("[nemesis] groups entries must be integers")?;
                if g < 0 || g as usize >= config.groups {
                    bail!(
                        "[nemesis] group {g} out of range for groups = {}",
                        config.groups
                    );
                }
                scope.push(g as usize);
            }
            if scope.is_empty() {
                bail!("[nemesis] groups must name at least one group");
            }
            config.nemesis_groups = Some(scope);
        }
    }

    if let Some(m) = doc.get("membership") {
        if let Some(k) = m.get("members").and_then(|v| v.as_int()) {
            // negative values would wrap through the usize cast; the range
            // itself (3..=n) is checked by the shared validate_membership
            if k < 0 {
                bail!("[membership] members must be >= 0, got {k}");
            }
            config.initial_members = Some(k as usize);
        }
        if let Some(dr) = m.get("drain_rounds").and_then(|v| v.as_int()) {
            if dr < 1 {
                bail!("[membership] drain_rounds must be >= 1, got {dr}");
            }
            config.drain_rounds = dr as usize;
        }
        if let Some(w) = m.get("join_warmup").and_then(|v| v.as_int()) {
            if w < 0 {
                bail!("[membership] join_warmup must be >= 0, got {w}");
            }
            config.join_warmup = w as u64;
        }
        if let Some(evs) = m.get("events").and_then(|v| v.as_array()) {
            let mut spec = MembershipSpec::default();
            for e in evs {
                let s = e
                    .as_str()
                    .context("[membership] events entries must be strings")?;
                spec.events.push(MembershipEvent::parse(s)?);
            }
            if !spec.is_noop() {
                config.membership = Some(spec);
            }
        }
        if let Err(e) = config.validate_membership() {
            bail!("[membership] {e}");
        }
    }

    if let Some(s) = doc.get("storage") {
        let on = s.get("wal").and_then(|v| v.as_bool()).unwrap_or(true);
        if on {
            let mut spec = StorageSpec::default();
            if let Some(g) = s.get("fsync_group").and_then(|v| v.as_int()) {
                if g < 1 {
                    bail!("[storage] fsync_group must be >= 1, got {g}");
                }
                spec.fsync_group = g as usize;
            }
            if let Some(ms) = s.get("fsync_ms").and_then(|v| v.as_float()) {
                if !(ms >= 0.0) {
                    bail!("[storage] fsync_ms must be >= 0, got {ms}");
                }
                spec.fsync_ms = ms;
            }
            spec.torn_writes =
                s.get("torn_writes").and_then(|v| v.as_bool()).unwrap_or(false);
            config.storage = Some(spec);
        } else if s.get("fsync_group").is_some()
            || s.get("fsync_ms").is_some()
            || s.get("torn_writes").is_some()
        {
            bail!("[storage] wal = false cannot be combined with other storage knobs");
        }
    }

    if let Some(r) = doc.get("reconfig") {
        let rounds = r.get("rounds").and_then(|v| v.as_array());
        let ts = r.get("thresholds").and_then(|v| v.as_array());
        if let (Some(rounds), Some(ts)) = (rounds, ts) {
            for (round, t) in rounds.iter().zip(ts) {
                if let (Some(round), Some(t)) = (round.as_int(), t.as_int()) {
                    config
                        .reconfigs
                        .push(ReconfigSpec { round: round as u64, new_t: t as usize });
                }
            }
        }
    }

    if root.get("digests").and_then(|v| v.as_bool()).unwrap_or(false) {
        config.digest_mode = DigestMode::Sample;
    }
    Ok(config)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_roundtrip() {
        let cfg = sim_config_from_toml(
            r#"
protocol = "cabinet"
t = 5
n = 50
heterogeneous = true
rounds = 30
seed = 7
pipeline = 4
snapshot_every = 16
digests = true

[workload]
kind = "ycsb"
workload = "B"
batch = 2000

[delay]
model = "d1"
mean_ms = 200
spread_ms = 40

[faults]
kill_round = 10
kill_count = 2
kill_strategy = "strong"
contention_round = 15
contention_slowdown = 2.0
restart_kill_round = 12
restart_round = 22

[reconfig]
rounds = [20, 25]
thresholds = [3, 1]

[storage]
fsync_group = 64
fsync_ms = 0.25
"#,
        )
        .unwrap();
        assert_eq!(cfg.n(), 50);
        assert_eq!(cfg.rounds, 30);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.pipeline, 4);
        assert_eq!(cfg.snapshot_every, Some(16));
        let rs = cfg.restart.expect("restart spec parsed");
        assert_eq!((rs.kill_round, rs.restart_round), (12, 22));
        assert!(matches!(cfg.protocol, Protocol::Cabinet { t: 5 }));
        assert!(matches!(cfg.delay, DelayModel::Uniform { .. }));
        assert_eq!(cfg.kills.len(), 1);
        assert!(cfg.contention.is_some());
        assert_eq!(cfg.reconfigs.len(), 2);
        assert_eq!(cfg.digest_mode, DigestMode::Sample);
        let st = cfg.storage.expect("storage spec parsed");
        assert_eq!(st.fsync_group, 64);
        assert_eq!(st.fsync_ms, 0.25);
        assert!(!st.torn_writes);
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = sim_config_from_toml("protocol = \"raft\"\n").unwrap();
        assert!(matches!(cfg.protocol, Protocol::Raft));
        assert_eq!(cfg.n(), 11);
        assert_eq!(cfg.pipeline, 1, "default must stay lock-step");
    }

    #[test]
    fn pipeline_depth_validated() {
        let cfg = sim_config_from_toml("pipeline = 8\n").unwrap();
        assert_eq!(cfg.pipeline, 8);
        assert!(sim_config_from_toml("pipeline = 0\n").is_err());
        assert!(sim_config_from_toml("pipeline = -3\n").is_err());
    }

    #[test]
    fn snapshot_every_validated() {
        assert_eq!(sim_config_from_toml("snapshot_every = 64\n").unwrap().snapshot_every, Some(64));
        // 0 = off (the default), negatives rejected
        assert_eq!(sim_config_from_toml("snapshot_every = 0\n").unwrap().snapshot_every, None);
        assert_eq!(sim_config_from_toml("rounds = 5\n").unwrap().snapshot_every, None);
        assert!(sim_config_from_toml("snapshot_every = -1\n").is_err());
    }

    #[test]
    fn restart_spec_requires_both_rounds_in_order() {
        assert!(sim_config_from_toml("[faults]\nrestart_kill_round = 5\n").is_err());
        assert!(sim_config_from_toml("[faults]\nrestart_round = 5\n").is_err());
        assert!(sim_config_from_toml(
            "[faults]\nrestart_kill_round = 9\nrestart_round = 4\n"
        )
        .is_err());
    }

    #[test]
    fn storage_table_roundtrip_and_validation() {
        let cfg = sim_config_from_toml(
            "[storage]\nfsync_group = 1\nfsync_ms = 2\ntorn_writes = true\n",
        )
        .unwrap();
        let st = cfg.storage.expect("storage parsed");
        assert_eq!(st.fsync_group, 1);
        assert_eq!(st.fsync_ms, 2.0);
        assert!(st.torn_writes);
        // a bare table turns the WAL on with the stock group-commit knobs
        let st = sim_config_from_toml("[storage]\n").unwrap().storage.expect("defaults");
        assert_eq!(st.fsync_group, 8);
        assert!(!st.torn_writes);
        // wal = false is an explicit off switch — stray knobs under it are a
        // config bug, not a silent no-op
        assert!(sim_config_from_toml("[storage]\nwal = false\n").unwrap().storage.is_none());
        assert!(sim_config_from_toml("[storage]\nwal = false\nfsync_group = 8\n").is_err());
        assert!(sim_config_from_toml("[storage]\nfsync_group = 0\n").is_err());
        assert!(sim_config_from_toml("[storage]\nfsync_ms = -0.5\n").is_err());
        // no table at all = amnesiac restarts, preserving historical digests
        assert!(sim_config_from_toml("rounds = 5\n").unwrap().storage.is_none());
    }

    #[test]
    fn nemesis_table_roundtrip() {
        use crate::net::nemesis::PartitionKind;
        let cfg = sim_config_from_toml(
            r#"
protocol = "cabinet"
t = 2
n = 11
pre_vote = true

[nemesis]
drop_p = 0.05
dup_p = 0.02
reorder_p = 0.1
reorder_max_ms = 40
partitions = ["2000..6000=leader", "8000..20000=followers:2"]
"#,
        )
        .unwrap();
        assert!(cfg.pre_vote);
        let nm = cfg.nemesis.expect("nemesis parsed");
        assert_eq!(nm.drop_p, 0.05);
        assert_eq!(nm.dup_p, 0.02);
        assert_eq!(nm.reorder_p, 0.1);
        assert_eq!(nm.reorder_max_ms, 40.0);
        assert_eq!(nm.partitions.len(), 2);
        assert_eq!(nm.partitions[0].kind, PartitionKind::LeaderIsolation);
        assert_eq!(nm.partitions[1].kind, PartitionKind::Followers { count: 2 });
    }

    #[test]
    fn nemesis_validation_rejects_bad_tables() {
        // probability outside [0, 1]
        assert!(sim_config_from_toml("[nemesis]\ndrop_p = 1.5\n").is_err());
        // overlapping partition windows
        assert!(sim_config_from_toml(
            "[nemesis]\npartitions = [\"0..100=leader\", \"50..200=followers:1\"]\n"
        )
        .is_err());
        // group out of range for n
        assert!(sim_config_from_toml("n = 5\n[nemesis]\npartitions = [\"0..10=split:9\"]\n")
            .is_err());
        // malformed DSL
        assert!(sim_config_from_toml("[nemesis]\npartitions = [\"garbage\"]\n").is_err());
        // empty table = no nemesis, defaults stay clean
        let cfg = sim_config_from_toml("[nemesis]\n").unwrap();
        assert!(cfg.nemesis.is_none());
        assert!(!cfg.pre_vote);
    }

    #[test]
    fn read_path_roundtrip_and_validation() {
        let cfg = sim_config_from_toml(
            "protocol = \"cabinet\"\nt = 2\nn = 7\nread_path = \"readindex\"\n",
        )
        .unwrap();
        assert_eq!(cfg.read_path, ReadPath::ReadIndex);
        let cfg =
            sim_config_from_toml("read_path = \"lease\"\nlease_drift_ms = 80\n").unwrap();
        assert_eq!(cfg.read_path, ReadPath::Lease);
        assert_eq!(cfg.lease_drift_ms, 80.0);
        // the default stays on the log path with the stock drift margin
        let cfg = sim_config_from_toml("protocol = \"raft\"\n").unwrap();
        assert_eq!(cfg.read_path, ReadPath::Log);
        assert_eq!(cfg.lease_drift_ms, 50.0);
        // rejected: unknown path, negative drift, drift swallowing the lease
        assert!(sim_config_from_toml("read_path = \"quorum\"\n").is_err());
        assert!(sim_config_from_toml("lease_drift_ms = -1\n").is_err());
        assert!(
            sim_config_from_toml("read_path = \"lease\"\nlease_drift_ms = 2500\n").is_err()
        );
    }

    #[test]
    fn warehouses_validated_at_parse_time() {
        assert!(sim_config_from_toml("[workload]\nkind = \"tpcc\"\nwarehouses = 0\n").is_err());
        assert!(sim_config_from_toml("[workload]\nkind = \"tpcc\"\nwarehouses = -3\n").is_err());
        let cfg =
            sim_config_from_toml("[workload]\nkind = \"tpcc\"\nwarehouses = 4\n").unwrap();
        assert!(matches!(cfg.workload, WorkloadSpec::Tpcc { warehouses: 4, .. }));
    }

    #[test]
    fn sharding_validated_at_parse_time() {
        // happy path: groups + explicit shard_by round-trip
        let cfg = sim_config_from_toml(
            "n = 11\n[sharding]\ngroups = 4\nshard_by = \"hash\"\n",
        )
        .unwrap();
        assert_eq!(cfg.groups, 4);
        assert_eq!(cfg.shard_by, Some(ShardBy::KeyHash));
        let cfg = sim_config_from_toml(
            "n = 8\n[workload]\nkind = \"tpcc\"\nwarehouses = 8\n\
             [sharding]\ngroups = 4\nshard_by = \"warehouse\"\n",
        )
        .unwrap();
        assert_eq!(cfg.groups, 4);
        assert_eq!(cfg.shard_by, Some(ShardBy::Warehouse));
        // default stays single-group with workload-derived shard dimension
        let cfg = sim_config_from_toml("protocol = \"cabinet\"\n").unwrap();
        assert_eq!(cfg.groups, 1);
        assert_eq!(cfg.shard_by, None);
        assert_eq!(cfg.effective_shard_by(), ShardBy::KeyHash);

        // groups < 1 rejected
        assert!(sim_config_from_toml("[sharding]\ngroups = 0\n").is_err());
        assert!(sim_config_from_toml("[sharding]\ngroups = -2\n").is_err());
        // groups > n rejected
        assert!(sim_config_from_toml("n = 5\n[sharding]\ngroups = 6\n").is_err());
        // groups exceeding the YCSB key count rejected
        assert!(sim_config_from_toml(
            "n = 5\n[workload]\nkind = \"ycsb\"\nrecords = 3\n[sharding]\ngroups = 4\n"
        )
        .is_err());
        // groups exceeding the TPC-C warehouse count rejected
        assert!(sim_config_from_toml(
            "n = 5\n[workload]\nkind = \"tpcc\"\nwarehouses = 3\n[sharding]\ngroups = 4\n"
        )
        .is_err());
        // shard dimension must match the workload kind
        assert!(sim_config_from_toml(
            "[sharding]\ngroups = 2\nshard_by = \"warehouse\"\n"
        )
        .is_err());
        assert!(sim_config_from_toml(
            "n = 8\n[workload]\nkind = \"tpcc\"\nwarehouses = 8\n\
             [sharding]\ngroups = 2\nshard_by = \"hash\"\n"
        )
        .is_err());
        // unknown shard dimension rejected
        assert!(sim_config_from_toml("[sharding]\nshard_by = \"modulo\"\n").is_err());
        // HQC cannot shard
        assert!(sim_config_from_toml(
            "protocol = \"hqc\"\nn = 9\nsizes = [3, 3, 3]\n[sharding]\ngroups = 3\n"
        )
        .is_err());
    }

    #[test]
    fn ycsb_records_knob_parses_and_validates() {
        let cfg = sim_config_from_toml("[workload]\nkind = \"ycsb\"\nrecords = 5000\n").unwrap();
        assert!(matches!(cfg.workload, WorkloadSpec::Ycsb { records: 5000, .. }));
        assert!(sim_config_from_toml("[workload]\nkind = \"ycsb\"\nrecords = 0\n").is_err());
        let err = sim_config_from_toml(
            "n = 5\n[workload]\nkind = \"tpcc\"\nwarehouses = 2\n[sharding]\ngroups = 3\n",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("warehouse count"), "{err:#}");
    }

    #[test]
    fn nemesis_group_scope_validated() {
        let cfg = sim_config_from_toml(
            "n = 11\n[sharding]\ngroups = 4\n\
             [nemesis]\ndrop_p = 0.05\ngroups = [0, 2]\n",
        )
        .unwrap();
        assert_eq!(cfg.nemesis_groups, Some(vec![0, 2]));
        // out-of-range group index
        assert!(sim_config_from_toml(
            "n = 11\n[sharding]\ngroups = 2\n[nemesis]\ndrop_p = 0.05\ngroups = [2]\n"
        )
        .is_err());
        // scope without a schedule
        assert!(sim_config_from_toml("n = 11\n[nemesis]\ngroups = [0]\n").is_err());
        // empty scope
        assert!(sim_config_from_toml(
            "n = 11\n[sharding]\ngroups = 2\n[nemesis]\ndrop_p = 0.05\ngroups = []\n"
        )
        .is_err());
    }

    #[test]
    fn membership_table_roundtrip() {
        use crate::net::nemesis::MembershipKind;
        let cfg = sim_config_from_toml(
            r#"
protocol = "cabinet"
t = 1
n = 7
[membership]
members = 5
drain_rounds = 2
join_warmup = 1
events = ["4=join:5", "10=leave:0", "16=replace:1>6"]
"#,
        )
        .unwrap();
        assert_eq!(cfg.initial_members, Some(5));
        assert_eq!(cfg.drain_rounds, 2);
        assert_eq!(cfg.join_warmup, 1);
        let spec = cfg.membership.expect("membership parsed");
        assert_eq!(spec.events.len(), 3);
        assert_eq!(spec.events[0].round, 4);
        assert_eq!(spec.events[0].kind, MembershipKind::Join(5));
        assert_eq!(spec.events[2].kind, MembershipKind::Replace { leave: 1, join: 6 });
        assert!(cfg.membership_on());
    }

    #[test]
    fn membership_table_rejects_bad_knobs() {
        // founding membership out of range
        assert!(sim_config_from_toml("n = 7\n[membership]\nmembers = 2\n").is_err());
        assert!(sim_config_from_toml("n = 7\n[membership]\nmembers = 8\n").is_err());
        assert!(sim_config_from_toml("n = 7\n[membership]\nmembers = -1\n").is_err());
        // drain ramp must exist
        assert!(sim_config_from_toml("n = 7\n[membership]\ndrain_rounds = 0\n").is_err());
        // malformed event DSL
        assert!(sim_config_from_toml(
            "n = 7\n[membership]\nevents = [\"4=promote:5\"]\n"
        )
        .is_err());
        assert!(sim_config_from_toml("n = 7\n[membership]\nevents = [\"garbage\"]\n").is_err());
        // event id out of the slot range
        assert!(sim_config_from_toml("n = 5\n[membership]\nevents = [\"4=join:9\"]\n").is_err());
        // round 0 never fires
        assert!(sim_config_from_toml("n = 7\n[membership]\nevents = [\"0=join:5\"]\n").is_err());
        // self-replace
        assert!(sim_config_from_toml(
            "n = 7\n[membership]\nevents = [\"4=replace:3>3\"]\n"
        )
        .is_err());
        // empty table = membership off, defaults untouched
        let cfg = sim_config_from_toml("n = 7\n[membership]\n").unwrap();
        assert!(!cfg.membership_on());
        assert!(cfg.membership.is_none() && cfg.initial_members.is_none());
    }

    #[test]
    fn coding_and_batching_knobs_roundtrip() {
        let cfg = sim_config_from_toml(
            "protocol = \"cabinet\"\nt = 2\nn = 11\nmax_batch_bytes = 1048576\n\
             [workload]\nkind = \"ycsb\"\nvalue_size = 65536\n\
             [delay]\nmodel = \"d0\"\nbandwidth_bytes_per_ms = 25000\n\
             [coding]\nk = 3\ncutover_bytes = 4096\n",
        )
        .unwrap();
        assert_eq!(cfg.max_batch_bytes, Some(1_048_576));
        assert_eq!(cfg.value_size, 65_536);
        assert_eq!(cfg.bandwidth_bytes_per_ms, Some(25_000.0));
        let c = cfg.coding.expect("coding parsed");
        assert_eq!((c.k, c.cutover_bytes), (3, Some(4096)));
        assert_eq!(cfg.coding_params(), Some((3, 4096)));
        // omitted cutover resolves adaptively from the constrained bandwidth
        let cfg = sim_config_from_toml(
            "n = 11\n[delay]\nbandwidth_bytes_per_ms = 25000\n[coding]\nk = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.coding_params(), Some((3, 35_000)));
        // a bare table turns coding on with the stock k
        let cfg = sim_config_from_toml("n = 11\n[coding]\n").unwrap();
        assert_eq!(cfg.coding.map(|c| c.k), Some(3));
        // enabled = false is an explicit off switch; stray knobs are an error
        assert!(sim_config_from_toml("[coding]\nenabled = false\n").unwrap().coding.is_none());
        assert!(sim_config_from_toml("[coding]\nenabled = false\nk = 3\n").is_err());
        // no table at all = full-copy replication, knobs at their defaults
        let cfg = sim_config_from_toml("rounds = 5\n").unwrap();
        assert!(cfg.coding.is_none() && cfg.max_batch_bytes.is_none());
        assert_eq!(cfg.value_size, 0);
        assert!(cfg.bandwidth_bytes_per_ms.is_none());
        // rejected: k out of range for n, degenerate k, non-positive
        // bandwidth, zero batch budget, oversized values, value_size under
        // TPC-C, coding under HQC
        assert!(sim_config_from_toml("n = 4\n[coding]\nk = 4\n").is_err());
        assert!(sim_config_from_toml("[coding]\nk = 1\n").is_err());
        assert!(sim_config_from_toml("[delay]\nbandwidth_bytes_per_ms = 0\n").is_err());
        assert!(sim_config_from_toml("max_batch_bytes = 0\n").is_err());
        assert!(sim_config_from_toml(
            "[workload]\nkind = \"ycsb\"\nvalue_size = 999999999\n"
        )
        .is_err());
        assert!(sim_config_from_toml(
            "[workload]\nkind = \"tpcc\"\nvalue_size = 1024\n"
        )
        .is_err());
        assert!(sim_config_from_toml(
            "protocol = \"hqc\"\nn = 9\nsizes = [3, 3, 3]\n[coding]\nk = 3\n"
        )
        .is_err());
    }

    #[test]
    fn hqc_sizes() {
        let cfg =
            sim_config_from_toml("protocol = \"hqc\"\nn = 11\nsizes = [3, 3, 5]\n").unwrap();
        match cfg.protocol {
            Protocol::Hqc { sizes } => assert_eq!(sizes, vec![3, 3, 5]),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_unknowns() {
        assert!(sim_config_from_toml("protocol = \"paxos\"\n").is_err());
        assert!(sim_config_from_toml("[delay]\nmodel = \"d9\"\n").is_err());
        assert!(sim_config_from_toml("[workload]\nkind = \"tatp\"\n").is_err());
    }
}
