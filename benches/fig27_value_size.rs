//! `cargo bench` target regenerating Fig 27 — payload-adaptive coded
//! replication vs value size (quick scale; run `cargo run --release
//! --example figures -- fig27 --paper` for the full version). Each cell
//! runs YCSB-A with 1 KiB–256 KiB values on 25 MB/s links, full-copy vs
//! coded (k=3 + XOR parity, adaptive cutover) for Raft and cab f20%. The
//! acceptance shape: below the cutover both variants are bit-for-bit; at
//! 64 KiB+ the coded variant wins on bytes/op and committed wall-clock
//! throughput. Emits `BENCH_fig27_value_size.json` for the CI bench-check
//! job.

use cabinet::bench::{figures, quick_requested, BenchReport, Bencher, Scale};

fn main() {
    let quick = quick_requested();
    let b = Bencher::quick();
    let mut report = BenchReport::new(
        "fig27_value_size",
        "coded replication vs value size: full vs coded (k=3, adaptive cutover); n=7, 25 MB/s links",
        quick,
    );
    let mut last = None;
    b.iter_rec(&mut report, "fig27_value_size", || {
        last = Some(figures::fig27_value_size(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
    match report.write_to_repo_root() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write bench report: {e}");
            std::process::exit(1);
        }
    }
}
