//! Adversarial property tests over the sans-io consensus state machines:
//! random schedules with message drops, duplication and reordering, random
//! timer fires and leader changes — asserting Raft/Cabinet safety
//! (Theorem 4.2) and the weight-scheme invariants throughout.
//!
//! (The vendored crate set has no proptest; this is a seeded-chaos harness
//! with explicit seeds, which doubles as a regression corpus: any failing
//! seed is a one-line reproduction.)

use std::collections::HashMap;
use std::sync::Arc;

use cabinet::consensus::message::{Message, NodeId, Payload};
use cabinet::consensus::node::{Input, Mode, Node, Output, ReadPath, Role};
use cabinet::consensus::weights::WeightScheme;
use cabinet::net::nemesis::Nemesis;
use cabinet::net::rng::Rng;
use cabinet::sim::ReadRecord;

/// A chaos network: pending messages get dropped, duplicated, delayed and
/// reordered under RNG control; nodes can be crash-killed mid-schedule, and
/// an optional [`Nemesis`] layers scheduled partitions (by step index) plus
/// its own loss/duplication on top.
struct Chaos {
    nodes: Vec<Node>,
    alive: Vec<bool>,
    queue: Vec<(NodeId, NodeId, Message)>,
    commits: Vec<Vec<(u64, u64)>>, // per node: (index, term) in commit order
    /// Every leadership establishment: (term, node) — safety-checker input.
    leaders: Vec<(u64, NodeId)>,
    /// Leader-side quorum closures: (leader, wclock, index, quorum weight).
    round_commits: Vec<(NodeId, u64, u64, f64)>,
    /// The same closures in checker form — weighted-rule evidence plus the
    /// coded-reconstruction conjunct (distinct acked shards vs k).
    commit_evidence: Vec<cabinet::sim::CommitEvidence>,
    /// Bytes per proposed payload (0 = the historical tag-only payloads);
    /// coded schedules pad proposals past the shard cutover.
    payload_pad: usize,
    rng: Rng,
    drop_p: f64,
    dup_p: f64,
    /// Scheduled adversarial layer; windows run on the step counter.
    nemesis: Option<Nemesis>,
    step_no: u64,
    // ---- linearizable read evidence (non-log read paths) -----------------
    /// Outstanding reads: id → invocation step.
    read_outstanding: HashMap<u64, f64>,
    next_read_id: u64,
    /// Served reads + the commit timeline, in checker form.
    reads: Vec<ReadRecord>,
    commit_times: Vec<(f64, u64)>,
    /// Lease timing discipline: minimum steps between a node's last
    /// election-timer reset and a delivered `ElectionTimeout`. None = fully
    /// chaotic timers (log/readindex schedules — those paths are safe under
    /// full asynchrony; leases are not, by design).
    et_min_steps: Option<u64>,
    last_reset: Vec<u64>,
}

impl Chaos {
    fn new(n: usize, mode: impl Fn(usize) -> Mode, seed: u64, drop_p: f64, dup_p: f64) -> Self {
        Chaos {
            nodes: (0..n).map(|i| Node::new(i, n, mode(i))).collect(),
            alive: vec![true; n],
            queue: Vec::new(),
            commits: vec![Vec::new(); n],
            leaders: Vec::new(),
            round_commits: Vec::new(),
            commit_evidence: Vec::new(),
            payload_pad: 0,
            rng: Rng::new(seed),
            drop_p,
            dup_p,
            nemesis: None,
            step_no: 0,
            read_outstanding: HashMap::new(),
            next_read_id: 0,
            reads: Vec::new(),
            commit_times: Vec::new(),
            et_min_steps: None,
            last_reset: vec![0; n],
        }
    }

    fn absorb(&mut self, src: NodeId, outs: Vec<Output>) {
        for o in outs {
            match o {
                Output::Send(dst, msg) => self.queue.push((src, dst, msg)),
                Output::Commit(e) => self.commits[src].push((e.index, e.term)),
                Output::BecameLeader { term } => self.leaders.push((term, src)),
                Output::RoundCommitted {
                    wclock, index, quorum_weight, epoch, ct, joint, coded, ..
                } => {
                    self.round_commits.push((src, wclock, index, quorum_weight));
                    self.commit_times.push((self.step_no as f64, index));
                    self.commit_evidence.push(cabinet::sim::CommitEvidence {
                        index,
                        epoch,
                        acc: quorum_weight,
                        ct,
                        joint,
                        coded,
                    });
                }
                Output::ResetElectionTimer => self.last_reset[src] = self.step_no,
                Output::ReadReady { id, index, lease } => {
                    if let Some(invoked) = self.read_outstanding.remove(&id) {
                        self.reads.push(ReadRecord {
                            node: src,
                            id,
                            invoked_ms: invoked,
                            served_ms: self.step_no as f64,
                            read_index: index,
                            lease,
                        });
                    }
                }
                Output::ReadFailed { id } => {
                    // dropped reads are simply re-issued later as fresh ids
                    self.read_outstanding.remove(&id);
                }
                _ => {}
            }
        }
    }

    /// Step one node with the harness clock observed (lease bookkeeping).
    fn step_node(&mut self, node: NodeId, input: Input) {
        self.nodes[node].observe_time(self.step_no as f64);
        let outs = self.nodes[node].step(input);
        self.absorb(node, outs);
    }

    /// The run's safety evidence, in checker form.
    fn safety_log(&self) -> cabinet::sim::SafetyLog {
        let mut log = cabinet::sim::SafetyLog::new(self.nodes.len());
        log.commits = self.commits.clone();
        log.leaders = self.leaders.clone();
        log.commit_times = self.commit_times.clone();
        log.reads = self.reads.clone();
        log.commit_evidence = self.commit_evidence.clone();
        log
    }

    /// Issue a linearizable read at a random alive node (non-log schedules).
    fn try_read(&mut self) {
        let n = self.nodes.len();
        let node = self.rng.below(n as u64) as usize;
        if !self.alive[node] {
            return;
        }
        let id = self.next_read_id;
        self.next_read_id += 1;
        self.read_outstanding.insert(id, self.step_no as f64);
        self.step_node(node, Input::Read { id });
    }

    /// Crash a node: it stops stepping and every message to it is dropped.
    fn kill(&mut self, node: NodeId) {
        self.alive[node] = false;
    }

    /// One chaos step: either deliver a random queued message (maybe
    /// dropping/duplicating it, maybe cut by the nemesis) or fire a random
    /// timer. The step counter doubles as the nemesis's time axis.
    fn step(&mut self) {
        self.step_no += 1;
        let n = self.nodes.len();
        let fire_timer = self.queue.is_empty() || self.rng.chance(0.08);
        if fire_timer {
            let node = self.rng.below(n as u64) as usize;
            if !self.alive[node] {
                return;
            }
            let input = if self.rng.chance(0.5) && self.nodes[node].role() == Role::Leader {
                Input::HeartbeatTimeout
            } else {
                // Lease schedules model a minimum election timeout: a node
                // fires only once `et_min_steps` have passed since its last
                // timer reset. This is the §6.4.1 timing assumption leases
                // rest on — without it, arbitrary timer fires could elect a
                // new leader inside a still-valid lease window, and the
                // "stale" reads the checker would flag are exactly the ones
                // real deployments exclude by bounding clock drift.
                if let Some(min) = self.et_min_steps {
                    if self.step_no.saturating_sub(self.last_reset[node]) < min {
                        return;
                    }
                }
                Input::ElectionTimeout
            };
            self.step_node(node, input);
            return;
        }
        let pick = self.rng.below(self.queue.len() as u64) as usize;
        let (src, dst, msg) = self.queue.swap_remove(pick); // reorders
        if !self.alive[dst] || self.rng.chance(self.drop_p) {
            return; // dropped (dead receiver or lossy link)
        }
        let leader = self.leader();
        let now = self.step_no;
        if let Some(nm) = self.nemesis.as_mut() {
            let fate = nm.fate(now as f64, src, dst, leader);
            if fate.copies == 0 {
                return; // partitioned or lost by the nemesis
            }
            if fate.copies > 1 {
                // duplicate back into the pool — a later pick redelivers it
                self.queue.push((src, dst, msg.clone()));
            }
        }
        if self.rng.chance(self.dup_p) {
            self.queue.push((src, dst, msg.clone())); // duplicated
        }
        self.step_node(dst, Input::Receive(src, msg));
    }

    fn leader(&self) -> Option<NodeId> {
        (0..self.nodes.len())
            .find(|&i| self.alive[i] && self.nodes[i].role() == Role::Leader)
    }

    /// A tagged payload, padded to `payload_pad` bytes on coded schedules
    /// so data rounds cross the shard cutover.
    fn payload(&self, tag: &[u8]) -> Payload {
        let mut data = tag.to_vec();
        if data.len() < self.payload_pad {
            data.resize(self.payload_pad, tag[0]);
        }
        Payload::Bytes(Arc::new(data))
    }

    /// Propose at whichever node is currently a leader (if any).
    fn try_propose(&mut self, k: u8) {
        if let Some(leader) = self.leader() {
            let p = self.payload(&[k]);
            self.step_node(leader, Input::Propose(p));
        }
    }

    /// Burst-propose `depth` rounds back-to-back at the current leader — the
    /// pipelined client pattern: no waiting for acks between proposals.
    fn try_propose_burst(&mut self, depth: usize, tag: u8) {
        if let Some(leader) = self.leader() {
            for j in 0..depth {
                if self.leader() != Some(leader) {
                    break;
                }
                let p = self.payload(&[tag, j as u8]);
                self.step_node(leader, Input::Propose(p));
            }
        }
    }

    /// Deliver everything remaining without faults (quiescence).
    fn settle(&mut self) {
        for _ in 0..50_000 {
            if self.queue.is_empty() {
                break;
            }
            let (src, dst, msg) = self.queue.remove(0);
            if !self.alive[dst] {
                continue;
            }
            self.step_node(dst, Input::Receive(src, msg));
        }
    }

    /// SAFETY: committed sequences must agree on (index → term) — no two
    /// nodes decide differently at any index (Theorem 4.2).
    fn assert_safety(&self, seed: u64) {
        for a in 0..self.nodes.len() {
            for b in (a + 1)..self.nodes.len() {
                let ca = &self.commits[a];
                let cb = &self.commits[b];
                for (ia, ta) in ca {
                    for (ib, tb) in cb {
                        if ia == ib {
                            assert_eq!(
                                ta, tb,
                                "seed {seed}: nodes {a} and {b} committed different \
                                 terms at index {ia}"
                            );
                        }
                    }
                }
            }
        }
        // commit order is by increasing index on every node
        for (i, c) in self.commits.iter().enumerate() {
            for w in c.windows(2) {
                assert!(w[0].0 < w[1].0, "node {i} committed out of order: {c:?}");
            }
        }
    }

    /// Cabinet leaders always hold a weight assignment that is exactly the
    /// scheme's multiset (weights are re-dealt, never invented).
    fn assert_weight_permutation(&self) {
        for node in &self.nodes {
            if node.role() != Role::Leader {
                continue;
            }
            if let Mode::Cabinet { scheme } = node.mode() {
                let mut got: Vec<f64> = node.weight_assignment().to_vec();
                got.sort_by(|x, y| y.partial_cmp(x).unwrap());
                for (g, w) in got.iter().zip(scheme.weights()) {
                    assert!((g - w).abs() < 1e-9, "weights not a permutation");
                }
            }
        }
    }

    /// Log matching (Raft §5.3 / Theorem 4.2): whenever two nodes hold the
    /// same `(index, term)` entry, their logs agree on the entire prefix.
    fn assert_log_matching(&self, seed: u64) {
        let n = self.nodes.len();
        for a in 0..n {
            for b in (a + 1)..n {
                let (la, lb) = (self.nodes[a].log(), self.nodes[b].log());
                let common = la.last_index().min(lb.last_index());
                // highest index where the two logs carry the same term
                let agree = (1..=common)
                    .rev()
                    .find(|&i| la.term_at(i).is_some() && la.term_at(i) == lb.term_at(i));
                if let Some(i) = agree {
                    assert_eq!(
                        la.prefix_digest(i),
                        lb.prefix_digest(i),
                        "seed {seed}: nodes {a} and {b} agree at index {i} but \
                         diverge below it"
                    );
                }
            }
        }
    }

    /// Weighted-commit rule: every quorum a leader closed accumulated
    /// strictly more weight than the scheme's consensus threshold, and a
    /// node's (wclock, commit index) pairs advance monotonically.
    fn assert_weighted_commits(&self, ct: f64, seed: u64) {
        for &(node, _, _, qw) in &self.round_commits {
            assert!(
                qw > ct - 1e-9,
                "seed {seed}: node {node} closed a quorum at weight {qw} <= CT {ct}"
            );
        }
        let n = self.nodes.len();
        for node in 0..n {
            let mine: Vec<(u64, u64)> = self
                .round_commits
                .iter()
                .filter(|(who, ..)| *who == node)
                .map(|&(_, wc, idx, _)| (wc, idx))
                .collect();
            for w in mine.windows(2) {
                assert!(
                    w[0].0 <= w[1].0,
                    "seed {seed}: node {node} weight clock went backwards: {mine:?}"
                );
                assert!(
                    w[0].1 < w[1].1,
                    "seed {seed}: node {node} commit index not monotone: {mine:?}"
                );
            }
        }
    }

    /// No committed entry is ever lost or rewritten: everything committed at
    /// `before` must appear, with the same term, in any node's later
    /// committed sequence that reaches that index.
    fn assert_commits_preserved(&self, before: &[(u64, u64)], seed: u64) {
        for (idx, term) in before {
            for node_commits in &self.commits {
                if let Some((_, t2)) = node_commits.iter().find(|(i2, _)| i2 == idx) {
                    assert_eq!(
                        t2, term,
                        "seed {seed}: committed entry at index {idx} was rewritten"
                    );
                }
            }
        }
    }
}

fn chaos_run(n: usize, mode: impl Fn(usize) -> Mode + Copy, seed: u64, steps: usize) {
    let mut c = Chaos::new(n, mode, seed, 0.10, 0.10);
    // bootstrap one election
    let outs = c.nodes[0].step(Input::ElectionTimeout);
    c.absorb(0, outs);
    for i in 0..steps {
        c.step();
        if i % 37 == 0 {
            c.try_propose((i % 251) as u8);
        }
        if i % 101 == 0 {
            c.assert_weight_permutation();
        }
    }
    c.settle();
    c.assert_safety(seed);
}

#[test]
fn raft_safety_under_chaos() {
    for seed in 0..30 {
        chaos_run(5, |_| Mode::Raft, seed, 4000);
    }
}

#[test]
fn cabinet_safety_under_chaos() {
    for seed in 0..30 {
        chaos_run(5, |_| Mode::cabinet(5, 1), seed, 4000);
        chaos_run(7, |_| Mode::cabinet(7, 2), seed + 1000, 4000);
    }
}

#[test]
fn cabinet_safety_larger_cluster() {
    for seed in 0..8 {
        chaos_run(11, |_| Mode::cabinet(11, 4), seed + 77, 8000);
    }
}

#[test]
fn at_most_one_leader_per_term() {
    for seed in 0..20 {
        let mut c = Chaos::new(7, |_| Mode::cabinet(7, 3), seed, 0.15, 0.05);
        let outs = c.nodes[0].step(Input::ElectionTimeout);
        c.absorb(0, outs);
        let mut leaders_by_term: Vec<(u64, NodeId)> = Vec::new();
        for _ in 0..6000 {
            c.step();
            for (i, node) in c.nodes.iter().enumerate() {
                if node.role() == Role::Leader {
                    let term = node.term();
                    match leaders_by_term.iter().find(|(t, _)| *t == term) {
                        Some((_, id)) => assert_eq!(
                            *id, i,
                            "seed {seed}: two leaders in term {term}"
                        ),
                        None => leaders_by_term.push((term, i)),
                    }
                }
            }
        }
    }
}

#[test]
fn committed_entries_survive_leader_changes() {
    // force repeated elections; whatever was committed must never be lost
    for seed in 0..15 {
        let mut c = Chaos::new(5, |_| Mode::cabinet(5, 2), seed, 0.0, 0.0);
        let outs = c.nodes[0].step(Input::ElectionTimeout);
        c.absorb(0, outs);
        c.settle();
        c.try_propose(1);
        c.settle();
        let committed_before: Vec<_> = c.commits[0].clone();
        assert!(!committed_before.is_empty(), "seed {seed}: nothing committed");
        // new election at a different node
        let mut rng = Rng::new(seed);
        for _ in 0..3 {
            let cand = 1 + rng.below(4) as usize;
            let outs = c.nodes[cand].step(Input::ElectionTimeout);
            c.absorb(cand, outs);
            c.settle();
            c.try_propose(9);
            c.settle();
        }
        c.assert_safety(seed);
        // every index committed before is still committed with same term
        for (idx, term) in &committed_before {
            for node_commits in &c.commits {
                if let Some((_, t2)) = node_commits.iter().find(|(i2, _)| i2 == idx) {
                    assert_eq!(t2, term, "seed {seed}: committed entry rewritten");
                }
            }
        }
    }
}

/// One randomized-schedule run: seeded chaos mixing drop/duplication rates
/// (adversarial reordering doubles as unbounded delay skew), a scheduled
/// nemesis window (partition/heal of rotating kinds + 1–10% extra loss +
/// duplication), mid-schedule crash kills, PreVote on half the schedules,
/// and pipelined proposal bursts at depth 1–8. Half the schedules
/// additionally run snapshot compaction at tiny intervals (1–3 committed
/// entries), so InstallSnapshot catch-up races the chaos too; half run a
/// fast linearizable read path (25% ReadIndex, 25% lease — lease schedules
/// model the minimum election timeout on the step axis) with client reads
/// injected throughout; half run payload-adaptive coded replication (k = 2
/// data shards + XOR parity, 64-byte cutover, proposals padded past it) so
/// the k-distinct-shards commit conjunct races the same chaos. Asserts
/// election safety, log matching (digest-chained across compaction), the
/// weighted-commit rule + monotonicity, no committed-entry loss, and a
/// clean `bench::safety` verdict — prefix consistency,
/// single-leader-per-term, monotone commits, read linearizability, and
/// coded-reconstruction evidence — at every depth. Returns the number of
/// coded round commits observed so sweeps can assert the slice is live.
fn nemesis_schedule(seed: u64) -> usize {
    use cabinet::net::nemesis::{NemesisSpec, PartitionKind, PartitionSpec};
    use cabinet::net::rng::splitmix64;

    // Decorrelated schedule dimensions: modular selectors on the raw seed
    // would alias (e.g. seed % 4 picking both the protocol and the
    // partition kind means Cabinet × LeaderIsolation never occurs), so the
    // interacting dimensions — protocol, PreVote, partition kind,
    // compaction — each take independent bits of a hashed seed. Over 128
    // seeds every protocol × PreVote × kind combination appears.
    let mut h = seed ^ 0x5EED_0F_CAB1_2357;
    let bits = splitmix64(&mut h);
    let raft = bits & 3 == 0; // 25% Raft, 75% Cabinet
    let pre_vote_on = (bits >> 2) & 1 == 1;
    let kind_sel = (bits >> 3) & 3;
    let compact = (bits >> 5) & 1 == 1;
    // half the schedules run a fast read path (25% readindex, 25% lease) —
    // the read-linearizability checker runs on every schedule either way
    let read_path = match (bits >> 6) & 3 {
        2 => ReadPath::ReadIndex,
        3 => ReadPath::Lease,
        _ => ReadPath::Log,
    };

    let depth = 1 + (seed % 8) as usize;
    let n = [5usize, 7, 9][(seed % 3) as usize];
    let cabinet_t = 1 + (seed % 2) as usize;
    let mode = move |_i: usize| {
        if raft {
            Mode::Raft
        } else {
            Mode::cabinet(n, cabinet_t)
        }
    };
    let ct = if raft {
        n as f64 / 2.0
    } else {
        WeightScheme::geometric(n, cabinet_t).unwrap().ct()
    };
    let drop_p = 0.02 + (seed % 5) as f64 * 0.03;
    let dup_p = 0.02 + (seed % 3) as f64 * 0.04;
    let mut c = Chaos::new(n, mode, seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1, drop_p, dup_p);
    if compact {
        let every = 1 + (seed % 3); // aggressive: compact every 1–3 commits
        for node in &mut c.nodes {
            node.set_snapshot_every(Some(every));
        }
    }
    if pre_vote_on {
        for node in &mut c.nodes {
            node.set_pre_vote(true);
        }
    }
    // half the schedules ship data rounds coded — k = 2 data shards + XOR
    // parity (m = 3 fits every n here) with a 64-byte cutover; proposals
    // are padded to 256 bytes so every client round crosses it. Crash kills
    // can legitimately leave the survivors short of k distinct shard slots,
    // in which case coded rounds (safely) stop committing — the checker's
    // reconstruction property validates every round that did commit.
    let coded = (bits >> 8) & 1 == 1;
    if coded {
        for node in &mut c.nodes {
            node.set_coding(Some((2, 64)));
        }
        c.payload_pad = 256;
    }
    // Lease timing: a 150-step minimum election timeout with a 30-step
    // drift margin (duration 120). ReadIndex needs no timing assumption.
    const ET_MIN_STEPS: u64 = 150;
    if !matches!(read_path, ReadPath::Log) {
        for node in &mut c.nodes {
            node.set_read_path(read_path);
            node.set_lease_duration_ms((ET_MIN_STEPS - 30) as f64);
        }
        if matches!(read_path, ReadPath::Lease) {
            c.et_min_steps = Some(ET_MIN_STEPS);
        }
    }
    // scheduled nemesis: a partition window over steps [600, 1400) of a
    // kind rotating with the hashed seed, plus 1–10% extra loss and dup
    let kind = match kind_sel {
        0 => PartitionKind::LeaderIsolation,
        1 => PartitionKind::Followers { count: 2.min(n - 3) },
        2 => PartitionKind::Split { group: vec![n - 1] },
        _ => PartitionKind::OneWay { group: vec![n - 2] },
    };
    let spec = NemesisSpec {
        partitions: vec![PartitionSpec::new(600.0, 1400.0, kind)],
        drop_p: 0.01 + (seed % 10) as f64 * 0.01,
        dup_p: 0.01 + (seed % 7) as f64 * 0.01,
        reorder_p: 0.0, // the chaos queue already delivers in random order
        reorder_max_ms: 0.0,
    };
    spec.validate(n).expect("sweep spec must be valid");
    c.nemesis = Some(Nemesis::new(spec, n, Rng::new(seed ^ 0xBAD_C0DE)));

    let outs = c.nodes[0].step(Input::ElectionTimeout);
    c.absorb(0, outs);
    let mut sched = Rng::new(seed ^ 0x00C0_FFEE);
    let mut committed_snapshot: Vec<(u64, u64)> = Vec::new();
    for i in 0..2000usize {
        c.step();
        if i % 37 == 0 {
            c.try_propose_burst(depth, (i % 251) as u8);
        }
        if i % 29 == 0 && !matches!(read_path, ReadPath::Log) {
            c.try_read();
        }
        if i == 900 {
            // snapshot what's committed so far, then crash two
            // non-leader nodes on two thirds of the schedules
            committed_snapshot = c.commits.iter().flatten().copied().collect();
            if seed % 3 != 2 {
                let leader = c.leader();
                let mut victims = 0;
                while victims < 2 {
                    let v = sched.below(n as u64) as usize;
                    if Some(v) != leader && c.alive[v] {
                        c.kill(v);
                        victims += 1;
                    }
                }
            }
        }
        if i % 97 == 0 {
            c.assert_weight_permutation();
        }
    }
    c.settle();
    c.assert_safety(seed);
    c.assert_log_matching(seed);
    c.assert_weighted_commits(ct, seed);
    c.assert_commits_preserved(&committed_snapshot, seed);
    // the deterministic safety checker agrees: prefix consistency, single
    // leader per term, monotone commits, weighted-rule + coded evidence
    let report = cabinet::bench::safety_check(&c.safety_log());
    assert!(report.is_clean(), "seed {seed}: {:?}", report.violations);
    let coded_commits =
        c.commit_evidence.iter().filter(|e| e.coded.is_some()).count();
    if !coded {
        assert_eq!(coded_commits, 0, "seed {seed}: coded-off schedule emitted shard evidence");
    }
    coded_commits
}

#[test]
fn randomized_schedule_safety_sweep() {
    let mut coded_commits = 0usize;
    for seed in 0..128u64 {
        coded_commits += nemesis_schedule(seed);
    }
    // the coded slice must actually exercise the shard commit rule
    assert!(coded_commits > 0, "no coded round ever committed across the sweep");
}

/// The long chaos sweep for the scheduled CI `chaos` job:
/// `cargo test --release -- --ignored nemesis_long_sweep`.
#[test]
#[ignore = "long nemesis sweep (512 seeds) — run by the scheduled CI chaos job"]
fn nemesis_long_sweep() {
    let mut coded_commits = 0usize;
    for seed in 0..512u64 {
        coded_commits += nemesis_schedule(seed);
    }
    assert!(coded_commits > 0, "no coded round ever committed across the sweep");
}

/// Full-stack randomized sims over the event-driven harness: random delay
/// models, kills, contention, and pipeline depth 1–8. Every configuration
/// completes its rounds, replicas converge, and each run is a pure function
/// of its seed (bit-identical replay of both commit sequence and metrics).
#[test]
fn randomized_sim_configs_safe_and_deterministic() {
    use cabinet::net::delay::DelayModel;
    use cabinet::net::fault::{ContentionSpec, KillSpec, KillStrategy};
    use cabinet::sim::{run, DigestMode, Protocol, SimConfig, WorkloadSpec};
    use cabinet::workload::Workload;

    for seed in 0..24u64 {
        let depth = [1usize, 2, 4, 8][(seed % 4) as usize];
        let n = [5usize, 7, 11][(seed % 3) as usize];
        let t = 1 + (seed % 2) as usize;
        let mut c = SimConfig::new(Protocol::Cabinet { t }, n, true);
        c.rounds = 6;
        c.pipeline = depth;
        c.seed = 1000 + seed;
        c.digest_mode = DigestMode::All;
        c.workload =
            WorkloadSpec::Ycsb { workload: Workload::A, batch: 200, records: 5_000 };
        c.delay = match seed % 3 {
            0 => DelayModel::None,
            1 => DelayModel::Uniform { mean_ms: 60.0, spread_ms: 15.0 },
            _ => DelayModel::Skew,
        };
        if seed % 4 == 1 {
            c.kills = vec![KillSpec::new(3, 1, KillStrategy::Weak)];
        }
        if seed % 4 == 2 {
            c.contention = Some(ContentionSpec::new(3, 2.0));
        }
        let a = run(&c);
        assert_eq!(a.rounds.len(), 6, "seed {seed} depth {depth}: rounds incomplete");
        assert_eq!(a.digests_match, Some(true), "seed {seed}: replicas diverged");
        let b = run(&c);
        assert_eq!(a.metrics_digest(), b.metrics_digest(), "seed {seed}: replay diverged");
        assert_eq!(
            a.commit_sequence_digest(),
            b.commit_sequence_digest(),
            "seed {seed}: commit sequence diverged"
        );
    }
}

/// Sharded slice: 64 seeds of G = 4 groups over one fabric, each with a
/// *per-shard* nemesis window — one rotating victim group runs a
/// leader-isolation schedule with light loss/duplication while the other
/// three shards stay clean. The `bench::safety` checker runs on every
/// group's evidence (consensus is per-group: prefix consistency,
/// single-leader-per-term and monotone commits must hold inside each
/// shard), every shard must finish its rounds despite its neighbors'
/// chaos, and the whole sharded run must replay bit-for-bit.
#[test]
fn sharded_randomized_safety_sweep() {
    use cabinet::net::delay::DelayModel;
    use cabinet::net::nemesis::{NemesisSpec, PartitionKind, PartitionSpec};
    use cabinet::sim::{run, Protocol, SimConfig, WorkloadSpec};
    use cabinet::workload::Workload;

    let groups = 4usize;
    for seed in 0..64u64 {
        let t = 1 + (seed % 2) as usize;
        let depth = [1usize, 2][(seed % 2) as usize];
        let mut c = SimConfig::new(Protocol::Cabinet { t }, 11, true);
        c.rounds = 4;
        c.pipeline = depth;
        c.seed = 9_000 + seed;
        c.groups = groups;
        c.track_safety = true;
        c.pre_vote = seed % 2 == 0;
        c.workload =
            WorkloadSpec::Ycsb { workload: Workload::A, batch: 200, records: 5_000 };
        c.delay = if seed % 3 == 0 {
            DelayModel::Uniform { mean_ms: 60.0, spread_ms: 15.0 }
        } else {
            DelayModel::None
        };
        // per-shard nemesis window: leader isolation early in the run plus
        // 2% loss / 1% duplication, confined to the rotating victim group
        let victim = (seed % groups as u64) as usize;
        c.nemesis = Some(NemesisSpec {
            partitions: vec![PartitionSpec::new(
                // open early so the window catches the victim shard mid-run
                // even on the fast d0 schedules
                50.0 + 100.0 * (seed % 5) as f64,
                4_000.0,
                PartitionKind::LeaderIsolation,
            )],
            drop_p: 0.02,
            dup_p: 0.01,
            reorder_p: 0.0,
            reorder_max_ms: 0.0,
        });
        c.nemesis_groups = Some(vec![victim]);

        let a = run(&c);
        assert_eq!(
            a.rounds.len() as u64,
            groups as u64 * c.rounds,
            "seed {seed}: a shard stalled (victim {victim})"
        );
        assert_eq!(a.group_safety.len(), groups, "seed {seed}: missing group evidence");
        for (g, log) in a.group_safety.iter().enumerate() {
            let report = cabinet::bench::safety_check(log);
            assert!(
                report.is_clean(),
                "seed {seed} group {g} (victim {victim}): {:?}",
                report.violations
            );
        }
        assert!(a.nemesis_stats.is_some(), "seed {seed}: victim group ran no nemesis");
        let b = run(&c);
        assert_eq!(a.metrics_digest(), b.metrics_digest(), "seed {seed}: replay diverged");
        assert_eq!(
            a.commit_sequence_digest(),
            b.commit_sequence_digest(),
            "seed {seed}: commit sequence diverged"
        );
    }
}

#[test]
fn weight_scheme_invariants_random_nt() {
    // randomized (n, t) sweep — the property-based check for Eq. 2
    let mut rng = Rng::new(2024);
    for _ in 0..300 {
        let n = 3 + rng.below(126) as usize;
        let t_max = (n - 1) / 2;
        let t = 1 + rng.below(t_max as u64) as usize;
        let ws = WeightScheme::geometric(n, t)
            .unwrap_or_else(|e| panic!("n={n} t={t}: {e}"));
        ws.validate().unwrap();
        assert!(ws.non_cabinet_weight() < ws.ct(), "L3.1 n={n} t={t}");
        assert!(ws.lightest_survivor_weight() > ws.ct(), "L3.2 n={n} t={t}");
        // strictly descending and positive
        for w in ws.weights().windows(2) {
            assert!(w[0] > w[1] && w[1] > 0.0);
        }
    }
}

#[test]
fn fifo_reassignment_tracks_any_reply_permutation() {
    // For arbitrary reply orders, next-round ranks must follow FIFO order.
    let mut rng = Rng::new(7);
    for _ in 0..50 {
        let n = 5 + rng.below(8) as usize % 8; // 5..12
        let t = 1 + rng.below(((n - 1) / 2) as u64) as usize;
        let mut leader = Node::new(0, n, Mode::cabinet(n, t));
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..n {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
        }
        assert_eq!(leader.role(), Role::Leader);
        let _ = leader.step(Input::Propose(Payload::Noop));
        let wc = leader.wclock();
        let last = leader.log().last_index();
        let mut order: Vec<usize> = (1..n).collect();
        rng.shuffle(&mut order);
        for &p in &order {
            let _ = leader.step(Input::Receive(
                p,
                Message::AppendEntriesReply {
                    term: 1,
                    from: p,
                    success: true,
                    match_index: last,
                    wclock: wc,
                },
            ));
        }
        let _ = leader.step(Input::Propose(Payload::Noop));
        let scheme = WeightScheme::geometric(n, t).unwrap();
        let w = leader.weight_assignment();
        assert!((w[0] - scheme.weight_of_rank(0)).abs() < 1e-12);
        for (rank, &p) in order.iter().enumerate() {
            assert!(
                (w[p] - scheme.weight_of_rank(rank + 1)).abs() < 1e-12,
                "n={n} t={t}: reply rank {rank} node {p} got weight {}",
                w[p]
            );
        }
    }
}
