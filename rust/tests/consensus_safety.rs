//! Adversarial property tests over the sans-io consensus state machines:
//! random schedules with message drops, duplication and reordering, random
//! timer fires and leader changes — asserting Raft/Cabinet safety
//! (Theorem 4.2) and the weight-scheme invariants throughout.
//!
//! (The vendored crate set has no proptest; this is a seeded-chaos harness
//! with explicit seeds, which doubles as a regression corpus: any failing
//! seed is a one-line reproduction.)

use std::sync::Arc;

use cabinet::consensus::message::{Message, NodeId, Payload};
use cabinet::consensus::node::{Input, Mode, Node, Output, Role};
use cabinet::consensus::weights::WeightScheme;
use cabinet::net::rng::Rng;

/// A chaos network: pending messages get dropped, duplicated, delayed and
/// reordered under RNG control.
struct Chaos {
    nodes: Vec<Node>,
    queue: Vec<(NodeId, NodeId, Message)>,
    commits: Vec<Vec<(u64, u64)>>, // per node: (index, term) in commit order
    rng: Rng,
    drop_p: f64,
    dup_p: f64,
}

impl Chaos {
    fn new(n: usize, mode: impl Fn(usize) -> Mode, seed: u64, drop_p: f64, dup_p: f64) -> Self {
        Chaos {
            nodes: (0..n).map(|i| Node::new(i, n, mode(i))).collect(),
            queue: Vec::new(),
            commits: vec![Vec::new(); n],
            rng: Rng::new(seed),
            drop_p,
            dup_p,
        }
    }

    fn absorb(&mut self, src: NodeId, outs: Vec<Output>) {
        for o in outs {
            match o {
                Output::Send(dst, msg) => self.queue.push((src, dst, msg)),
                Output::Commit(e) => self.commits[src].push((e.index, e.term)),
                _ => {}
            }
        }
    }

    /// One chaos step: either deliver a random queued message (maybe
    /// dropping/duplicating it) or fire a random timer.
    fn step(&mut self) {
        let n = self.nodes.len();
        let fire_timer = self.queue.is_empty() || self.rng.chance(0.08);
        if fire_timer {
            let node = self.rng.below(n as u64) as usize;
            let input = if self.rng.chance(0.5) && self.nodes[node].role() == Role::Leader {
                Input::HeartbeatTimeout
            } else {
                Input::ElectionTimeout
            };
            let outs = self.nodes[node].step(input);
            self.absorb(node, outs);
            return;
        }
        let pick = self.rng.below(self.queue.len() as u64) as usize;
        let (src, dst, msg) = self.queue.swap_remove(pick); // reorders
        if self.rng.chance(self.drop_p) {
            return; // dropped
        }
        if self.rng.chance(self.dup_p) {
            self.queue.push((src, dst, msg.clone())); // duplicated
        }
        let outs = self.nodes[dst].step(Input::Receive(src, msg));
        self.absorb(dst, outs);
    }

    /// Propose at whichever node is currently a leader (if any).
    fn try_propose(&mut self, k: u8) {
        if let Some(leader) =
            (0..self.nodes.len()).find(|&i| self.nodes[i].role() == Role::Leader)
        {
            let outs =
                self.nodes[leader].step(Input::Propose(Payload::Bytes(Arc::new(vec![k]))));
            self.absorb(leader, outs);
        }
    }

    /// Deliver everything remaining without faults (quiescence).
    fn settle(&mut self) {
        for _ in 0..50_000 {
            if self.queue.is_empty() {
                break;
            }
            let (src, dst, msg) = self.queue.remove(0);
            let outs = self.nodes[dst].step(Input::Receive(src, msg));
            self.absorb(dst, outs);
        }
    }

    /// SAFETY: committed sequences must agree on (index → term) — no two
    /// nodes decide differently at any index (Theorem 4.2).
    fn assert_safety(&self, seed: u64) {
        for a in 0..self.nodes.len() {
            for b in (a + 1)..self.nodes.len() {
                let ca = &self.commits[a];
                let cb = &self.commits[b];
                for (ia, ta) in ca {
                    for (ib, tb) in cb {
                        if ia == ib {
                            assert_eq!(
                                ta, tb,
                                "seed {seed}: nodes {a} and {b} committed different \
                                 terms at index {ia}"
                            );
                        }
                    }
                }
            }
        }
        // commit order is by increasing index on every node
        for (i, c) in self.commits.iter().enumerate() {
            for w in c.windows(2) {
                assert!(w[0].0 < w[1].0, "node {i} committed out of order: {c:?}");
            }
        }
    }

    /// Cabinet leaders always hold a weight assignment that is exactly the
    /// scheme's multiset (weights are re-dealt, never invented).
    fn assert_weight_permutation(&self) {
        for node in &self.nodes {
            if node.role() != Role::Leader {
                continue;
            }
            if let Mode::Cabinet { scheme } = node.mode() {
                let mut got: Vec<f64> = node.weight_assignment().to_vec();
                got.sort_by(|x, y| y.partial_cmp(x).unwrap());
                for (g, w) in got.iter().zip(scheme.weights()) {
                    assert!((g - w).abs() < 1e-9, "weights not a permutation");
                }
            }
        }
    }
}

fn chaos_run(n: usize, mode: impl Fn(usize) -> Mode + Copy, seed: u64, steps: usize) {
    let mut c = Chaos::new(n, mode, seed, 0.10, 0.10);
    // bootstrap one election
    let outs = c.nodes[0].step(Input::ElectionTimeout);
    c.absorb(0, outs);
    for i in 0..steps {
        c.step();
        if i % 37 == 0 {
            c.try_propose((i % 251) as u8);
        }
        if i % 101 == 0 {
            c.assert_weight_permutation();
        }
    }
    c.settle();
    c.assert_safety(seed);
}

#[test]
fn raft_safety_under_chaos() {
    for seed in 0..30 {
        chaos_run(5, |_| Mode::Raft, seed, 4000);
    }
}

#[test]
fn cabinet_safety_under_chaos() {
    for seed in 0..30 {
        chaos_run(5, |_| Mode::cabinet(5, 1), seed, 4000);
        chaos_run(7, |_| Mode::cabinet(7, 2), seed + 1000, 4000);
    }
}

#[test]
fn cabinet_safety_larger_cluster() {
    for seed in 0..8 {
        chaos_run(11, |_| Mode::cabinet(11, 4), seed + 77, 8000);
    }
}

#[test]
fn at_most_one_leader_per_term() {
    for seed in 0..20 {
        let mut c = Chaos::new(7, |_| Mode::cabinet(7, 3), seed, 0.15, 0.05);
        let outs = c.nodes[0].step(Input::ElectionTimeout);
        c.absorb(0, outs);
        let mut leaders_by_term: Vec<(u64, NodeId)> = Vec::new();
        for _ in 0..6000 {
            c.step();
            for (i, node) in c.nodes.iter().enumerate() {
                if node.role() == Role::Leader {
                    let term = node.term();
                    match leaders_by_term.iter().find(|(t, _)| *t == term) {
                        Some((_, id)) => assert_eq!(
                            *id, i,
                            "seed {seed}: two leaders in term {term}"
                        ),
                        None => leaders_by_term.push((term, i)),
                    }
                }
            }
        }
    }
}

#[test]
fn committed_entries_survive_leader_changes() {
    // force repeated elections; whatever was committed must never be lost
    for seed in 0..15 {
        let mut c = Chaos::new(5, |_| Mode::cabinet(5, 2), seed, 0.0, 0.0);
        let outs = c.nodes[0].step(Input::ElectionTimeout);
        c.absorb(0, outs);
        c.settle();
        c.try_propose(1);
        c.settle();
        let committed_before: Vec<_> = c.commits[0].clone();
        assert!(!committed_before.is_empty(), "seed {seed}: nothing committed");
        // new election at a different node
        let mut rng = Rng::new(seed);
        for _ in 0..3 {
            let cand = 1 + rng.below(4) as usize;
            let outs = c.nodes[cand].step(Input::ElectionTimeout);
            c.absorb(cand, outs);
            c.settle();
            c.try_propose(9);
            c.settle();
        }
        c.assert_safety(seed);
        // every index committed before is still committed with same term
        for (idx, term) in &committed_before {
            for node_commits in &c.commits {
                if let Some((_, t2)) = node_commits.iter().find(|(i2, _)| i2 == idx) {
                    assert_eq!(t2, term, "seed {seed}: committed entry rewritten");
                }
            }
        }
    }
}

#[test]
fn weight_scheme_invariants_random_nt() {
    // randomized (n, t) sweep — the property-based check for Eq. 2
    let mut rng = Rng::new(2024);
    for _ in 0..300 {
        let n = 3 + rng.below(126) as usize;
        let t_max = (n - 1) / 2;
        let t = 1 + rng.below(t_max as u64) as usize;
        let ws = WeightScheme::geometric(n, t)
            .unwrap_or_else(|e| panic!("n={n} t={t}: {e}"));
        ws.validate().unwrap();
        assert!(ws.non_cabinet_weight() < ws.ct(), "L3.1 n={n} t={t}");
        assert!(ws.lightest_survivor_weight() > ws.ct(), "L3.2 n={n} t={t}");
        // strictly descending and positive
        for w in ws.weights().windows(2) {
            assert!(w[0] > w[1] && w[1] > 0.0);
        }
    }
}

#[test]
fn fifo_reassignment_tracks_any_reply_permutation() {
    // For arbitrary reply orders, next-round ranks must follow FIFO order.
    let mut rng = Rng::new(7);
    for _ in 0..50 {
        let n = 5 + rng.below(8) as usize % 8; // 5..12
        let t = 1 + rng.below(((n - 1) / 2) as u64) as usize;
        let mut leader = Node::new(0, n, Mode::cabinet(n, t));
        let _ = leader.step(Input::ElectionTimeout);
        for p in 1..n {
            let _ = leader.step(Input::Receive(
                p,
                Message::RequestVoteReply { term: 1, from: p, granted: true },
            ));
        }
        assert_eq!(leader.role(), Role::Leader);
        let _ = leader.step(Input::Propose(Payload::Noop));
        let wc = leader.wclock();
        let last = leader.log().last_index();
        let mut order: Vec<usize> = (1..n).collect();
        rng.shuffle(&mut order);
        for &p in &order {
            let _ = leader.step(Input::Receive(
                p,
                Message::AppendEntriesReply {
                    term: 1,
                    from: p,
                    success: true,
                    match_index: last,
                    wclock: wc,
                },
            ));
        }
        let _ = leader.step(Input::Propose(Payload::Noop));
        let scheme = WeightScheme::geometric(n, t).unwrap();
        let w = leader.weight_assignment();
        assert!((w[0] - scheme.weight_of_rank(0)).abs() < 1e-12);
        for (rank, &p) in order.iter().enumerate() {
            assert!(
                (w[p] - scheme.weight_of_rank(rank + 1)).abs() < 1e-12,
                "n={n} t={t}: reply rank {rank} node {p} got weight {}",
                w[p]
            );
        }
    }
}
