//! The per-group consensus engine: everything one Cabinet/Raft group needs
//! to be driven over the shared virtual-time fabric — its n sans-io nodes,
//! timer generations, forked RNG streams, workload shard generator, the
//! lock-step and pipelined replication windows, client-read bookkeeping,
//! fault/restart schedules, and the per-group nemesis.
//!
//! `sim::cluster::run` is a thin scheduler: it builds G `GroupEngine`s,
//! multiplexes their events through one [`EventQueue`] (each event wrapped
//! in a [`GroupEv`] carrying its [`GroupId`], mirroring the wire-level
//! [`crate::consensus::message::Envelope`]), and merges the per-group
//! results. With `groups = 1` the engine is a line-for-line transplant of
//! the historical single-group drivers: same RNG fork order (streams 1–5
//! off the root), same event push order, same service-time model — so a
//! one-group run reproduces the pre-sharding commit sequences and metrics
//! digests bit-for-bit (the replay-determinism suite pins this).
//!
//! Both drive modes live here, selected by `SimConfig::pipeline`:
//! the lock-step window (`depth == 1`, frozen — the paper's Fig. 7 loop)
//! and the pipelined window (`depth > 1`, out-of-order-ack-tolerant
//! retirement with leadership-epoch voiding). The read-retry/rotation
//! logic both drivers used to duplicate is one implementation now
//! ([`GroupEngine`]'s `ReadAt`/`ReadRetry` handling and [`ReadCtl`]).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::consensus::host::{Effects, ReplicaHost, RoundCommit};
use crate::consensus::message::{
    ClusterConfig, Entry, Envelope, GroupId, LogIndex, Message, NodeId, Payload, SnapshotBlob,
    Term,
};
use crate::consensus::node::{AdminCmd, Input, Mode, Node, Output, ReadPath, Role};
use crate::net::fault::KillSpec;
use crate::net::nemesis::{Fate, MembershipEvent, MembershipKind, Nemesis};
use crate::net::rng::Rng;
use crate::sim::cluster::{
    CommitEvidence, Protocol, ReadRecord, ReconfigSpec, RestartSpec, RoundStat, SafetyLog,
    SimConfig, SimResult, WorkloadSpec,
};
use crate::sim::event::EventQueue;
use crate::storage::wal::{HardState, MemDisk, Wal, WalConfig};
use crate::storage::{DocStore, RelStore};
use crate::workload::shard::warehouse_range;
use crate::workload::ycsb::{OP_READ, OP_SCAN};
use crate::workload::{TpccGen, YcsbBatch, YcsbGen};

/// Client-side retry cadence for unserved reads (virtual ms).
const READ_RETRY_MS: f64 = 400.0;
/// Concurrent read requests per round on a non-log read path — an open-loop
/// fan-out client: each round's read-only ops are split across this many
/// parallel requests at rotated nodes (followers included), so read work is
/// spread across the cluster instead of riding every replication round.
const READ_FAN: u64 = 4;

/// One event on the shared fabric: the per-group event plus the group it
/// belongs to. The scheduler routes it to that group's engine — the
/// in-queue analogue of the wire [`crate::consensus::message::Envelope`].
pub(crate) struct GroupEv {
    pub group: GroupId,
    pub ev: Ev,
}

pub(crate) enum Ev {
    Deliver { to: NodeId, from: NodeId, msg: Message },
    ElectionTimer { node: NodeId, generation: u64 },
    HeartbeatTimer { node: NodeId, generation: u64 },
    /// Harness: try to propose the next round at the current leader.
    ProposeNext,
    /// Harness: a client read request arrives at `node` (non-log paths).
    ReadAt { id: u64, node: NodeId },
    /// Harness: re-drive a read that has not been served yet (a forward or
    /// grant was lost, or leadership moved mid-confirmation).
    ReadRetry { id: u64 },
}

/// One in-flight client read request.
struct ReadReq {
    invoked_ms: f64,
    /// Read ops this request carries (for throughput accounting).
    ops: usize,
    /// Apply cost of those ops at unit speed (charged at the serving node).
    cost_ms: f64,
    /// Round the request belongs to (target rotation slot).
    round: u64,
    /// Position in the fan (rotates the serving node).
    k: u64,
}

/// Client-side read bookkeeping — one instance per group engine (the
/// deduplicated successor of the two near-copies the round drivers grew).
#[derive(Default)]
pub(crate) struct ReadCtl {
    next_id: u64,
    outstanding: HashMap<u64, ReadReq>,
    pub(crate) latencies: Vec<f64>,
    reads_served: u64,
    read_ops_served: u64,
    lease_reads: u64,
    failures: u64,
    /// Virtual time the last read finished (combined-throughput span end).
    done_ms: f64,
}

impl ReadCtl {
    /// Fan a round's read-only sub-batch out as [`READ_FAN`] concurrent
    /// requests at rotated alive targets (followers serve local reads too),
    /// each with a standing retry timer. The first request absorbs the
    /// division remainder so op totals stay exact.
    fn issue_fan(
        &mut self,
        gid: GroupId,
        q: &mut EventQueue<GroupEv>,
        alive: &[bool],
        invoked_ms: f64,
        round: u64,
        reads: &YcsbBatch,
    ) {
        let live = reads.live_ops();
        let fan = READ_FAN.min(live.max(1) as u64);
        let ops_per = live / fan as usize;
        let cost_per = DocStore::estimate_cost_ms(reads) / fan as f64;
        for k in 0..fan {
            let ops = if k == 0 { live - ops_per * (fan as usize - 1) } else { ops_per };
            let Some(target) = pick_read_target(round + k, alive) else { continue };
            let id = self.next_id;
            self.next_id += 1;
            self.outstanding
                .insert(id, ReadReq { invoked_ms, ops, cost_ms: cost_per, round, k });
            q.push_after(0.0, GroupEv { group: gid, ev: Ev::ReadAt { id, node: target } });
            q.push_after(READ_RETRY_MS, GroupEv { group: gid, ev: Ev::ReadRetry { id } });
        }
    }
}

/// Deterministic read-target rotation over the alive nodes.
fn pick_read_target(slot: u64, alive: &[bool]) -> Option<NodeId> {
    let n = alive.len();
    (0..n).map(|d| (slot as usize + d) % n).find(|&i| alive[i])
}

/// Split a YCSB batch into its mutating part (replicated through the log)
/// and its read-only part (READ + SCAN, served through the read path).
fn split_ycsb(b: &YcsbBatch) -> (YcsbBatch, YcsbBatch) {
    let empty = YcsbBatch {
        workload: b.workload,
        ops: Vec::new(),
        keys: Vec::new(),
        vals: Vec::new(),
        value_size: b.value_size,
    };
    let (mut writes, mut reads) = (empty.clone(), empty);
    for i in 0..b.ops.len() {
        let dst = if b.ops[i] == OP_READ || b.ops[i] == OP_SCAN { &mut reads } else { &mut writes };
        dst.ops.push(b.ops[i]);
        dst.keys.push(b.keys[i]);
        dst.vals.push(b.vals[i]);
    }
    (writes, reads)
}

/// Generate the next round's batch; on a non-log read path, split out the
/// read-only ops. Returns (payload, tracked batch, apply cost of the
/// replicated part, replicated live ops, read-only sub-batch). TPC-C rounds
/// stay fully log-replicated (transactions are read-write).
fn next_round_batch(
    driver: &mut WorkloadDriver,
    read_path: ReadPath,
) -> (Payload, Batch, f64, usize, Option<YcsbBatch>) {
    let (payload, batch, cost, ops) = driver.next_batch();
    if matches!(read_path, ReadPath::Log) {
        return (payload, batch, cost, ops, None);
    }
    match payload {
        Payload::Ycsb(full) => {
            let (writes, reads) = split_ycsb(&full);
            let writes = Arc::new(writes);
            let cost = DocStore::estimate_cost_ms(&writes);
            let ops = writes.live_ops();
            let reads = (!reads.is_empty()).then_some(reads);
            (Payload::Ycsb(writes.clone()), Batch::Ycsb(writes), cost, ops, reads)
        }
        other => (other, batch, cost, ops, None),
    }
}

pub(crate) enum Batch {
    Ycsb(Arc<crate::workload::YcsbBatch>),
    Tpcc(Arc<crate::workload::TpccBatch>),
}

/// Per-group workload source: the shard router in action. With `groups = 1`
/// it is the historical full-keyspace generator (identical RNG
/// consumption); with `groups > 1` each group generates full-size batches
/// restricted to its own shard — hash-partitioned YCSB keys,
/// range-partitioned TPC-C warehouses — modelling every shard serving its
/// own client population.
pub(crate) struct WorkloadDriver {
    ycsb: Option<YcsbGen>,
    tpcc: Option<TpccGen>,
    pub(crate) batch_size: usize,
    pub(crate) warehouses: u32,
    /// Modeled per-op value size stamped onto generated YCSB batches
    /// (0 = the historical 12-byte wire ops, bit-identical).
    pub(crate) value_size: u64,
    group: usize,
    groups: usize,
    /// TPC-C: the warehouse range this group owns.
    wh_range: (u32, u32),
}

impl WorkloadDriver {
    pub(crate) fn new(spec: &WorkloadSpec, seed: u64) -> Self {
        Self::new_sharded(spec, seed, 0, 1)
    }

    pub(crate) fn new_sharded(
        spec: &WorkloadSpec,
        seed: u64,
        group: usize,
        groups: usize,
    ) -> Self {
        match spec {
            WorkloadSpec::Ycsb { workload, batch, records } => {
                assert!(
                    groups as u64 <= *records,
                    "groups ({groups}) exceed the YCSB key count ({records}) — \
                     validated at config parse"
                );
                WorkloadDriver {
                    ycsb: Some(YcsbGen::new(*workload, *records, seed)),
                    tpcc: None,
                    batch_size: *batch,
                    warehouses: 0,
                    value_size: 0,
                    group,
                    groups,
                    wh_range: (0, 0),
                }
            }
            WorkloadSpec::Tpcc { batch, warehouses } => {
                debug_assert!(*warehouses >= 1, "warehouses is validated at config parse");
                assert!(
                    groups as u32 <= *warehouses,
                    "groups ({groups}) exceed the TPC-C warehouse count ({warehouses}) — \
                     validated at config parse"
                );
                WorkloadDriver {
                    ycsb: None,
                    tpcc: Some(TpccGen::new(*warehouses, seed)),
                    batch_size: *batch,
                    warehouses: *warehouses,
                    value_size: 0,
                    group,
                    groups,
                    wh_range: warehouse_range(group, groups, *warehouses),
                }
            }
        }
    }

    /// Generate the next round's batch; returns (payload, base apply cost in
    /// ms at unit speed, live op count).
    pub(crate) fn next_batch(&mut self) -> (Payload, Batch, f64, usize) {
        if let Some(gen) = self.ycsb.as_mut() {
            // groups = 1 takes the untouched generator path (bit-identical)
            let mut b = if self.groups <= 1 {
                gen.batch(self.batch_size)
            } else {
                gen.batch_sharded(self.batch_size, self.group, self.groups)
            };
            b.value_size = self.value_size;
            let b = Arc::new(b);
            let cost = DocStore::estimate_cost_ms(&b);
            let ops = b.live_ops();
            (Payload::Ycsb(b.clone()), Batch::Ycsb(b), cost, ops)
        } else {
            let gen = self.tpcc.as_mut().unwrap();
            let b = Arc::new(if self.groups <= 1 {
                gen.batch(self.batch_size)
            } else {
                gen.batch_sharded(self.batch_size, self.wh_range.0, self.wh_range.1)
            });
            let cost = RelStore::estimate_cost_ms(&b, self.warehouses as usize);
            let ops = b.live_txns();
            (Payload::Tpcc(b.clone()), Batch::Tpcc(b), cost, ops)
        }
    }
}

/// One workload round the pipelined window has proposed but whose commit it
/// has not yet observed.
struct PendingRound {
    round: u64,
    entry_index: u64,
    /// Term of the entry at propose time — (index, term) is exact entry
    /// identity (Raft log matching), so a leader change can tell surviving
    /// rounds from overwritten ones.
    term: u64,
    start_ms: f64,
    ops: usize,
    leader_apply_done: f64,
    batch: Batch,
}

/// Track the peak retained (post-compaction) log length across all nodes —
/// the quantity `snapshot_every` bounds.
fn sample_retained(nodes: &[Node], max_retained: &mut u64) {
    for node in nodes {
        *max_retained = (*max_retained).max(node.log().len() as u64);
    }
}

/// Fold a sorted (ascending) read-latency population into the result's
/// mean/p50/p99 — the one copy of this computation, shared by the
/// per-group fold below and the multi-group merge in `sim::cluster`.
pub(crate) fn fold_read_latencies(result: &mut SimResult, sorted_lats: &[f64]) {
    if sorted_lats.is_empty() {
        return;
    }
    use crate::bench::metrics::percentile_sorted;
    result.read_mean_ms = sorted_lats.iter().sum::<f64>() / sorted_lats.len() as f64;
    result.read_p50_ms = percentile_sorted(sorted_lats, 0.50);
    result.read_p99_ms = percentile_sorted(sorted_lats, 0.99);
}

/// Fold the read-client bookkeeping and node-side read counters into the
/// result (no-op on log-path runs: everything stays zero). `sorted_lats`
/// is the request-latency population, ascending — the caller keeps
/// ownership so the multi-group merge can re-pool it without a copy.
fn finish_reads(result: &mut SimResult, readctl: &ReadCtl, sorted_lats: &[f64], nodes: &[Node]) {
    result.reads_served = readctl.reads_served;
    result.read_ops_served = readctl.read_ops_served;
    result.lease_reads = readctl.lease_reads;
    result.read_failures = readctl.failures;
    result.readindex_rounds = nodes.iter().map(|nd| nd.readindex_rounds()).sum();
    result.read_done_ms = readctl.done_ms;
    fold_read_latencies(result, sorted_lats);
}

/// What one finished engine hands back to the scheduler: the group's full
/// [`SimResult`] (for `groups = 1` it *is* the run result, bit-for-bit the
/// historical one), plus the raw read latencies and final leader the
/// multi-group merge needs for aggregate rollups.
pub(crate) struct GroupOutcome {
    pub result: SimResult,
    pub read_latencies: Vec<f64>,
    pub final_leader: Option<NodeId>,
}

/// One consensus group being driven over the shared fabric. See the module
/// docs for the bit-for-bit G=1 contract.
pub(crate) struct GroupEngine {
    gid: GroupId,
    /// Shared, immutable run configuration (one allocation for all G
    /// engines — the per-group mutable schedules below are copied out).
    config: Arc<SimConfig>,
    mode: Mode,
    depth: usize,
    /// `pipeline == 1`: the frozen lock-step window (Fig. 7 drive loop).
    lockstep: bool,

    nodes: Vec<Node>,
    alive: Vec<bool>,
    /// Timer generations (stale-timer cancellation).
    el_gen: Vec<u64>,
    hb_gen: Vec<u64>,

    /// Per-group forked RNG streams — group g forks streams 8g+1..8g+5 off
    /// the root, so group 0 forks 1..5 in the historical order.
    net_rng: Rng,
    timer_rng: Rng,
    kill_rng: Rng,
    driver: WorkloadDriver,
    nemesis: Option<Nemesis>,
    safety: Option<SafetyLog>,
    readctl: ReadCtl,

    /// Fig. 21 restart schedule + retained-log peak tracking.
    restart_pending: Option<RestartSpec>,
    restart_victim: Option<NodeId>,
    max_retained: u64,

    /// Per-node simulated WALs (`SimConfig::storage`): every slot holds a
    /// `Wal<MemDisk>` when durable storage is on, `None` otherwise. A
    /// restarted node recovers from its entry instead of booting amnesiac.
    wals: Vec<Option<Wal<MemDisk>>>,
    /// Torn-write fault stream (fork 8g+6) — forked only when
    /// `storage.torn_writes` is set, so fault-free runs draw nothing new.
    wal_fault_rng: Option<Rng>,
    wal_appends: u64,
    wal_fsyncs: u64,
    wal_recoveries: u64,
    wal_recovered_entries: u64,

    /// Digest-tracked replica stores (one shard's state per group).
    tracked: Vec<usize>,
    doc_stores: Vec<DocStore>,
    rel_stores: Vec<RelStore>,
    is_tpcc: bool,

    /// Completed rounds.
    round: u64,
    /// Rounds handed to the leader (pipelined window accounting).
    proposed: u64,
    stats: Vec<RoundStat>,
    current_leader: Option<NodeId>,
    /// Leadership epoch tracking (pipelined): when a new leader takes over,
    /// pending rounds whose entries did not survive into its log are void.
    known_leader: Option<NodeId>,
    elections: u64,

    // -- lock-step window (depth == 1) --
    /// (round, start, ops, leader_apply_done, batch)
    pending1: Option<(u64, f64, usize, f64, Batch)>,
    pending1_entry: u64,
    /// Batch cost of the in-flight round, for follower service times.
    inflight_cost_ms: f64,

    // -- pipelined window (depth > 1) --
    /// In-flight rounds, oldest first. A deque: the retire loop pops the
    /// committed prefix from the front, which `Vec::remove(0)` made O(n)
    /// per retired round.
    pending: VecDeque<PendingRound>,
    /// Entry index → batch apply cost at unit speed (for follower service
    /// times); retained for the whole run so retransmits resolve too.
    batch_costs: HashMap<u64, f64>,

    reconfig_queue: VecDeque<ReconfigSpec>,
    kills: VecDeque<KillSpec>,
    kill_leader_at: Option<u64>,

    /// Dynamic membership (all fields inert on fixed-membership runs).
    membership_on: bool,
    /// Founding voter count: slots `founding..n` boot empty.
    founding: usize,
    membership_queue: VecDeque<MembershipEvent>,
    /// The engine's view of the current voter set — updated from committed
    /// config entries, used to retire removed slots (power off) without
    /// touching slots that merely have not joined yet.
    members: Vec<bool>,
    /// Highest config epoch applied to `members`/`alive` — a re-commit of
    /// older config entries after a failover must not resurrect slots.
    max_config_epoch: u64,
    /// Leader-observed config-entry commits.
    config_commits: u64,

    /// Reusable output buffer for `Node::step_into` — one allocation per
    /// engine instead of one `Vec<Output>` per step (the routing hot path).
    out_scratch: Vec<Output>,
    /// The shared sans-io effect interpreter (`consensus::host`): `route`
    /// drives every step's outputs through it, with [`SimEffects`] mapping
    /// the effect calls onto the virtual fabric.
    host: ReplicaHost,
    /// Messages delivered to live nodes (host-profiling telemetry for the
    /// `sim_throughput` bench; never folded into the metrics digest).
    messages: u64,
    /// Wire bytes delivered to live nodes (fig 27 telemetry; like
    /// `messages`, never folded into the metrics digest).
    bytes_sent: u64,
    /// Effective per-link bandwidth (bytes/ms) for the transfer term —
    /// resolved once so the send hot path never unwraps the Option.
    bandwidth: f64,
    /// Node-facing coding parameters (k, cutover bytes), resolved once and
    /// re-applied to restarted nodes.
    coding: Option<(u32, u64)>,
}

impl GroupEngine {
    pub(crate) fn new(
        config: &Arc<SimConfig>,
        gid: GroupId,
        groups: usize,
        root_rng: &mut Rng,
    ) -> Self {
        let n = config.n();
        let mode = match &config.protocol {
            Protocol::Raft => Mode::Raft,
            Protocol::Cabinet { t } => Mode::cabinet(n, *t),
            Protocol::Hqc { .. } => unreachable!("HQC runs through the replication baseline"),
        };
        // fork order is part of the determinism contract: streams 1..4 in
        // order, then 5 only when this group actually runs a nemesis — for
        // group 0 that is exactly the historical single-group sequence
        let base = 8 * gid as u64;
        let net_rng = root_rng.fork(base + 1);
        let timer_rng = root_rng.fork(base + 2);
        let kill_rng = root_rng.fork(base + 3);
        let wl_seed = root_rng.fork(base + 4).next_u64();
        let mut driver = WorkloadDriver::new_sharded(&config.workload, wl_seed, gid, groups);
        driver.value_size = config.value_size;
        let coding = config.coding_params();
        let nemesis_here = config.nemesis.is_some()
            && config.nemesis_groups.as_ref().map_or(true, |gs| gs.contains(&gid));
        let nemesis = if nemesis_here {
            let spec = config.nemesis.as_ref().unwrap();
            spec.validate(n).expect("invalid nemesis spec");
            Some(Nemesis::new(spec.clone(), n, root_rng.fork(base + 5)))
        } else {
            None
        };
        let safety = if config.track_safety { Some(SafetyLog::new(n)) } else { None };
        // stream 6 exists only when crash faults can tear a WAL tail — a
        // fresh stream must never perturb the historical draw sequence
        let wal_fault_rng = match &config.storage {
            Some(s) if s.torn_writes => Some(root_rng.fork(base + 6)),
            _ => None,
        };
        let wals: Vec<Option<Wal<MemDisk>>> = (0..n)
            .map(|_| {
                config.storage.as_ref().map(|s| {
                    let cfg = WalConfig { fsync_group: s.fsync_group, ..WalConfig::default() };
                    Wal::open(MemDisk::new(), cfg).0
                })
            })
            .collect();

        let membership_on = config.membership_on();
        let founding = config.initial_members.unwrap_or(n).min(n);
        if let Some(spec) = &config.membership {
            spec.validate(n).expect("invalid membership spec");
        }
        // the founding config: slots `founding..n` are non-members a later
        // join can admit (shared Arc — every node adopts the same one)
        let founding_cfg = Arc::new(ClusterConfig::bootstrap(founding));

        let nodes: Vec<Node> = (0..n)
            .map(|i| {
                let mut node = Node::new(i, n, mode.clone());
                node.set_static_weights(config.static_weights);
                node.set_snapshot_every(config.snapshot_every);
                node.set_pre_vote(config.pre_vote);
                node.set_read_path(config.read_path);
                node.set_lease_duration_ms(config.lease_duration_ms());
                node.set_coding(coding);
                node.set_durable(config.storage.is_some());
                if membership_on {
                    node.set_drain_rounds(config.drain_rounds);
                    node.set_join_warmup(config.join_warmup);
                    if founding < n {
                        node.set_initial_config(Arc::clone(&founding_cfg));
                    }
                }
                node
            })
            .collect();

        let tracked: Vec<usize> = match config.digest_mode {
            crate::sim::cluster::DigestMode::Off => vec![],
            crate::sim::cluster::DigestMode::Sample => vec![0, n - 1],
            crate::sim::cluster::DigestMode::All => (0..n).collect(),
        };
        let is_tpcc = matches!(config.workload, WorkloadSpec::Tpcc { .. });
        let doc_stores: Vec<DocStore> = tracked.iter().map(|_| DocStore::new()).collect();
        // relational stores exist only for TPC-C runs — `warehouses >= 1` is
        // a config-parse invariant, not a construction-site patch-up
        let rel_stores: Vec<RelStore> = if is_tpcc {
            tracked.iter().map(|_| RelStore::new(driver.warehouses as usize)).collect()
        } else {
            Vec::new()
        };

        let mut reconfig_queue = config.reconfigs.clone();
        reconfig_queue.sort_by_key(|r| r.round);
        let mut kills = config.kills.clone();
        kills.sort_by_key(|k| k.round);
        let (reconfig_queue, kills) = (VecDeque::from(reconfig_queue), VecDeque::from(kills));
        let mut membership_events: Vec<MembershipEvent> =
            config.membership.as_ref().map(|m| m.events.clone()).unwrap_or_default();
        membership_events.sort_by_key(|e| e.round);
        let membership_queue = VecDeque::from(membership_events);

        // empty slots boot powered off: no timers, no deliveries, no reads
        let mut alive = vec![true; n];
        let mut members = vec![true; n];
        if membership_on {
            for slot in founding..n {
                alive[slot] = false;
                members[slot] = false;
            }
        }

        GroupEngine {
            gid,
            config: Arc::clone(config),
            mode,
            depth: config.pipeline.max(1),
            lockstep: config.pipeline <= 1,
            nodes,
            alive,
            el_gen: vec![0u64; n],
            hb_gen: vec![0u64; n],
            net_rng,
            timer_rng,
            kill_rng,
            driver,
            nemesis,
            safety,
            readctl: ReadCtl::default(),
            restart_pending: config.restart,
            restart_victim: None,
            max_retained: 0,
            wals,
            wal_fault_rng,
            wal_appends: 0,
            wal_fsyncs: 0,
            wal_recoveries: 0,
            wal_recovered_entries: 0,
            tracked,
            doc_stores,
            rel_stores,
            is_tpcc,
            round: 0,
            proposed: 0,
            stats: Vec::with_capacity(config.rounds as usize),
            current_leader: None,
            known_leader: None,
            elections: 0,
            pending1: None,
            pending1_entry: 0,
            inflight_cost_ms: 0.0,
            pending: VecDeque::with_capacity(config.pipeline.max(1)),
            batch_costs: HashMap::new(),
            reconfig_queue,
            kills,
            kill_leader_at: config.kill_leader_at_round,
            membership_on,
            founding,
            membership_queue,
            members,
            max_config_epoch: 0,
            config_commits: 0,
            out_scratch: Vec::new(),
            host: ReplicaHost::new(gid),
            messages: 0,
            bytes_sent: 0,
            bandwidth: config.effective_bandwidth(),
            coding,
        }
    }

    /// Step `node` with `input` and route the outputs, reusing the engine's
    /// scratch buffer so the hot path performs no per-step allocation.
    /// `route` never re-enters `step_into`, so one buffer suffices.
    fn step_route(
        &mut self,
        node: NodeId,
        input: Input,
        extra_delay: f64,
        q: &mut EventQueue<GroupEv>,
    ) {
        let mut outs = std::mem::take(&mut self.out_scratch);
        self.nodes[node].step_into(input, &mut outs);
        self.route(node, &mut outs, extra_delay, q);
        outs.clear();
        self.out_scratch = outs;
    }

    #[inline]
    fn push(&self, q: &mut EventQueue<GroupEv>, delay: f64, ev: Ev) {
        q.push_after(delay, GroupEv { group: self.gid, ev });
    }

    /// Bootstrap this group: one node starts the first election immediately
    /// (node `gid % n`, so sharded runs spread initial leaders across the
    /// cluster; for a single group that is node 0, the historical choice);
    /// everyone else arms a randomized election timer.
    pub(crate) fn bootstrap(&mut self, q: &mut EventQueue<GroupEv>) {
        let n = self.config.n();
        // empty slots draw no timers (membership-off: every slot is alive,
        // so the draw sequence is bit-identical to the historical one)
        let mut first = self.gid % n;
        if !self.alive[first] {
            first = (0..n)
                .map(|d| (first + d) % n)
                .find(|&i| self.alive[i])
                .expect("at least one founding member");
        }
        for node in 0..n {
            if !self.alive[node] {
                continue;
            }
            let delay = if node == first {
                0.0
            } else {
                self.timer_rng
                    .range_f64(self.config.election_timeout_ms.0, self.config.election_timeout_ms.1)
            };
            self.el_gen[node] += 1;
            self.push(q, delay, Ev::ElectionTimer { node, generation: self.el_gen[node] });
        }
        self.push(q, 1.0, Ev::ProposeNext);
    }

    /// This group has committed every round and drained every read.
    pub(crate) fn done(&self) -> bool {
        self.round >= self.config.rounds && self.readctl.outstanding.is_empty()
    }

    /// Process one fabric event addressed to this group.
    pub(crate) fn handle(&mut self, now: f64, ev: Ev, q: &mut EventQueue<GroupEv>) {
        match ev {
            Ev::ElectionTimer { node, generation } => {
                if !self.alive[node] || generation != self.el_gen[node] {
                    return;
                }
                self.nodes[node].observe_time(now);
                self.step_route(node, Input::ElectionTimeout, 0.0, q);
            }
            Ev::HeartbeatTimer { node, generation } => {
                if !self.alive[node] || generation != self.hb_gen[node] {
                    return;
                }
                self.nodes[node].observe_time(now);
                self.step_route(node, Input::HeartbeatTimeout, 0.0, q);
            }
            Ev::Deliver { to, from, msg } => {
                if !self.alive[to] {
                    return;
                }
                // follower service time: RPC processing + batch apply,
                // scaled by zone speed and contention (modeled by delaying
                // the node's outputs)
                let service = if self.lockstep {
                    self.service_ms_lockstep(to, &msg)
                } else {
                    self.service_ms_pipelined(to, &msg)
                };
                self.messages += 1;
                self.bytes_sent += msg.wire_size() as u64;
                self.nodes[to].observe_time(now);
                self.step_route(to, Input::Receive(from, msg), service, q);
            }
            Ev::ReadAt { id, node } => {
                if !self.readctl.outstanding.contains_key(&id) {
                    return; // already served
                }
                if !self.alive[node] {
                    return; // the standing retry timer re-targets it
                }
                self.nodes[node].observe_time(now);
                let service = self.config.rpc_proc_ms / self.effective_speed(node);
                self.step_route(node, Input::Read { id }, service, q);
            }
            Ev::ReadRetry { id } => {
                if let Some(req) = self.readctl.outstanding.get(&id) {
                    let target = self
                        .current_leader
                        .filter(|&l| self.alive[l])
                        .or_else(|| pick_read_target(req.round + req.k, &self.alive));
                    if let Some(target) = target {
                        self.push(q, 0.0, Ev::ReadAt { id, node: target });
                    }
                    self.push(q, READ_RETRY_MS, Ev::ReadRetry { id });
                }
            }
            Ev::ProposeNext => {
                if self.lockstep {
                    self.propose_next_lockstep(now, q);
                } else {
                    self.propose_next_pipelined(now, q);
                }
            }
        }
        // A leadership change voids every pending round whose entry did not
        // survive into the new leader's log — (index, term) is exact entry
        // identity by Raft log matching. The winner overwrites dead slots,
        // so retiring them on its commits would misattribute fresh entries
        // to old batches. Dropped rounds are regenerated with fresh batches.
        // This runs before any RoundCommitted from the new leader can be
        // processed (its quorum needs at least one more network round trip).
        // Pipelined window only — the lock-step window keeps its single
        // pending round across leader changes (the frozen Fig. 7 behavior).
        if !self.lockstep && self.current_leader != self.known_leader {
            if let Some(x) = self.current_leader {
                let nodes = &self.nodes;
                let proposed = &mut self.proposed;
                self.pending.retain(|p| {
                    let survived = nodes[x].log().term_at(p.entry_index) == Some(p.term);
                    if !survived {
                        *proposed -= 1;
                    }
                    survived
                });
            }
            self.known_leader = self.current_leader;
        }
    }

    /// The lock-step proposer (`pipeline = 1`): one round in flight, frozen
    /// so the historical figures reproduce bit-for-bit.
    fn propose_next_lockstep(&mut self, now: f64, q: &mut EventQueue<GroupEv>) {
        sample_retained(&self.nodes, &mut self.max_retained);
        if self.round >= self.config.rounds {
            return; // only reads are draining now
        }
        if self.pending1.is_some() {
            return; // a round is already in flight
        }
        let Some(leader) = self.current_leader.filter(|&l| self.alive[l]) else {
            self.push(q, 50.0, Ev::ProposeNext);
            return;
        };
        if self.nodes[leader].role() != Role::Leader {
            self.push(q, 50.0, Ev::ProposeNext);
            return;
        }
        let next_round = self.round + 1;

        self.maybe_kill_restart(next_round, leader, q);
        self.run_scheduled_kills(next_round, leader);
        if self.kill_leader_at == Some(next_round) {
            self.kill_leader_at = None; // fire exactly once
            self.alive[leader] = false;
            self.current_leader = None;
            self.push(q, 50.0, Ev::ProposeNext);
            return;
        }
        // scheduled reconfiguration (not counted as a round)
        if let Some(rc) = self.reconfig_queue.front().copied() {
            if rc.round == next_round {
                self.reconfig_queue.pop_front();
                self.step_route(leader, Input::Propose(Payload::Reconfig { new_t: rc.new_t }), 0.0, q);
                self.push(q, 1.0, Ev::ProposeNext);
                return;
            }
        }

        // scheduled membership change (not counted as a round) — the
        // leader's admin queue serializes overlapping operations
        if let Some(me) = self.membership_queue.front().copied() {
            if me.round == next_round {
                self.membership_queue.pop_front();
                self.fire_membership(me, leader, now, q);
                self.push(q, 1.0, Ev::ProposeNext);
                return;
            }
        }

        let (payload, batch, cost_ms, ops, read_batch) =
            next_round_batch(&mut self.driver, self.config.read_path);
        self.inflight_cost_ms = cost_ms;
        // Fig. 7: the leader batches + coordinates; *followers* execute the
        // workload. Leader-side work is the batching / RPC-issue overhead.
        let leader_speed = self.effective_speed_at(leader, next_round);
        let leader_apply_done = now + self.config.rpc_proc_ms / leader_speed;
        self.nodes[leader].observe_time(now);
        // window bookkeeping must land between step and route, so this site
        // spells out the scratch-buffer pattern `step_route` wraps
        let mut outs = std::mem::take(&mut self.out_scratch);
        self.nodes[leader].step_into(Input::Propose(payload), &mut outs);
        self.pending1 = Some((next_round, now, ops, leader_apply_done, batch));
        self.pending1_entry = self.nodes[leader].log().last_index();
        self.route(leader, &mut outs, 0.0, q);
        outs.clear();
        self.out_scratch = outs;
        // the round's read-only ops go through the selected fast path
        if let Some(rb) = read_batch {
            self.readctl.issue_fan(self.gid, q, &self.alive, now, next_round, &rb);
        }
    }

    /// The pipelined proposer (`pipeline > 1`): keeps up to `depth` rounds
    /// in flight, refilled on every commit.
    fn propose_next_pipelined(&mut self, now: f64, q: &mut EventQueue<GroupEv>) {
        sample_retained(&self.nodes, &mut self.max_retained);
        if self.pending.len() >= self.depth || self.proposed >= self.config.rounds {
            return; // window full (a commit re-arms the proposer)
        }
        let Some(leader) = self.current_leader.filter(|&l| self.alive[l]) else {
            self.push(q, 50.0, Ev::ProposeNext);
            return;
        };
        if self.nodes[leader].role() != Role::Leader {
            self.push(q, 50.0, Ev::ProposeNext);
            return;
        }
        if self.nodes[leader].reconfig_pending() {
            // §4.1.4: the pipeline drains across a reconfiguration
            self.push(q, 5.0, Ev::ProposeNext);
            return;
        }
        let next_round = self.proposed + 1;

        self.maybe_kill_restart(next_round, leader, q);
        self.run_scheduled_kills(next_round, leader);
        if self.kill_leader_at == Some(next_round) {
            self.kill_leader_at = None; // fire exactly once
            self.alive[leader] = false;
            self.current_leader = None;
            // rounds that died in the old leader's window get regenerated
            // (fresh batches) under the next leader. Every pending round
            // incremented `proposed` when it was pushed, so the subtraction
            // is exact — a saturating_sub here would only mask a broken
            // window invariant.
            debug_assert!(
                self.proposed >= self.pending.len() as u64,
                "window accounting underflow: proposed {} < pending {}",
                self.proposed,
                self.pending.len()
            );
            self.proposed -= self.pending.len() as u64;
            self.pending.clear();
            self.push(q, 50.0, Ev::ProposeNext);
            return;
        }
        // scheduled reconfiguration (not counted as a round) — may land
        // while earlier rounds are still in flight; their propose-time
        // weight/CT snapshots keep them correct
        if let Some(rc) = self.reconfig_queue.front().copied() {
            if rc.round == next_round {
                self.reconfig_queue.pop_front();
                self.step_route(leader, Input::Propose(Payload::Reconfig { new_t: rc.new_t }), 0.0, q);
                self.push(q, 1.0, Ev::ProposeNext);
                return;
            }
        }

        // scheduled membership change (not counted as a round) — may land
        // while earlier rounds are still in flight; their propose-time
        // config/weight snapshots keep them correct
        if let Some(me) = self.membership_queue.front().copied() {
            if me.round == next_round {
                self.membership_queue.pop_front();
                self.fire_membership(me, leader, now, q);
                self.push(q, 1.0, Ev::ProposeNext);
                return;
            }
        }

        // Adaptive leader batching (`max_batch_bytes`): coalesce queued
        // workload rounds into ONE replication round — one wclock, one
        // persist record, one AppendEntries per follower — until the byte
        // budget, the window, the round budget, or the next scheduled
        // fault/config event stops the draw. None = single-draw, the
        // historical step sequence bit-for-bit.
        let mut draws = vec![next_round_batch(&mut self.driver, self.config.read_path)];
        if let Some(mb) = self.config.max_batch_bytes {
            let mut bytes = crate::consensus::message::payload_wire(&draws[0].0) as u64;
            loop {
                let claimed = self.proposed + draws.len() as u64;
                if bytes >= mb
                    || self.pending.len() + draws.len() >= self.depth
                    || claimed >= self.config.rounds
                    || self.round_has_scheduled_event(claimed + 1)
                {
                    break;
                }
                let d = next_round_batch(&mut self.driver, self.config.read_path);
                bytes += crate::consensus::message::payload_wire(&d.0) as u64;
                draws.push(d);
            }
        }
        let count = draws.len() as u64;
        let leader_speed = self.effective_speed_at(leader, next_round);
        let leader_apply_done = now + self.config.rpc_proc_ms / leader_speed;
        self.nodes[leader].observe_time(now);
        // window bookkeeping must land between step and route, so this site
        // spells out the scratch-buffer pattern `step_route` wraps
        let mut outs = std::mem::take(&mut self.out_scratch);
        if count == 1 {
            // the historical single-proposal step
            self.nodes[leader].step_into(Input::Propose(draws[0].0.clone()), &mut outs);
        } else {
            let payloads: Vec<Payload> = draws.iter().map(|d| d.0.clone()).collect();
            self.nodes[leader].propose_all(payloads, &mut outs);
        }
        let last_index = self.nodes[leader].log().last_index();
        let first_index = last_index + 1 - count;
        let term = self.nodes[leader].term();
        let mut fans: Vec<(u64, YcsbBatch)> = Vec::new();
        for (i, (_payload, batch, cost_ms, ops, read_batch)) in draws.into_iter().enumerate() {
            let entry_index = first_index + i as u64;
            let rnd = next_round + i as u64;
            self.batch_costs.insert(entry_index, cost_ms);
            self.pending.push_back(PendingRound {
                round: rnd,
                entry_index,
                term,
                start_ms: now,
                ops,
                leader_apply_done,
                batch,
            });
            if let Some(rb) = read_batch {
                fans.push((rnd, rb));
            }
        }
        self.proposed = next_round + count - 1;
        self.route(leader, &mut outs, 0.0, q);
        outs.clear();
        self.out_scratch = outs;
        // the rounds' read-only ops go through the selected fast path
        for (rnd, rb) in fans {
            self.readctl.issue_fan(self.gid, q, &self.alive, now, rnd, &rb);
        }
        if self.pending.len() < self.depth && self.proposed < self.config.rounds {
            // back-to-back proposal to fill the window
            self.push(q, 0.2, Ev::ProposeNext);
        }
    }

    /// Does round `r` carry a scheduled fault/config event? The batching
    /// coalescer must not draw past one — those events fire at the start of
    /// their round in the proposer, so the round has to be proposed by its
    /// own tick.
    fn round_has_scheduled_event(&self, r: u64) -> bool {
        self.reconfig_queue.front().map_or(false, |x| x.round == r)
            || self.membership_queue.front().map_or(false, |x| x.round == r)
            || self.kills.front().map_or(false, |x| x.round == r)
            || self.kill_leader_at == Some(r)
            || self
                .restart_pending
                .map_or(false, |rs| rs.kill_round == r || rs.restart_round == r)
    }

    /// Fig. 21 kill/restart schedule, shared by both windows: kill the
    /// highest-id non-leader follower at the start of `kill_round`, bring
    /// it back with completely fresh state (empty log, zero commit) at the
    /// start of `restart_round`. The restarted node re-arms a randomized
    /// election timer; with compaction on, catch-up goes through
    /// `InstallSnapshot`.
    fn maybe_kill_restart(&mut self, next_round: u64, leader: NodeId, q: &mut EventQueue<GroupEv>) {
        let Some(rs) = self.restart_pending else { return };
        let n = self.nodes.len();
        if rs.kill_round == next_round && self.restart_victim.is_none() {
            if let Some(v) = (0..n).rev().find(|&i| i != leader && self.alive[i]) {
                self.alive[v] = false;
                self.restart_victim = Some(v);
            }
        }
        if rs.restart_round == next_round {
            self.restart_pending = None; // one-shot
            if let Some(v) = self.restart_victim {
                let mut fresh = Node::new(v, n, self.mode.clone());
                fresh.set_static_weights(self.config.static_weights);
                fresh.set_snapshot_every(self.config.snapshot_every);
                fresh.set_pre_vote(self.config.pre_vote);
                fresh.set_read_path(self.config.read_path);
                fresh.set_lease_duration_ms(self.config.lease_duration_ms());
                fresh.set_coding(self.coding);
                if self.membership_on {
                    fresh.set_drain_rounds(self.config.drain_rounds);
                    fresh.set_join_warmup(self.config.join_warmup);
                    if self.founding < n {
                        // catch-up replays or snapshot-installs the current
                        // config; the founding one is only the fallback
                        fresh.set_initial_config(Arc::new(ClusterConfig::bootstrap(
                            self.founding,
                        )));
                    }
                }
                if matches!(self.config.read_path, ReadPath::Lease) {
                    // a restarted voter may have acked a probe whose lease is
                    // still live — hold its vote for one full election timeout
                    fresh.hold_votes_until_timeout();
                }
                // Durable storage: crash the simulated disk (unsynced tail
                // lost; torn-write faults may keep a corrupted partial
                // tail), recover the WAL, and replay HardState + snapshot +
                // log into the fresh node — the double-vote fix. Storage
                // off keeps the historical amnesiac reboot, draw-for-draw.
                if let Some(wal) = self.wals[v].take() {
                    let cfg = WalConfig {
                        fsync_group: self.config.storage.map_or(8, |s| s.fsync_group),
                        ..WalConfig::default()
                    };
                    let mut disk = wal.into_disk();
                    disk.crash(self.wal_fault_rng.as_mut());
                    let (wal, rec) = Wal::open(disk, cfg);
                    fresh.set_durable(true);
                    fresh.restore_hard_state(rec.hard_state.term, rec.hard_state.voted_for);
                    if let Some(blob) = rec.snapshot.clone() {
                        fresh.restore_snapshot(blob);
                    }
                    for (prev, w, es) in &rec.splices {
                        fresh.restore_entries(*prev, *w, es);
                    }
                    self.wal_recoveries += 1;
                    self.wal_recovered_entries += rec.entries() as u64;
                    self.wals[v] = Some(wal);
                }
                self.nodes[v] = fresh;
                // a fresh node legitimately re-commits from the bottom of
                // the log — restart its safety-evidence stream with it, or
                // the checker would flag the replay as a commit regression
                if let Some(sl) = self.safety.as_mut() {
                    sl.commits[v].clear();
                }
                self.alive[v] = true;
                self.el_gen[v] += 1;
                let d = self
                    .timer_rng
                    .range_f64(self.config.election_timeout_ms.0, self.config.election_timeout_ms.1);
                self.push(q, d, Ev::ElectionTimer { node: v, generation: self.el_gen[v] });
            }
        }
    }

    /// Scheduled kills fire at the start of their round.
    fn run_scheduled_kills(&mut self, next_round: u64, leader: NodeId) {
        while let Some(k) = self.kills.front().cloned() {
            if k.round != next_round {
                break;
            }
            let weights = self.nodes[leader].weight_assignment().to_vec();
            for v in k.victims(&weights, leader, &self.alive, &mut self.kill_rng) {
                self.alive[v] = false;
            }
            self.kills.pop_front();
        }
    }

    /// Fire one scheduled membership event at the current leader. A joining
    /// slot powers on here — it can arm timers and receive appends from now
    /// on — while the consensus-side admission (joint config, minimum
    /// weight, warmup) is driven entirely by the leader's admin queue.
    /// Removal powers a slot off only when its `LeaveJoint` config commits
    /// (see [`SimEffects::config_committed`]).
    fn fire_membership(
        &mut self,
        ev: MembershipEvent,
        leader: NodeId,
        now: f64,
        q: &mut EventQueue<GroupEv>,
    ) {
        let cmds: [Option<AdminCmd>; 2] = match ev.kind {
            MembershipKind::Join(id) => [Some(AdminCmd::Join(id)), None],
            MembershipKind::Leave(id) => [Some(AdminCmd::Leave(id)), None],
            // join first: the replacement is admitted before the old node
            // drains, so capacity never dips below the founding size
            MembershipKind::Replace { leave, join } => {
                [Some(AdminCmd::Join(join)), Some(AdminCmd::Leave(leave))]
            }
        };
        for cmd in cmds.into_iter().flatten() {
            if let AdminCmd::Join(id) = cmd {
                if id < self.nodes.len() && !self.alive[id] && !self.members[id] {
                    self.alive[id] = true;
                    self.el_gen[id] += 1;
                    let d = self.timer_rng.range_f64(
                        self.config.election_timeout_ms.0,
                        self.config.election_timeout_ms.1,
                    );
                    self.push(q, d, Ev::ElectionTimer { node: id, generation: self.el_gen[id] });
                }
            }
            self.nodes[leader].observe_time(now);
            self.step_route(leader, Input::Admin(cmd), 0.0, q);
        }
    }

    /// Apply a committed (non-joint) config to the engine's power state:
    /// newly removed voters power off, newly admitted ones are confirmed.
    /// Epoch-guarded so a failover replaying older config commits cannot
    /// resurrect a removed slot.
    fn apply_committed_config(&mut self, epoch: u64, voters: &[NodeId]) {
        if epoch < self.max_config_epoch {
            return;
        }
        self.max_config_epoch = epoch;
        for slot in 0..self.members.len() {
            let is_voter = voters.contains(&slot);
            if self.members[slot] && !is_voter {
                self.members[slot] = false;
                self.alive[slot] = false;
            } else if !self.members[slot] && is_voter {
                self.members[slot] = true;
                self.alive[slot] = true;
            }
        }
    }

    /// Lock-step service time: any batch-carrying AppendEntries charges the
    /// one in-flight round's apply cost.
    fn service_ms_lockstep(&self, node: NodeId, msg: &Message) -> f64 {
        match msg {
            Message::AppendEntries { entries, .. } if !entries.is_empty() => {
                let speed = self.effective_speed(node);
                let has_batch = entries
                    .iter()
                    .any(|e| matches!(e.payload, Payload::Ycsb(_) | Payload::Tpcc(_)));
                let apply = if has_batch { self.inflight_cost_ms } else { 0.0 };
                (self.config.rpc_proc_ms + apply) / speed
            }
            _ => self.config.rpc_proc_ms / self.effective_speed(node),
        }
    }

    /// Pipelined service time: apply cost accrues per batch entry the node
    /// will actually append — the message must pass the term and
    /// log-consistency checks, and each entry is charged at its own round's
    /// cost only the first time it ships. Overlapping retransmissions inside
    /// the window and rejected appends (stale term / log mismatch after a
    /// failover) never re-charge an executed batch.
    fn service_ms_pipelined(&self, node: NodeId, msg: &Message) -> f64 {
        let receiver = &self.nodes[node];
        match msg {
            Message::AppendEntries { term, prev_log_index, prev_log_term, entries, .. }
                if !entries.is_empty() =>
            {
                let speed = self.effective_speed(node);
                let accepted = *term >= receiver.term()
                    && receiver.log().matches(*prev_log_index, *prev_log_term);
                let apply: f64 = if accepted {
                    let last = receiver.log().last_index();
                    entries
                        .iter()
                        .filter(|e| {
                            e.index > last
                                && matches!(e.payload, Payload::Ycsb(_) | Payload::Tpcc(_))
                        })
                        .map(|e| self.batch_costs.get(&e.index).copied().unwrap_or(0.0))
                        .sum()
                } else {
                    0.0
                };
                (self.config.rpc_proc_ms + apply) / speed
            }
            _ => self.config.rpc_proc_ms / self.effective_speed(node),
        }
    }

    /// Zone speed × contention factor at this group's current round.
    fn effective_speed(&self, node: NodeId) -> f64 {
        self.effective_speed_at(node, self.round)
    }

    fn effective_speed_at(&self, node: NodeId, round: u64) -> f64 {
        let mut speed = self.config.zones.speed(node);
        if let Some(c) = &self.config.contention {
            speed /= c.factor(round);
        }
        speed
    }

    /// Persist a freshly captured snapshot to `node`'s WAL (storage on):
    /// the blob goes down durably, segments older than the current one are
    /// pruned, and the log tail the node retains past the snapshot is
    /// re-appended so the prune loses nothing. Returns the fsync latency
    /// to charge this step (0 when storage is off or nothing new).
    fn persist_snapshot(&mut self, node: NodeId) -> f64 {
        let Some(wal) = self.wals[node].as_mut() else { return 0.0 };
        let nd = &self.nodes[node];
        let Some(blob) = nd.snapshot() else { return 0.0 };
        if blob.last_index <= wal.snapshot_index() {
            return 0.0;
        }
        let fsync_ms = self.config.storage.map_or(0.0, |s| s.fsync_ms);
        wal.record_snapshot(blob);
        self.wal_fsyncs += 1;
        let mut charge = fsync_ms;
        let tail = nd.log().slice(blob.last_index, nd.log().last_index());
        if !tail.is_empty() {
            self.wal_appends += 1;
            if wal.append_splice(blob.last_index, nd.my_weight(), &tail) {
                self.wal_fsyncs += 1;
                charge += fsync_ms;
            }
        }
        charge
    }

    /// Route one node's outputs into the fabric through the shared
    /// [`ReplicaHost`] interpreter (`consensus::host`); sends leave
    /// `extra_delay` ms after now (the node's service time). What each
    /// effect *does* here lives in [`SimEffects`] — the engine keeps no
    /// per-arm `Output` match of its own. Drains the caller's buffer so
    /// `step_route` can hand the same allocation to every step.
    fn route(
        &mut self,
        node: NodeId,
        outs: &mut Vec<Output>,
        extra_delay: f64,
        q: &mut EventQueue<GroupEv>,
    ) {
        // Persist-before-reply: fsync latency accrued by durability work —
        // this pre-step snapshot persist plus the batch's persist outputs,
        // accumulated by the host — delays every subsequent Send in the
        // same batch. Zero when storage is off, so send delays are
        // bit-identical to the historical ones.
        let initial_lag = self.persist_snapshot(node);
        let n = self.config.n();
        let now = q.now();
        let fsync_ms = self.config.storage.map_or(0.0, |s| s.fsync_ms);
        // the host is taken out for the drive so the adapter can borrow
        // the rest of the engine mutably (it is two words — a swap, not an
        // allocation)
        let mut host = std::mem::replace(&mut self.host, ReplicaHost::new(self.gid));
        let mut fx = SimEffects { eng: self, q, node, extra_delay, now, fsync_ms, n };
        host.drive_with_lag(outs, initial_lag, &mut fx);
        self.host = host;
    }

    /// Lock-step retirement: only the harness round (pending batch) counts.
    fn round_committed_lockstep(
        &mut self,
        node: NodeId,
        index: u64,
        repliers: usize,
        now: f64,
        q: &mut EventQueue<GroupEv>,
    ) {
        // write-completion timeline for the read checker (recorded for
        // every leader-observed commit, barrier no-ops included)
        if Some(node) == self.current_leader {
            if let Some(sl) = self.safety.as_mut() {
                sl.commit_times.push((now, index));
            }
        }
        if let Some((rnd, start, ops, leader_apply_done, _)) = self.pending1.as_ref() {
            if index >= self.pending1_entry && Some(node) == self.current_leader {
                let commit_time = now.max(*leader_apply_done);
                let latency = commit_time - start;
                self.stats.push(RoundStat {
                    round: *rnd,
                    entry_index: self.pending1_entry,
                    start_ms: *start,
                    latency_ms: latency,
                    tput_ops_s: *ops as f64 / (latency / 1000.0),
                    ops: *ops,
                    repliers,
                });
                self.round = *rnd;
                // apply to tracked replicas (replica convergence)
                if let Some((_, _, _, _, batch)) = self.pending1.take() {
                    apply_tracked(
                        &batch,
                        &self.tracked,
                        &mut self.doc_stores,
                        &mut self.rel_stores,
                        self.is_tpcc,
                    );
                }
                self.push(q, 0.2, Ev::ProposeNext); // client turnaround
            }
        }
    }

    /// Pipelined retirement: the committed prefix of the window retires in
    /// order and the proposer is re-armed.
    fn round_committed_pipelined(
        &mut self,
        node: NodeId,
        index: u64,
        repliers: usize,
        now: f64,
        q: &mut EventQueue<GroupEv>,
    ) {
        if Some(node) != self.current_leader {
            return;
        }
        // write-completion timeline for the read checker (barrier no-ops
        // included — read indices can point at them)
        if let Some(sl) = self.safety.as_mut() {
            sl.commit_times.push((now, index));
        }
        // retire the committed prefix of the window, in order — pop_front
        // is O(1) where the historical Vec::remove(0) shifted the window
        while self.pending.front().map_or(false, |p| p.entry_index <= index) {
            let p = self.pending.pop_front().expect("front checked");
            let commit_time = now.max(p.leader_apply_done);
            let latency = commit_time - p.start_ms;
            self.stats.push(RoundStat {
                round: p.round,
                entry_index: p.entry_index,
                start_ms: p.start_ms,
                latency_ms: latency,
                tput_ops_s: p.ops as f64 / (latency / 1000.0),
                ops: p.ops,
                repliers,
            });
            if p.round > self.round {
                self.round = p.round;
            }
            apply_tracked(
                &p.batch,
                &self.tracked,
                &mut self.doc_stores,
                &mut self.rel_stores,
                self.is_tpcc,
            );
        }
        self.push(q, 0.2, Ev::ProposeNext); // client turnaround
    }

    /// Retire one served read: record its latency and checker evidence.
    fn serve_read(&mut self, node: NodeId, id: u64, index: u64, lease: bool, now: f64) {
        let Some(req) = self.readctl.outstanding.remove(&id) else {
            return; // a duplicate grant after a retry already served it
        };
        let done = now + req.cost_ms / self.effective_speed(node);
        self.readctl.latencies.push(done - req.invoked_ms);
        self.readctl.reads_served += 1;
        self.readctl.read_ops_served += req.ops as u64;
        if lease {
            self.readctl.lease_reads += 1;
        }
        if done > self.readctl.done_ms {
            self.readctl.done_ms = done;
        }
        if let Some(sl) = self.safety.as_mut() {
            sl.reads.push(ReadRecord {
                node,
                id,
                invoked_ms: req.invoked_ms,
                served_ms: now,
                read_index: index,
                lease,
            });
        }
    }

    /// Fold this group's run into its [`SimResult`] — the exact tail both
    /// historical drivers shared.
    pub(crate) fn finish(mut self) -> GroupOutcome {
        // convergence check across tracked replicas
        let digests = if self.tracked.is_empty() {
            None
        } else if self.is_tpcc {
            let d0 = self.rel_stores[0].stream_digest();
            Some(self.rel_stores.iter().all(|s| s.stream_digest() == d0))
        } else {
            let d0 = self.doc_stores[0].state_digest();
            Some(self.doc_stores.iter().all(|s| s.state_digest() == d0))
        };

        sample_retained(&self.nodes, &mut self.max_retained);
        let mut result = SimResult::from_rounds(
            self.config.protocol.label(),
            self.stats,
            digests,
            self.elections,
        );
        result.snapshots_taken = self.nodes.iter().map(|nd| nd.snapshots_taken()).sum();
        result.snapshots_installed = self.nodes.iter().map(|nd| nd.snapshots_installed()).sum();
        result.max_retained_log = self.max_retained;
        result.elections_started = self.nodes.iter().map(|nd| nd.elections_started()).sum();
        result.terms_advanced = self.nodes.iter().map(|nd| nd.term()).max().unwrap_or(0);
        result.nemesis_stats = self.nemesis.as_ref().map(|nm| nm.stats);
        result.safety = self.safety.take();
        result.messages_delivered = self.messages;
        result.bytes_sent = self.bytes_sent;
        let total_ops: u64 = result.rounds.iter().map(|r| r.ops as u64).sum();
        result.bytes_per_op =
            if total_ops > 0 { self.bytes_sent as f64 / total_ops as f64 } else { 0.0 };
        result.config_commits = self.config_commits;
        result.wal_appends = self.wal_appends;
        result.wal_fsyncs = self.wal_fsyncs;
        result.wal_recoveries = self.wal_recoveries;
        result.wal_recovered_entries = self.wal_recovered_entries;
        // one sorted pass serves both the per-group percentiles and (moved,
        // not cloned) the multi-group merge's pooled population
        let mut read_latencies = std::mem::take(&mut self.readctl.latencies);
        read_latencies.sort_by(|a, b| a.total_cmp(b));
        finish_reads(&mut result, &self.readctl, &read_latencies, &self.nodes);
        GroupOutcome { result, read_latencies, final_leader: self.current_leader }
    }
}

/// The simulator's [`Effects`] adapter: maps each interpreter callback onto
/// the virtual fabric — `EventQueue` pushes for sends and timers, `MemDisk`
/// WALs with fsync-delay accounting for persists, and the engine's safety /
/// read / round bookkeeping for the observer effects. One step's worth of
/// context (`node`, `now`, service-time `extra_delay`) is captured at
/// construction in [`GroupEngine::route`]; the persist lag the host
/// accumulates arrives per-send as `persist_lag_ms`.
struct SimEffects<'a> {
    eng: &'a mut GroupEngine,
    q: &'a mut EventQueue<GroupEv>,
    /// The node whose outputs are being interpreted.
    node: NodeId,
    /// Service time already charged to this step (added to every send).
    extra_delay: f64,
    /// Virtual time at route entry, captured once for determinism.
    now: f64,
    /// Per-fsync latency charge (0 when storage is off).
    fsync_ms: f64,
    /// Founding cluster size (link-latency shaping needs it).
    n: usize,
}

impl Effects for SimEffects<'_> {
    fn send(&mut self, to: NodeId, env: Envelope, persist_lag_ms: f64) {
        let eng = &mut *self.eng;
        if !eng.alive[to] {
            return;
        }
        // wire-level vote-grant evidence for the double-vote checker
        // (informational — no timing effect)
        if let Message::RequestVoteReply { term, granted: true, .. } = &env.msg {
            if let Some(sl) = eng.safety.as_mut() {
                sl.votes.push((*term, self.node, to));
            }
        }
        // link delay is sampled on the non-leader endpoint (the paper's
        // netem delays are installed on follower nodes)
        let shaped_end =
            if self.node == eng.current_leader.unwrap_or(usize::MAX) { to } else { self.node };
        let lat = eng.config.delay.link_latency_bw(
            shaped_end,
            self.n,
            self.now,
            eng.round,
            env.msg.wire_size(),
            eng.bandwidth,
            &mut eng.net_rng,
        );
        let fate = match eng.nemesis.as_mut() {
            Some(nm) => nm.fate(self.now, self.node, to, eng.current_leader),
            None => Fate::deliver(),
        };
        if fate.copies == 0 {
            return; // partitioned or lost
        }
        if fate.copies > 1 {
            eng.push(
                self.q,
                self.extra_delay + persist_lag_ms + lat + fate.extra_delay_ms[1],
                Ev::Deliver { to, from: self.node, msg: env.msg.clone() },
            );
        }
        eng.push(
            self.q,
            self.extra_delay + persist_lag_ms + lat + fate.extra_delay_ms[0],
            Ev::Deliver { to, from: self.node, msg: env.msg },
        );
    }

    fn arm_election(&mut self) {
        let eng = &mut *self.eng;
        eng.el_gen[self.node] += 1;
        let d = eng
            .timer_rng
            .range_f64(eng.config.election_timeout_ms.0, eng.config.election_timeout_ms.1);
        eng.push(
            self.q,
            d,
            Ev::ElectionTimer { node: self.node, generation: eng.el_gen[self.node] },
        );
    }

    fn arm_heartbeat(&mut self) {
        let eng = &mut *self.eng;
        eng.hb_gen[self.node] += 1;
        eng.push(
            self.q,
            eng.config.heartbeat_ms,
            Ev::HeartbeatTimer { node: self.node, generation: eng.hb_gen[self.node] },
        );
    }

    fn disarm_heartbeat(&mut self) {
        self.eng.hb_gen[self.node] += 1;
    }

    fn persist_hard_state(&mut self, hs: HardState) -> f64 {
        let eng = &mut *self.eng;
        let Some(wal) = eng.wals[self.node].as_mut() else { return 0.0 };
        eng.wal_appends += 1;
        if wal.append_hard_state(hs) {
            eng.wal_fsyncs += 1;
            self.fsync_ms
        } else {
            0.0
        }
    }

    fn persist_entries(&mut self, prev_index: LogIndex, weight: f64, entries: &[Entry]) -> f64 {
        let eng = &mut *self.eng;
        let Some(wal) = eng.wals[self.node].as_mut() else { return 0.0 };
        eng.wal_appends += 1;
        if wal.append_splice(prev_index, weight, entries) {
            eng.wal_fsyncs += 1;
            self.fsync_ms
        } else {
            0.0
        }
    }

    // nodes snapshot inline in the sim (`SnapshotCapture::Inline`) — these
    // are informational; installs are counted via node counters
    fn capture_snapshot(&mut self, _through: LogIndex) -> bool {
        true
    }

    fn install_snapshot(&mut self, _blob: SnapshotBlob) -> bool {
        true
    }

    fn apply_batch(&mut self, entry: &Entry) -> bool {
        // per-node commit evidence for the bench::safety checker
        if let Some(sl) = self.eng.safety.as_mut() {
            sl.commits[self.node].push((entry.index, entry.term));
        }
        true
    }

    fn read_ready(&mut self, id: u64, index: LogIndex, lease: bool) -> bool {
        self.eng.serve_read(self.node, id, index, lease, self.now);
        true
    }

    fn read_failed(&mut self, id: u64) -> bool {
        let eng = &mut *self.eng;
        if eng.readctl.outstanding.contains_key(&id) {
            eng.readctl.failures += 1; // the standing retry re-drives it
        }
        true
    }

    fn became_leader(&mut self, term: Term) -> bool {
        let eng = &mut *self.eng;
        eng.current_leader = Some(self.node);
        eng.elections += 1;
        if let Some(sl) = eng.safety.as_mut() {
            sl.leaders.push((term, self.node));
        }
        true
    }

    fn stepped_down(&mut self) {
        let eng = &mut *self.eng;
        if eng.current_leader == Some(self.node) {
            eng.current_leader = None;
        }
    }

    fn round_committed(&mut self, rc: RoundCommit) -> bool {
        let eng = &mut *self.eng;
        // leader-observed quorum evidence for the config-epoch checker: the
        // commit rule this round actually closed under (both halves when it
        // was proposed mid-joint)
        if Some(self.node) == eng.current_leader {
            if let Some(sl) = eng.safety.as_mut() {
                sl.commit_evidence.push(CommitEvidence {
                    index: rc.index,
                    epoch: rc.epoch,
                    acc: rc.quorum_weight,
                    ct: rc.ct,
                    joint: rc.joint,
                    coded: rc.coded,
                });
            }
        }
        if eng.lockstep {
            eng.round_committed_lockstep(self.node, rc.index, rc.repliers, self.now, self.q);
        } else {
            eng.round_committed_pipelined(self.node, rc.index, rc.repliers, self.now, self.q);
        }
        true
    }

    fn config_committed(&mut self, epoch: u64, index: LogIndex, joint: bool, voters: Vec<NodeId>) -> bool {
        let eng = &mut *self.eng;
        if Some(self.node) == eng.current_leader {
            eng.config_commits += 1;
        }
        if let Some(sl) = eng.safety.as_mut() {
            sl.config_epochs.push((epoch, index, joint));
        }
        // only a completed (non-joint) config changes the power state: the
        // old half of a joint config still votes
        if !joint && eng.membership_on {
            eng.apply_committed_config(epoch, &voters);
        }
        true
    }
}

fn apply_tracked(
    batch: &Batch,
    tracked: &[usize],
    doc_stores: &mut [DocStore],
    rel_stores: &mut [RelStore],
    is_tpcc: bool,
) {
    if tracked.is_empty() {
        return;
    }
    match batch {
        Batch::Ycsb(b) => {
            for store in doc_stores.iter_mut() {
                store.apply(b);
            }
        }
        Batch::Tpcc(b) => {
            if is_tpcc {
                for store in rel_stores.iter_mut() {
                    store.apply(b);
                }
            }
        }
    }
}
