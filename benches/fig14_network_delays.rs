//! `cargo bench` target regenerating Fig 14 — D1/D2 network delays (quick scale; run
//! `cargo run --release --example figures -- fig14 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig14_network_delays", || {
        last = Some(figures::fig14(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
