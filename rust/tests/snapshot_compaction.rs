//! Snapshot/compaction integration tests — the scale story behind
//! `docs/ARCHITECTURE.md` §"Snapshotting": with `snapshot_every` set, a
//! long simulation runs on a bounded in-memory log, a killed-and-restarted
//! follower catches up from a leader snapshot instead of full log replay,
//! and compaction never changes *what* commits (the commit-sequence digest
//! is bit-identical to the compaction-off run, at pipeline depth 1 and
//! above). Plus the storage-level property: restoring a serialized store
//! snapshot and applying the remaining batches reaches the exact state that
//! full replay reaches.

use std::sync::Arc;

use cabinet::consensus::{
    AppState, Input, Message, Mode, Node, Output, Payload, SnapshotCapture,
};
use cabinet::sim::{run, Protocol, RestartSpec, SimConfig, WorkloadSpec};
use cabinet::storage::{DocStore, RelStore};
use cabinet::workload::{TpccGen, Workload, YcsbGen};

fn small(depth: usize, rounds: u64, every: Option<u64>) -> SimConfig {
    let mut c = SimConfig::new(Protocol::Cabinet { t: 1 }, 5, true);
    c.rounds = rounds;
    c.pipeline = depth;
    c.snapshot_every = every;
    c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 32, records: 2_000 };
    c
}

/// Acceptance: a 10k-round sim keeps the in-memory log bounded by the
/// snapshot interval + pipeline window, while the commit sequence stays
/// bit-identical to the unbounded run — at depth 1 (lock-step) and depth 4.
#[test]
fn ten_k_rounds_bounded_log_same_commit_sequence() {
    for depth in [1usize, 4] {
        let every = 64u64;
        let on = run(&small(depth, 10_000, Some(every)));
        let off = run(&small(depth, 10_000, None));
        assert_eq!(on.rounds.len(), 10_000, "depth {depth}: rounds incomplete");
        assert_eq!(off.rounds.len(), 10_000, "depth {depth}");
        assert_eq!(
            on.commit_sequence_digest(),
            off.commit_sequence_digest(),
            "depth {depth}: compaction changed the commit sequence"
        );
        assert!(
            on.snapshots_taken >= 10_000 / every - 2,
            "depth {depth}: too few snapshots ({})",
            on.snapshots_taken
        );
        assert!(
            on.max_retained_log <= every + 2 * depth as u64 + 16,
            "depth {depth}: retained log {} exceeds interval + window bound",
            on.max_retained_log
        );
        assert!(
            off.max_retained_log > 10_000,
            "depth {depth}: the off-run must grow with the round count"
        );
    }
}

/// Acceptance: a follower killed mid-run and restarted with fresh state
/// (empty log) catches up via `InstallSnapshot` — the leader has compacted
/// past the follower's log, so replay alone cannot recover it — and the
/// whole scenario replays deterministically.
#[test]
fn restarted_follower_catches_up_via_install_snapshot() {
    let mut c = small(4, 60, Some(8));
    c.restart = Some(RestartSpec { kill_round: 10, restart_round: 30 });
    let r = run(&c);
    assert_eq!(r.rounds.len(), 60, "rounds must continue across kill + restart");
    assert!(
        r.snapshots_installed >= 1,
        "the restarted follower must install a leader snapshot"
    );
    let r2 = run(&c);
    assert_eq!(r.metrics_digest(), r2.metrics_digest(), "restart replay diverged");
    assert_eq!(r.commit_sequence_digest(), r2.commit_sequence_digest());
}

/// With compaction off, the same restart recovers by full log replay — no
/// snapshot ever flows — pinning that `InstallSnapshot` is tied to
/// compaction, not to restarts per se.
#[test]
fn restart_without_compaction_replays_the_log() {
    let mut c = small(2, 40, None);
    c.restart = Some(RestartSpec { kill_round: 8, restart_round: 20 });
    let r = run(&c);
    assert_eq!(r.rounds.len(), 40);
    assert_eq!(r.snapshots_taken, 0);
    assert_eq!(r.snapshots_installed, 0);
}

/// End-to-end store catch-up: a leader whose driver owns a `DocStore` ships
/// its serialized state inside `InstallSnapshot` (the `AppState::Ycsb`
/// payload), and a fresh follower's driver rebuilds a bit-identical store
/// from the installed blob — no log replay involved.
#[test]
fn install_snapshot_carries_serialized_doc_store_end_to_end() {
    // Play the driver by hand: apply committed YCSB batches to a store,
    // answer SnapshotRequest with the store's serialized bytes.
    fn drive(leader: &mut Node, store: &mut DocStore, outs: Vec<Output>) {
        for o in outs {
            match o {
                Output::Commit(e) => {
                    if let Payload::Ycsb(b) = &e.payload {
                        store.apply(b);
                    }
                }
                Output::SnapshotRequest { through } => {
                    let bytes = Arc::new(store.to_snapshot_bytes());
                    leader.complete_snapshot(through, AppState::Ycsb(bytes));
                }
                _ => {}
            }
        }
    }

    let n = 5;
    let mut leader = Node::new(0, n, Mode::cabinet(n, 1));
    leader.set_snapshot_every(Some(1));
    leader.set_snapshot_capture(SnapshotCapture::Driver);
    let mut store = DocStore::new();
    let outs = leader.step(Input::ElectionTimeout);
    drive(&mut leader, &mut store, outs);
    for p in 1..n {
        let outs = leader.step(Input::Receive(
            p,
            Message::RequestVoteReply { term: 1, from: p, granted: true },
        ));
        drive(&mut leader, &mut store, outs);
    }
    let mut gen = YcsbGen::new(Workload::A, 2_000, 3);
    // commit the noop barrier, then two YCSB batches; node 4 never hears a
    // thing (partitioned), so its next_index falls behind the compaction
    let commit_up_to = |leader: &mut Node, store: &mut DocStore, idx: u64| {
        for p in [1usize, 2] {
            let wc = leader.wclock();
            let outs = leader.step(Input::Receive(
                p,
                Message::AppendEntriesReply {
                    term: 1,
                    from: p,
                    success: true,
                    match_index: idx,
                    wclock: wc,
                },
            ));
            drive(leader, store, outs);
        }
    };
    commit_up_to(&mut leader, &mut store, 1);
    for _ in 0..2 {
        let batch = Arc::new(gen.batch(200));
        let outs = leader.step(Input::Propose(Payload::Ycsb(batch)));
        drive(&mut leader, &mut store, outs);
        let idx = leader.log().last_index();
        commit_up_to(&mut leader, &mut store, idx);
    }
    assert_eq!(leader.commit_index(), 3);
    assert_eq!(leader.log().last_compacted_index(), 3, "leader compacted");
    assert_eq!(store.applied_batches(), 2);

    // the next heartbeat ships InstallSnapshot to the partitioned node
    let hb = leader.step(Input::HeartbeatTimeout);
    let snap_msg = hb
        .into_iter()
        .find_map(|o| match o {
            Output::Send(4, m @ Message::InstallSnapshot { .. }) => Some(m),
            _ => None,
        })
        .expect("lagging follower must be sent a snapshot");

    let mut follower = Node::new(4, n, Mode::cabinet(n, 1));
    let f_outs = follower.step(Input::Receive(0, snap_msg));
    let blob = f_outs
        .into_iter()
        .find_map(|o| match o {
            Output::SnapshotInstalled(b) => Some(b),
            _ => None,
        })
        .expect("follower must install the snapshot");
    assert_eq!(follower.commit_index(), 3);
    let bytes = match &blob.app {
        AppState::Ycsb(b) => Arc::clone(b),
        other => panic!("expected serialized DocStore, got {other:?}"),
    };
    let restored = DocStore::from_snapshot_bytes(&bytes).expect("decode");
    assert_eq!(restored.state_digest(), store.state_digest(), "stores diverge");
    assert_eq!(restored.applied_batches(), 2);
    assert_eq!(restored.len(), store.len());
}

/// Storage property (YCSB): state digest identical via full log replay vs
/// snapshot-install + suffix replay, across random batch streams and split
/// points.
#[test]
fn doc_store_snapshot_install_equals_full_replay() {
    for seed in 0..10u64 {
        let mut gen = YcsbGen::new(Workload::A, 5_000, seed);
        let batches: Vec<_> = (0..8).map(|_| gen.batch(300)).collect();
        let mut replayed = DocStore::new();
        for b in &batches {
            replayed.apply(b);
        }
        let split = 1 + (seed as usize % 7);
        let mut head = DocStore::new();
        for b in &batches[..split] {
            head.apply(b);
        }
        let bytes = head.to_snapshot_bytes();
        let mut restored = DocStore::from_snapshot_bytes(&bytes).expect("decode");
        for b in &batches[split..] {
            restored.apply(b);
        }
        assert_eq!(
            restored.state_digest(),
            replayed.state_digest(),
            "seed {seed} split {split}: digests diverge"
        );
        assert_eq!(restored.len(), replayed.len(), "seed {seed}");
        assert_eq!(restored.applied_batches(), replayed.applied_batches());
        assert_eq!(restored.digest_state(), replayed.digest_state());
    }
}

/// Storage property (TPC-C): stream digest and table state identical via
/// full replay vs snapshot-install + suffix replay.
#[test]
fn rel_store_snapshot_install_equals_full_replay() {
    for seed in 0..8u64 {
        let mut gen = TpccGen::new(8, seed);
        let batches: Vec<_> = (0..6).map(|_| gen.batch(300)).collect();
        let mut replayed = RelStore::new(8);
        for b in &batches {
            replayed.apply(b);
        }
        let split = 1 + (seed as usize % 5);
        let mut head = RelStore::new(8);
        for b in &batches[..split] {
            head.apply(b);
        }
        let bytes = head.to_snapshot_bytes();
        let mut restored = RelStore::from_snapshot_bytes(&bytes).expect("decode");
        for b in &batches[split..] {
            restored.apply(b);
        }
        assert_eq!(
            restored.stream_digest(),
            replayed.stream_digest(),
            "seed {seed} split {split}"
        );
        for w in 0..replayed.warehouses() {
            assert_eq!(restored.warehouse(w).ytd, replayed.warehouse(w).ytd);
            assert_eq!(
                restored.warehouse(w).delivered_orders,
                replayed.warehouse(w).delivered_orders
            );
        }
    }
}
