//! Follower state machines: the document store (MongoDB stand-in), the
//! relational store (PostgreSQL stand-in), the shared digest spec that
//! ties the native mirrors to the AOT Pallas kernels bit-for-bit, and the
//! durable segmented WAL ([`wal`]) behind `Node::set_durable`.

pub mod digest;
pub mod doc;
pub mod rel;
pub mod wal;

pub use digest::DigestState;
pub use doc::{ApplyResult, DocStore};
pub use rel::{RelStore, TpccApplyResult};
pub use wal::{Disk, FsDisk, HardState, MemDisk, Recovered, Wal, WalConfig};

/// Little-endian wire helpers shared by the store snapshot codecs
/// (`DocStore::to_snapshot_bytes` / `RelStore::to_snapshot_bytes`): the
/// serialized replica state `InstallSnapshot` ships to a catching-up
/// follower. Readers return `None` on truncated input instead of panicking.
pub(crate) mod wire {
    pub fn push_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn push_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn read_u32(bytes: &[u8], at: &mut usize) -> Option<u32> {
        let end = at.checked_add(4)?;
        let chunk: [u8; 4] = bytes.get(*at..end)?.try_into().ok()?;
        *at = end;
        Some(u32::from_le_bytes(chunk))
    }

    pub fn read_u64(bytes: &[u8], at: &mut usize) -> Option<u64> {
        let end = at.checked_add(8)?;
        let chunk: [u8; 8] = bytes.get(*at..end)?.try_into().ok()?;
        *at = end;
        Some(u64::from_le_bytes(chunk))
    }
}
