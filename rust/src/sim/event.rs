//! Deterministic discrete-event queue with a virtual millisecond clock.
//!
//! Ties are broken by insertion sequence, so a run is a pure function of
//! (config, seed) — every figure in EXPERIMENTS.md is exactly re-runnable.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in milliseconds.
pub type SimTime = f64;

struct Item<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Item<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Item<E> {}

impl<E> Ord for Item<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first.
        // total_cmp, not partial_cmp(..).unwrap_or(Equal): the old fallback
        // made a NaN time compare Equal to *everything*, silently corrupting
        // heap order (Ord's transitivity contract) — under total_cmp a NaN
        // orders deterministically (after every real time), and push_at
        // rejects it loudly in debug builds before it ever reaches the heap.
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Item<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Item<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at` (clamped to now).
    pub fn push_at(&mut self, at: SimTime, event: E) {
        debug_assert!(at.is_finite(), "non-finite event time {at}");
        let time = if at < self.now { self.now } else { at };
        self.seq += 1;
        self.heap.push(Item { time, seq: self.seq, event });
    }

    /// Schedule `event` after `delay` ms.
    pub fn push_after(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        let now = self.now;
        self.push_at(now + delay.max(0.0), event);
    }

    /// Time of the earliest scheduled event without popping it — drivers use
    /// this to stop cleanly at a virtual-time horizon instead of popping an
    /// event they will discard.
    pub fn next_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|item| item.time)
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|item| {
            debug_assert!(item.time >= self.now);
            self.now = item.time;
            (item.time, item.event)
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(5.0, "c");
        q.push_at(1.0, "a");
        q.push_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.push_at(1.0, 1);
        q.push_at(1.0, 2);
        q.push_at(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(2.0, ());
        q.push_at(7.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        q.push_after(1.0, ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 3.0);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 7.0);
    }

    #[test]
    fn next_time_peeks_without_advancing() {
        let mut q = EventQueue::new();
        assert_eq!(q.next_time(), None);
        q.push_at(4.0, "b");
        q.push_at(2.0, "a");
        assert_eq!(q.next_time(), Some(2.0));
        assert_eq!(q.now(), 0.0);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (2.0, "a"));
        assert_eq!(q.next_time(), Some(4.0));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite event time")]
    fn nan_event_time_fails_loudly() {
        // regression: a NaN time used to slip into the heap and compare
        // Equal to everything, silently corrupting pop order; now the push
        // asserts in debug builds (and orders deterministically in release)
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, ());
    }

    #[test]
    fn negative_zero_time_orders_deterministically() {
        // total_cmp orders -0.0 before +0.0 — harmless here (the clock
        // starts at 0.0 and delays are clamped nonnegative) but pinned so a
        // future change to the comparator is a conscious one
        let mut q = EventQueue::new();
        q.push_at(0.0, "pos");
        q.push_at(-0.0, "neg");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["neg", "pos"]);
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q = EventQueue::new();
        q.push_at(5.0, "later");
        q.pop();
        q.push_at(1.0, "past");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "past");
        assert_eq!(t, 5.0);
    }
}
