//! `cargo bench` target regenerating Fig 17 — bursting delays (D4) + HQC (quick scale; run
//! `cargo run --release --example figures -- fig17 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig17_bursting_hqc", || {
        last = Some(figures::fig17(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
