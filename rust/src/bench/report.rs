//! Machine-readable bench artifacts: `BENCH_<suite>.json` at the repo root.
//!
//! Every `benches/*.rs` target records its [`crate::bench::BenchStats`]
//! into a [`BenchReport`] and writes one JSON file per suite, so the perf
//! trajectory is a diffable sequence of artifacts instead of scrollback:
//! each record carries name, sample count, and mean/σ/min/max in
//! nanoseconds, and the report header pins the git revision and a
//! fingerprint of the bench configuration. The vendored crate set has no
//! serde, so the writer and the (deliberately minimal) parser are
//! hand-rolled here — `cabinet bench-check` and the schema round-trip test
//! in `rust/tests/bench_report.rs` keep them honest against each other.

use std::path::PathBuf;

use crate::bench::harness::BenchStats;
use crate::util::Fnv64;

/// Bumped whenever a field is added/renamed, so trajectory tooling can
/// refuse to compare artifacts across incompatible shapes.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// One benchmark's measured result (durations in nanoseconds), plus any
/// derived rates (`rounds_per_sec`, `messages_per_sec`, `ops_per_sec`, …).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub samples: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Extra named metrics, in insertion order (kept as a vec, not a map,
    /// so emission order — and therefore the artifact bytes — is
    /// deterministic).
    pub metrics: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn from_stats(name: &str, stats: &BenchStats) -> Self {
        BenchRecord {
            name: name.to_string(),
            samples: stats.samples as u64,
            mean_ns: stats.mean.as_secs_f64() * 1e9,
            stddev_ns: stats.stddev.as_secs_f64() * 1e9,
            min_ns: stats.min.as_secs_f64() * 1e9,
            max_ns: stats.max.as_secs_f64() * 1e9,
            metrics: Vec::new(),
        }
    }

    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == name).map(|&(_, v)| v)
    }
}

/// One suite's emission: header + records, serialized to
/// `BENCH_<suite>.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub suite: String,
    pub schema: u64,
    /// `git rev-parse --short HEAD` at emission time ("unknown" when git
    /// is unavailable — artifacts must still be writable offline).
    pub git_rev: String,
    /// FNV-1a fingerprint (16 hex digits) of the canonical configuration
    /// string the suite was run with — two artifacts are comparable iff
    /// their fingerprints match.
    pub config_fingerprint: String,
    /// Was this a quick-profile run (CI trajectory mode)?
    pub quick: bool,
    pub records: Vec<BenchRecord>,
}

impl BenchReport {
    /// `config` is the canonical human-readable description of the suite's
    /// parameters; its fingerprint gates artifact-to-artifact comparison.
    pub fn new(suite: &str, config: &str, quick: bool) -> Self {
        BenchReport {
            suite: suite.to_string(),
            schema: BENCH_SCHEMA_VERSION,
            git_rev: git_short_rev(),
            config_fingerprint: fingerprint(config),
            quick,
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, name: &str, stats: &BenchStats) -> &mut BenchRecord {
        self.records.push(BenchRecord::from_stats(name, stats));
        self.records.last_mut().expect("just pushed")
    }

    pub fn record(&self, name: &str) -> Option<&BenchRecord> {
        self.records.iter().find(|r| r.name == name)
    }

    // ---- emission --------------------------------------------------------

    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(256 + self.records.len() * 192);
        s.push_str("{\n");
        s.push_str(&format!("  \"suite\": {},\n", json_str(&self.suite)));
        s.push_str(&format!("  \"schema\": {},\n", self.schema));
        s.push_str(&format!("  \"git_rev\": {},\n", json_str(&self.git_rev)));
        s.push_str(&format!(
            "  \"config_fingerprint\": {},\n",
            json_str(&self.config_fingerprint)
        ));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str("  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str("    {");
            s.push_str(&format!("\"name\": {}, ", json_str(&r.name)));
            s.push_str(&format!("\"samples\": {}, ", r.samples));
            s.push_str(&format!("\"mean_ns\": {}, ", json_num(r.mean_ns)));
            s.push_str(&format!("\"stddev_ns\": {}, ", json_num(r.stddev_ns)));
            s.push_str(&format!("\"min_ns\": {}, ", json_num(r.min_ns)));
            s.push_str(&format!("\"max_ns\": {}, ", json_num(r.max_ns)));
            s.push_str("\"metrics\": {");
            for (j, (k, v)) in r.metrics.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("{}: {}", json_str(k), json_num(*v)));
            }
            s.push_str("}}");
            s.push_str(if i + 1 < self.records.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write `BENCH_<suite>.json` at the repo root; returns the path.
    pub fn write_to_repo_root(&self) -> std::io::Result<PathBuf> {
        let path = repo_root().join(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    // ---- parsing ---------------------------------------------------------

    /// Parse an emitted artifact back into a report. Strict about the
    /// schema (every header field and per-record stat must be present and
    /// of the right type) — `cabinet bench-check` rides on this to fail CI
    /// on malformed emission.
    pub fn parse(text: &str) -> Result<BenchReport, String> {
        let v = Json::parse(text)?;
        let top = v.as_obj().ok_or("top level is not an object")?;
        let records_json = obj_get(top, "records")?
            .as_arr()
            .ok_or("\"records\" is not an array")?;
        let mut records = Vec::with_capacity(records_json.len());
        for (i, rec) in records_json.iter().enumerate() {
            let o = rec.as_obj().ok_or_else(|| format!("record {i} is not an object"))?;
            let metrics_obj = obj_get(o, "metrics")?
                .as_obj()
                .ok_or_else(|| format!("record {i}: \"metrics\" is not an object"))?;
            let metrics = metrics_obj
                .iter()
                .map(|(k, v)| {
                    v.as_num()
                        .map(|x| (k.clone(), x))
                        .ok_or_else(|| format!("record {i}: metric {k:?} is not a number"))
                })
                .collect::<Result<Vec<_>, _>>()?;
            records.push(BenchRecord {
                name: get_str(o, "name").map_err(|e| format!("record {i}: {e}"))?,
                samples: get_num(o, "samples").map_err(|e| format!("record {i}: {e}"))? as u64,
                mean_ns: get_num(o, "mean_ns").map_err(|e| format!("record {i}: {e}"))?,
                stddev_ns: get_num(o, "stddev_ns").map_err(|e| format!("record {i}: {e}"))?,
                min_ns: get_num(o, "min_ns").map_err(|e| format!("record {i}: {e}"))?,
                max_ns: get_num(o, "max_ns").map_err(|e| format!("record {i}: {e}"))?,
                metrics,
            });
        }
        Ok(BenchReport {
            suite: get_str(top, "suite")?,
            schema: get_num(top, "schema")? as u64,
            git_rev: get_str(top, "git_rev")?,
            config_fingerprint: get_str(top, "config_fingerprint")?,
            quick: obj_get(top, "quick")?.as_bool().ok_or("\"quick\" is not a bool")?,
            records,
        })
    }
}

/// Repo root: cargo sets `CARGO_MANIFEST_DIR` for bench/test targets; fall
/// back to the current directory for standalone binaries.
pub fn repo_root() -> PathBuf {
    std::env::var_os("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Short git revision of the working tree, or "unknown".
pub fn git_short_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .current_dir(repo_root())
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// FNV-1a fingerprint of a canonical config string, as 16 hex digits.
pub fn fingerprint(config: &str) -> String {
    let mut h = Fnv64::new();
    h.write_bytes(config.as_bytes());
    format!("{:016x}", h.finish())
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// `{:?}` prints the shortest decimal that round-trips the exact f64, so
/// write → parse → write is byte-stable. JSON has no NaN/∞; durations and
/// rates are nonnegative reals, so a non-finite value is itself a bug —
/// surface it as 0 rather than emitting unparseable output.
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:?}")
    } else {
        "0.0".to_string()
    }
}

// ---------------------------------------------------------------------------
// Minimal JSON value + recursive-descent parser (std only, no serde)
// ---------------------------------------------------------------------------

/// A parsed JSON value. Objects keep insertion order (vec of pairs) so a
/// parse → re-emit cycle is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

fn obj_get<'a>(obj: &'a [(String, Json)], key: &str) -> Result<&'a Json, String> {
    obj.iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v)
        .ok_or_else(|| format!("missing field {key:?}"))
}

fn get_str(obj: &[(String, Json)], key: &str) -> Result<String, String> {
    obj_get(obj, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| format!("field {key:?} is not a string"))
}

fn get_num(obj: &[(String, Json)], key: &str) -> Result<f64, String> {
    obj_get(obj, key)?.as_num().ok_or_else(|| format!("field {key:?} is not a number"))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            kv.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = std::str::from_utf8(hex)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or("invalid \\u escape")?;
                            // BMP only — enough for our own emission, which
                            // never escapes astral characters
                            out.push(char::from_u32(code).ok_or("invalid \\u code point")?);
                            self.pos += 4;
                        }
                        _ => return Err(format!("invalid escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is &str, so slicing on
                    // the next boundary is safe)
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| "invalid utf-8")?;
                    let c = s.chars().next().ok_or("unterminated string")?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|_| "bad number")?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::Bencher;

    #[test]
    fn json_escaping_round_trips() {
        let s = "quote \" slash \\ newline \n tab \t";
        let parsed = Json::parse(&json_str(s)).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn parser_handles_nesting_and_numbers() {
        let v = Json::parse(r#"{"a": [1, 2.5, -3e-2], "b": {"c": true, "d": null}}"#).unwrap();
        let top = v.as_obj().unwrap();
        let arr = obj_get(top, "a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_num(), Some(2.5));
        assert_eq!(arr[2].as_num(), Some(-0.03));
        let b = obj_get(top, "b").unwrap().as_obj().unwrap();
        assert_eq!(obj_get(b, "c").unwrap().as_bool(), Some(true));
        assert_eq!(obj_get(b, "d").unwrap(), &Json::Null);
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn report_round_trips_exactly() {
        let b = Bencher::quick();
        let mut report = BenchReport::new("unit", "cfg=1", true);
        let stats = b.iter("unit_noop", || std::hint::black_box(1 + 1));
        report.push("unit_noop", &stats).metrics.push(("ops_per_sec".to_string(), 1.5e9));
        let parsed = BenchReport::parse(&report.to_json()).expect("own emission parses");
        assert_eq!(parsed, report);
    }

    #[test]
    fn parse_is_strict_about_schema() {
        // a record missing its stats must fail, not default to zero
        let bad = r#"{"suite": "x", "schema": 1, "git_rev": "r", "config_fingerprint": "f",
                      "quick": false, "records": [{"name": "a", "samples": 3}]}"#;
        assert!(BenchReport::parse(bad).is_err());
        // wrong type fails too
        let bad2 = r#"{"suite": "x", "schema": 1, "git_rev": "r", "config_fingerprint": "f",
                       "quick": "yes", "records": []}"#;
        assert!(BenchReport::parse(bad2).is_err());
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        assert_eq!(fingerprint("a"), fingerprint("a"));
        assert_ne!(fingerprint("a"), fingerprint("b"));
        assert_eq!(fingerprint("a").len(), 16);
    }
}
