//! `cargo bench` target regenerating Fig 19 — crash failures (quick scale; run
//! `cargo run --release --example figures -- fig19 --paper` for the
//! full 100-round version). See DESIGN.md §5 and EXPERIMENTS.md.

use cabinet::bench::{figures, Bencher, Scale};

fn main() {
    let b = Bencher::quick();
    let mut last = None;
    b.iter("fig19_failures", || {
        last = Some(figures::fig19(Scale::Quick));
    });
    if let Some(t) = last {
        print!("{}", t.render());
    }
}
