//! Network + heterogeneity substrates: seeded RNG, the paper's delay
//! models D1–D4 (§5.3 / Fig. 13), zone topology Z1–Z5 (§5), fault
//! injection (strong/weak/random kills + CPU contention, §5.4), and the
//! adversarial nemesis layer (partitions, loss, duplication, reordering).

pub mod delay;
pub mod fault;
pub mod nemesis;
pub mod rng;
pub mod topology;

pub use delay::DelayModel;
pub use fault::{ContentionSpec, KillSpec, KillStrategy};
pub use nemesis::{Fate, Nemesis, NemesisSpec, NemesisStats, PartitionKind, PartitionSpec};
pub use rng::{Rng, Zipfian};
pub use topology::{Zone, ZoneAlloc};
