//! Randomized membership-under-chaos sweep: join/leave/replace schedules
//! layered over rotating nemesis partition kinds in the deterministic sim,
//! with PreVote on half the schedules and a fast linearizable read path on
//! half. Every schedule runs the full `bench::safety` checker — prefix
//! consistency, single leader per term, monotone commits, read
//! linearizability, weighted-quorum commit evidence (both halves during
//! joint phases), and config-epoch coherence by log index.
//!
//! Membership ops are best-effort under chaos (an op fired into a window
//! with no reachable leader is dropped, never retried), so the hard
//! per-seed criterion is checker cleanliness + every client round
//! committing; epoch progress is asserted in aggregate across the sweep.

use cabinet::net::nemesis::{
    MembershipEvent, MembershipKind, MembershipSpec, NemesisSpec, PartitionKind, PartitionSpec,
};
use cabinet::net::rng::splitmix64;
use cabinet::sim::{run, Protocol, ReadPath, SimConfig, WorkloadSpec};
use cabinet::workload::Workload;

/// One randomized schedule: returns this seed's config-entry commit count
/// (0 when chaos swallowed the admin ops — legal, checked in aggregate).
fn membership_schedule(seed: u64) -> u64 {
    // Decorrelated schedule dimensions (same idiom as the consensus_safety
    // sweep): interacting dimensions each take independent bits of a hashed
    // seed so every op × partition-kind × PreVote combination appears.
    let mut h = seed ^ 0x5EED_0F_CAB1_2357;
    let bits = splitmix64(&mut h);
    let pre_vote_on = bits & 1 == 1;
    let kind_sel = (bits >> 1) & 3;
    // half the schedules run a fast read path (25% readindex, 25% lease) —
    // reads must stay linearizable across config epochs too
    let read_path = match (bits >> 3) & 3 {
        2 => ReadPath::ReadIndex,
        3 => ReadPath::Lease,
        _ => ReadPath::Log,
    };
    let op_sel = (bits >> 5) & 3;
    let pipeline = 1 + ((bits >> 7) & 3) as usize;
    // leave/replace always target a founding voter (slots 5–6 boot empty)
    let victim = ((bits >> 9) % 5) as usize;

    let n = 7;
    let mut c = SimConfig::new(Protocol::Cabinet { t: 1 }, n, true);
    c.rounds = 24;
    c.seed = seed;
    c.pipeline = pipeline;
    c.pre_vote = pre_vote_on;
    c.read_path = read_path;
    c.initial_members = Some(5);
    c.drain_rounds = 1 + (seed % 3) as usize;
    c.join_warmup = seed % 3;
    c.track_safety = true;
    c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 300, records: 10_000 };

    let first = 3 + (seed % 3);
    let events = match op_sel {
        0 => vec![MembershipEvent { round: first, kind: MembershipKind::Join(5) }],
        1 => vec![MembershipEvent { round: first, kind: MembershipKind::Leave(victim) }],
        2 => vec![MembershipEvent {
            round: first,
            kind: MembershipKind::Replace { leave: victim, join: 5 },
        }],
        // depth-2 schedule: a join settling while a leave starts exercises
        // the admin queue's serialization under chaos
        _ => vec![
            MembershipEvent { round: first, kind: MembershipKind::Join(5) },
            MembershipEvent { round: first + 6, kind: MembershipKind::Leave(victim) },
        ],
    };
    c.membership = Some(MembershipSpec { events });
    c.validate_membership().expect("sweep membership spec must be valid");

    // rotating partition kind over a mid-run window, always among the
    // founding voters so the cut actually bites
    let kind = match kind_sel {
        0 => PartitionKind::LeaderIsolation,
        1 => PartitionKind::Followers { count: 2 },
        2 => PartitionKind::Split { group: vec![4] },
        _ => PartitionKind::OneWay { group: vec![3] },
    };
    let spec = NemesisSpec {
        partitions: vec![PartitionSpec::new(1500.0, 4500.0, kind)],
        drop_p: 0.01 + (seed % 5) as f64 * 0.01,
        dup_p: 0.01 + (seed % 3) as f64 * 0.01,
        reorder_p: 0.0,
        reorder_max_ms: 0.0,
    };
    spec.validate(n).expect("sweep nemesis spec must be valid");
    c.nemesis = Some(spec);

    let r = run(&c);
    assert_eq!(
        r.rounds.len(),
        c.rounds as usize,
        "seed {seed}: every client round must commit through the chaos"
    );
    for (group, log) in r.safety_logs() {
        let report = cabinet::bench::safety_check(log);
        assert!(
            report.is_clean(),
            "seed {seed} (group {group:?}): {:?}",
            report.violations
        );
        if r.config_commits > 0 {
            assert!(
                report.epochs_checked > 0,
                "seed {seed}: config commits observed but no epoch evidence recorded"
            );
        }
    }
    r.config_commits
}

fn sweep(seeds: u64) {
    let mut seeds_advanced = 0u64;
    let mut total_commits = 0u64;
    for seed in 0..seeds {
        let commits = membership_schedule(seed);
        if commits > 0 {
            seeds_advanced += 1;
        }
        total_commits += commits;
    }
    // aggregate progress: chaos may swallow individual admin ops, but the
    // sweep as a whole must actually exercise config changes — a floor far
    // below the expected ~all-seeds rate, so only wholesale breakage trips
    assert!(
        seeds_advanced >= seeds / 4,
        "only {seeds_advanced}/{seeds} schedules advanced a config epoch"
    );
    assert!(
        total_commits >= seeds,
        "too little config traffic across the sweep: {total_commits} commits"
    );
}

#[test]
fn randomized_membership_safety_sweep() {
    sweep(128);
}

/// The long membership sweep for the scheduled CI `chaos` job:
/// `cargo test --release -- --ignored membership_long_sweep`.
#[test]
#[ignore = "long membership sweep (512 seeds) — run by the scheduled CI chaos job"]
fn membership_long_sweep() {
    sweep(512);
}
