//! Quickstart: a 5-node live Cabinet cluster (t = 1) on OS threads.
//!
//! Elects a leader, replicates a few client commands and one YCSB batch
//! (applied through the AOT PJRT artifact when `make artifacts` has run),
//! and prints the weight assignment + replica digests.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;
use std::time::Duration;

use cabinet::consensus::{Mode, Payload};
use cabinet::live::{ApplyService, LiveCluster, LiveTimers};
use cabinet::runtime::default_artifact_dir;
use cabinet::workload::{Workload, YcsbGen};

fn main() {
    let n = 5;
    let t = 1;
    println!("starting a {n}-node Cabinet cluster with failure threshold t={t}");

    let mut svc = ApplyService::spawn(default_artifact_dir());
    println!("state-machine apply backend: {:?}", svc.backend());

    let cluster = LiveCluster::start(
        n,
        Mode::cabinet(n, t),
        LiveTimers::default(),
        Some(svc.submitter()),
        42,
    );
    cluster.force_election(0);
    let leader = cluster
        .wait_for_leader(Duration::from_secs(5))
        .expect("no leader elected");
    println!("node {leader} won the election (needs n-t = {} votes)", n - t);

    // replicate three opaque client commands
    for (i, cmd) in ["set x=1", "set y=2", "del x"].iter().enumerate() {
        cluster.propose(leader, Payload::Bytes(Arc::new(cmd.as_bytes().to_vec())));
        let lat = cluster
            .wait_for_round((i + 2) as u64, Duration::from_secs(5))
            .expect("commit timed out");
        println!("committed {cmd:?} in {lat:.2?}");
    }

    // replicate one real YCSB batch — applied via the PJRT artifact
    let mut gen = YcsbGen::new(Workload::A, 10_000, 7);
    cluster.propose(leader, Payload::Ycsb(Arc::new(gen.batch(1000))));
    let lat = cluster
        .wait_for_round(5, Duration::from_secs(10))
        .expect("batch commit timed out");
    println!("committed a 1,000-op YCSB-A batch in {lat:.2?}");

    std::thread::sleep(Duration::from_millis(300)); // let commits propagate
    let reports = cluster.shutdown();
    println!("\nfinal state:");
    for r in &reports {
        println!(
            "  node {}: commit_index={} applies={} digest={:?}",
            r.id, r.commit_index, r.applies, r.final_digest
        );
    }
    let digests: Vec<_> = reports.iter().filter_map(|r| r.final_digest).collect();
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "replica digests diverged!"
    );
    println!("replica digests match across {} replicas ✓", digests.len());
}
