//! Nemesis determinism + idempotency properties.
//!
//! (1) A run with the adversarial network layer enabled is still a pure
//! function of (config, seed): same seed ⇒ bit-identical commit-sequence
//! and metrics digests, at pipeline depth 1 (the lock-step driver) and 4
//! (the pipelined driver), with PreVote off and on.
//!
//! (2) Node-level property tests for what the nemesis stresses: duplicated
//! or reordered InstallSnapshot and stale AppendEntries deliveries never
//! regress `commit_index` or change the log's prefix digest.

use std::sync::Arc;

use cabinet::consensus::message::{
    AppState, Entry, Message, Payload, SnapshotBlob,
};
use cabinet::consensus::log::Log;
use cabinet::consensus::node::{Input, Mode, Node, Output, ReadPath, Role};
use cabinet::net::nemesis::{NemesisSpec, PartitionKind, PartitionSpec};
use cabinet::net::rng::Rng;
use cabinet::sim::{run, Protocol, SimConfig, SimResult, WorkloadSpec};
use cabinet::workload::Workload;

fn nemesis_spec() -> NemesisSpec {
    NemesisSpec {
        partitions: vec![PartitionSpec::new(
            800.0,
            2_400.0,
            PartitionKind::Followers { count: 1 },
        )],
        drop_p: 0.05,
        dup_p: 0.05,
        reorder_p: 0.10,
        reorder_max_ms: 30.0,
    }
}

fn nem_config(depth: usize, pre_vote: bool, seed: u64) -> SimConfig {
    let mut c = SimConfig::new(Protocol::Cabinet { t: 2 }, 7, true);
    c.rounds = 10;
    c.pipeline = depth;
    c.seed = seed;
    c.pre_vote = pre_vote;
    c.nemesis = Some(nemesis_spec());
    c.track_safety = true;
    c.delay = cabinet::net::delay::DelayModel::Uniform { mean_ms: 60.0, spread_ms: 15.0 };
    c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 300, records: 10_000 };
    c
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.commit_sequence_digest(), b.commit_sequence_digest(), "{what}: commit seq");
    assert_eq!(a.metrics_digest(), b.metrics_digest(), "{what}: metrics");
    assert_eq!(a.elections_started, b.elections_started, "{what}: elections_started");
    assert_eq!(a.terms_advanced, b.terms_advanced, "{what}: terms_advanced");
    let (sa, sb) = (a.nemesis_stats.unwrap(), b.nemesis_stats.unwrap());
    assert_eq!(
        (sa.cut, sa.dropped, sa.duplicated, sa.reordered),
        (sb.cut, sb.dropped, sb.duplicated, sb.reordered),
        "{what}: nemesis stats"
    );
}

#[test]
fn nemesis_same_seed_bit_identical_depth_1_and_4() {
    for depth in [1usize, 4] {
        for pre_vote in [false, true] {
            let c = nem_config(depth, pre_vote, 42);
            let a = run(&c);
            let b = run(&c);
            assert_eq!(a.rounds.len(), 10, "depth {depth} pre_vote {pre_vote}: incomplete");
            assert_bit_identical(&a, &b, &format!("depth {depth} pre_vote {pre_vote}"));
            // every nemesis run self-checks safety
            let report = cabinet::bench::safety_check(a.safety.as_ref().unwrap());
            assert!(report.is_clean(), "depth {depth}: {:?}", report.violations);
        }
    }
}

#[test]
fn nemesis_different_seeds_diverge() {
    let a = run(&nem_config(4, true, 1));
    let b = run(&nem_config(4, true, 2));
    assert_ne!(
        a.metrics_digest(),
        b.metrics_digest(),
        "different seeds must take different trajectories"
    );
}

#[test]
fn nemesis_actually_perturbs_the_trajectory() {
    // guards against the nemesis being silently disconnected: the same seed
    // with and without it must take different virtual-time trajectories
    let with = run(&nem_config(4, false, 7));
    let mut without_cfg = nem_config(4, false, 7);
    without_cfg.nemesis = None;
    let without = run(&without_cfg);
    assert_ne!(with.metrics_digest(), without.metrics_digest());
    let stats = with.nemesis_stats.unwrap();
    assert!(
        stats.cut + stats.dropped + stats.duplicated + stats.reordered > 0,
        "the schedule must have touched some messages: {stats:?}"
    );
}

#[test]
fn read_paths_under_nemesis_deterministic_and_clean() {
    // the nemesis determinism guarantee extends to the read paths: same
    // seed ⇒ bit-identical run (read metrics fold into the digest), and the
    // read-linearizability checker stays clean through partition + loss
    for path in [ReadPath::ReadIndex, ReadPath::Lease] {
        let mut c = nem_config(2, true, 77);
        c.read_path = path;
        c.workload = WorkloadSpec::Ycsb { workload: Workload::B, batch: 300, records: 10_000 };
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.rounds.len(), 10, "{path:?}: rounds incomplete");
        assert!(a.reads_served > 0, "{path:?}: no reads served under nemesis");
        assert_bit_identical(&a, &b, &format!("read path {path:?}"));
        assert_eq!(a.reads_served, b.reads_served, "{path:?}");
        assert_eq!(a.lease_reads, b.lease_reads, "{path:?}");
        let report = cabinet::bench::safety_check(a.safety.as_ref().unwrap());
        assert!(report.is_clean(), "{path:?}: {:?}", report.violations);
        assert!(report.reads_checked > 0, "{path:?}: checker saw no reads");
    }
}

// ---------------------------------------------------------------------------
// Node-level idempotency properties
// ---------------------------------------------------------------------------

fn entry(term: u64, index: u64) -> Entry {
    Entry { term, index, payload: Payload::Bytes(Arc::new(vec![index as u8])), wclock: index }
}

fn append_msg(prev: (u64, u64), entries: Vec<Entry>, commit: u64) -> Message {
    Message::AppendEntries {
        term: 1,
        leader: 0,
        prev_log_index: prev.0,
        prev_log_term: prev.1,
        entries,
        leader_commit: commit,
        wclock: 0,
        weight: 1.0,
    }
}

/// Digest of the committed prefix — what "monotone applied state" protects.
fn committed_digest(n: &Node) -> (u64, u64) {
    (n.commit_index(), n.log().prefix_digest(n.commit_index()))
}

#[test]
fn stale_append_entries_never_regress_commit_or_digest() {
    let mut f = Node::new(1, 5, Mode::cabinet(5, 1));
    let msgs = [
        append_msg((0, 0), vec![entry(1, 1)], 0),
        append_msg((1, 1), vec![entry(1, 2), entry(1, 3)], 1),
        append_msg((0, 0), vec![entry(1, 1), entry(1, 2), entry(1, 3)], 3),
    ];
    for m in &msgs {
        let _ = f.step(Input::Receive(0, m.clone()));
    }
    assert_eq!(f.commit_index(), 3);
    let settled = committed_digest(&f);
    let last = f.log().last_index();

    // replay every stale/duplicated prefix message, in every order, twice
    let mut rng = Rng::new(99);
    for _ in 0..200 {
        let pick = rng.below(msgs.len() as u64) as usize;
        let _ = f.step(Input::Receive(0, msgs[pick].clone()));
        assert_eq!(committed_digest(&f), settled, "stale replay moved committed state");
        assert_eq!(f.log().last_index(), last, "stale replay changed the log");
    }
}

#[test]
fn duplicated_or_late_install_snapshot_never_regresses() {
    // reference log to compute the blob's chained digest
    let mut reference = Log::new();
    for i in 1..=2u64 {
        reference.append(entry(1, i), 1.0);
    }
    let blob = SnapshotBlob {
        last_index: 2,
        last_term: 1,
        prefix_digest: reference.prefix_digest(2),
        wclock: 2,
        cabinet_t: Some(1),
        app: AppState::None,
    };
    let install = Message::InstallSnapshot { term: 1, leader: 0, snapshot: blob };

    let mut f = Node::new(1, 5, Mode::cabinet(5, 1));
    let outs = f.step(Input::Receive(0, install.clone()));
    assert_eq!(f.commit_index(), 2, "fresh follower installs the snapshot");
    assert!(outs.iter().any(|o| matches!(o, Output::SnapshotInstalled(_))));
    assert_eq!(f.snapshots_installed(), 1);

    // the log grows past the snapshot point
    let _ = f.step(Input::Receive(0, append_msg((2, 1), vec![entry(1, 3)], 3)));
    assert_eq!(f.commit_index(), 3);
    let settled = committed_digest(&f);

    // duplicated and reordered (now stale) installs must be inert
    for _ in 0..5 {
        let outs = f.step(Input::Receive(0, install.clone()));
        assert_eq!(committed_digest(&f), settled, "late install regressed state");
        assert_eq!(f.log().last_index(), 3, "late install truncated the suffix");
        assert_eq!(f.snapshots_installed(), 1, "duplicate install was re-applied");
        assert!(
            !outs.iter().any(|o| matches!(o, Output::SnapshotInstalled(_))),
            "stale install must not re-announce"
        );
    }
}

/// The stale-lease-under-partition regression: an isolated leader whose
/// lease has expired must fall back to ReadIndex confirmation — and, cut
/// off from every quorum, must then never serve the read at all. Serving it
/// would be exactly the stale read the checker flags: a healed majority may
/// have elected a new leader and committed past the isolated one.
#[test]
fn isolated_leader_with_expired_lease_never_serves_reads() {
    let n = 5;
    let mut leader = Node::new(0, n, Mode::cabinet(n, 1));
    leader.set_read_path(ReadPath::Lease);
    leader.set_lease_duration_ms(100.0);
    // elect + commit the term barrier
    let _ = leader.step(Input::ElectionTimeout);
    for p in [1usize, 2, 3] {
        let _ = leader.step(Input::Receive(
            p,
            Message::RequestVoteReply { term: 1, from: p, granted: true },
        ));
    }
    assert_eq!(leader.role(), Role::Leader);
    let barrier = leader.log().last_index();
    for p in [1usize, 2] {
        let _ = leader.step(Input::Receive(
            p,
            Message::AppendEntriesReply {
                term: 1,
                from: p,
                success: true,
                match_index: barrier,
                wclock: leader.wclock(),
            },
        ));
    }
    assert_eq!(leader.commit_index(), barrier);
    // a heartbeat-cadence probe round earns the lease
    let outs = leader.step(Input::HeartbeatTimeout);
    let seq = outs
        .iter()
        .find_map(|o| match o {
            Output::Send(_, Message::ReadIndex { seq, .. }) => Some(*seq),
            _ => None,
        })
        .expect("lease mode probes at heartbeat cadence");
    for p in [1usize, 2] {
        let _ = leader.step(Input::Receive(p, Message::ReadIndexResp { term: 1, from: p, seq }));
    }
    assert!(leader.lease_valid());
    // the partition opens: no acks ever arrive again. Within the lease the
    // leader may still serve (provably no newer leader can exist yet)...
    leader.observe_time(60.0);
    let outs = leader.step(Input::Read { id: 1 });
    assert!(outs.iter().any(|o| matches!(o, Output::ReadReady { id: 1, lease: true, .. })));
    // ...but past expiry every read falls back to ReadIndex and, with no
    // quorum reachable, never serves — across repeated attempts and
    // heartbeat re-probes
    leader.observe_time(300.0);
    assert!(!leader.lease_valid(), "lease must expire without fresh acks");
    for (t, id) in [(300.0, 2u64), (500.0, 3), (900.0, 4)] {
        leader.observe_time(t);
        let outs = leader.step(Input::Read { id });
        assert!(
            !outs.iter().any(|o| matches!(o, Output::ReadReady { .. })),
            "isolated leader served read {id} on a dead lease"
        );
        let outs = leader.step(Input::HeartbeatTimeout);
        assert!(
            !outs.iter().any(|o| matches!(o, Output::ReadReady { .. })),
            "re-probing without a quorum must not serve"
        );
    }
    assert!(leader.pending_confirm_rounds() >= 1, "reads parked on confirmation");
}

#[test]
fn random_replay_of_recorded_traffic_keeps_commit_monotone() {
    // Record a healthy message trace, then bombard a fresh follower with
    // random duplicated/reordered deliveries of it. The commit index must
    // move monotonically and the committed prefix digest must match the
    // in-order replica's at every point.
    let msgs = [
        append_msg((0, 0), vec![entry(1, 1)], 0),
        append_msg((1, 1), vec![entry(1, 2)], 1),
        append_msg((2, 1), vec![entry(1, 3), entry(1, 4)], 2),
        append_msg((4, 1), vec![entry(1, 5)], 4),
        append_msg((5, 1), vec![], 5),
    ];
    // the in-order replica is the reference
    let mut reference = Node::new(2, 5, Mode::cabinet(5, 1));
    for m in &msgs {
        let _ = reference.step(Input::Receive(0, m.clone()));
    }
    assert_eq!(reference.commit_index(), 5);

    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let mut f = Node::new(1, 5, Mode::cabinet(5, 1));
        let mut last_commit = 0;
        for _ in 0..300 {
            let pick = rng.below(msgs.len() as u64) as usize;
            let _ = f.step(Input::Receive(0, msgs[pick].clone()));
            let commit = f.commit_index();
            assert!(commit >= last_commit, "seed {seed}: commit regressed");
            last_commit = commit;
            assert_eq!(
                f.log().prefix_digest(commit),
                reference.log().prefix_digest(commit),
                "seed {seed}: committed prefix diverged at {commit}"
            );
        }
        assert_eq!(last_commit, 5, "seed {seed}: replay never converged");
    }
}
