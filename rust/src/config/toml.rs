//! Minimal TOML-subset parser (offline substitute for the `toml` crate):
//! `[section]` headers, `key = value` with integers, floats, booleans,
//! quoted strings and flat arrays of those. Sufficient for experiment
//! config files; rejects what it doesn't understand instead of guessing.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar or flat-array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// section name → (key → value); keys before any `[section]` land in "".
pub type Document = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc: Document = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(value.trim())
            .with_context(|| format!("line {}: bad value for {key}", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but safe: only strip # outside quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

/// Split on commas that are not inside quotes (flat arrays only).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse(
            r#"
# top comment
n = 50
t = 5
het = true
name = "cab f10%"

[delay]
model = "D2"
mean_ms = 100.5
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["n"], Value::Int(50));
        assert_eq!(doc[""]["het"], Value::Bool(true));
        assert_eq!(doc[""]["name"].as_str(), Some("cab f10%"));
        assert_eq!(doc["delay"]["model"].as_str(), Some("D2"));
        assert_eq!(doc["delay"]["mean_ms"].as_float(), Some(100.5));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("sizes = [3, 3, 5]\nmix = [0.5, 0.5]\n").unwrap();
        let sizes: Vec<i64> =
            doc[""]["sizes"].as_array().unwrap().iter().map(|v| v.as_int().unwrap()).collect();
        assert_eq!(sizes, vec![3, 3, 5]);
        assert_eq!(doc[""]["mix"].as_array().unwrap().len(), 2);
    }

    #[test]
    fn comments_inside_strings_survive() {
        let doc = parse("s = \"a # b\"\n").unwrap();
        assert_eq!(doc[""]["s"].as_str(), Some("a # b"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("novalue =\n").is_err());
        assert!(parse("x = what\n").is_err());
        assert!(parse("= 3\n").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = parse("a = []\n").unwrap();
        assert_eq!(doc[""]["a"].as_array().unwrap().len(), 0);
    }
}
