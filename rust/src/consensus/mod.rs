//! The paper's Layer-3 contribution: Raft, Cabinet weighted consensus
//! (Algorithm 1), and the HQC baseline — all as sans-io state machines
//! driven by either the deterministic simulator (`sim::`) or the live
//! std-thread runtime (`live::`), both through the one shared effect
//! interpreter in [`host`] ([`ReplicaHost`] + the [`Effects`] trait).

pub mod coding;
pub mod host;
pub mod hqc;
pub mod log;
pub mod message;
pub mod node;
pub mod weights;

pub use coding::CodingConfig;
pub use host::{check_persist_order, Effects, PersistOrderViolation, ReplicaHost, RoundCommit};
pub use message::{
    AppState, Entry, LogIndex, Message, NodeId, Payload, ShardData, SnapshotBlob, Term, WClock,
};
pub use node::{Input, Mode, Node, Output, ReadPath, Role, SnapshotCapture};
pub use weights::{ratio_bounds, threshold_pct, WeightScheme};
