//! Deterministic-replay regression tests: a simulation run is a pure
//! function of (config, seed). Same seed ⇒ bit-identical commit sequence and
//! metrics digest — at pipeline depth 1 (the lock-step driver) and above
//! (the pipelined driver) — and different seeds must actually diverge.

use cabinet::net::delay::DelayModel;
use cabinet::net::fault::{KillSpec, KillStrategy};
use cabinet::sim::{run, Protocol, SimConfig, SimResult, WorkloadSpec};
use cabinet::workload::Workload;

fn base(proto: Protocol, n: usize, depth: usize, seed: u64) -> SimConfig {
    let mut c = SimConfig::new(proto, n, true);
    c.rounds = 8;
    c.pipeline = depth;
    c.seed = seed;
    c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 400, records: 10_000 };
    c
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.commit_sequence_digest(), b.commit_sequence_digest(), "{what}: commit seq");
    assert_eq!(a.metrics_digest(), b.metrics_digest(), "{what}: metrics");
    // digests are built from the rounds — double-check the raw bits too
    let bits = |r: &SimResult| -> Vec<(u64, u64, u64, u64)> {
        r.rounds
            .iter()
            .map(|s| (s.round, s.entry_index, s.start_ms.to_bits(), s.latency_ms.to_bits()))
            .collect()
    };
    assert_eq!(bits(a), bits(b), "{what}: per-round bits");
}

#[test]
fn same_seed_replays_bit_identical_all_depths() {
    for depth in [1usize, 2, 4, 8] {
        for proto in [Protocol::Raft, Protocol::Cabinet { t: 2 }] {
            let c = base(proto, 7, depth, 42);
            let a = run(&c);
            let b = run(&c);
            assert_eq!(a.rounds.len(), 8, "depth {depth}");
            assert_bit_identical(&a, &b, &format!("depth {depth} {}", a.label));
        }
    }
}

#[test]
fn replay_holds_under_delays_and_faults() {
    for depth in [1usize, 4] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 11, depth, 7);
        c.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
        c.kills = vec![KillSpec::new(4, 2, KillStrategy::Random)];
        let a = run(&c);
        let b = run(&c);
        assert_bit_identical(&a, &b, &format!("faulty depth {depth}"));
    }
}

#[test]
fn different_seeds_diverge() {
    for depth in [1usize, 4] {
        let mut c1 = base(Protocol::Cabinet { t: 2 }, 7, depth, 1);
        c1.delay = DelayModel::Uniform { mean_ms: 50.0, spread_ms: 10.0 };
        let mut c2 = c1.clone();
        c2.seed = 2;
        let a = run(&c1);
        let b = run(&c2);
        assert_ne!(
            a.metrics_digest(),
            b.metrics_digest(),
            "depth {depth}: different seeds produced identical trajectories"
        );
    }
}

#[test]
fn compaction_preserves_commit_sequence_and_replays_bit_identical() {
    // Snapshot compaction is pure bookkeeping: it must not change what
    // commits (commit-sequence digest vs the compaction-off run), and a
    // compacting run must itself replay bit-for-bit — at depth 1 and above.
    for depth in [1usize, 4] {
        let mut on = base(Protocol::Cabinet { t: 2 }, 7, depth, 11);
        on.rounds = 24;
        on.snapshot_every = Some(4);
        let mut off = on.clone();
        off.snapshot_every = None;
        let a = run(&on);
        let b = run(&off);
        assert_eq!(a.rounds.len(), 24, "depth {depth}");
        assert_eq!(
            a.commit_sequence_digest(),
            b.commit_sequence_digest(),
            "depth {depth}: compaction changed the commit sequence"
        );
        assert!(a.snapshots_taken > 0, "depth {depth}: no snapshots taken");
        let a2 = run(&on);
        assert_bit_identical(&a, &a2, &format!("compacting depth {depth}"));
    }
}

#[test]
fn single_group_is_bitwise_the_unsharded_driver() {
    // The sharding refactor's acceptance criterion: groups = 1 must take
    // exactly the historical code path. `groups: 1` is the constructor
    // default, so the default-config digests *are* the pre-refactor
    // digests the whole existing suite pins; here we additionally pin that
    // an explicit groups = 1 changes nothing (no rollups, no label suffix,
    // no digest perturbation) at both pipeline depths and under
    // delays + faults.
    for depth in [1usize, 4] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 11, depth, 7);
        c.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
        c.kills = vec![KillSpec::new(4, 2, KillStrategy::Random)];
        let implicit = run(&c);
        let mut explicit_cfg = c.clone();
        explicit_cfg.groups = 1;
        let explicit = run(&explicit_cfg);
        assert_bit_identical(&implicit, &explicit, &format!("groups=1 depth {depth}"));
        assert!(explicit.group_stats.is_empty(), "G=1 must not grow rollups");
        assert!(explicit.group_safety.is_empty());
        assert_eq!(implicit.label, explicit.label, "G=1 must keep the flat label");
    }
}

#[test]
fn sharded_replay_bit_identical_and_groups_is_a_real_knob() {
    for depth in [1usize, 4] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 11, depth, 17);
        c.rounds = 6;
        c.groups = 4;
        c.delay = DelayModel::Uniform { mean_ms: 60.0, spread_ms: 15.0 };
        let a = run(&c);
        let b = run(&c);
        // same seed ⇒ bit-identical aggregate AND per-group trajectories
        assert_eq!(a.rounds.len(), 4 * 6, "depth {depth}: every group commits");
        assert_bit_identical(&a, &b, &format!("sharded depth {depth}"));
        assert_eq!(a.group_stats.len(), 4);
        for (ga, gb) in a.group_stats.iter().zip(&b.group_stats) {
            assert_eq!(ga.commit_digest, gb.commit_digest, "group {} replay", ga.group);
            assert_eq!(ga.rounds, gb.rounds);
            assert_eq!(ga.leader, gb.leader);
            assert_eq!(ga.term, gb.term);
        }
        // sharding must actually change the trajectory vs a G=1 run of the
        // same seed — guards against the groups knob being silently ignored
        let mut c1 = c.clone();
        c1.groups = 1;
        let single = run(&c1);
        assert_ne!(
            single.metrics_digest(),
            a.metrics_digest(),
            "depth {depth}: groups = 4 must not reuse the single-group trajectory"
        );
    }
}

#[test]
fn sharded_different_seeds_diverge() {
    let mut c1 = base(Protocol::Cabinet { t: 2 }, 8, 2, 1);
    c1.groups = 4;
    c1.rounds = 5;
    let mut c2 = c1.clone();
    c2.seed = 2;
    let a = run(&c1);
    let b = run(&c2);
    assert_ne!(
        a.metrics_digest(),
        b.metrics_digest(),
        "sharded runs of different seeds produced identical trajectories"
    );
}

#[test]
fn membership_replay_bit_identical_at_both_depths() {
    // Dynamic membership rides the same deterministic machinery: a run
    // with a join/leave schedule (depth 1 and 4) replays bit-for-bit,
    // including the config-entry commits interleaved with client rounds.
    use cabinet::net::nemesis::{MembershipEvent, MembershipKind, MembershipSpec};
    for depth in [1usize, 4] {
        let mut c = base(Protocol::Cabinet { t: 1 }, 7, depth, 13);
        c.rounds = 16;
        c.initial_members = Some(5);
        c.drain_rounds = 2;
        c.join_warmup = 1;
        c.membership = Some(MembershipSpec {
            events: vec![
                MembershipEvent { round: 3, kind: MembershipKind::Join(5) },
                MembershipEvent { round: 9, kind: MembershipKind::Leave(1) },
            ],
        });
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.rounds.len(), 16, "depth {depth}");
        assert!(a.config_commits > 0, "depth {depth}: schedule must commit configs");
        assert_eq!(a.config_commits, b.config_commits, "depth {depth}");
        assert_bit_identical(&a, &b, &format!("membership depth {depth}"));

        // the schedule is a real knob: the same seed without it must take a
        // different trajectory
        let mut off = c.clone();
        off.membership = None;
        off.initial_members = None;
        let plain = run(&off);
        assert_ne!(
            a.metrics_digest(),
            plain.metrics_digest(),
            "depth {depth}: membership schedule must change the trajectory"
        );
    }
}

#[test]
fn membership_off_is_bitwise_the_fixed_cluster_driver() {
    // The determinism guardrail for the membership refactor: with no
    // founding restriction and no schedule, every membership branch is
    // behind `cfg_boot` fast paths, so the default-config digests — the
    // digests the whole pre-membership suite pins — must be reproduced
    // bit-for-bit whatever the (then-inert) drain/warmup knobs hold.
    for depth in [1usize, 4] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 11, depth, 7);
        c.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
        c.kills = vec![KillSpec::new(4, 2, KillStrategy::Random)];
        let stock = run(&c);
        let mut knobbed_cfg = c.clone();
        knobbed_cfg.drain_rounds = 9;
        knobbed_cfg.join_warmup = 0;
        let knobbed = run(&knobbed_cfg);
        assert_bit_identical(&stock, &knobbed, &format!("membership-off depth {depth}"));
        assert_eq!(stock.config_commits, 0);
        assert_eq!(knobbed.config_commits, 0);
    }
}

#[test]
fn coded_replication_off_is_bitwise_the_full_copy_driver() {
    // The coding refactor's acceptance criterion: with no [coding] table and
    // the new knobs absent-or-inert (bandwidth pinned to the stock NIC,
    // value_size 0, no batching budget), the coded-replication plumbing must
    // reproduce the historical full-copy digests bit-for-bit — at depth 1
    // and above, under delays and faults.
    for depth in [1usize, 4] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 11, depth, 7);
        c.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
        c.kills = vec![KillSpec::new(4, 2, KillStrategy::Random)];
        let stock = run(&c);
        let mut inert_cfg = c.clone();
        inert_cfg.coding = None;
        inert_cfg.max_batch_bytes = None;
        inert_cfg.value_size = 0;
        inert_cfg.bandwidth_bytes_per_ms =
            Some(cabinet::net::delay::BANDWIDTH_BYTES_PER_MS);
        let inert = run(&inert_cfg);
        assert_bit_identical(&stock, &inert, &format!("coding-off depth {depth}"));
    }
}

#[test]
fn coded_replication_replays_bit_identical_at_both_depths() {
    // Coding on (forced cutover low enough that every data round codes),
    // sized values, constrained bandwidth, batching budget: the whole
    // data-heavy configuration must still replay bit-for-bit, and it must
    // be a real knob vs the full-copy run of the same seed.
    use cabinet::consensus::coding::CodingConfig;
    for depth in [1usize, 8] {
        let mut c = base(Protocol::Cabinet { t: 2 }, 7, depth, 29);
        c.workload =
            WorkloadSpec::Ycsb { workload: Workload::A, batch: 16, records: 10_000 };
        c.value_size = 65_536;
        c.bandwidth_bytes_per_ms = Some(25_000.0);
        c.coding = Some(CodingConfig { k: 3, cutover_bytes: None });
        if depth > 1 {
            c.max_batch_bytes = Some(1 << 20);
        }
        c.validate_coding().unwrap();
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.rounds.len(), 8, "depth {depth}");
        assert_bit_identical(&a, &b, &format!("coded depth {depth}"));
        assert!(a.bytes_sent > 0 && a.bytes_sent == b.bytes_sent, "depth {depth}");

        let mut off = c.clone();
        off.coding = None;
        let full = run(&off);
        assert!(
            full.bytes_sent > a.bytes_sent,
            "depth {depth}: coding must cut replicated bytes ({} vs {})",
            full.bytes_sent,
            a.bytes_sent
        );
    }
}

#[test]
fn depth_changes_the_trajectory_but_not_the_commit_count() {
    // Depth is a real knob: depth 4 must take a different virtual-time
    // trajectory than depth 1 (same seed) while still committing every
    // round — guards against the pipeline flag being silently ignored.
    let mut c1 = base(Protocol::Cabinet { t: 2 }, 11, 1, 33);
    c1.delay = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
    let mut c4 = c1.clone();
    c4.pipeline = 4;
    let a = run(&c1);
    let b = run(&c4);
    assert_eq!(a.rounds.len(), 8);
    assert_eq!(b.rounds.len(), 8);
    assert_ne!(
        a.metrics_digest(),
        b.metrics_digest(),
        "depth 4 must not silently reuse the lock-step trajectory"
    );
}
