"""TPC-C cost-model Pallas kernels vs oracle (counts, costs, digest)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    TPCC_BASE_COST,
    TPCC_BATCH,
    TPCC_BLOCK,
    TPCC_LOCK_COEF,
    TPCC_WAREHOUSES,
    TXN_DELIVERY,
    TXN_NEW_ORDER,
    TXN_NOP,
    TXN_ORDER_STATUS,
    TXN_PAYMENT,
    TXN_STOCK_LEVEL,
    ref,
    tpcc_cost_pallas,
)

U32 = np.uint32


def _run_both(types, wids, args, block, n_wh):
    cnt_r = ref.tpcc_lock_counts_ref(types, wids, n_wh)
    cost_r, dig_r = ref.tpcc_cost_ref(types, wids, args, cnt_r)
    cnt_p, cost_p, dig_p = tpcc_cost_pallas(
        types, wids, args, block=block, n_warehouses=n_wh
    )
    return (cnt_r, cost_r, dig_r), (cnt_p, cost_p, dig_p)


def test_artifact_shape_exact():
    rng = np.random.default_rng(3)
    types = jnp.array(rng.integers(0, TXN_NOP + 1, TPCC_BATCH, dtype=U32))
    wids = jnp.array(rng.integers(0, TPCC_WAREHOUSES, TPCC_BATCH, dtype=U32))
    args = jnp.array(rng.integers(0, 16, TPCC_BATCH, dtype=U32))
    (cnt_r, cost_r, dig_r), (cnt_p, cost_p, dig_p) = _run_both(
        types, wids, args, TPCC_BLOCK, TPCC_WAREHOUSES
    )
    np.testing.assert_array_equal(np.array(cnt_r), np.array(cnt_p))
    np.testing.assert_allclose(np.array(cost_r), np.array(cost_p), rtol=1e-6)
    assert int(dig_r) == int(dig_p)


def test_lock_counts_only_write_txns():
    """OrderStatus / StockLevel take no warehouse lock."""
    types = jnp.array(
        [TXN_ORDER_STATUS, TXN_STOCK_LEVEL, TXN_NEW_ORDER, TXN_PAYMENT] * 16,
        U32,
    )
    wids = jnp.zeros((64,), U32)
    counts = ref.tpcc_lock_counts_ref(types, wids, 8)
    assert float(counts[0]) == 32.0  # only NewOrder + Payment
    cnt_p, _, _ = tpcc_cost_pallas(
        types, wids, jnp.zeros((64,), U32), block=32, n_warehouses=8
    )
    np.testing.assert_array_equal(np.array(counts), np.array(cnt_p))


def test_contention_raises_cost():
    """Two NewOrders on one warehouse cost more than on two warehouses."""
    types = jnp.full((32,), TXN_NOP, U32).at[0].set(TXN_NEW_ORDER).at[1].set(
        TXN_NEW_ORDER
    )
    args = jnp.zeros((32,), U32)
    same = jnp.zeros((32,), U32)
    diff = jnp.zeros((32,), U32).at[1].set(1)
    _, cost_same, _ = tpcc_cost_pallas(types, same, args, block=32, n_warehouses=4)
    _, cost_diff, _ = tpcc_cost_pallas(types, diff, args, block=32, n_warehouses=4)
    assert float(cost_same[0]) == TPCC_BASE_COST[0] + TPCC_LOCK_COEF
    assert float(cost_diff[0]) == TPCC_BASE_COST[0]


def test_nop_txns_cost_zero():
    types = jnp.full((32,), TXN_NOP, U32)
    _, costs, dig = tpcc_cost_pallas(
        types, jnp.zeros((32,), U32), jnp.zeros((32,), U32), block=32, n_warehouses=4
    )
    assert float(np.abs(np.array(costs)).sum()) == 0.0
    assert int(dig) == 0


def test_base_costs_per_type():
    """Each txn type alone (no contention, zero args) costs its base."""
    for code, base in enumerate(TPCC_BASE_COST):
        types = jnp.full((16,), TXN_NOP, U32).at[0].set(U32(code))
        _, costs, _ = tpcc_cost_pallas(
            types, jnp.zeros((16,), U32), jnp.zeros((16,), U32), block=16, n_warehouses=4
        )
        assert float(costs[0]) == base, f"type={code}"


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(1, 6),
    block=st.sampled_from([32, 64, 128, 256]),
    n_wh=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(blocks, block, n_wh, seed):
    rng = np.random.default_rng(seed)
    batch = blocks * block
    types = jnp.array(rng.integers(0, TXN_NOP + 2, batch, dtype=U32))
    wids = jnp.array(rng.integers(0, n_wh, batch, dtype=U32))
    args = jnp.array(rng.integers(0, 64, batch, dtype=U32))
    (cnt_r, cost_r, dig_r), (cnt_p, cost_p, dig_p) = _run_both(
        types, wids, args, block, n_wh
    )
    np.testing.assert_array_equal(np.array(cnt_r), np.array(cnt_p))
    np.testing.assert_allclose(np.array(cost_r), np.array(cost_p), rtol=1e-6)
    assert int(dig_r) == int(dig_p)
