//! The Cabinet benchmark framework (Fig. 7): metrics, the in-crate bench
//! harness (criterion substitute), and one experiment harness per paper
//! figure.

pub mod figures;
pub mod harness;
pub mod metrics;
pub mod report;
pub mod safety;
pub mod throughput;

pub use figures::{all_figures, lineup, Scale};
pub use harness::{quick_requested, Bencher, BenchStats};
pub use metrics::{fmt_tps, Summary, Table};
pub use report::{BenchRecord, BenchReport};
pub use safety::{check as safety_check, SafetyReport};
