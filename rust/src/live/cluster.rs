//! Live cluster: one OS thread per consensus node, real message passing
//! over channels, real wall-clock timers — the same sans-io `Node` state
//! machines the simulator drives, now with Python-free PJRT apply on every
//! commit. This is the runtime behind `examples/quickstart.rs` and
//! `examples/e2e_live.rs`.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::consensus::message::{AppState, Entry, LogIndex, Message, NodeId, Payload};
use crate::consensus::node::{Input, Mode, Node, Output, ReadPath, Role, SnapshotCapture};
use crate::live::apply::{empty_state, ApplyReq};
use crate::net::rng::Rng;
use crate::workload::YcsbBatch;

/// Work items for the applier thread, processed strictly in commit order.
enum ApplierMsg {
    /// A committed batch to fold into the replica state.
    Batch(Arc<YcsbBatch>),
    /// Capture the replica state for a snapshot through `through`. The node
    /// thread enqueues this *after* every commit the snapshot covers, so the
    /// applier's state at dequeue time is exactly the state at `through`;
    /// the answer goes back over the node's own inbox, so heartbeats never
    /// wait on the capture.
    Capture { through: LogIndex, reply: Sender<LiveIn> },
    /// Replace the replica state with an installed leader snapshot (a
    /// lagging follower caught up past its missing log prefix).
    Install(Vec<u32>),
}

/// Per-replica applier: a thread owning this node's replica state, applying
/// committed batches in commit order through the apply service. Keeping the
/// apply off the consensus thread is essential — a blocking apply starves
/// heartbeats and triggers spurious elections (found the hard way; see
/// rust/tests/live_e2e.rs). Snapshot capture rides the same queue for the
/// same reason.
struct Applier {
    tx: Sender<ApplierMsg>,
    handle: JoinHandle<(usize, Option<[u32; 2]>)>,
}

impl Applier {
    fn spawn(node: NodeId, service: Sender<ApplyReq>) -> Applier {
        let (tx, rx) = channel::<ApplierMsg>();
        let handle = std::thread::Builder::new()
            .name(format!("applier-{node}"))
            .spawn(move || {
                let mut state = empty_state();
                let mut applies = 0usize;
                let mut last_digest = None;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        ApplierMsg::Batch(batch) => {
                            let (resp, resp_rx) = channel();
                            let req = ApplyReq {
                                state: std::mem::take(&mut state),
                                batch: (*batch).clone(),
                                resp,
                            };
                            if service.send(req).is_err() {
                                break;
                            }
                            match resp_rx.recv() {
                                Ok((ns, d)) => {
                                    state = ns;
                                    applies += 1;
                                    last_digest = Some(d);
                                }
                                Err(_) => break,
                            }
                        }
                        ApplierMsg::Capture { through, reply } => {
                            let _ = reply
                                .send(LiveIn::SnapshotReady { through, state: state.clone() });
                        }
                        ApplierMsg::Install(s) => {
                            state = s;
                            // digests resume with the next applied batch
                            // (state_digest is a pure function of the state)
                            last_digest = None;
                        }
                    }
                }
                (applies, last_digest)
            })
            .expect("spawn applier");
        Applier { tx, handle }
    }
}

/// Inputs to a node thread.
pub enum LiveIn {
    Rpc(NodeId, Message),
    Propose(Payload),
    /// A client read request (non-log read paths): serve via ReadIndex /
    /// lease at the leader, or forward-and-serve-locally at a follower.
    Read(u64),
    /// Fire the election timer immediately (bootstrap).
    ForceElection,
    /// Applier → node: captured replica state for a pending snapshot
    /// (completes the `Output::SnapshotRequest` handshake).
    SnapshotReady { through: LogIndex, state: Vec<u32> },
    Stop,
}

/// Events surfaced to the harness/client.
#[derive(Clone, Debug)]
pub enum LiveEvent {
    Committed { node: NodeId, index: LogIndex, digest: Option<[u32; 2]> },
    BecameLeader { node: NodeId, term: u64 },
    RoundCommitted { node: NodeId, index: LogIndex, repliers: usize },
    /// A read is servable from `node`'s applied state at `index`.
    ReadReady { node: NodeId, id: u64, index: LogIndex, lease: bool },
    /// A read could not be served at `node` (no leader known / leadership
    /// lost) — re-issue it.
    ReadFailed { node: NodeId, id: u64 },
}

/// Timer configuration for live nodes.
#[derive(Clone, Copy, Debug)]
pub struct LiveTimers {
    pub election_lo: Duration,
    pub election_hi: Duration,
    pub heartbeat: Duration,
}

impl Default for LiveTimers {
    fn default() -> Self {
        LiveTimers {
            election_lo: Duration::from_millis(150),
            election_hi: Duration::from_millis(300),
            heartbeat: Duration::from_millis(40),
        }
    }
}

/// Link filter between node threads — the live runtime's nemesis hook.
/// Every `Output::Send` consults it before crossing a channel; a blocked
/// link silently drops the message, exactly like a partitioned network.
/// Operator-driven (no schedule): tests and demos cut and heal links while
/// the cluster runs.
struct LinkTable {
    n: usize,
    /// Flattened n×n matrix: `blocked[from * n + to]`.
    blocked: RwLock<Vec<bool>>,
}

impl LinkTable {
    fn new(n: usize) -> LinkTable {
        LinkTable { n, blocked: RwLock::new(vec![false; n * n]) }
    }

    fn allowed(&self, from: NodeId, to: NodeId) -> bool {
        !self.blocked.read().expect("link table poisoned")[from * self.n + to]
    }

    fn set(&self, from: NodeId, to: NodeId, blocked: bool) {
        self.blocked.write().expect("link table poisoned")[from * self.n + to] = blocked;
    }
}

/// A running cluster. Dropping it (including during a panic unwind) stops
/// all node threads.
pub struct LiveCluster {
    inboxes: Vec<Sender<LiveIn>>,
    pub events: Receiver<LiveEvent>,
    handles: Vec<JoinHandle<NodeReport>>,
    links: Arc<LinkTable>,
    n: usize,
}

/// Final per-node report returned at shutdown.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub id: NodeId,
    pub commit_index: LogIndex,
    pub final_digest: Option<[u32; 2]>,
    pub committed_entries: usize,
    pub applies: usize,
    /// Last compacted log index (> 0 iff snapshotting trimmed the log).
    pub last_compacted: LogIndex,
    /// Final term the node reached (the live `terms_advanced` signal: max
    /// over the reports).
    pub term: u64,
    /// Real (term-incrementing) candidacies this node started — with
    /// PreVote on, a partitioned minority reports zero.
    pub elections_started: u64,
}

impl LiveCluster {
    /// Start `n` node threads in the given mode. `apply_tx`: submit side of
    /// a running [`crate::live::ApplyService`] (or None to skip apply).
    pub fn start(
        n: usize,
        mode: Mode,
        timers: LiveTimers,
        apply_tx: Option<Sender<ApplyReq>>,
        seed: u64,
    ) -> LiveCluster {
        Self::start_with_snapshots(n, mode, timers, apply_tx, seed, None)
    }

    /// Like [`LiveCluster::start`], with snapshotting enabled: every node
    /// takes a snapshot every `snapshot_every` committed entries and
    /// compacts its log prefix. Replica state is captured on the applier
    /// thread (never blocking heartbeats); a follower that falls behind the
    /// leader's compaction point catches up via `InstallSnapshot`.
    pub fn start_with_snapshots(
        n: usize,
        mode: Mode,
        timers: LiveTimers,
        apply_tx: Option<Sender<ApplyReq>>,
        seed: u64,
        snapshot_every: Option<u64>,
    ) -> LiveCluster {
        Self::start_configured(n, mode, timers, apply_tx, seed, snapshot_every, false)
    }

    /// Fully configured start: everything `start_with_snapshots` offers plus
    /// PreVote elections (Raft §9.6 / Cabinet n − t quorum) on every node.
    pub fn start_configured(
        n: usize,
        mode: Mode,
        timers: LiveTimers,
        apply_tx: Option<Sender<ApplyReq>>,
        seed: u64,
        snapshot_every: Option<u64>,
        pre_vote: bool,
    ) -> LiveCluster {
        Self::start_full(
            n, mode, timers, apply_tx, seed, snapshot_every, pre_vote, ReadPath::Log, 40.0,
        )
    }

    /// Everything `start_configured` offers plus a linearizable read path:
    /// client reads (`LiveCluster::read`) are served via ReadIndex or leader
    /// leases (lease bound = `election_lo − lease_drift_ms`), with follower
    /// reads forwarded over the same links the link table filters.
    #[allow(clippy::too_many_arguments)]
    pub fn start_full(
        n: usize,
        mode: Mode,
        timers: LiveTimers,
        apply_tx: Option<Sender<ApplyReq>>,
        seed: u64,
        snapshot_every: Option<u64>,
        pre_vote: bool,
        read_path: ReadPath,
        lease_drift_ms: f64,
    ) -> LiveCluster {
        let (event_tx, event_rx) = channel::<LiveEvent>();
        let mut inbox_txs = Vec::with_capacity(n);
        let mut inbox_rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<LiveIn>();
            inbox_txs.push(tx);
            inbox_rxs.push(rx);
        }
        let peers: Arc<Vec<Sender<LiveIn>>> = Arc::new(inbox_txs.clone());
        let links = Arc::new(LinkTable::new(n));
        let mut handles = Vec::with_capacity(n);
        for (id, rx) in inbox_rxs.into_iter().enumerate() {
            let peers = Arc::clone(&peers);
            let links = Arc::clone(&links);
            let event_tx = event_tx.clone();
            let apply_tx = apply_tx.clone();
            let mode = mode.clone();
            let handle = std::thread::Builder::new()
                .name(format!("node-{id}"))
                .spawn(move || {
                    node_loop(
                        id, n, mode, timers, rx, peers, links, event_tx, apply_tx, seed,
                        snapshot_every, pre_vote, read_path, lease_drift_ms,
                    )
                })
                .expect("spawn node");
            handles.push(handle);
        }
        LiveCluster { inboxes: inbox_txs, events: event_rx, handles, links, n }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    // ---- link filtering (the live nemesis hook) --------------------------

    /// Block or unblock one directed link. Blocked sends are dropped
    /// silently, exactly like a partitioned network path.
    pub fn set_link(&self, from: NodeId, to: NodeId, up: bool) {
        self.links.set(from, to, !up);
    }

    /// Cut every link between `group` and the rest of the cluster, both
    /// directions (a bidirectional split). Links inside the group — and
    /// inside its complement — keep working.
    pub fn partition(&self, group: &[NodeId]) {
        for from in 0..self.n {
            for to in 0..self.n {
                if group.contains(&from) != group.contains(&to) {
                    self.links.set(from, to, true);
                }
            }
        }
    }

    /// Cut a single node off from everyone else (both directions).
    pub fn isolate(&self, node: NodeId) {
        self.partition(&[node]);
    }

    /// Restore every link.
    pub fn heal(&self) {
        let mut blocked = self.links.blocked.write().expect("link table poisoned");
        blocked.fill(false);
    }

    /// Bootstrap: make `node` start an election now.
    pub fn force_election(&self, node: NodeId) {
        let _ = self.inboxes[node].send(LiveIn::ForceElection);
    }

    /// Submit a proposal to `node` (should be the leader).
    pub fn propose(&self, node: NodeId, payload: Payload) {
        let _ = self.inboxes[node].send(LiveIn::Propose(payload));
    }

    /// Submit a linearizable read to `node` (any node: followers forward to
    /// their leader and serve locally once granted). The answer arrives as
    /// [`LiveEvent::ReadReady`] / [`LiveEvent::ReadFailed`].
    pub fn read(&self, node: NodeId, id: u64) {
        let _ = self.inboxes[node].send(LiveIn::Read(id));
    }

    /// Wait until read `id` is served; returns (read index, via lease).
    /// Returns `None` promptly when the read fails *locally* (no leader
    /// known / leadership lost mid-confirmation). A forwarded read the
    /// leader drops (e.g. its term barrier has not committed yet) produces
    /// no reply at all and only surfaces as a timeout — there are no
    /// node-side retries, so callers should re-issue with a fresh id.
    pub fn wait_for_read(&self, id: u64, timeout: Duration) -> Option<(LogIndex, bool)> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.checked_duration_since(Instant::now())?;
            match self.events.recv_timeout(remaining) {
                Ok(LiveEvent::ReadReady { id: rid, index, lease, .. }) if rid == id => {
                    return Some((index, lease))
                }
                Ok(LiveEvent::ReadFailed { id: rid, .. }) if rid == id => return None,
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }

    /// Wait until some node reports leadership; returns its id.
    pub fn wait_for_leader(&self, timeout: Duration) -> Option<NodeId> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.checked_duration_since(Instant::now())?;
            match self.events.recv_timeout(remaining) {
                Ok(LiveEvent::BecameLeader { node, .. }) => return Some(node),
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }

    /// Wait until the leader commits `index` (RoundCommitted); returns the
    /// elapsed time.
    pub fn wait_for_round(&self, index: LogIndex, timeout: Duration) -> Option<Duration> {
        let t0 = Instant::now();
        let deadline = t0 + timeout;
        loop {
            let remaining = deadline.checked_duration_since(Instant::now())?;
            match self.events.recv_timeout(remaining) {
                Ok(LiveEvent::RoundCommitted { index: i, .. }) if i >= index => {
                    return Some(t0.elapsed())
                }
                Ok(_) => continue,
                Err(_) => return None,
            }
        }
    }

    /// Crash a single node (its thread exits; peers stop hearing from it).
    pub fn stop_node(&self, node: NodeId) {
        let _ = self.inboxes[node].send(LiveIn::Stop);
    }

    /// Stop all nodes and collect their final reports.
    pub fn shutdown(mut self) -> Vec<NodeReport> {
        for tx in &self.inboxes {
            let _ = tx.send(LiveIn::Stop);
        }
        self.handles.drain(..).map(|h| h.join().expect("node panicked")).collect()
    }
}

impl Drop for LiveCluster {
    fn drop(&mut self) {
        // stop node threads even on the panic path (they hold each other's
        // senders via the peers Arc, so channel disconnection alone would
        // never terminate them)
        for tx in &self.inboxes {
            let _ = tx.send(LiveIn::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn node_loop(
    id: NodeId,
    n: usize,
    mode: Mode,
    timers: LiveTimers,
    rx: Receiver<LiveIn>,
    peers: Arc<Vec<Sender<LiveIn>>>,
    links: Arc<LinkTable>,
    events: Sender<LiveEvent>,
    apply_tx: Option<Sender<ApplyReq>>,
    seed: u64,
    snapshot_every: Option<u64>,
    pre_vote: bool,
    read_path: ReadPath,
    lease_drift_ms: f64,
) -> NodeReport {
    let mut node = Node::new(id, n, mode);
    node.set_snapshot_every(snapshot_every);
    node.set_pre_vote(pre_vote);
    node.set_read_path(read_path);
    node.set_lease_duration_ms(
        (timers.election_lo.as_secs_f64() * 1000.0 - lease_drift_ms).max(0.0),
    );
    if apply_tx.is_some() {
        // replica state lives on the applier thread — capture goes through
        // the SnapshotRequest / SnapshotReady handshake
        node.set_snapshot_capture(SnapshotCapture::Driver);
    }
    // the node's sans-io clock: ms since this thread started (all lease
    // decisions are relative, so per-node epochs are fine)
    let epoch = Instant::now();
    let my_inbox = peers[id].clone();
    let mut rng = Rng::new(seed ^ (id as u64).wrapping_mul(0x9E37_79B9));
    let rand_election = |rng: &mut Rng| {
        let lo = timers.election_lo.as_secs_f64();
        let hi = timers.election_hi.as_secs_f64();
        Duration::from_secs_f64(rng.range_f64(lo, hi))
    };

    let mut election_deadline = Instant::now() + rand_election(&mut rng);
    let mut heartbeat_deadline: Option<Instant> = None;

    // committed batches are applied off-thread, in commit order
    let applier = apply_tx.map(|service| Applier::spawn(id, service));
    let mut committed = 0usize;

    let handle_outputs = |outs: Vec<Output>,
                              applier: &Option<Applier>,
                              committed: &mut usize,
                              election_deadline: &mut Instant,
                              heartbeat_deadline: &mut Option<Instant>,
                              rng: &mut Rng| {
        for o in outs {
            match o {
                Output::Send(to, msg) => {
                    // the live nemesis hook: a cut link swallows the message
                    if links.allowed(id, to) {
                        let _ = peers[to].send(LiveIn::Rpc(id, msg));
                    }
                }
                Output::ResetElectionTimer => {
                    *election_deadline = Instant::now() + rand_election(rng);
                }
                Output::StartHeartbeat => {
                    *heartbeat_deadline = Some(Instant::now() + timers.heartbeat);
                }
                Output::StopHeartbeat => {
                    *heartbeat_deadline = None;
                }
                Output::BecameLeader { term } => {
                    let _ = events.send(LiveEvent::BecameLeader { node: id, term });
                }
                Output::RoundCommitted { index, repliers, .. } => {
                    let _ = events.send(LiveEvent::RoundCommitted { node: id, index, repliers });
                }
                Output::Commit(Entry { index, payload, .. }) => {
                    *committed += 1;
                    if let (Payload::Ycsb(batch), Some(a)) = (&payload, applier) {
                        let _ = a.tx.send(ApplierMsg::Batch(Arc::clone(batch)));
                    }
                    let _ = events.send(LiveEvent::Committed { node: id, index, digest: None });
                }
                Output::SnapshotRequest { through } => {
                    // Driver capture: ride the applier queue so the state is
                    // captured exactly after the commits the blob covers —
                    // the consensus thread never waits.
                    if let Some(a) = applier {
                        let _ = a
                            .tx
                            .send(ApplierMsg::Capture { through, reply: my_inbox.clone() });
                    }
                }
                Output::SnapshotInstalled(blob) => {
                    if let (AppState::Slots(s), Some(a)) = (&blob.app, applier) {
                        let _ = a.tx.send(ApplierMsg::Install(s.to_vec()));
                    }
                }
                Output::ReadReady { id: rid, index, lease } => {
                    let _ = events.send(LiveEvent::ReadReady { node: id, id: rid, index, lease });
                }
                Output::ReadFailed { id: rid } => {
                    let _ = events.send(LiveEvent::ReadFailed { node: id, id: rid });
                }
                Output::SteppedDown | Output::ProposalRejected(_) => {}
            }
        }
    };

    loop {
        // next wakeup: the earlier of election / heartbeat deadline
        let now = Instant::now();
        let mut next = election_deadline;
        if let Some(hb) = heartbeat_deadline {
            if hb < next {
                next = hb;
            }
        }
        let wait = next.saturating_duration_since(now);
        node.observe_time(epoch.elapsed().as_secs_f64() * 1000.0);
        match rx.recv_timeout(wait) {
            Ok(LiveIn::Stop) => break,
            Ok(LiveIn::Rpc(from, msg)) => {
                node.observe_time(epoch.elapsed().as_secs_f64() * 1000.0);
                let outs = node.step(Input::Receive(from, msg));
                handle_outputs(
                    outs, &applier, &mut committed,
                    &mut election_deadline, &mut heartbeat_deadline, &mut rng,
                );
            }
            Ok(LiveIn::Propose(payload)) => {
                let outs = node.step(Input::Propose(payload));
                handle_outputs(
                    outs, &applier, &mut committed,
                    &mut election_deadline, &mut heartbeat_deadline, &mut rng,
                );
            }
            Ok(LiveIn::Read(id)) => {
                node.observe_time(epoch.elapsed().as_secs_f64() * 1000.0);
                let outs = node.step(Input::Read { id });
                handle_outputs(
                    outs, &applier, &mut committed,
                    &mut election_deadline, &mut heartbeat_deadline, &mut rng,
                );
            }
            Ok(LiveIn::ForceElection) => {
                let outs = node.step(Input::ElectionTimeout);
                handle_outputs(
                    outs, &applier, &mut committed,
                    &mut election_deadline, &mut heartbeat_deadline, &mut rng,
                );
            }
            Ok(LiveIn::SnapshotReady { through, state }) => {
                node.complete_snapshot(through, AppState::Slots(Arc::new(state)));
            }
            Err(RecvTimeoutError::Timeout) => {
                let now = Instant::now();
                node.observe_time(epoch.elapsed().as_secs_f64() * 1000.0);
                if let Some(hb) = heartbeat_deadline {
                    if now >= hb {
                        heartbeat_deadline = Some(now + timers.heartbeat);
                        let outs = node.step(Input::HeartbeatTimeout);
                        handle_outputs(
                            outs, &applier, &mut committed,
                            &mut election_deadline, &mut heartbeat_deadline, &mut rng,
                        );
                    }
                }
                if now >= election_deadline && node.role() != Role::Leader {
                    election_deadline = now + rand_election(&mut rng);
                    let outs = node.step(Input::ElectionTimeout);
                    handle_outputs(
                        outs, &applier, &mut committed,
                        &mut election_deadline, &mut heartbeat_deadline, &mut rng,
                    );
                } else if now >= election_deadline {
                    // leaders don't run election timers; push it out
                    election_deadline = now + rand_election(&mut rng);
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }

    // drain the applier: close its queue and collect the final digest
    let (applies, final_digest) = match applier {
        Some(Applier { tx, handle }) => {
            drop(tx);
            handle.join().unwrap_or((0, None))
        }
        None => (0, None),
    };
    NodeReport {
        id,
        commit_index: node.commit_index(),
        final_digest,
        committed_entries: committed,
        applies,
        last_compacted: node.log().last_compacted_index(),
        term: node.term(),
        elections_started: node.elections_started(),
    }
}

/// Convenience: map of per-node final digests (for convergence assertions).
pub fn digest_map(reports: &[NodeReport]) -> HashMap<NodeId, Option<[u32; 2]>> {
    reports.iter().map(|r| (r.id, r.final_digest)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Workload, YcsbGen};
    use std::path::PathBuf;

    #[test]
    fn live_cluster_elects_and_commits() {
        let cluster =
            LiveCluster::start(3, Mode::Raft, LiveTimers::default(), None, 7);
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        cluster.propose(leader, Payload::Bytes(Arc::new(vec![1, 2, 3])));
        assert!(cluster.wait_for_round(2, Duration::from_secs(5)).is_some());
        let reports = cluster.shutdown();
        assert!(reports.iter().any(|r| r.commit_index >= 2));
    }

    #[test]
    fn live_pipelined_burst_commits_everything() {
        // The same per-index ack engine drives the live path: a client that
        // never waits between proposals keeps a deep window in flight, and
        // every round must still commit, in order.
        let cluster =
            LiveCluster::start(5, Mode::cabinet(5, 1), LiveTimers::default(), None, 23);
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        for i in 0..8u8 {
            cluster.propose(leader, Payload::Bytes(Arc::new(vec![i])));
        }
        // noop barrier (1) + 8 batches → index 9
        assert!(
            cluster.wait_for_round(9, Duration::from_secs(10)).is_some(),
            "burst of 8 in-flight proposals must all commit"
        );
        std::thread::sleep(Duration::from_millis(300));
        let reports = cluster.shutdown();
        let caught_up = reports.iter().filter(|r| r.commit_index >= 9).count();
        assert!(caught_up >= 3, "quorum must hold the full window: {reports:?}");
    }

    #[test]
    fn live_snapshot_capture_compacts_without_stalling() {
        // Applier-thread capture: snapshots are taken while the cluster
        // keeps committing; the consensus threads never block on capture,
        // so no spurious elections, and replica digests still converge.
        let svc = crate::live::apply::ApplyService::spawn(PathBuf::from("/nonexistent"));
        let cluster = LiveCluster::start_with_snapshots(
            5,
            Mode::cabinet(5, 1),
            LiveTimers::default(),
            Some(svc.submitter()),
            31,
            Some(3),
        );
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        let mut gen = YcsbGen::new(Workload::A, 1000, 9);
        for _ in 0..8 {
            cluster.propose(leader, Payload::Ycsb(Arc::new(gen.batch(150))));
        }
        // noop barrier (1) + 8 batches → index 9
        assert!(cluster.wait_for_round(9, Duration::from_secs(10)).is_some());
        // give followers heartbeats to learn the commit index and the
        // capture round-trips time to drain
        std::thread::sleep(Duration::from_millis(400));
        let reports = cluster.shutdown();
        let compacted = reports.iter().filter(|r| r.last_compacted > 0).count();
        assert!(
            compacted >= 3,
            "a quorum must have captured + compacted: {reports:?}"
        );
        let digests: Vec<_> = reports.iter().filter_map(|r| r.final_digest).collect();
        assert!(digests.len() >= 2, "at least leader+1 follower applied");
        assert!(
            digests.windows(2).all(|w| w[0] == w[1]),
            "replica digests diverge: {digests:?}"
        );
    }

    #[test]
    fn live_partition_failover_and_heal() {
        // Link filtering end-to-end: isolate the leader, the majority elects
        // a replacement (through PreVote), heal, and the old leader rejoins
        // without deposing the new cabinet.
        let cluster = LiveCluster::start_configured(
            5,
            Mode::cabinet(5, 1),
            LiveTimers::default(),
            None,
            77,
            None,
            true, // PreVote on
        );
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        cluster.propose(leader, Payload::Bytes(Arc::new(vec![1])));
        assert!(cluster.wait_for_round(2, Duration::from_secs(5)).is_some());

        cluster.isolate(leader);
        let new_leader =
            cluster.wait_for_leader(Duration::from_secs(10)).expect("no failover election");
        assert_ne!(new_leader, leader, "isolated leader cannot keep leading");

        cluster.heal();
        cluster.propose(new_leader, Payload::Bytes(Arc::new(vec![2])));
        // old barrier (1) + entry (2) + new barrier (3) + entry (4)
        assert!(
            cluster.wait_for_round(4, Duration::from_secs(10)).is_some(),
            "post-heal proposal must commit"
        );
        std::thread::sleep(Duration::from_millis(300));
        let reports = cluster.shutdown();
        let caught_up = reports.iter().filter(|r| r.commit_index >= 4).count();
        assert!(caught_up >= 4, "healed cluster must reconverge: {reports:?}");
        // PreVote kept the disruption bounded: the bootstrap and failover
        // elections happened (possibly with a few vote-split retries), and
        // the isolated old leader ran none at all
        let candidacies: u64 = reports.iter().map(|r| r.elections_started).sum();
        assert!(
            (2..=8).contains(&candidacies),
            "PreVote should bound candidacies, got {candidacies}: {reports:?}"
        );
        // the isolated leader's candidacies all date from bootstrap (1,
        // plus possible vote-split retries); while cut off it stays a
        // silent leader, and after heal it follows — no churn from it
        assert!(
            (1..=3).contains(&reports[leader].elections_started),
            "isolated leader must not campaign beyond bootstrap: {reports:?}"
        );
        let max_term = reports.iter().map(|r| r.term).max().unwrap();
        assert!(max_term >= 2, "failover must have advanced the term");
    }

    #[test]
    fn live_readindex_follower_read() {
        // Client read API end-to-end on the readindex path: a follower
        // forwards over the link table, the leader confirms with a weighted
        // probe quorum, and the follower serves locally at the read index.
        let cluster = LiveCluster::start_full(
            5,
            Mode::cabinet(5, 1),
            LiveTimers::default(),
            None,
            41,
            None,
            false,
            ReadPath::ReadIndex,
            40.0,
        );
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        cluster.propose(leader, Payload::Bytes(Arc::new(vec![7])));
        assert!(cluster.wait_for_round(2, Duration::from_secs(5)).is_some());
        // give followers a heartbeat to learn the leader + commit index;
        // retry with fresh ids if a read races the hint propagation
        std::thread::sleep(Duration::from_millis(150));
        let follower = (leader + 1) % 5;
        let mut served = None;
        for attempt in 0..20u64 {
            cluster.read(follower, 99 + attempt);
            if let Some(r) = cluster.wait_for_read(99 + attempt, Duration::from_secs(2)) {
                served = Some(r);
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        let (index, lease) = served.expect("read never served");
        assert!(index >= 2, "read index must cover the committed write, got {index}");
        assert!(!lease, "readindex path must not claim a lease serve");
        cluster.shutdown();
    }

    #[test]
    fn live_lease_read_at_leader() {
        // Lease path: once the heartbeat-cadence probe quorum grants the
        // lease, leader reads serve without a confirmation round.
        let cluster = LiveCluster::start_full(
            5,
            Mode::cabinet(5, 1),
            LiveTimers::default(),
            None,
            43,
            None,
            true, // lease integrates with PreVote stickiness
            ReadPath::Lease,
            40.0,
        );
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        cluster.propose(leader, Payload::Bytes(Arc::new(vec![1])));
        assert!(cluster.wait_for_round(2, Duration::from_secs(5)).is_some());
        // a couple of heartbeat intervals: renewal probes grant the lease.
        // Retry a few times — an unlucky scheduling gap can catch the lease
        // mid-renewal, in which case the read (correctly) falls back to
        // ReadIndex and we simply try again.
        std::thread::sleep(Duration::from_millis(200));
        let mut lease_served = false;
        for attempt in 0..20u64 {
            cluster.read(leader, 100 + attempt);
            if let Some((index, lease)) =
                cluster.wait_for_read(100 + attempt, Duration::from_secs(2))
            {
                assert!(index >= 2, "read index must cover the committed write");
                if lease {
                    lease_served = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        assert!(lease_served, "no read was served via the lease fast path");
        cluster.shutdown();
    }

    #[test]
    fn live_cabinet_applies_batches_and_converges() {
        let svc = crate::live::apply::ApplyService::spawn(PathBuf::from("/nonexistent"));
        let cluster = LiveCluster::start(
            5,
            Mode::cabinet(5, 1),
            LiveTimers::default(),
            Some(svc.submitter()),
            11,
        );
        cluster.force_election(0);
        let leader = cluster.wait_for_leader(Duration::from_secs(5)).expect("no leader");
        let mut gen = YcsbGen::new(Workload::A, 1000, 5);
        for _ in 0..3 {
            cluster.propose(leader, Payload::Ycsb(Arc::new(gen.batch(200))));
        }
        // noop(1) + 3 batches → index 4
        assert!(cluster.wait_for_round(4, Duration::from_secs(10)).is_some());
        // give followers a couple heartbeats to learn the commit index
        std::thread::sleep(Duration::from_millis(300));
        let reports = cluster.shutdown();
        let digests: Vec<_> = reports
            .iter()
            .filter_map(|r| r.final_digest)
            .collect();
        assert!(digests.len() >= 2, "at least leader+1 follower applied");
        assert!(
            digests.windows(2).all(|w| w[0] == w[1]),
            "replica digests diverge: {digests:?}"
        );
    }
}
