//! Workload generators: YCSB core workloads A–F and TPC-C (§5.1), plus the
//! deterministic shard router the multi-group deployments partition them
//! with ([`shard`]).

pub mod shard;
pub mod tpcc;
pub mod ycsb;

pub use shard::ShardBy;
pub use tpcc::{TpccBatch, TpccGen};
pub use ycsb::{Workload, YcsbBatch, YcsbGen};
