//! Payload erasure coding for data-heavy entries (Crossword-style, see
//! PAPERS.md): entries whose payload clears a size cutover are split into
//! `k` systematic data shards plus one XOR parity shard (m = k + 1), and
//! each follower receives only its deterministically assigned shard inside
//! a shard-bearing AppendEntries variant. Any `k` distinct shards
//! reconstruct the payload, so the leader's weighted commit rule gains one
//! conjunct: a coded round commits only when the acked shard set covers at
//! least `k` distinct shards (the leader keeps the full payload and never
//! occupies a shard slot).
//!
//! The coding is deliberately the simplest scheme that satisfies the
//! k-of-m reconstruction property with the vendored dependency set
//! (std + anyhow): a systematic layout where shards `0..k` are the
//! zero-padded stripes of the original bytes and shard `k` is their XOR.
//! Losing any single shard is recoverable; that matches m − k = 1.

use std::sync::Arc;

use crate::consensus::message::{NodeId, Payload, ShardData};
use crate::net::delay::LAN_BASE_MS;

/// Coding knobs as configured (CLI / TOML / SimConfig). `cutover_bytes =
/// None` selects the adaptive cutover derived from the delay model's
/// bandwidth term via [`adaptive_cutover`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CodingConfig {
    /// Data shards per coded entry; any `k` of the `k + 1` shards
    /// reconstruct. Must satisfy `2 <= k` and `k + 1 <= n - 1` so the
    /// follower set can cover a reconstructing shard set with one follower
    /// down.
    pub k: u32,
    /// Payload-size cutover in bytes (entries at or above it are coded);
    /// `None` = derive adaptively from the observed per-link bandwidth.
    pub cutover_bytes: Option<u64>,
}

impl CodingConfig {
    /// The concrete cutover for a deployment whose links move
    /// `bandwidth_bytes_per_ms` bytes per virtual millisecond.
    pub fn resolve_cutover(&self, bandwidth_bytes_per_ms: f64) -> u64 {
        self.cutover_bytes.unwrap_or_else(|| adaptive_cutover(bandwidth_bytes_per_ms))
    }

    /// Validate against the follower count (`n` total nodes).
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.k < 2 {
            return Err(format!("coding k must be >= 2, got {}", self.k));
        }
        if self.k as usize + 1 > n.saturating_sub(1) {
            return Err(format!(
                "coding k = {} needs m = k + 1 = {} shard slots but only {} followers exist \
                 (need k + 1 <= n - 1)",
                self.k,
                self.k + 1,
                n.saturating_sub(1)
            ));
        }
        Ok(())
    }
}

/// Adaptive cutover: coding pays for its reconstruction bookkeeping once
/// transfer time dominates propagation — take "transfer ≥ 4 × the LAN base
/// latency" as the knee, i.e. cutover = 4 · LAN_BASE_MS · bandwidth. On the
/// paper's 400 MB/s testbed this lands at ≈ 560 KB (only truly large
/// entries code); on a bandwidth-constrained 25 MB/s link it drops to
/// ≈ 35 KB, so 64 KB+ values take the coded path.
pub fn adaptive_cutover(bandwidth_bytes_per_ms: f64) -> u64 {
    (4.0 * LAN_BASE_MS * bandwidth_bytes_per_ms).max(1.0) as u64
}

/// Total shard count m for `k` data shards (one XOR parity).
pub fn shard_count(k: u32) -> u32 {
    k + 1
}

/// Deterministic shard slot for follower `peer`: peers cycle through the m
/// shard ids by node id. Both the leader (when substituting shards into
/// AppendEntries) and the commit rule (when crediting a follower's ack to a
/// shard) derive the slot from this one function, so no shard id ever
/// travels in a reply.
pub fn shard_for_peer(peer: NodeId, m: u32) -> u32 {
    debug_assert!(m >= 1);
    (peer as u32) % m
}

/// Stripe length for a payload of `len` bytes split `k` ways (zero-padded).
pub fn shard_len(len: usize, k: usize) -> usize {
    debug_assert!(k >= 1);
    (len + k - 1) / k
}

/// Split `data` into `k` systematic stripes + 1 XOR parity (m = k + 1
/// shards of `shard_len(data.len(), k)` bytes each, zero-padded).
pub fn encode(data: &[u8], k: usize) -> Vec<Vec<u8>> {
    let sl = shard_len(data.len().max(1), k);
    let mut shards: Vec<Vec<u8>> = Vec::with_capacity(k + 1);
    for s in 0..k {
        let start = (s * sl).min(data.len());
        let end = ((s + 1) * sl).min(data.len());
        let mut stripe = data[start..end].to_vec();
        stripe.resize(sl, 0);
        shards.push(stripe);
    }
    let mut parity = vec![0u8; sl];
    for stripe in &shards {
        for (p, b) in parity.iter_mut().zip(stripe) {
            *p ^= b;
        }
    }
    shards.push(parity);
    shards
}

/// Rebuild the original `total_len` bytes from any `k` of the `k + 1`
/// shards (`shards[s] = None` marks shard `s` as missing). Returns `None`
/// when fewer than `k` shards are present or the shapes are inconsistent.
pub fn reconstruct(shards: &[Option<Vec<u8>>], k: usize, total_len: usize) -> Option<Vec<u8>> {
    if shards.len() != k + 1 {
        return None;
    }
    let present = shards.iter().filter(|s| s.is_some()).count();
    if present < k {
        return None;
    }
    let sl = shard_len(total_len.max(1), k);
    if shards.iter().flatten().any(|s| s.len() != sl) {
        return None;
    }
    // at most one shard is missing; XOR of the other k recovers it
    let missing = shards.iter().position(|s| s.is_none());
    let mut stripes: Vec<Vec<u8>> = Vec::with_capacity(k);
    for (idx, s) in shards.iter().enumerate().take(k) {
        match s {
            Some(b) => stripes.push(b.clone()),
            None => {
                debug_assert_eq!(missing, Some(idx));
                let mut rec = vec![0u8; sl];
                for (j, other) in shards.iter().enumerate() {
                    if j != idx {
                        if let Some(b) = other {
                            for (r, x) in rec.iter_mut().zip(b) {
                                *r ^= x;
                            }
                        }
                    }
                }
                stripes.push(rec);
            }
        }
    }
    let mut data: Vec<u8> = stripes.concat();
    data.truncate(total_len);
    Some(data)
}

/// Modeled payload size in bytes — the quantity the cutover compares and
/// the shard wire model divides. Delegates to the one wire model in
/// `message::payload_wire` so "observed payload size" and "transfer term"
/// always agree.
pub fn payload_wire_bytes(p: &Payload) -> u64 {
    crate::consensus::message::payload_wire(p) as u64
}

/// Does this payload kind take the coded path at all? Only the
/// data-bearing client payloads with a canonical serialization code;
/// control entries (Noop / Reconfig / ConfigChange), TPC-C batches (their
/// wire model is op-count based, never data-heavy), and shards themselves
/// (a restart-inherited shard entry forwards as-is) do not.
pub fn payload_codes(p: &Payload) -> bool {
    matches!(p, Payload::Ycsb(_) | Payload::Bytes(_))
}

/// Canonical byte serialization of the payloads coding supports — the
/// bytes [`encode`] stripes and the safety property reconstructs. `None`
/// for payload kinds that never take the coded path (control entries, and
/// shards themselves). YCSB values are *modeled* at `value_size` bytes on
/// the wire but carried as one u32 seed word, so the canonical form stays
/// small while the wire model pays full freight.
pub fn payload_bytes(p: &Payload) -> Option<Vec<u8>> {
    match p {
        Payload::Ycsb(b) => {
            let mut out = Vec::with_capacity(12 * b.len() + 16);
            out.extend_from_slice(&(b.len() as u64).to_le_bytes());
            out.extend_from_slice(&b.value_size.to_le_bytes());
            for i in 0..b.len() {
                out.extend_from_slice(&b.ops[i].to_le_bytes());
                out.extend_from_slice(&b.keys[i].to_le_bytes());
                out.extend_from_slice(&b.vals[i].to_le_bytes());
            }
            Some(out)
        }
        Payload::Bytes(b) => Some(b.as_ref().clone()),
        _ => None,
    }
}

/// Shard-substituted payloads for one coded entry: `m` [`Payload::Shard`]
/// values over the entry's canonical bytes, ready to slot into the
/// shard-bearing AppendEntries per receiving peer. Returns `None` when the
/// payload kind does not code.
pub fn encode_payload(p: &Payload, k: u32) -> Option<Vec<Payload>> {
    let bytes = payload_bytes(p)?;
    let total_bytes = payload_wire_bytes(p);
    let shards = encode(&bytes, k as usize);
    Some(
        shards
            .into_iter()
            .enumerate()
            .map(|(s, data)| {
                Payload::Shard(Arc::new(ShardData {
                    shard_id: s as u32,
                    k,
                    total_bytes,
                    data: Arc::new(data),
                }))
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Workload, YcsbGen};

    #[test]
    fn roundtrip_all_shards_present() {
        for len in [0usize, 1, 2, 3, 29, 64, 1000, 4097] {
            for k in [2usize, 3, 5] {
                let data: Vec<u8> = (0..len).map(|i| (i * 7 + 3) as u8).collect();
                let shards = encode(&data, k);
                assert_eq!(shards.len(), k + 1);
                let opts: Vec<Option<Vec<u8>>> = shards.iter().cloned().map(Some).collect();
                assert_eq!(reconstruct(&opts, k, len).as_deref(), Some(&data[..]));
            }
        }
    }

    #[test]
    fn any_single_missing_shard_reconstructs() {
        let data: Vec<u8> = (0..1234).map(|i| (i % 251) as u8).collect();
        for k in [2usize, 3, 4] {
            let shards = encode(&data, k);
            for missing in 0..=k {
                let opts: Vec<Option<Vec<u8>>> = shards
                    .iter()
                    .enumerate()
                    .map(|(i, s)| (i != missing).then(|| s.clone()))
                    .collect();
                assert_eq!(
                    reconstruct(&opts, k, data.len()).as_deref(),
                    Some(&data[..]),
                    "k={k} missing={missing}"
                );
            }
        }
    }

    #[test]
    fn fewer_than_k_shards_fail() {
        let data = vec![9u8; 300];
        let k = 3;
        let shards = encode(&data, k);
        // drop two shards: k - 1 present out of the data stripes + parity
        let opts: Vec<Option<Vec<u8>>> = shards
            .iter()
            .enumerate()
            .map(|(i, s)| (i >= 2).then(|| s.clone()))
            .collect();
        assert_eq!(reconstruct(&opts, k, data.len()), None);
        assert_eq!(reconstruct(&[], k, data.len()), None);
    }

    #[test]
    fn shard_assignment_covers_all_slots() {
        // n = 6, leader 0, k = 3 (m = 4): followers 1..=5 must cover >= k
        // distinct shard slots under the deterministic assignment
        let m = shard_count(3);
        let mut seen = [false; 4];
        for peer in 1..6 {
            seen[shard_for_peer(peer, m) as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 3);
    }

    #[test]
    fn adaptive_cutover_tracks_bandwidth() {
        // paper testbed (400 MB/s): only very large payloads code
        assert_eq!(adaptive_cutover(400_000.0), 560_000);
        // constrained link (25 MB/s): 64 KB values clear the cutover
        let c = adaptive_cutover(25_000.0);
        assert_eq!(c, 35_000);
        assert!(64 * 1024 > c);
        assert!(16 * 1024 < c);
    }

    #[test]
    fn config_validation() {
        let cfg = CodingConfig { k: 3, cutover_bytes: None };
        assert!(cfg.validate(5).is_ok());
        assert!(cfg.validate(4).is_err(), "m = 4 > 3 followers");
        assert!(CodingConfig { k: 1, cutover_bytes: None }.validate(9).is_err());
        assert_eq!(cfg.resolve_cutover(25_000.0), 35_000);
        assert_eq!(
            CodingConfig { k: 3, cutover_bytes: Some(1024) }.resolve_cutover(25_000.0),
            1024
        );
    }

    #[test]
    fn ycsb_canonical_bytes_roundtrip_through_shards() {
        let mut gen = YcsbGen::new(Workload::A, 10_000, 42);
        let mut batch = gen.batch(500);
        batch.value_size = 65_536;
        let p = Payload::Ycsb(std::sync::Arc::new(batch));
        let canonical = payload_bytes(&p).expect("ycsb codes");
        let shards = encode_payload(&p, 3).expect("ycsb codes");
        assert_eq!(shards.len(), 4);
        // strip one data shard, reconstruct from the rest
        let mut opts: Vec<Option<Vec<u8>>> = shards
            .iter()
            .map(|s| match s {
                Payload::Shard(sd) => Some(sd.data.as_ref().clone()),
                _ => unreachable!(),
            })
            .collect();
        opts[1] = None;
        assert_eq!(reconstruct(&opts, 3, canonical.len()), Some(canonical));
        // modeled size carries the value-size dimension, canonical does not
        match &shards[0] {
            Payload::Shard(sd) => {
                assert_eq!(sd.total_bytes, payload_wire_bytes(&p));
                assert!(sd.total_bytes > 65_536 * 500);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn control_payloads_never_code() {
        assert!(encode_payload(&Payload::Noop, 3).is_none());
        assert!(encode_payload(&Payload::Reconfig { new_t: 2 }, 3).is_none());
        // a shard never re-codes (restart-inherited shard entries forward as-is)
        let shard = Payload::Shard(Arc::new(ShardData {
            shard_id: 0,
            k: 3,
            total_bytes: 1000,
            data: Arc::new(vec![0u8; 10]),
        }));
        assert!(encode_payload(&shard, 3).is_none());
    }
}
