//! Emulated network-delay models D1–D4 (§5.3, Fig. 13) — the netem stand-in.
//!
//! Each model answers "what extra one-way delay does a message on link
//! (leader ↔ node i) experience at virtual time `now`?". The baseline LAN
//! (d = 0) keeps the paper's testbed profile: raw latency < 1 ms at
//! ≈ 400 MB/s.

use crate::net::rng::Rng;

/// Bandwidth of the emulated testbed NIC (§5: ≈400 MB/s).
pub const BANDWIDTH_BYTES_PER_MS: f64 = 400_000.0;
/// Raw LAN latency mean (paper: < 1 ms).
pub const LAN_BASE_MS: f64 = 0.35;
pub const LAN_JITTER_MS: f64 = 0.10;

/// D4 burst schedule (§5.3): 10 s of no extra delay, then a 5 s spike
/// window (2:1 ratio), spikes of 1000 ± 100 ms.
pub const BURST_QUIET_MS: f64 = 10_000.0;
pub const BURST_ACTIVE_MS: f64 = 5_000.0;
pub const BURST_SPIKE_MS: f64 = 1_000.0;
pub const BURST_SPIKE_JITTER_MS: f64 = 100.0;

/// The §5.3 delay taxonomy.
#[derive(Clone, Debug)]
pub enum DelayModel {
    /// d = 0: base LAN only.
    None,
    /// D1 — uniformly distributed delays across all nodes: `mean ± spread`
    /// (the paper's sets: 100±20, 200±40, 500±100, 1000±200 ms).
    Uniform { mean_ms: f64, spread_ms: f64 },
    /// D2 — skew delays: declining from 1000±200 ms on the first nodes to
    /// 100±20 ms on the last (Fig. 13).
    Skew,
    /// D3 — the D2 ramp rotated across nodes every `period_rounds` rounds
    /// so every zone experiences the full delay range.
    Rotating { period_rounds: u64 },
    /// D4 — bursting delays: intermittent 1000±100 ms spikes on all nodes
    /// (5 s burst / 10 s quiet).
    Bursting,
}

impl DelayModel {
    pub fn name(&self) -> String {
        match self {
            DelayModel::None => "d0".into(),
            DelayModel::Uniform { mean_ms, .. } => format!("D1-{mean_ms:.0}ms"),
            DelayModel::Skew => "D2-skew".into(),
            DelayModel::Rotating { .. } => "D3-rotating".into(),
            DelayModel::Bursting => "D4-bursting".into(),
        }
    }

    /// The paper's four D1 presets.
    pub fn d1_presets() -> [DelayModel; 4] {
        [
            DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 },
            DelayModel::Uniform { mean_ms: 200.0, spread_ms: 40.0 },
            DelayModel::Uniform { mean_ms: 500.0, spread_ms: 100.0 },
            DelayModel::Uniform { mean_ms: 1000.0, spread_ms: 200.0 },
        ]
    }

    /// D2 ramp for node i of n: interpolate mean from 1000 down to 100 ms,
    /// spread = 20% of mean (matching the paper's ±20% at both ends).
    fn skew_mean(node: usize, n: usize) -> f64 {
        if n <= 1 {
            return 100.0;
        }
        let frac = node as f64 / (n - 1) as f64;
        1000.0 - 900.0 * frac
    }

    /// Extra one-way delay (ms) for a message on link (leader ↔ `node`) at
    /// virtual time `now_ms`; `round` indexes replication rounds (D3).
    pub fn sample(
        &self,
        node: usize,
        n: usize,
        now_ms: f64,
        round: u64,
        rng: &mut Rng,
    ) -> f64 {
        match self {
            DelayModel::None => 0.0,
            DelayModel::Uniform { mean_ms, spread_ms } => {
                rng.range_f64(mean_ms - spread_ms, mean_ms + spread_ms).max(0.0)
            }
            DelayModel::Skew => {
                let mean = Self::skew_mean(node, n);
                rng.range_f64(0.8 * mean, 1.2 * mean)
            }
            DelayModel::Rotating { period_rounds } => {
                let shift = ((round / (*period_rounds).max(1)) as usize) % n;
                let pos = (node + shift) % n;
                let mean = Self::skew_mean(pos, n);
                rng.range_f64(0.8 * mean, 1.2 * mean)
            }
            DelayModel::Bursting => {
                let cycle = BURST_QUIET_MS + BURST_ACTIVE_MS;
                let phase = now_ms.rem_euclid(cycle);
                if phase >= BURST_QUIET_MS {
                    rng.range_f64(
                        BURST_SPIKE_MS - BURST_SPIKE_JITTER_MS,
                        BURST_SPIKE_MS + BURST_SPIKE_JITTER_MS,
                    )
                } else {
                    0.0
                }
            }
        }
    }

    /// Full one-way link latency: LAN base + transfer time + model delay.
    pub fn link_latency(
        &self,
        node: usize,
        n: usize,
        now_ms: f64,
        round: u64,
        wire_bytes: usize,
        rng: &mut Rng,
    ) -> f64 {
        self.link_latency_bw(node, n, now_ms, round, wire_bytes, BANDWIDTH_BYTES_PER_MS, rng)
    }

    /// `link_latency` with an explicit per-link bandwidth (bytes/ms). The
    /// transfer term `bytes / bandwidth` is what makes large payloads pay
    /// for full-copy replication and is the input to the coding cutover.
    /// RNG draw order matches `link_latency` exactly, so runs that leave
    /// bandwidth at `BANDWIDTH_BYTES_PER_MS` are bit-identical.
    pub fn link_latency_bw(
        &self,
        node: usize,
        n: usize,
        now_ms: f64,
        round: u64,
        wire_bytes: usize,
        bandwidth_bytes_per_ms: f64,
        rng: &mut Rng,
    ) -> f64 {
        let base = rng.normal_pos(LAN_BASE_MS, LAN_JITTER_MS);
        let transfer = wire_bytes as f64 / bandwidth_bytes_per_ms.max(1.0);
        base + transfer + self.sample(node, n, now_ms, round, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(xs: &[f64]) -> (f64, f64, f64) {
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let min = xs.iter().cloned().fold(f64::MAX, f64::min);
        let max = xs.iter().cloned().fold(f64::MIN, f64::max);
        (mean, min, max)
    }

    #[test]
    fn d0_adds_nothing() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(DelayModel::None.sample(3, 50, 0.0, 0, &mut rng), 0.0);
        }
    }

    #[test]
    fn d1_within_bounds() {
        let m = DelayModel::Uniform { mean_ms: 100.0, spread_ms: 20.0 };
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..5000).map(|i| m.sample(i % 50, 50, 0.0, 0, &mut rng)).collect();
        let (mean, min, max) = stats(&xs);
        assert!(min >= 80.0 && max <= 120.0, "({min},{max})");
        assert!((mean - 100.0).abs() < 2.0);
    }

    #[test]
    fn d2_declines_across_nodes() {
        let mut rng = Rng::new(3);
        let mut mean_of = |node: usize| {
            let xs: Vec<f64> =
                (0..2000).map(|_| DelayModel::Skew.sample(node, 50, 0.0, 0, &mut rng)).collect();
            stats(&xs).0
        };
        let first = mean_of(0);
        let mid = mean_of(25);
        let last = mean_of(49);
        assert!(first > 900.0 && first < 1100.0, "{first}");
        assert!(last > 90.0 && last < 110.0, "{last}");
        assert!(first > mid && mid > last);
    }

    #[test]
    fn d3_rotates_with_rounds() {
        let m = DelayModel::Rotating { period_rounds: 10 };
        let mut rng = Rng::new(4);
        // node 49 starts fast (~100 ms) and later inherits the slow slot
        let early: f64 = (0..500).map(|_| m.sample(49, 50, 0.0, 0, &mut rng)).sum::<f64>() / 500.0;
        let later: f64 =
            (0..500).map(|_| m.sample(49, 50, 0.0, 10, &mut rng)).sum::<f64>() / 500.0;
        assert!(early < 150.0, "{early}");
        assert!(later > early, "{later} vs {early}");
    }

    #[test]
    fn d3_full_rotation_returns() {
        let m = DelayModel::Rotating { period_rounds: 1 };
        let mut rng = Rng::new(5);
        let a: f64 = (0..500).map(|_| m.sample(3, 10, 0.0, 0, &mut rng)).sum::<f64>() / 500.0;
        let b: f64 = (0..500).map(|_| m.sample(3, 10, 0.0, 10, &mut rng)).sum::<f64>() / 500.0;
        assert!((a - b).abs() < 40.0, "{a} vs {b}");
    }

    #[test]
    fn d4_burst_schedule() {
        let m = DelayModel::Bursting;
        let mut rng = Rng::new(6);
        // quiet window
        assert_eq!(m.sample(0, 11, 500.0, 0, &mut rng), 0.0);
        assert_eq!(m.sample(0, 11, 9_999.0, 0, &mut rng), 0.0);
        // burst window
        let x = m.sample(0, 11, 12_000.0, 0, &mut rng);
        assert!((900.0..=1100.0).contains(&x), "{x}");
        // next cycle quiet again
        assert_eq!(m.sample(0, 11, 15_100.0, 0, &mut rng), 0.0);
    }

    #[test]
    fn link_latency_includes_transfer() {
        let mut rng = Rng::new(7);
        // 4 MB at 400 MB/s ⇒ ≈10 ms transfer
        let lat =
            DelayModel::None.link_latency(1, 5, 0.0, 0, 4_000_000, &mut rng);
        assert!(lat > 9.0 && lat < 12.5, "{lat}");
        // small control message ⇒ sub-ms
        let lat2 = DelayModel::None.link_latency(1, 5, 0.0, 0, 48, &mut rng);
        assert!(lat2 < 1.5, "{lat2}");
    }

    #[test]
    fn constrained_bandwidth_stretches_transfer() {
        // 64 KB at 400 MB/s ≈ 0.16 ms; at 25 MB/s ≈ 2.6 ms.
        let mut a = Rng::new(8);
        let mut b = Rng::new(8);
        let fast = DelayModel::None.link_latency_bw(1, 5, 0.0, 0, 65_536, 400_000.0, &mut a);
        let slow = DelayModel::None.link_latency_bw(1, 5, 0.0, 0, 65_536, 25_000.0, &mut b);
        assert!((slow - fast - (65_536.0 / 25_000.0 - 65_536.0 / 400_000.0)).abs() < 1e-9);
        assert!(slow > fast + 2.0, "{slow} vs {fast}");
    }

    #[test]
    fn default_bandwidth_delegation_is_bit_identical() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let x = DelayModel::Bursting.link_latency(2, 7, 12_000.0, 3, 4096, &mut a);
        let y = DelayModel::Bursting.link_latency_bw(
            2,
            7,
            12_000.0,
            3,
            4096,
            BANDWIDTH_BYTES_PER_MS,
            &mut b,
        );
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
