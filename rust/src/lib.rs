//! Cabinet: dynamically weighted consensus made fast.
//!
//! Full-system reproduction of "Cabinet: Dynamically Weighted Consensus Made
//! Fast" (Zhang et al., 2025). Layer-3 Rust coordinator implementing Raft,
//! Cabinet weighted consensus, and an HQC baseline over both a deterministic
//! discrete-event simulator and a live threaded runtime; Layer-2/1 JAX +
//! Pallas state-machine kernels AOT-compiled to HLO and executed via PJRT.
//!
//! The full module map, the sans-io dataflow between [`consensus::Node`] and
//! its drivers, and the figure → bench → module table live in
//! `docs/ARCHITECTURE.md` at the repository root — start there when adding a
//! subsystem.
//!
//! # Architecture in one paragraph
//!
//! [`consensus`] holds pure state machines: inputs are delivered RPCs, fired
//! timers and client proposals; outputs are RPCs to send, timer (re)arms and
//! committed entries. Every output batch is interpreted by the one shared
//! sans-io host ([`consensus::ReplicaHost`] driving the
//! [`consensus::Effects`] trait — persist-before-reply and dropped-event
//! accounting live there, not per driver). Three drivers own the I/O:
//! [`sim`] (deterministic virtual-time event queue — every paper figure in
//! [`bench`] is re-runnable from a seed), [`live`] (one OS thread per node,
//! channel transport, wall-clock timers, PJRT apply service), and the
//! adversarial-schedule harnesses in `rust/tests/`. [`workload`] generates YCSB/TPC-C batches,
//! [`storage`] applies them (with digests that tie replicas — and the
//! [`runtime`] AOT kernels — together bit-for-bit), and [`net`] models
//! delays, zones and faults — including the adversarial nemesis layer
//! (deterministic partitions, loss, duplication, reordering), with PreVote
//! elections hardening [`consensus::Node`] against exactly that traffic.
//!
//! Replication is pipelined (the leader keeps up to `SimConfig::pipeline`
//! rounds in flight, each judged by its propose-time weight/CT snapshot) and
//! the log is compactable: with `snapshot_every` set, every node snapshots
//! its applied state and truncates the committed prefix, lagging or
//! restarted followers catch up via `InstallSnapshot`, and digest chaining
//! keeps replay fingerprints bit-identical across the cut.
//!
//! Deployments shard horizontally (`SimConfig::groups`,
//! `live::LiveCluster::start_sharded`): G independent consensus groups run
//! over one fabric, Multi-Raft style — every node hosts a replica per
//! group, every message travels in a `consensus::message::Envelope` naming
//! its group, and each group replicates only its own workload shard
//! (hash-partitioned YCSB keys / range-partitioned TPC-C warehouses, see
//! `workload::shard`). A `groups = 1` run is bit-for-bit the historical
//! single-group driver.
//!
//! # Driving a node directly
//!
//! ```
//! use cabinet::consensus::{Input, Mode, Node, Output};
//!
//! let mut node = Node::new(0, 3, Mode::cabinet(3, 1));
//! // the election timer fires: the node becomes a candidate and requests votes
//! let outs = node.step(Input::ElectionTimeout);
//! assert!(outs.iter().any(|o| matches!(o, Output::Send(..))));
//! ```
//!
//! # Running a small deterministic simulation
//!
//! ```
//! use cabinet::sim::{run, Protocol, SimConfig, WorkloadSpec};
//! use cabinet::workload::Workload;
//!
//! let mut c = SimConfig::new(Protocol::Cabinet { t: 1 }, 5, true);
//! c.rounds = 3;
//! c.snapshot_every = Some(2); // bounded in-memory log
//! c.workload = WorkloadSpec::Ycsb { workload: Workload::A, batch: 100, records: 1_000 };
//! let r = run(&c);
//! assert_eq!(r.rounds.len(), 3);
//! // same config + seed ⇒ bit-identical replay
//! assert_eq!(r.commit_sequence_digest(), run(&c).commit_sequence_digest());
//! ```

pub mod config;
pub mod consensus;
pub(crate) mod util;
pub mod net;
pub mod sim;
pub mod live;
pub mod storage;
pub mod workload;
pub mod bench;
pub mod runtime;
