"""AOT path: the lowered HLO text is well-formed and matches the manifest
contract the Rust runtime validates at load time."""

import json
import os

from compile import aot, model
from compile.kernels import (
    MAX_NODES,
    STATE_SLOTS,
    TPCC_BATCH,
    YCSB_BATCH,
)


def test_lower_all_produces_hlo_text():
    lowered = model.lower_all()
    assert set(lowered) == {"ycsb_apply", "tpcc_cost", "weight_scheme"}
    for name, low in lowered.items():
        text = aot.to_hlo_text(low)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_ycsb_artifact_signature():
    text = aot.to_hlo_text(model.lower_all()["ycsb_apply"])
    # parameters: state u32[S], ops/keys/vals u32[B]
    assert f"u32[{STATE_SLOTS}]" in text
    assert f"u32[{YCSB_BATCH}]" in text
    # output tuple: (new_state u32[S], digest u32[2])
    assert "u32[2]" in text


def test_tpcc_artifact_signature():
    text = aot.to_hlo_text(model.lower_all()["tpcc_cost"])
    assert f"u32[{TPCC_BATCH}]" in text
    assert f"f32[{TPCC_BATCH}]" in text


def test_weight_scheme_artifact_signature():
    text = aot.to_hlo_text(model.lower_all()["weight_scheme"])
    assert f"f64[{MAX_NODES}]" in text  # padded weight vector
    assert "f64[]" in text  # r and ct scalars


def test_artifacts_on_disk_match_if_built():
    """If `make artifacts` has run, the manifest must match the compiled-in
    constants (this is what the Rust runtime asserts too)."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(adir, "manifest.json")
    if not os.path.exists(mpath):
        return  # artifacts not built yet — covered by the Makefile flow
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["state_slots"] == STATE_SLOTS
    assert manifest["ycsb_batch"] == YCSB_BATCH
    assert manifest["tpcc_batch"] == TPCC_BATCH
    assert manifest["max_nodes"] == MAX_NODES
    for name in manifest["artifacts"]:
        apath = os.path.join(adir, f"{name}.hlo.txt")
        assert os.path.exists(apath), name
        with open(apath) as f:
            assert f.read(9) == "HloModule"
