//! L3 ↔ L2/L1 equivalence: the AOT HLO artifacts executed via PJRT must be
//! bit-identical (u32 paths) / numerically identical (f32/f64 paths) to the
//! native Rust mirrors. Skips cleanly when `make artifacts` hasn't run.

use cabinet::consensus::weights::WeightScheme;
use cabinet::net::rng::Rng;
use cabinet::runtime::{artifacts_available, default_artifact_dir, Engine};
use cabinet::storage::digest::{
    tpcc_costs, DigestState, STATE_SLOTS, TPCC_BATCH, TPCC_WAREHOUSES, YCSB_BATCH,
};
use cabinet::workload::{TpccGen, Workload, YcsbGen};

fn engine() -> Option<Engine> {
    let dir = default_artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(&dir).expect("engine load"))
}

#[test]
fn ycsb_apply_bit_exact_random_batches() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(1);
    for seed in 0..5u64 {
        // random pre-state + random workload batch
        let state: Vec<u32> = (0..STATE_SLOTS).map(|_| rng.next_u32()).collect();
        let wl = [Workload::A, Workload::B, Workload::E, Workload::F][seed as usize % 4];
        let batch = YcsbGen::new(wl, 50_000, seed).batch(4000 + seed as usize * 200);
        let padded = batch.padded_to(YCSB_BATCH);

        let (hlo_state, hlo_digest) = engine
            .ycsb_apply(&state, &padded.ops, &padded.keys, &padded.vals)
            .expect("hlo exec");
        let mut native = DigestState::from_state(state.clone());
        let native_digest = native.apply_ycsb(&padded.ops, &padded.keys, &padded.vals);
        assert_eq!(hlo_digest, native_digest, "seed {seed}: digest mismatch");
        assert_eq!(hlo_state, native.slots(), "seed {seed}: state mismatch");
    }
}

#[test]
fn ycsb_apply_chained_rounds_stay_identical() {
    let Some(engine) = engine() else { return };
    let mut gen = YcsbGen::new(Workload::A, 100_000, 42);
    let mut hlo_state = vec![0u32; STATE_SLOTS];
    let mut native = DigestState::default();
    for round in 0..4 {
        let padded = gen.batch(5000).padded_to(YCSB_BATCH);
        let (ns, hd) = engine
            .ycsb_apply(&hlo_state, &padded.ops, &padded.keys, &padded.vals)
            .expect("exec");
        hlo_state = ns;
        let nd = native.apply_ycsb(&padded.ops, &padded.keys, &padded.vals);
        assert_eq!(hd, nd, "round {round} digests diverged");
        assert_eq!(hlo_state, native.slots(), "round {round} state diverged");
    }
}

#[test]
fn tpcc_cost_matches_native() {
    let Some(engine) = engine() else { return };
    for seed in 0..4u64 {
        let batch =
            TpccGen::new(TPCC_WAREHOUSES as u32, seed).batch(1500).padded_to(TPCC_BATCH);
        let (counts, costs, dig) =
            engine.tpcc_cost(&batch.types, &batch.wids, &batch.args).expect("exec");
        let (ncounts, ncosts, ndig) =
            tpcc_costs(&batch.types, &batch.wids, &batch.args, TPCC_WAREHOUSES);
        assert_eq!(dig, ndig, "seed {seed}: stream digest mismatch");
        assert_eq!(counts, ncounts, "seed {seed}: lock counts mismatch");
        for (i, (a, b)) in costs.iter().zip(&ncosts).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * b.abs().max(1.0),
                "seed {seed} txn {i}: cost {a} vs {b}"
            );
        }
    }
}

#[test]
fn weight_scheme_solver_cross_layer() {
    let Some(engine) = engine() else { return };
    for n in [3usize, 7, 10, 11, 20, 50, 100, 128] {
        for t in [1, (n - 1) / 4, (n - 1) / 2] {
            let t = t.max(1);
            let (r_hlo, w_hlo, ct_hlo) =
                engine.weight_scheme(n as i32, t as i32).expect("exec");
            let ws = WeightScheme::geometric(n, t).expect("native scheme");
            assert!(
                (r_hlo - ws.ratio()).abs() < 1e-6,
                "n={n} t={t}: r {r_hlo} vs {}",
                ws.ratio()
            );
            assert!(
                (ct_hlo - ws.ct()).abs() / ws.ct() < 1e-9,
                "n={n} t={t}: ct {ct_hlo} vs {}",
                ws.ct()
            );
            for (k, (a, b)) in w_hlo.iter().zip(ws.weights()).enumerate() {
                assert!(
                    (a - b).abs() / b < 1e-9,
                    "n={n} t={t} w[{k}]: {a} vs {b}"
                );
            }
            // padding beyond n must be zero
            assert!(w_hlo[n..].iter().all(|&w| w == 0.0));
        }
    }
}

#[test]
fn manifest_matches_compiled_constants() {
    let Some(engine) = engine() else { return };
    assert_eq!(engine.manifest.state_slots, STATE_SLOTS);
    assert_eq!(engine.manifest.ycsb_batch, YCSB_BATCH);
    assert_eq!(engine.manifest.tpcc_batch, TPCC_BATCH);
}
