//! Micro-benchmarks for the L3 hot paths (used by the §Perf pass in
//! EXPERIMENTS.md): quorum accumulation, FIFO weight re-deal, log append,
//! batch generation, native apply, and a full simulated round.

use std::sync::Arc;

use cabinet::bench::{quick_requested, BenchReport, Bencher};
use cabinet::consensus::message::{Message, Payload};
use cabinet::consensus::node::{Input, Mode, Node, Role};
use cabinet::sim::{run, Protocol, SimConfig};
use cabinet::storage::digest::DigestState;
use cabinet::storage::DocStore;
use cabinet::workload::{Workload, YcsbGen};

/// Build an n-node Cabinet leader with all votes collected.
fn make_leader(n: usize, t: usize) -> Node {
    let mut leader = Node::new(0, n, Mode::cabinet(n, t));
    let _ = leader.step(Input::ElectionTimeout);
    for p in 1..n {
        let _ = leader.step(Input::Receive(
            p,
            Message::RequestVoteReply { term: 1, from: p, granted: true },
        ));
        if leader.role() == Role::Leader {
            break;
        }
    }
    assert_eq!(leader.role(), Role::Leader);
    leader
}

fn main() {
    let quick = quick_requested();
    let b = Bencher::from_env();
    let mut report = BenchReport::new(
        "micro_hotpath",
        "leader_round n=[11,50,100]; ycsb_gen/native_apply/docstore_apply 5k; sim_run n50 r12; wire_size 5k",
        quick,
    );

    // 1. replication round at the leader: propose + n-1 replies + commit
    for (n, t) in [(11usize, 1usize), (50, 5), (100, 10)] {
        let leader0 = make_leader(n, t);
        b.iter_rec(&mut report, &format!("leader_round/n{n}_t{t}"), || {
            let mut leader = leader0.clone();
            let _ = leader.step(Input::Propose(Payload::Noop));
            let wc = leader.wclock();
            let last = leader.log().last_index();
            for p in 1..n {
                let _ = leader.step(Input::Receive(
                    p,
                    Message::AppendEntriesReply {
                        term: 1,
                        from: p,
                        success: true,
                        match_index: last,
                        wclock: wc,
                    },
                ));
            }
            leader.commit_index()
        });
    }

    // 2. YCSB batch generation (5k ops, workload A)
    let mut gen = YcsbGen::new(Workload::A, 100_000, 1);
    b.iter_rec(&mut report, "ycsb_gen/5k", || gen.batch(5000));

    // 3. native digest apply (the simulator's state-machine path)
    let batch = YcsbGen::new(Workload::A, 100_000, 2).batch(5000).padded_to(5120);
    b.iter_rec(&mut report, "native_apply/5120", || {
        let mut st = DigestState::default();
        st.apply_ycsb(&batch.ops, &batch.keys, &batch.vals)
    });

    // 4. document-store apply (real CRUD + digest)
    b.iter_rec(&mut report, "docstore_apply/5k", || {
        let mut store = DocStore::new();
        store.apply(&batch)
    });

    // 5. full simulated experiment (12 rounds, n=50 het)
    b.iter_rec(&mut report, "sim_run/n50_cab_f10_12rounds", || {
        let mut c = SimConfig::new(Protocol::Cabinet { t: 5 }, 50, true);
        c.rounds = 12;
        run(&c).tput_ops_s
    });

    // 6. wire-size accounting on a large AppendEntries
    let entries_batch = Arc::new(YcsbGen::new(Workload::A, 100_000, 3).batch(5000));
    b.iter_rec(&mut report, "wire_size/5k", || {
        Message::AppendEntries {
            term: 1,
            leader: 0,
            prev_log_index: 0,
            prev_log_term: 0,
            entries: vec![cabinet::consensus::message::Entry {
                term: 1,
                index: 1,
                payload: Payload::Ycsb(Arc::clone(&entries_batch)),
                wclock: 1,
            }],
            leader_commit: 0,
            wclock: 1,
            weight: 1.0,
        }
        .wire_size()
    });

    match report.write_to_repo_root() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write bench report: {e}");
            std::process::exit(1);
        }
    }
}
