//! Live runtime: OS-thread nodes + channel transport + wall-clock timers +
//! the PJRT apply service. (The environment's vendored crate set has no
//! async runtime, so this is std-threads rather than tokio — the
//! architecture is identical: an event loop per node, a dedicated
//! apply-service thread owning the PJRT engine.)
//!
//! Thread layout per node: the *consensus thread* runs the sans-io
//! `consensus::Node` event loop (RPCs in, RPCs out, timer deadlines), and
//! an optional *applier thread* owns the replica state, folding committed
//! batches in commit order through the shared apply service. Anything slow
//! — batch apply, and snapshot capture when `snapshot_every` is enabled via
//! [`LiveCluster::start_with_snapshots`] — happens on the applier thread,
//! because a stalled consensus thread misses heartbeats and triggers
//! spurious elections. Snapshot capture rides the applier's own queue (so
//! it sees exactly the committed prefix it covers) and answers back over
//! the node's inbox; see `docs/ARCHITECTURE.md` §"Snapshotting".
//!
//! Sharded clusters ([`LiveCluster::start_sharded`]) multiplex G consensus
//! groups over the same n threads and the one link table: every consensus
//! thread hosts one `consensus::Node` per group (with per-group timers and
//! its own applier), and every RPC crosses the channel inside a
//! [`crate::consensus::message::Envelope`] naming its group — so a cut
//! physical link partitions every group at once, like a real switch
//! failure. Reports come back per (group, node): [`NodeReport::group`].

pub mod apply;
pub mod cluster;

pub use apply::{ApplyService, Backend};
pub use cluster::{
    digest_map, LiveCluster, LiveEvent, LiveMembership, LiveStorage, LiveTimers, NodeReport,
};
